"""Control-plane benchmark: the operational safety story, measured.

Three claims of the adapter control plane (DESIGN.md §13), each a CI gate
in ``BENCH_control.json``:

- **The gate fires on a poisoned corpus**: a tenant whose cache partition
  is recycled (``release``) and refilled with constant-label garbage
  regresses on its clean held-out rows when it re-adapts, and the
  regression gate refuses the write-back — the served slot keeps the
  pre-poison version, so serve quality is monotone non-regressing on
  held-out data *by mechanism*, not by luck.
- **Rollback restores the pre-poison version bitwise**: with the gate
  disabled (``threshold=inf``) the poisoned write-back lands; one
  ``rollback(tenant)`` restores the archived payload bit-for-bit (pool
  storage layout, quantised or not), brings back its recorded eval loss,
  and the tenant's served tokens return to exactly the pre-poison stream.
- **Shadow eval is near-free**: pre/post held-out loss rides the SAME
  fused scan dispatch as the cached training epoch (two extra cache
  gathers + grouped skip-sums, zero backbone forwards), so a gated adapt
  must stay within 10% wall-clock of an ungated one
  (``shadow_eval_overhead_x`` < 1.10).

The shadow split measures against the tenant's held-out rows, so the
poison deliberately leaves those rows' labels clean: garbage that also
lands in the held-out set corrupts the measurement itself, and the gate
cannot (and should not be expected to) see the regression. The gate's
guarantee is conditional on the held-out rows being representative; this
bench exercises exactly that contract.

Oracle (jnp) kernel path on CPU like the other benches. Run:

  PYTHONPATH=src python -m benchmarks.control_bench [--quick]
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.control_plane import ControlConfig
from repro.core.runtime import SessionRuntime
from repro.models.lm import init_lm


def _session(cfg, sl, params, *, n_t, spt, seq, control):
    return SessionRuntime(
        cfg, sl, params, max_tenants=n_t, samples_per_tenant=spt, seq=seq,
        lr=5e-2, control=control,
    )


def _clean_batch(cfg, t, rows, seq, seed=11):
    k1, k2 = jax.random.split(jax.random.fold_in(jax.random.key(seed), t))
    toks = jax.random.randint(k1, (rows, seq), 0, cfg.vocab_size)
    labs = jax.random.randint(k2, (rows, seq), 0, cfg.vocab_size)
    return toks, labs


def _poison_batch(cfg, params, rows, seq, *, holdout_every):
    """Garbage labels on the partition's TRAIN rows; the rows the shadow
    split holds out (``(r+1) % holdout_every == 0``) keep the BASE model's
    own argmax as labels — the distribution the tenant was serving well.
    All rows share one context, so training on the garbage tears down
    exactly the calibration the held-out rows measure: the regression is
    large and monotone. (Random held-out labels would be confounded by the
    entropy-raising side effect of any training — a more uniform predictive
    distribution *lowers* expected loss on random targets.)"""
    from repro.models.lm import lm_forward, readout

    rng = np.random.default_rng(23)
    row = rng.integers(0, cfg.vocab_size, (1, seq)).astype(np.int32)
    logits = readout(params, cfg, lm_forward(params, cfg, jnp.asarray(row))["h"])
    base_best = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
    garbage = rng.integers(0, cfg.vocab_size, (1, seq)).astype(np.int32)
    toks = np.repeat(row, rows, 0)
    labs = np.repeat(garbage, rows, 0)
    held = (np.arange(rows) + 1) % holdout_every == 0
    labs[held] = base_best
    return toks, labs


def _slot_payload(rt, tenant):
    shard = rt.pool.shards[rt.pool.shard_of(tenant)]
    return {n: np.asarray(v) for n, v in shard.slot_payload(tenant).items()}


def _poison_victim(cfg, params, rt, victim, spt, seq, holdout_every):
    """The recycle-then-garbage scenario: the victim's cache partition is
    released (its pool slot stays registered and serving) and refilled
    with a poisoned corpus, so the next adapt trains from fresh state on
    ~pure garbage — and its write-back is still a RE-registration, which
    is what the gate guards."""
    rt.release(victim)
    rt.ingest(victim, *_poison_batch(
        cfg, params, spt, seq, holdout_every=holdout_every
    ))


def control_bench(quick: bool = False):
    """Returns (csv rows, BENCH_control.json payload with "_gates")."""
    cfg = reduce_config(get_config("stablelm-1.6b"))
    sl = SL.SkipLoRAConfig(rank=4, mode="full", cache_dtype="float32")
    params = init_lm(jax.random.key(0), cfg)

    n_t = 2 if quick else 4
    spt = 16
    seq = 8 if quick else 16
    epochs = 2 if quick else 4
    poison_epochs = 3 * epochs   # long enough to regress decisively
    bpt = 4
    ctl = ControlConfig(
        holdout_every=4, threshold=0.0, mode="reject", history_depth=2
    )
    names = list(range(n_t))
    victim = 0

    rows: list[tuple[str, float]] = []
    gates: dict[str, bool] = {}

    # ---- leg 1: the gate fires on a poisoned corpus ------------------------
    rt = _session(cfg, sl, params, n_t=n_t, spt=spt, seq=seq, control=ctl)
    for t in names:
        rt.ingest(t, *_clean_batch(cfg, t, spt, seq))
    rt.adapt(names, epochs=epochs, batch_per_tenant=bpt,
             key=jax.random.key(3))
    clean = {t: rec for t, rec in rt.control_metrics()["tenants"]}
    served_clean = _slot_payload(rt, victim)
    _poison_victim(cfg, params, rt, victim, spt, seq, ctl.holdout_every)
    rt.adapt([victim], epochs=poison_epochs, batch_per_tenant=bpt,
             key=jax.random.key(5))
    cm = rt.control_metrics()
    victim_rec = {t: rec for t, rec in cm["tenants"]}[victim]
    served_after = _slot_payload(rt, victim)
    slot_kept_old = all(
        np.array_equal(served_clean[n], served_after[n]) for n in served_clean
    )
    # The served slot's recorded held-out loss never regressed past the
    # threshold: a reject leaves the clean version's record in place.
    served_eval = rt.pool.version_info(victim)["eval_loss"]
    gates["gate_fires_on_poison"] = (
        victim_rec["decision"] == "reject"
        and victim_rec["delta"] > ctl.threshold
        and slot_kept_old
        and served_eval is not None
        and served_eval <= clean[victim]["post"] + ctl.threshold
    )
    rows += [
        ("control/poison_pre_loss", float(victim_rec["pre"])),
        ("control/poison_post_loss", float(victim_rec["post"])),
        ("control/poison_delta", float(victim_rec["delta"])),
        ("control/gate_rejected", float(cm["rejected"])),
    ]

    # ---- leg 2: rollback restores the pre-poison version bitwise -----------
    open_ctl = ControlConfig(
        holdout_every=4, threshold=float("inf"), mode="reject",
        history_depth=2,
    )
    rt2 = _session(cfg, sl, params, n_t=n_t, spt=spt, seq=seq,
                   control=open_ctl)
    prompts = jax.random.randint(
        jax.random.key(7), (1, 6), 0, cfg.vocab_size
    )
    for t in names:
        rt2.ingest(t, *_clean_batch(cfg, t, spt, seq))
    rt2.adapt(names, epochs=epochs, batch_per_tenant=bpt,
              key=jax.random.key(3))
    pre_poison = _slot_payload(rt2, victim)
    pre_poison_eval = rt2.pool.version_info(victim)["eval_loss"]
    toks_clean = np.asarray(rt2.serve([victim], prompts, max_new=8))
    _poison_victim(cfg, params, rt2, victim, spt, seq, open_ctl.holdout_every)
    rt2.adapt([victim], epochs=poison_epochs, batch_per_tenant=bpt,
              key=jax.random.key(5))
    toks_poisoned = np.asarray(rt2.serve([victim], prompts, max_new=8))
    restored = rt2.rollback(victim)
    post_roll = _slot_payload(rt2, victim)
    toks_rolled = np.asarray(rt2.serve([victim], prompts, max_new=8))
    gates["rollback_bitwise"] = all(
        np.array_equal(pre_poison[n], post_roll[n]) for n in pre_poison
    )
    gates["rollback_restores_eval"] = (
        restored["eval_loss"] == pre_poison_eval
        and rt2.pool.version_info(victim)["eval_loss"] == pre_poison_eval
    )
    gates["rollback_restores_serve"] = np.array_equal(
        toks_clean, toks_rolled
    )
    rows += [
        ("control/rollback_eval_loss", float(restored["eval_loss"])),
        ("control/poison_serve_diverged",
         float(not np.array_equal(toks_clean, toks_poisoned))),
    ]

    # ---- leg 3: shadow eval adds < 10% wall-clock to adapt -----------------
    # Measures the EVAL machinery (two fused-in cache gathers + grouped
    # skip-sums, one host sync for the gate decision), so the gate is held
    # open (threshold=inf): a firing gate would split the accepted/rejected
    # tenants into different trajectory groups and retrace mid-timing.
    # Warm-up runs the same epoch count as the timed calls so every
    # (eval_pre, eval_post) jit entry compiles before the clock starts.
    epochs_timed = 16 if quick else 8  # quick's tiny steps need more epochs
                                       # to amortise the per-adapt host sync

    def timed_adapt(control):
        rt3 = _session(cfg, sl, params, n_t=n_t, spt=spt, seq=seq,
                       control=control)
        for t in names:
            rt3.ingest(t, *_clean_batch(cfg, t, spt, seq))
        rt3.adapt(names, epochs=epochs_timed, batch_per_tenant=bpt,
                  key=jax.random.key(3))     # warm-up: compiles the entries
        best = float("inf")
        for _ in range(7 if quick else 5):  # quick's ~20ms adapts are noisy:
                                            # more best-of trials, still cheap
            t0 = time.perf_counter()
            rt3.adapt(names, epochs=epochs_timed, batch_per_tenant=bpt)
            best = min(best, time.perf_counter() - t0)
        return best

    t_plain = timed_adapt(None)
    t_gated = timed_adapt(open_ctl)
    overhead = t_gated / t_plain
    gates["shadow_eval_overhead_lt_10pct"] = overhead < 1.10
    rows += [
        ("control/adapt_plain_s", t_plain),
        ("control/adapt_gated_s", t_gated),
        ("control/shadow_eval_overhead_x", overhead),
    ]

    payload = {key: val for key, val in rows}
    payload["_gates"] = {k: bool(v) for k, v in gates.items()}
    return rows, payload


def main(argv=None) -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_control.json")
    args = ap.parse_args(argv)
    rows, payload = control_bench(quick=args.quick)
    print("name,value")
    for name, val in rows:
        print(f"{name},{val:.6f}")
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.json}")
    broken = [k for k, ok in payload["_gates"].items() if not ok]
    if broken:
        raise SystemExit(f"control gates broken: {broken}")
    print(f"gates OK: {sorted(payload['_gates'])}")


if __name__ == "__main__":
    main()
