"""Fleet fine-tuning benchmark: tenants/sec, sequential vs grouped.

The orchestration claim of DESIGN.md §8, measured: fine-tuning N tenants by
looping single-tenant ``finetune()``-style runs costs N populate dispatches
(whose backbone forwards run at per-tenant batch size) plus N cached-epoch
scans per epoch, each with its own cache allocation and per-call pytree
dispatch; the fleet trainer runs ONE populate and ONE cached scan per epoch
whose fleet batches restore arithmetic density. The measured workload is
the *whole* fine-tune — populate epoch + cached epochs — at the paper's
operating point: each tenant owns a tiny on-device fine-tune set (the
Skip2-LoRA premise), which is exactly the regime where per-run overhead
dominates and sequential serving of a fleet falls behind.

Both sides run the XLA-compiled jnp paths (single-stack einsum vs the
blocked fleet einsum) — interpret-mode Pallas timing on CPU is
correctness-grade only (see ``lm_bench.kernel_vs_einsum``); the kernel's
HBM-traffic win is a TPU story argued in DESIGN.md §6.

Reported per tenant count: full-fine-tune wall time per strategy,
``tenants_per_s`` (tenants fully fine-tuned per second), and the speedup.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import fleet_finetune as FF
from repro.core import lm_skiplora as SL
from repro.models.lm import init_lm
from repro.optim.optimizers import adamw


def _time(fn, repeats: int) -> float:
    jax.block_until_ready(fn())  # compile / warm — and finish before timing
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def fleet_vs_sequential(
    arch: str = "stablelm-1.6b",
    tenant_counts: tuple[int, ...] = (1, 4, 8),
    *,
    quick: bool = False,
) -> list[tuple[str, float]]:
    cfg = reduce_config(get_config(arch))
    sl = SL.SkipLoRAConfig(rank=8, mode="full", cache_dtype="float32")
    # Tiny per-tenant fine-tune sets over several epochs: the paper's
    # on-device regime, where a fleet's worth of sequential runs is
    # overhead-bound and grouping actually pays.
    n_per, seq, bpt, epochs = 8, 8, 2, 4
    repeats = 1 if quick else 3
    if quick:
        tenant_counts = tuple(t for t in tenant_counts if t <= 4)
    params = init_lm(jax.random.key(0), cfg)
    opt = adamw(1e-3)
    rows = []

    for n_t in tenant_counts:
        tokens = jax.random.randint(
            jax.random.key(1), (n_t, n_per, seq), 0, cfg.vocab_size
        )
        stacked = FF.init_fleet_adapters(jax.random.key(3), cfg, sl, n_t)
        row_tenant = FF.fleet_row_tenant(n_t, bpt)
        idx = [
            jnp.asarray(FF.fleet_index_matrix(e, n_t, n_per, bpt))
            for e in range(epochs)
        ]

        # Fleet: one populate + one cached scan per epoch for ALL tenants.
        pop_n = FF.make_fleet_populate_epoch(
            cfg, sl, opt, n_t, use_kernel=False, donate=False
        )
        cch_n = FF.make_fleet_cached_epoch(
            cfg, sl, opt, n_t, use_kernel=False, donate=False
        )

        def fleet():
            cache = SL.init_lm_cache(n_t * n_per, cfg, sl, seq)
            st, os_ = stacked, opt.init(stacked)
            st, os_, cache, ls = pop_n(
                params, st, os_, cache,
                tokens.reshape(-1, seq), tokens.reshape(-1, seq),
                idx[0], row_tenant,
            )
            for e in range(1, epochs):
                st, os_, ls = cch_n(params, st, os_, cache, idx[e], row_tenant)
            return ls

        # Sequential: the whole single-tenant Algorithm-1 run, N times.
        pop_1 = SL.make_populate_epoch(cfg, sl, opt, donate=False)
        cch_1 = SL.make_cached_epoch(cfg, sl, opt, donate=False)

        def sequential():
            ls = None
            for t in range(n_t):
                cache = SL.init_lm_cache(n_per, cfg, sl, seq)
                tr, static = SL.split_trainable(FF.tenant_adapters(stacked, t), sl)
                os_ = opt.init(tr)
                im = [i[:, t * bpt:(t + 1) * bpt] - t * n_per for i in idx]
                tr, os_, cache, ls = pop_1(
                    params, tr, static, os_, cache, tokens[t], tokens[t], im[0]
                )
                for e in range(1, epochs):
                    tr, os_, ls = cch_1(params, tr, static, os_, cache, im[e])
            return ls

        t_seq = _time(sequential, repeats)
        t_fleet = _time(fleet, repeats)

        rows += [
            (f"fleet/{arch}/t{n_t}/sequential_finetune_ms", t_seq * 1e3),
            (f"fleet/{arch}/t{n_t}/fleet_finetune_ms", t_fleet * 1e3),
            (f"fleet/{arch}/t{n_t}/sequential_tenants_per_s", n_t / t_seq),
            (f"fleet/{arch}/t{n_t}/fleet_tenants_per_s", n_t / t_fleet),
            (f"fleet/{arch}/t{n_t}/speedup_x", t_seq / t_fleet),
        ]
    return rows
