"""Kernel speed benchmarks: autotuned tiles, fused decode, q4 pools.

The three claims of the kernel speed pass, measured and gated:

  - **Tile sweep** (``kernels.autotune``): the tuned ``(tm, grid_order)``
    beats the hand-picked ``TM=128`` rows-outer default on at least one
    config. At decode shape (m = batch) the padded row count
    ``ceil(m/tm)*tm + groups*tm`` dominates, so small tiles win — the
    sweep proves it with real timings and records the roofline prediction
    next to each winner.
  - **Fused decode**: ``decode_fuse=True`` routes the grouped skip-sum
    through the dense per-row gather (one XLA program with the backbone,
    no separate sort/pad/scatter dispatch). Measured as sustained tok/s
    through ``RequestScheduler`` in continuous mode, with the PR 6 parity
    bar enforced: every temperature-0 request yields identical tokens in
    fused and split runs.
  - **q4 pools**: packed int4/nf4 ``AdapterPool`` payload is exactly half
    the int8 payload; eval loss (last-position CE through the serve path)
    is reported per compression so the accuracy cost is visible next to
    the bytes saved.

  PYTHONPATH=src python -m benchmarks.kernel_bench           # full
  PYTHONPATH=src python -m benchmarks.kernel_bench --quick   # CI smoke

Writes ``BENCH_kernels.json`` (``--json``); exits non-zero if a gate
breaks (temp-0 parity, tuned > default everywhere, payload not halved).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune as AT
from repro.kernels.skip_lora import kernel as K

Rows = "list[tuple[str, float]]"


# ---------------------------------------------------------------------------
# Section 1: tile sweep (tuned vs hand-picked default)
# ---------------------------------------------------------------------------


def _sweep_inputs(m: int, *, d: int = 64, r: int = 8, lnum: int = 4, n: int = 4):
    key = jax.random.PRNGKey(0)
    kx, ka, kb = jax.random.split(key, 3)
    x = jax.random.normal(kx, (lnum, m, d), jnp.float32)
    a_pool = jax.random.normal(ka, (n, lnum, d, r), jnp.float32) * 0.1
    b_pool = jax.random.normal(kb, (n, lnum, r, d), jnp.float32) * 0.1
    idx = jnp.arange(m, dtype=jnp.int32) % n
    return x, a_pool, b_pool, idx


def tile_sweep(quick: bool = False) -> Rows:
    """Tune every kernel variant at decode shape (m=8) and prefill shape
    (m=512; 128 in quick mode). Winner <= default by construction (the
    default is in the candidate set); the gate in main() wants a strict
    win somewhere."""
    shapes = [("decode_m8", 8), ("prefill_m128" if quick else "prefill_m512",
                                 128 if quick else 512)]
    variants = ["grouped"] if quick else [
        "grouped", "grouped_int8", "grouped_int4", "grouped_nf4"]
    timer = AT.median_timer(iters=2, warmup=1) if quick else None
    rows: list[tuple[str, float]] = []
    for shape_name, m in shapes:
        x, a_pool, b_pool, idx = _sweep_inputs(m)
        for variant in variants:
            ch = AT.tune_grouped(
                x, a_pool, b_pool, idx, variant,
                config=f"bench-{shape_name}", timer=timer,
                tiles=(8, 16, 32, K.TM) if quick else None,
            )
            base = f"kernel/tune/{shape_name}/{variant}"
            rows += [
                (f"{base}/tuned_ms", ch.time_s * 1e3),
                (f"{base}/default_ms", ch.default_time_s * 1e3),
                (f"{base}/speedup_x", ch.default_time_s / max(ch.time_s, 1e-12)),
                (f"{base}/tm", float(ch.tm)),
                (f"{base}/grid_order_is_lm", float(ch.grid_order == "lm")),
                (f"{base}/predicted_ms", ch.predicted_s * 1e3),
            ]
    # Decode-scan unroll at decode shape, using the grouped winner's tile.
    x, a_pool, b_pool, idx = _sweep_inputs(8)
    ch = AT.tune_grouped(x, a_pool, b_pool, idx, config="bench-decode_m8",
                         timer=timer, tiles=(8, 16, K.TM) if quick else None)
    u, t = AT.tune_decode_unroll(
        x, a_pool, b_pool, idx, tm=ch.tm, grid_order=ch.grid_order,
        steps=4 if quick else 16, timer=timer,
    )
    rows += [
        ("kernel/tune/decode_m8/unroll", float(u)),
        ("kernel/tune/decode_m8/unroll_scan_ms", t * 1e3),
    ]
    return rows


# ---------------------------------------------------------------------------
# Section 2: fused vs split decode through the scheduler
# ---------------------------------------------------------------------------


def _make_runtime(n_tenants: int, *, rank: int = 4, decode_fuse: bool = False):
    from repro.configs import get_config, reduce_config
    from repro.core import lm_skiplora as SL
    from repro.core.runtime import SessionRuntime
    from repro.models.lm import init_lm

    cfg = reduce_config(get_config("stablelm-1.6b"))
    params = init_lm(jax.random.key(0), cfg)
    sl = SL.SkipLoRAConfig(rank=rank)
    rt = SessionRuntime(
        cfg, sl, params, max_tenants=n_tenants, samples_per_tenant=1, seq=8,
        decode_fuse=decode_fuse,
    )
    for t in range(n_tenants):
        ad = SL.init_adapters(jax.random.key(100 + t), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(200 + t), ad["B"].shape) * 0.02
        rt.pool.register(f"tenant-{t}", ad)
    return rt


def _drain(rt, reqs_spec, *, max_batch: int, prompt_len: int, max_new: int):
    """Submit every request up front (saturated batch — the fusion win is
    per decode dispatch, arrival jitter only adds noise), pump to empty.
    Returns (makespan_s, [token lists])."""
    from repro.core.scheduler import RequestScheduler

    sched = RequestScheduler(
        rt, max_batch=max_batch, max_prompt=prompt_len, max_new_cap=max_new,
        admit_bucket=min(2, max_batch), inflight_per_tenant=len(reqs_spec),
        chunk=4, mode="continuous",
    )
    reqs = [sched.submit(tenant, prompt, max_new=max_new, temperature=0.0)
            for tenant, prompt in reqs_spec]
    t0 = time.perf_counter()
    while len(sched._completed) < len(reqs):
        sched.step()
    makespan = time.perf_counter() - t0
    return makespan, [r.result().tolist() for r in reqs]


def fused_decode(quick: bool = False) -> tuple[Rows, bool]:
    """Same request set through split (two-dispatch) and fused decode.
    All requests run at temperature 0 so the parity bar is token-level
    equality, request by request."""
    n_req = 4 if quick else 8
    n_tenants, prompt_len, max_new = 3, 8, 8 if quick else 16
    rng = np.random.default_rng(7)
    rt_probe = _make_runtime(n_tenants)
    vocab = rt_probe.cfg.vocab_size
    del rt_probe
    spec = [
        (None if i % (n_tenants + 1) == 0 else f"tenant-{i % n_tenants}",
         rng.integers(0, vocab, size=prompt_len, dtype=np.int32))
        for i in range(n_req)
    ]

    results = {}
    for label, fuse in (("split", False), ("fused", True)):
        rt = _make_runtime(n_tenants, decode_fuse=fuse)
        # Warm the compile caches so makespan measures steady-state decode.
        _drain(rt, spec[:2], max_batch=4, prompt_len=prompt_len, max_new=4)
        makespan, tokens = _drain(
            rt, spec, max_batch=4, prompt_len=prompt_len, max_new=max_new)
        results[label] = (makespan, tokens)

    parity = results["split"][1] == results["fused"][1]
    toks = n_req * max_new
    split_s, fused_s = results["split"][0], results["fused"][0]
    rows = [
        ("kernel/fused_decode/split_tok_s", toks / split_s),
        ("kernel/fused_decode/fused_tok_s", toks / fused_s),
        ("kernel/fused_decode/fused_speedup_x", split_s / fused_s),
        ("kernel/fused_decode/temp0_token_match", float(parity)),
    ]
    return rows, parity


# ---------------------------------------------------------------------------
# Section 3: q4 pools — bytes + eval loss per compression
# ---------------------------------------------------------------------------


def q4_pools(quick: bool = False) -> tuple[Rows, bool]:
    from repro.configs import get_config, reduce_config
    from repro.core import lm_skiplora as SL
    from repro.core.adapter_pool import AdapterPool
    from repro.models.lm import init_lm, init_serve_caches, serve_prefill_grouped

    cfg = reduce_config(get_config("stablelm-1.6b"))
    params = init_lm(jax.random.key(0), cfg)
    sl = SL.SkipLoRAConfig(rank=4)
    n_tenants, b, prompt = 3, 4, 8
    adapters = []
    for t in range(n_tenants):
        ad = SL.init_adapters(jax.random.key(100 + t), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(200 + t), ad["B"].shape) * 0.02
        adapters.append(ad)

    tokens = jax.random.randint(jax.random.key(1), (b, prompt), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.key(2), (b,), 0, cfg.vocab_size)

    payload_keys = ("A", "B", "qa", "qb", "qa4", "qb4")
    losses, payloads, totals = {}, {}, {}
    for compress in (None, "int8", "int4", "nf4"):
        pool = AdapterPool(n_tenants + 1, cfg, sl.rank, compress=compress)
        for t, ad in enumerate(adapters):
            pool.register(f"tenant-{t}", ad)
        idx = pool.lookup([None] + [f"tenant-{t}" for t in range(b - 1)])
        pools = pool.pools()
        caches = init_serve_caches(cfg, b, prompt)
        logits, _ = serve_prefill_grouped(
            params, cfg, tokens, caches, pools, idx, use_kernel=False)
        logits = logits.reshape(b, logits.shape[-1])
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
        name = compress or "float"
        losses[name] = float(loss)
        payloads[name] = sum(
            int(v.size) * v.dtype.itemsize
            for k, v in pools.items() if k in payload_keys)
        totals[name] = pool.nbytes()

    halved = payloads["int4"] * 2 == payloads["int8"] and \
        payloads["nf4"] * 2 == payloads["int8"]
    rows: list[tuple[str, float]] = []
    for name in ("float", "int8", "int4", "nf4"):
        rows += [
            (f"kernel/q4/{name}/eval_loss", losses[name]),
            (f"kernel/q4/{name}/eval_loss_delta", losses[name] - losses["float"]),
            (f"kernel/q4/{name}/payload_bytes", float(payloads[name])),
            (f"kernel/q4/{name}/total_bytes", float(totals[name])),
        ]
    rows += [
        ("kernel/q4/int4_payload_vs_int8_x",
         payloads["int4"] / payloads["int8"]),
        ("kernel/q4/int4_total_vs_int8_x", totals["int4"] / totals["int8"]),
    ]
    return rows, halved


# ---------------------------------------------------------------------------


def kernel_bench(quick: bool = False) -> tuple[Rows, dict]:
    tune_rows = tile_sweep(quick)
    fuse_rows, parity = fused_decode(quick)
    q4_rows, halved = q4_pools(quick)
    rows = tune_rows + fuse_rows + q4_rows
    speedups = [v for k, v in tune_rows if k.endswith("/speedup_x")]
    gates = {
        "tuned_beats_default": any(s > 1.0 for s in speedups),
        "temp0_parity": parity,
        "q4_payload_halved": halved,
    }
    return rows, gates


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_kernels.json")
    args = ap.parse_args(argv)

    rows, gates = kernel_bench(quick=args.quick)
    for name, val in rows:
        print(f"{name},{val:.6g}")
    payload = {name: val for name, val in rows}
    payload["_gates"] = {k: bool(v) for k, v in gates.items()}
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.json}")
    broken = [k for k, ok in gates.items() if not ok]
    if broken:
        raise SystemExit(f"kernel bench gates broken: {broken}")
    print(f"gates OK: {sorted(gates)}")


if __name__ == "__main__":
    main()
