"""LM-scale benchmarks (beyond the paper's tables).

- cached-vs-populate epoch wall time on a reduced LM (the paper's claim at
  transformer scale, measured);
- fused Skip-LoRA kernel vs unfused einsum path (interpret mode on CPU —
  correctness-grade timing, the HBM-traffic analysis lives in DESIGN.md);
- cache-mode footprints (full / int8 / freeze_a).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.models.lm import init_lm
from repro.optim.optimizers import adamw


def cached_epoch_speedup(arch: str = "stablelm-1.6b") -> list[tuple[str, float]]:
    cfg = reduce_config(get_config(arch))
    sl = SL.SkipLoRAConfig(rank=8, mode="full", cache_dtype="float32")
    params = init_lm(jax.random.key(0), cfg)
    adapters = SL.init_adapters(jax.random.key(1), cfg, sl)
    trainable, static = SL.split_trainable(adapters, sl)
    opt = adamw(1e-3)
    opt_state = opt.init(trainable)
    b, s, n = 8, 64, 32
    cache = SL.init_lm_cache(n, cfg, sl, s)
    key = jax.random.key(2)
    tokens = jax.random.randint(key, (n, s), 0, cfg.vocab_size)

    populate = jax.jit(SL.make_populate_step(cfg, sl, opt))
    cached = jax.jit(SL.make_cached_step(cfg, sl, opt))

    def pop_epoch():
        nonlocal trainable, opt_state, cache
        for i in range(n // b):
            idx = jnp.arange(i * b, (i + 1) * b)
            batch = {"tokens": tokens[idx], "labels": tokens[idx]}
            trainable, opt_state, cache, loss = populate(
                params, trainable, static, opt_state, cache, batch, idx
            )
        return loss

    def cached_epoch():
        nonlocal trainable, opt_state
        for i in range(n // b):
            idx = jnp.arange(i * b, (i + 1) * b)
            trainable, opt_state, loss = cached(
                params, trainable, static, opt_state, cache, idx
            )
        return loss

    jax.block_until_ready(pop_epoch())  # compile both
    jax.block_until_ready(cached_epoch())
    t0 = time.perf_counter()
    jax.block_until_ready(pop_epoch())
    t_pop = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(3):
        loss = cached_epoch()
    jax.block_until_ready(loss)
    t_cached = (time.perf_counter() - t0) / 3
    return [
        (f"lm/{arch}/populate_epoch_ms", t_pop * 1e3),
        (f"lm/{arch}/cached_epoch_ms", t_cached * 1e3),
        (f"lm/{arch}/epoch_speedup_x", t_pop / t_cached),
    ]


def kernel_vs_einsum(l=8, m=512, d=256, r=8) -> list[tuple[str, float]]:
    from repro.kernels.skip_lora.kernel import skip_lora_fwd
    from repro.kernels.skip_lora.ref import skip_lora_fwd_ref

    key = jax.random.key(0)
    x = jax.random.normal(key, (l, m, d))
    a = jax.random.normal(jax.random.key(1), (l, d, r)) * 0.05
    b = jax.random.normal(jax.random.key(2), (l, r, d)) * 0.05

    ref = jax.jit(skip_lora_fwd_ref)
    ker = jax.jit(lambda x, a, b: skip_lora_fwd(x, a, b, interpret=True))

    def timeit(f, n=20):
        jax.block_until_ready(f(x, a, b))
        t0 = time.perf_counter()
        for _ in range(n):
            out = f(x, a, b)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n * 1e6

    return [
        ("kernel/skip_lora_einsum_us", timeit(ref)),
        ("kernel/skip_lora_pallas_interpret_us", timeit(ker)),
    ]


def cache_footprints(arch: str = "gemma3-27b", seq: int = 4096) -> list[tuple[str, float]]:
    cfg = get_config(arch)
    rows = []
    for mode in ("full", "int8", "freeze_a"):
        sl = SL.SkipLoRAConfig(rank=16, mode=mode)
        rows.append(
            (f"cache/{arch}/{mode}_MiB_per_sample",
             SL.cache_nbytes_per_sample(cfg, sl, seq) / 2**20)
        )
    return rows
