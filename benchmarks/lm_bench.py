"""LM-scale benchmarks (beyond the paper's tables).

- cached-vs-populate epoch wall time on a reduced LM (the paper's claim at
  transformer scale, measured) — each epoch phase one lax.scan dispatch;
- the tiered cache engine under an HBM budget: streaming cached epochs with
  LRU spill + prefetch, reporting per-tier hit counts;
- fused Skip-LoRA kernel vs unfused einsum path (interpret mode on CPU —
  correctness-grade timing, the HBM-traffic analysis lives in DESIGN.md);
- cache-mode footprints (full / int8 / freeze_a).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.cache_engine import TieredCacheEngine
from repro.core.skip_cache import cache_read
from repro.models.lm import init_lm
from repro.optim.optimizers import adamw


def _setup(arch: str, b: int, s: int, n: int):
    cfg = reduce_config(get_config(arch))
    sl = SL.SkipLoRAConfig(rank=8, mode="full", cache_dtype="float32")
    params = init_lm(jax.random.key(0), cfg)
    adapters = SL.init_adapters(jax.random.key(1), cfg, sl)
    trainable, static = SL.split_trainable(adapters, sl)
    opt = adamw(1e-3)
    opt_state = opt.init(trainable)
    cache = SL.init_lm_cache(n, cfg, sl, s)
    tokens = jax.random.randint(jax.random.key(2), (n, s), 0, cfg.vocab_size)
    idx_mat = jnp.arange(n).reshape(n // b, b)
    return cfg, sl, params, trainable, static, opt, opt_state, cache, tokens, idx_mat


def cached_epoch_speedup(arch: str = "stablelm-1.6b") -> list[tuple[str, float]]:
    """Populate vs cached epoch wall time; one scan dispatch per epoch."""
    b, s, n = 8, 64, 32
    (cfg, sl, params, trainable, static, opt, opt_state, cache, tokens,
     idx_mat) = _setup(arch, b, s, n)

    populate_epoch = SL.make_populate_epoch(cfg, sl, opt)
    cached_epoch = SL.make_cached_epoch(cfg, sl, opt)

    trainable, opt_state, cache, ls = populate_epoch(  # compile
        params, trainable, static, opt_state, cache, tokens, tokens, idx_mat)
    jax.block_until_ready(ls)
    t0 = time.perf_counter()
    trainable, opt_state, cache, ls = populate_epoch(
        params, trainable, static, opt_state, cache, tokens, tokens, idx_mat)
    jax.block_until_ready(ls)
    t_pop = time.perf_counter() - t0

    trainable, opt_state, ls = cached_epoch(  # compile
        params, trainable, static, opt_state, cache, idx_mat)
    jax.block_until_ready(ls)
    t0 = time.perf_counter()
    for _ in range(3):
        trainable, opt_state, ls = cached_epoch(
            params, trainable, static, opt_state, cache, idx_mat)
    jax.block_until_ready(ls)
    t_cached = (time.perf_counter() - t0) / 3
    return [
        (f"lm/{arch}/populate_epoch_ms", t_pop * 1e3),
        (f"lm/{arch}/cached_epoch_ms", t_cached * 1e3),
        (f"lm/{arch}/epoch_speedup_x", t_pop / t_cached),
    ]


def tiered_engine_epoch(arch: str = "stablelm-1.6b") -> list[tuple[str, float]]:
    """Cached epochs through the TieredCacheEngine with an HBM budget that
    holds only half the fine-tune set: LRU spill to the host tier, reads
    promote back, next batch prefetched while the adapter step runs."""
    b, s, n = 4, 64, 32
    (cfg, sl, params, trainable, static, opt, opt_state, cache, tokens,
     idx_mat) = _setup(arch, b, s, n)

    populate_epoch = SL.make_populate_epoch(cfg, sl, opt)
    trainable, opt_state, cache, ls = populate_epoch(
        params, trainable, static, opt_state, cache, tokens, tokens, idx_mat)
    jax.block_until_ready(ls)

    layout = SL.lm_cache_layout(cfg, sl, s)
    engine = TieredCacheEngine(n, layout, capacity=n // 2)
    for row in np.asarray(idx_mat):
        idx = jnp.asarray(row)
        engine.write(idx, cache_read(cache, idx))

    step = jax.jit(SL.make_cached_step_from_vals(cfg, sl, opt))

    def engine_epoch():
        nonlocal trainable, opt_state
        for _, vals in engine.stream_batches(idx_mat):
            trainable, opt_state, loss = step(
                params, trainable, static, opt_state, vals)
        return loss

    jax.block_until_ready(engine_epoch())  # compile
    engine.stats.reset()  # count only the timed epochs
    t0 = time.perf_counter()
    for _ in range(3):
        loss = engine_epoch()
    jax.block_until_ready(loss)
    t_engine = (time.perf_counter() - t0) / 3
    st = engine.stats
    return [
        (f"lm/{arch}/engine_cached_epoch_ms", t_engine * 1e3),
        (f"lm/{arch}/engine_hbm_capacity_rows", float(engine.capacity)),
    ] + st.as_rows(f"lm/{arch}/engine")


def kernel_vs_einsum(l=8, m=512, d=256, r=8) -> list[tuple[str, float]]:
    from repro.kernels.skip_lora.kernel import skip_lora_fwd
    from repro.kernels.skip_lora.ref import skip_lora_fwd_ref

    key = jax.random.key(0)
    x = jax.random.normal(key, (l, m, d))
    a = jax.random.normal(jax.random.key(1), (l, d, r)) * 0.05
    b = jax.random.normal(jax.random.key(2), (l, r, d)) * 0.05

    ref = jax.jit(skip_lora_fwd_ref)
    ker = jax.jit(lambda x, a, b: skip_lora_fwd(x, a, b, interpret=True))

    def timeit(f, n=20):
        jax.block_until_ready(f(x, a, b))
        t0 = time.perf_counter()
        for _ in range(n):
            out = f(x, a, b)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n * 1e6

    return [
        ("kernel/skip_lora_einsum_us", timeit(ref)),
        ("kernel/skip_lora_pallas_interpret_us", timeit(ker)),
    ]


def cache_footprints(arch: str = "gemma3-27b", seq: int = 4096) -> list[tuple[str, float]]:
    cfg = get_config(arch)
    rows = []
    for mode in ("full", "int8", "freeze_a"):
        sl = SL.SkipLoRAConfig(rank=16, mode=mode)
        rows.append(
            (f"cache/{arch}/{mode}_MiB_per_sample",
             SL.cache_nbytes_per_sample(cfg, sl, seq) / 2**20)
        )
    return rows
