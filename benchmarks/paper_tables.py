"""Benchmarks mirroring the paper's tables/figures (CPU; sized down where
noted — 1-core container; the structure of each claim is what's validated).

Table 2 : execution-time/FLOP breakdown of FT-All-LoRA per layer.
Table 3 : accuracy before/after drift (no fine-tuning vs oracle retrain).
Table 4 : accuracy of the 8 fine-tuning methods on the drifted twins.
Table 6/7: per-batch train time split fwd/bwd/update, all methods, Fan+HAR.
Fig 3   : Skip2-LoRA training curves / required epochs.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compute_model as cm
from repro.core import methods as M
from repro.core.finetune import evaluate, finetune
from repro.data.synthetic import make_drifted_dataset
from repro.models.mlp import MLPConfig, accuracy, mlp_forward, pretrain

FAN = MLPConfig(in_dim=256, hidden_dim=96, out_dim=3, lora_rank=4)
HAR = MLPConfig(in_dim=561, hidden_dim=96, out_dim=6, lora_rank=4)
BATCH = 20


def table2_breakdown() -> list[tuple[str, float]]:
    """FLOP-share of each layer in FT-All-LoRA fwd+bwd (analytic; the paper
    measures time — shares are comparable). Paper: FC1+FC2 dominate."""
    rows = []
    for name, cfg in (("fan", FAN), ("har", HAR)):
        dims = cfg.dims
        fcs, loras = cm.method_layer_types("ft_all_lora", 3)
        total = cm.method_cost("ft_all_lora", BATCH, dims, cfg.lora_rank).total
        for k in range(3):
            fc = cm.fc_cost(fcs[k], BATCH, dims[k], dims[k + 1]).total
            lo = cm.lora_cost(loras[k], BATCH, dims[k], dims[k + 1], cfg.lora_rank).total
            rows.append((f"table2/{name}/FC{k+1}_pct", 100 * fc / total))
            rows.append((f"table2/{name}/LoRA{k+1}_pct", 100 * lo / total))
    return rows


def tables_3_4_accuracy(trials: int = 3, quick: bool = False) -> list[tuple[str, float]]:
    """Before/after-drift accuracy + 8 methods (paper: 20 trials, E=300/600;
    here: fewer trials/epochs — the orderings are the claim)."""
    rows = []
    methods = M.METHODS
    pre_epochs = 25
    ft_epochs = 30 if quick else 60
    for ds_name in ("damage1", "damage2", "har"):
        cfg = FAN if ds_name.startswith("damage") else HAR
        before_accs, after = [], {m: [] for m in methods}
        for t in range(trials):
            ds = make_drifted_dataset(jax.random.key(100 + t), ds_name)
            bb = pretrain(jax.random.key(t), cfg, ds.x_pre, ds.y_pre, epochs=pre_epochs, lr=0.05)
            logits, _ = mlp_forward(bb, ds.x_test, cfg)
            before_accs.append(float(accuracy(logits, ds.y_test)))
            for m in methods:
                res = finetune(
                    jax.random.key(1000 + t), m, cfg, bb, ds.x_ft, ds.y_ft,
                    epochs=ft_epochs, batch_size=BATCH, lr=0.05,
                )
                after[m].append(evaluate(m, cfg, res, ds.x_test, ds.y_test))
        rows.append((f"table3/{ds_name}/before_acc", float(np.mean(before_accs))))
        for m in methods:
            rows.append((f"table4/{ds_name}/{m}_acc", float(np.mean(after[m]))))
    return rows


def tables_6_7_time(epochs: int = 12) -> list[tuple[str, float]]:
    """Per-batch wall time (ms) split into forward/backward/update for all 8
    methods + the cached Skip2-LoRA fast path. Paper's headline: Skip2-LoRA
    train@batch ~10x cheaper than LoRA-All."""
    rows = []
    for ds_name, cfg in (("fan", FAN), ("har", HAR)):
        ds = make_drifted_dataset(jax.random.key(0), "damage1" if ds_name == "fan" else "har")
        bb = pretrain(jax.random.key(1), cfg, ds.x_pre, ds.y_pre, epochs=10, lr=0.05)
        xb, yb = ds.x_ft[:BATCH], ds.y_ft[:BATCH]

        def timeit(f, *a, n=50):
            f(*a)  # compile
            jax.block_until_ready(f(*a))
            t0 = time.perf_counter()
            for _ in range(n):
                out = f(*a)
            jax.block_until_ready(out)
            return (time.perf_counter() - t0) / n * 1e3

        for m in M.METHODS:
            fwd_m = "skip_lora" if m == "skip2_lora" else m
            trainable, frozen = M.init_method(jax.random.key(2), cfg, bb, m)
            phases = M.make_phase_fns(fwd_m, cfg)
            t_f = timeit(phases["forward"], trainable, frozen, xb)
            grads = phases["backward"](trainable, frozen, xb, yb)
            t_b = timeit(phases["backward"], trainable, frozen, xb, yb)
            t_u = timeit(phases["update"], trainable, grads, 0.05)
            rows.append((f"table67/{ds_name}/{m}/forward_ms", t_f))
            rows.append((f"table67/{ds_name}/{m}/backward_ms", t_b))
            rows.append((f"table67/{ds_name}/{m}/update_ms", t_u))
            rows.append((f"table67/{ds_name}/{m}/train_batch_ms", t_f + t_b + t_u))

        # Skip2-LoRA cached fast path (hit epochs): forward = cache gather +
        # adapter sum; backward = adapter grads only.
        from repro.core import skip_cache as C
        from repro.core.finetune import _cached_step, _populate_step

        trainable, frozen = M.init_method(jax.random.key(2), cfg, bb, "skip2_lora")
        cache = C.cache_for_mlp(len(ds.x_ft), cfg.dims)
        pop = _populate_step(cfg)
        idx = jnp.arange(BATCH)
        trainable, cache, _ = pop(trainable, frozen, cache, idx, xb, yb, 0.05)
        cached = _cached_step(cfg)
        t_c = timeit(lambda: cached(trainable, cache, idx, xb, yb, 0.05))
        rows.append((f"table67/{ds_name}/skip2_lora_cached/train_batch_ms", t_c))
    return rows


def fig3_required_epochs(max_epochs: int = 60) -> list[tuple[str, float]]:
    """Epochs until test accuracy first reaches within 1% of its final value
    (paper Fig. 3: 100/60/200 on real data; synthetic twins converge faster)."""
    rows = []
    for ds_name in ("damage1", "damage2", "har"):
        cfg = FAN if ds_name.startswith("damage") else HAR
        ds = make_drifted_dataset(jax.random.key(0), ds_name)
        bb = pretrain(jax.random.key(1), cfg, ds.x_pre, ds.y_pre, epochs=25, lr=0.05)
        accs = []
        for e in range(2, max_epochs + 1, 2):
            res = finetune(jax.random.key(2), "skip2_lora", cfg, bb, ds.x_ft, ds.y_ft,
                           epochs=e, batch_size=BATCH, lr=0.05)
            accs.append((e, evaluate("skip2_lora", cfg, res, ds.x_test, ds.y_test)))
        final = accs[-1][1]
        req = next(e for e, a in accs if a >= final - 0.01)
        rows.append((f"fig3/{ds_name}/required_epochs", float(req)))
        rows.append((f"fig3/{ds_name}/final_acc", float(final)))
    return rows


def headline_reduction() -> list[tuple[str, float]]:
    """Abstract claim: Skip2-LoRA cuts fine-tuning time ~90% vs LoRA-All at
    equal trainable-parameter count. FLOP-model at the paper's epoch counts."""
    rows = []
    for name, dims, e in (("fan", FAN.dims, 300), ("har", HAR.dims, 600)):
        hit = cm.expected_hit_rate(e)
        skip2 = cm.method_cost("skip2_lora", BATCH, dims, 4, cache_hit_rate=hit).total
        lora = cm.method_cost("lora_all", BATCH, dims, 4).total
        rows.append((f"headline/{name}/flop_reduction_pct", 100 * (1 - skip2 / lora)))
    return rows
