"""Benchmark harness: one section per paper table + LM-scale extensions.

Prints ``name,value,derived`` CSV rows (value units embedded in the name).
The ``runtime`` section additionally writes its rows machine-readably to
``BENCH_runtime.json`` (``--json-out``) — serve tok/s, routed-vs-direct
overhead, interleaved session tenant-rounds/sec, cache hit rates — so the
bench trajectory is trackable across commits without CSV scraping.

  PYTHONPATH=src python -m benchmarks.run          # full (~5 min on CPU)
  PYTHONPATH=src python -m benchmarks.run --quick  # reduced trials
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, help="substring filter on sections")
    ap.add_argument("--json-out", default="BENCH_runtime.json",
                    help="where the runtime section's metrics land")
    args = ap.parse_args()

    from benchmarks import (
        control_bench,
        fleet_bench,
        kernel_bench,
        lm_bench,
        paper_tables,
        runtime_bench,
        serve_bench,
        serving_bench,
    )

    def kernel_section():
        rows, gates = kernel_bench.kernel_bench(quick=args.quick)
        payload = {key: val for key, val in rows}
        payload["_gates"] = {k: bool(v) for k, v in gates.items()}
        with open("BENCH_kernels.json", "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        if not all(gates.values()):
            raise RuntimeError(f"kernel gates broken: "
                               f"{[k for k, ok in gates.items() if not ok]}")
        return rows

    def serving_section():
        rows, payload = serving_bench.serving_slo(quick=args.quick)
        with open("BENCH_serving_slo.json", "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        return rows

    def control_section():
        rows, payload = control_bench.control_bench(quick=args.quick)
        with open("BENCH_control.json", "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        gates = payload["_gates"]
        if not all(gates.values()):
            raise RuntimeError(f"control gates broken: "
                               f"{[k for k, ok in gates.items() if not ok]}")
        return rows

    sections = [
        ("serve_decode", lambda: serve_bench.decode_dispatch(
            gen=16 if args.quick else 64)),
        ("serve_grouped", lambda: serve_bench.grouped_adapters(
            gen=8 if args.quick else 32)),
        ("serving_slo", serving_section),
        ("kernel_speed", kernel_section),
        ("control", control_section),
        ("runtime", lambda: runtime_bench.runtime_session(quick=args.quick)),
        ("fleet", lambda: fleet_bench.fleet_vs_sequential(quick=args.quick)),
        ("table2", lambda: paper_tables.table2_breakdown()),
        ("headline", lambda: paper_tables.headline_reduction()),
        ("table67", lambda: paper_tables.tables_6_7_time()),
        ("table34", lambda: paper_tables.tables_3_4_accuracy(
            trials=1 if args.quick else 3, quick=args.quick)),
        ("fig3", lambda: paper_tables.fig3_required_epochs(
            max_epochs=30 if args.quick else 60)),
        ("lm_cached", lambda: lm_bench.cached_epoch_speedup()),
        ("cache_engine", lambda: lm_bench.tiered_engine_epoch()),
        ("kernel", lambda: lm_bench.kernel_vs_einsum()),
        ("cache_footprint", lambda: lm_bench.cache_footprints()),
    ]

    print("name,value,derived")
    failures = 0
    for name, fn in sections:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            rows = fn()
            for key, val in rows:
                print(f"{key},{val:.4f},")
            wall = time.time() - t0
            print(f"_section/{name}/wall_s,{wall:.1f},")
            if name == "runtime" and args.json_out:
                payload = {key: val for key, val in rows}
                payload["_wall_s"] = wall
                with open(args.json_out, "w") as f:
                    json.dump(payload, f, indent=2, sort_keys=True)
                print(f"_section/runtime/json,{0.0},{args.json_out}")
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"_section/{name}/ERROR,{0.0},{type(e).__name__}:{e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
