"""Session-runtime benchmarks: routed-serve overhead + interleaved session.

Two claims of the unified runtime (DESIGN.md §9), measured:

- **Routed decode overhead**: ``SessionRuntime.serve`` routes a mixed
  batch through the *same* compiled decode-scan entries as calling
  ``generate_grouped`` directly (the shared compiled-fn cache), so the
  runtime may add only a pool lookup and Python routing. The §9 bar is
  runtime-routed throughput within 10% of the direct PR 2 path on the same
  shapes; ``routed_overhead_x`` is the measured ratio.
- **Interleaved session throughput**: the full continual loop — serve,
  ingest (populate forward + logits back), grouped adapt, serve again —
  in tenant-rounds/sec, with the engine/pool counters that show the cache
  tiers and path selection doing their jobs.

Oracle (jnp) kernel path on CPU, like the other benches — interpret-mode
Pallas timing is correctness-grade only (see ``lm_bench.kernel_vs_einsum``).

The sharded section (``python -m benchmarks.runtime_bench --devices N
--json BENCH_runtime_sharded.json``) runs the SAME interleaved session on
an N-way forced-host-device mesh and on its 1-device same-layout twin,
reporting tenant-rounds/s for both plus the twin-parity max-abs-diff
(must be 0.0 — DESIGN.md §10). Forced CPU "devices" share the same cores,
so the ratio measures dispatch/overlap overhead, not real DP speedup; the
numbers are honest about that.

The 2-D section (``--mesh2d --devices M --json BENCH_runtime_2d.json``)
instead measures the big-backbone story on a ``(data=1, model=M)`` mesh:
per-device peak backbone bytes vs the replicated baseline (gate >= 0.8*M),
temp-0 serve token parity vs the 1-device twin (exact), and pipelined
scheduler admission (``pipeline_stages=M``) against the plain 2-D path
next to ``bubble_fraction``'s prediction (DESIGN.md §14).
"""

from __future__ import annotations

# The sharded section needs the forced device count set BEFORE the first
# jax import (the dryrun.py/fleet.py trick), so peek at argv when invoked
# as a script.
import os
import sys

def _peek_devices(argv: list[str]) -> str | None:
    for i, arg in enumerate(argv):
        if arg == "--devices" and i + 1 < len(argv):
            return argv[i + 1]
        if arg.startswith("--devices="):
            return arg.split("=", 1)[1]
    return None


if __name__ == "__main__":
    _n = _peek_devices(sys.argv)
    if _n and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_n} "
            + os.environ.get("XLA_FLAGS", "")
        )

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.runtime import SessionRuntime, generate_grouped
from repro.launch.flops import model_flops
from repro.launch.hlo_analysis import analyze_collectives, analyze_dot_flops
from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from repro.models.lm import init_lm, init_serve_caches, serve_decode_grouped, serve_prefill_grouped
from repro.runtime.sharding import make_mesh


def _time(fn, repeats: int = 5) -> float:
    jax.block_until_ready(fn())  # compile / warm caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _xla_cost(compiled) -> dict:
    """``Compiled.cost_analysis()`` across jax versions: newer returns a
    dict, older a list with one dict per partition, some backends raise."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if isinstance(ca, dict) else {}


def dispatch_cost(fn, *args) -> dict[str, float]:
    """Compile ``fn(*args)`` and report its static cost model: HLO dot
    FLOPs (launch.hlo_analysis, loop-multiplied), XLA's own flops/bytes
    estimate, collective bytes, and the roofline time bounds those imply."""
    compiled = jax.jit(fn).lower(*args).compile()
    cost = _xla_cost(compiled)
    hlo = compiled.as_text()
    dot = analyze_dot_flops(hlo)
    coll = analyze_collectives(hlo)
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    return {
        "dot_flops": dot,
        "xla_flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": bytes_accessed,
        "collective_bytes": float(coll.total_bytes),
        "roofline_compute_s": dot / PEAK_FLOPS,
        "roofline_memory_s": bytes_accessed / HBM_BW,
    }


def dispatch_cost_rows(
    arch: str, cfg, params, prompts, pools, idx, *, b: int, prompt: int
) -> list[tuple[str, float]]:
    """Per-dispatch FLOPs + bytes columns for the two serve dispatches the
    scheduler lives in (grouped prefill, one grouped decode step), plus the
    analytic MODEL_FLOPS so the JSON shows the HLO-vs-model ratio."""
    caches = init_serve_caches(cfg, b, prompt + 8)

    def _prefill(p, tk, c, pl_a, pl_b, ix):
        return serve_prefill_grouped(
            p, cfg, tk, c, {"A": pl_a, "B": pl_b}, ix, use_kernel=False
        )

    def _decode(p, tok, pos, c, pl_a, pl_b, ix):
        return serve_decode_grouped(
            p, cfg, tok, pos, c, {"A": pl_a, "B": pl_b}, ix, use_kernel=False
        )

    tok1 = prompts[:, -1:]
    pos = jnp.asarray(prompt, jnp.int32)
    costs = {
        "prefill": dispatch_cost(
            _prefill, params, prompts, caches, pools["A"], pools["B"], idx
        ),
        "decode_step": dispatch_cost(
            _decode, params, tok1, pos, caches, pools["A"], pools["B"], idx
        ),
    }
    rows = [
        (f"runtime/{arch}/{disp}/{col}", val)
        for disp, cost in costs.items()
        for col, val in cost.items()
    ]
    for disp, step in (("prefill", "prefill"), ("decode_step", "decode")):
        mf = model_flops(cfg, (b, prompt), step)
        rows.append((f"runtime/{arch}/{disp}/model_flops", mf))
        hlo_f = costs[disp]["dot_flops"]
        if hlo_f > 0:
            rows.append((f"runtime/{arch}/{disp}/hlo_vs_model_x", hlo_f / mf))
    return rows


def _session(cfg, sl, params, n_tenants: int, spt: int, seq: int) -> SessionRuntime:
    return SessionRuntime(
        cfg, sl, params, max_tenants=n_tenants, samples_per_tenant=spt,
        seq=seq, lr=1e-2, use_kernel=False,
    )


def runtime_session(
    arch: str = "stablelm-1.6b",
    *,
    b: int = 4,
    prompt: int = 16,
    gen: int = 32,
    n_tenants: int = 3,
    rank: int = 8,
    n_per: int = 8,
    seq: int = 16,
    adapt_epochs: int = 2,
    unroll: int = 8,
    quick: bool = False,
) -> list[tuple[str, float]]:
    if quick:
        gen, adapt_epochs = 8, 1
    cfg = reduce_config(get_config(arch))
    sl = SL.SkipLoRAConfig(rank=rank, mode="full", cache_dtype="float32")
    params = init_lm(jax.random.key(0), cfg)
    prompts = jax.random.randint(
        jax.random.key(1), (b, prompt), 0, cfg.vocab_size
    )

    # -- routed serve vs direct generate_grouped on identical shapes --------
    rt = _session(cfg, sl, params, n_tenants, n_per, seq)
    names = [f"u{t}" for t in range(n_tenants)]
    for t, name in enumerate(names):
        ad = SL.init_adapters(jax.random.key(10 + t), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(20 + t), ad["B"].shape) * 0.02
        rt.pool.register(name, ad)
    who = [None] + [names[i % n_tenants] for i in range(1, b)]
    idx = rt.pool.lookup(who)
    pools = rt.pool.pools()

    t_direct = _time(lambda: generate_grouped(
        params, cfg, prompts, pools, idx, max_new=gen, use_kernel=False,
        unroll=unroll,
    ))
    t_routed = _time(lambda: rt.serve(
        who, prompts, max_new=gen, unroll=unroll,
    ))
    toks = b * gen

    # -- static per-dispatch cost columns (launch.* cost models) ------------
    cost_rows = dispatch_cost_rows(
        arch, cfg, params, prompts, pools, idx, b=b, prompt=prompt
    )

    # -- interleaved session: serve -> ingest -> adapt -> serve -------------
    rt2 = _session(cfg, sl, params, n_tenants, n_per, seq)
    rng = jax.random.key(2)

    def session():
        # One continual round per tenant: serve, ingest (first trip fills
        # the partition; ingest cost then lives in session_cold_s), grouped
        # adapt, serve the freshly written-back slots.
        nonlocal rng
        rt2.serve([None] * b, prompts, max_new=gen, unroll=unroll)
        for name in names:
            if name in rt2._tenants and rt2.tenant(name).n_ingested >= n_per:
                continue
            rng, k1, k2 = jax.random.split(rng, 3)
            toks_in = jax.random.randint(k1, (n_per, seq), 0, cfg.vocab_size)
            labs = jax.random.randint(k2, (n_per, seq), 0, cfg.vocab_size)
            rt2.ingest(name, toks_in, labs)
        out = rt2.adapt(names, epochs=adapt_epochs, batch_per_tenant=4,
                        key=jax.random.key(3))
        rt2.serve([None] + who[1:], prompts, max_new=gen, unroll=unroll)
        return out["losses"][names[0]]

    t0 = time.perf_counter()
    session()  # compile + populate trip
    t_cold = time.perf_counter() - t0
    t_warm = _time(session, repeats=3)

    st = rt2.engine.stats
    return [
        (f"runtime/{arch}/direct_grouped_tok_s", toks / t_direct),
        (f"runtime/{arch}/routed_serve_tok_s", toks / t_routed),
        (f"runtime/{arch}/routed_overhead_x", t_routed / t_direct),
        (f"runtime/{arch}/session_cold_s", t_cold),
        (f"runtime/{arch}/session_tenant_rounds_per_s", n_tenants / t_warm),
        (f"runtime/{arch}/cache_hbm_hit_rate", st.hbm_hit_rate()),
        (f"runtime/{arch}/cache_spills", float(st.spills)),
        (f"runtime/{arch}/pool_tenants", float(len(rt2.pool))),
        (f"runtime/{arch}/pool_MiB", rt2.pool.nbytes() / 2**20),
        (f"runtime/{arch}/adapt_epochs", float(adapt_epochs)),
    ] + cost_rows


# ---------------------------------------------------------------------------
# Sharded section: mesh-native session vs its 1-device same-layout twin
# ---------------------------------------------------------------------------


def runtime_sharded(
    arch: str = "stablelm-1.6b",
    *,
    devices: int = 4,
    n_per: int = 8,
    seq: int = 16,
    bpt: int = 4,
    adapt_epochs: int = 2,
    rounds: int = 2,
    quick: bool = False,
) -> list[tuple[str, float]]:
    """One tenant per shard per device; the same event stream on the
    N-device mesh and the 1-device twin with identical logical layout.
    Twin parity (adapters) must be exactly 0.0."""
    if quick:
        adapt_epochs, rounds = 1, 1
    n_tenants = devices
    n_dev = min(devices, len(jax.devices()))
    cfg = reduce_config(get_config(arch))
    sl = SL.SkipLoRAConfig(rank=8, mode="full", cache_dtype="float32")
    params = init_lm(jax.random.key(0), cfg)
    tokens = jax.random.randint(
        jax.random.key(1), (n_tenants, rounds * n_per, seq), 0, cfg.vocab_size
    )
    labels = jax.random.randint(
        jax.random.key(2), (n_tenants, rounds * n_per, seq), 0, cfg.vocab_size
    )

    def session(n_devices: int):
        mesh = make_mesh(
            (n_devices,), ("data",), devices=jax.devices()[:n_devices]
        )
        rt = SessionRuntime(
            cfg, sl, params, max_tenants=n_tenants,
            samples_per_tenant=rounds * n_per, seq=seq, lr=1e-2,
            use_kernel=False, mesh=mesh, placement_shards=devices,
        )
        t0 = time.perf_counter()
        for rnd in range(rounds):
            for t in range(n_tenants):
                rt.ingest(f"u{t}", tokens[t, rnd * n_per:(rnd + 1) * n_per],
                          labels[t, rnd * n_per:(rnd + 1) * n_per])
            rt.adapt(epochs=adapt_epochs, batch_per_tenant=bpt,
                     key=jax.random.key(3))
        cold = time.perf_counter() - t0
        # Warm adapt epochs only (the steady state the mesh buys).
        t0 = time.perf_counter()
        rt.adapt(epochs=adapt_epochs, batch_per_tenant=bpt)
        warm = time.perf_counter() - t0
        return rt, cold, warm

    rt_mesh, cold_mesh, warm_mesh = session(n_dev)
    rt_twin, cold_twin, warm_twin = session(1)
    parity = max(
        float(np.max(np.abs(
            np.asarray(rt_mesh.tenant(f"u{t}").adapters[k])
            - np.asarray(rt_twin.tenant(f"u{t}").adapters[k])
        )))
        for t in range(n_tenants) for k in ("A", "B")
    )
    return [
        (f"runtime_sharded/{arch}/devices", float(n_dev)),
        (f"runtime_sharded/{arch}/shards", float(devices)),
        (f"runtime_sharded/{arch}/tenants", float(n_tenants)),
        (f"runtime_sharded/{arch}/session_cold_s", cold_mesh),
        (f"runtime_sharded/{arch}/adapt_warm_s", warm_mesh),
        (f"runtime_sharded/{arch}/adapt_warm_twin_1dev_s", warm_twin),
        (f"runtime_sharded/{arch}/adapt_tenants_per_s", n_tenants / warm_mesh),
        (f"runtime_sharded/{arch}/twin_parity_max_abs_diff", parity),
    ]


# ---------------------------------------------------------------------------
# 2-D section: one TP-sharded backbone on (data=1, model=M) vs replication
# ---------------------------------------------------------------------------


def runtime_2d(
    arch: str = "stablelm-1.6b",
    *,
    devices: int = 4,
    b: int = 4,
    prompt: int = 8,
    gen: int = 16,
    n_per: int = 4,
    seq: int = 8,
    quick: bool = False,
) -> list[tuple[str, float]]:
    """The big-backbone serving claim (DESIGN.md §14), measured on a
    ``(data=1, model=M)`` forced-host-device mesh against the replicated
    1-device twin running the same event stream:

      - ``backbone_bytes_ratio``: replicated param bytes over the peak
        per-device share of the TP-sharded replica — the reason to go 2-D.
        Gate: >= 0.8*M (tables and attention/FFN weights shard; norms and
        small biases replicate, hence the 0.8 slack).
      - ``serve_parity``: temp-0 serve tokens of a mixed base/adapter
        batch must match the twin exactly (GSPMD placement is numerically
        free at the dispatch granularity we compile).
      - ``pipe_wall_vs_bubble``: admission through the pipelined prefill
        (``pipeline_stages=M``, microbatched scheduler admission) vs the
        plain 2-D path on a prefill-heavy pass, next to ``bubble_fraction``'s
        prediction. Forced CPU devices share cores, so the wall gate is
        slack (1.5x over the bubble-adjusted bound), but pipelined tokens
        must equal the plain path bitwise.
    """
    import dataclasses

    from repro.runtime.pipeline_par import bubble_fraction

    if quick:
        gen = 8
    n_model = min(devices, len(jax.devices()))
    # One layer per pipeline stage; the reduced vocab (503) is deliberately
    # prime, but the bytes-ratio gate is *about* table sharding, so give TP
    # a divisible vocab.
    cfg = reduce_config(get_config(arch), n_periods=n_model)
    cfg = dataclasses.replace(cfg, vocab_size=512)
    sl = SL.SkipLoRAConfig(rank=4, mode="full", cache_dtype="float32")
    params = init_lm(jax.random.key(0), cfg)
    names = ["a", "b", "c"]
    prompts = jax.random.randint(jax.random.key(1), (b, prompt), 0, cfg.vocab_size)
    toks_in = jax.random.randint(jax.random.key(2), (n_per, seq), 0, cfg.vocab_size)
    labs_in = jax.random.randint(jax.random.key(3), (n_per, seq), 0, cfg.vocab_size)

    def session(mesh=None, pipeline_stages=0):
        rt = SessionRuntime(
            cfg, sl, params, max_tenants=len(names), samples_per_tenant=n_per,
            seq=seq, lr=1e-2, use_kernel=False, mesh=mesh, placement_shards=1,
            seed=0, pipeline_stages=pipeline_stages,
        )
        for name in names:
            rt.ingest(name, toks_in, labs_in)
        rt.adapt(names, epochs=1, key=jax.random.key(4))
        return rt

    mesh = make_mesh(
        (1, n_model), ("data", "model"), devices=jax.devices()[:n_model]
    )
    rt1 = session()
    rt2 = session(mesh)
    who = [None] + names[: b - 1]

    tok1 = rt1.serve(who, prompts, max_new=gen)
    tok2 = rt2.serve(who, prompts, max_new=gen)
    serve_parity = bool(np.array_equal(np.asarray(tok1), np.asarray(tok2)))
    t1 = _time(lambda: rt1.serve(who, prompts, max_new=gen), repeats=3)
    t2 = _time(lambda: rt2.serve(who, prompts, max_new=gen), repeats=3)
    toks = b * gen

    # Peak per-device backbone bytes: the replicated baseline holds every
    # param on its device; the 2-D replica's device share is read off the
    # committed arrays' addressable shards.
    total = sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree.leaves(params)
    )
    per_dev = max(
        sum(
            s.data.nbytes
            for x in jax.tree.leaves(rt2._shard_params[0])
            for s in x.addressable_shards
            if s.device == d
        )
        for d in mesh.devices.ravel()
    )
    bytes_ratio = total / per_dev

    # Pipelined admission vs the plain 2-D path on a prefill-heavy pass
    # (tiny decode budget, chunk covering it in one dispatch).
    rtp = session(mesh, pipeline_stages=n_model)
    s2 = rt2.attach_scheduler(
        max_batch=b, max_prompt=prompt, max_new_cap=gen, admit_bucket=b,
        chunk=gen,
    )
    sp = rtp.attach_scheduler(
        max_batch=b, max_prompt=prompt, max_new_cap=gen, admit_bucket=b,
        chunk=gen, microbatch=1,
    )
    bubble = bubble_fraction(sp.n_micro, n_model)
    assert abs(sp.predicted_bubble() - bubble) < 1e-12

    def sched_pass(rt):
        reqs = [
            rt.enqueue_serve(who[j], np.asarray(prompts[j]), max_new=4)
            for j in range(b)
        ]
        rt.drain()
        return [r.result().tolist() for r in reqs]

    toks_plain = sched_pass(rt2)   # compile trip
    toks_pipe = sched_pass(rtp)
    pipe_parity = toks_plain == toks_pipe
    t_plain = _time(lambda: sched_pass(rt2), repeats=3)
    t_pipe = _time(lambda: sched_pass(rtp), repeats=3)

    return [
        (f"runtime_2d/{arch}/model_parallel", float(n_model)),
        (f"runtime_2d/{arch}/backbone_bytes_total", float(total)),
        (f"runtime_2d/{arch}/backbone_bytes_per_device_peak", float(per_dev)),
        (f"runtime_2d/{arch}/backbone_bytes_ratio", bytes_ratio),
        (f"runtime_2d/{arch}/serve_tok_s_1dev", toks / t1),
        (f"runtime_2d/{arch}/serve_tok_s_2d", toks / t2),
        (f"runtime_2d/{arch}/serve_parity", 1.0 if serve_parity else 0.0),
        (f"runtime_2d/{arch}/pipe_bubble_predicted", bubble),
        (f"runtime_2d/{arch}/pipe_n_micro", float(sp.n_micro)),
        (f"runtime_2d/{arch}/sched_pass_plain_s", t_plain),
        (f"runtime_2d/{arch}/sched_pass_pipe_s", t_pipe),
        (f"runtime_2d/{arch}/pipe_wall_ratio", t_pipe / t_plain),
        (f"runtime_2d/{arch}/pipe_wall_bound", (1.0 + bubble) * 1.5),
        (f"runtime_2d/{arch}/pipe_parity", 1.0 if pipe_parity else 0.0),
    ]


def main(argv=None) -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--mesh2d", action="store_true",
                    help="run the (data=1, model=N) TP section instead of "
                         "the data-sharded one")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    if args.json is None:
        args.json = (
            "BENCH_runtime_2d.json" if args.mesh2d
            else "BENCH_runtime_sharded.json"
        )
    if len(jax.devices()) < args.devices:
        # The argv peek above must have forced the host device count; a
        # 1-device run would make the twin parity check vacuous.
        raise SystemExit(
            f"need {args.devices} devices, have {len(jax.devices())} "
            "(invoke as `python -m benchmarks.runtime_bench --devices N`)"
        )
    if args.mesh2d:
        rows = runtime_2d(devices=args.devices, quick=args.quick)
    else:
        rows = runtime_sharded(devices=args.devices, quick=args.quick)
    for name, val in rows:
        print(f"{name},{val}")
    payload = {name: val for name, val in rows}
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.json}")

    def _one(suffix):
        return payload[[k for k in payload if k.endswith(suffix)][0]]

    if args.mesh2d:
        m = _one("model_parallel")
        if _one("serve_parity") != 1.0 or _one("pipe_parity") != 1.0:
            raise SystemExit("2-D/twin temp-0 token parity broken")
        if _one("backbone_bytes_ratio") < 0.8 * m:
            raise SystemExit(
                f"per-device backbone bytes ratio {_one('backbone_bytes_ratio'):.2f} "
                f"< 0.8*{m:.0f}"
            )
        if _one("pipe_wall_ratio") > _one("pipe_wall_bound"):
            raise SystemExit(
                f"pipelined admission wall ratio {_one('pipe_wall_ratio'):.2f} "
                f"exceeds the bubble-adjusted bound {_one('pipe_wall_bound'):.2f}"
            )
    else:
        parity = _one("twin_parity_max_abs_diff")
        if parity != 0.0:
            raise SystemExit(f"sharded/twin parity broken: {parity:.3e}")


if __name__ == "__main__":
    main()
