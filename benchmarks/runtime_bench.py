"""Session-runtime benchmarks: routed-serve overhead + interleaved session.

Two claims of the unified runtime (DESIGN.md §9), measured:

- **Routed decode overhead**: ``SessionRuntime.serve`` routes a mixed
  batch through the *same* compiled decode-scan entries as calling
  ``generate_grouped`` directly (the shared compiled-fn cache), so the
  runtime may add only a pool lookup and Python routing. The §9 bar is
  runtime-routed throughput within 10% of the direct PR 2 path on the same
  shapes; ``routed_overhead_x`` is the measured ratio.
- **Interleaved session throughput**: the full continual loop — serve,
  ingest (populate forward + logits back), grouped adapt, serve again —
  in tenant-rounds/sec, with the engine/pool counters that show the cache
  tiers and path selection doing their jobs.

Oracle (jnp) kernel path on CPU, like the other benches — interpret-mode
Pallas timing is correctness-grade only (see ``lm_bench.kernel_vs_einsum``).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.runtime import SessionRuntime, generate_grouped
from repro.models.lm import init_lm


def _time(fn, repeats: int = 5) -> float:
    jax.block_until_ready(fn())  # compile / warm caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _session(cfg, sl, params, n_tenants: int, spt: int, seq: int) -> SessionRuntime:
    return SessionRuntime(
        cfg, sl, params, max_tenants=n_tenants, samples_per_tenant=spt,
        seq=seq, lr=1e-2, use_kernel=False,
    )


def runtime_session(
    arch: str = "stablelm-1.6b",
    *,
    b: int = 4,
    prompt: int = 16,
    gen: int = 32,
    n_tenants: int = 3,
    rank: int = 8,
    n_per: int = 8,
    seq: int = 16,
    adapt_epochs: int = 2,
    unroll: int = 8,
    quick: bool = False,
) -> list[tuple[str, float]]:
    if quick:
        gen, adapt_epochs = 8, 1
    cfg = reduce_config(get_config(arch))
    sl = SL.SkipLoRAConfig(rank=rank, mode="full", cache_dtype="float32")
    params = init_lm(jax.random.key(0), cfg)
    prompts = jax.random.randint(
        jax.random.key(1), (b, prompt), 0, cfg.vocab_size
    )

    # -- routed serve vs direct generate_grouped on identical shapes --------
    rt = _session(cfg, sl, params, n_tenants, n_per, seq)
    names = [f"u{t}" for t in range(n_tenants)]
    for t, name in enumerate(names):
        ad = SL.init_adapters(jax.random.key(10 + t), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(20 + t), ad["B"].shape) * 0.02
        rt.pool.register(name, ad)
    who = [None] + [names[i % n_tenants] for i in range(1, b)]
    idx = rt.pool.lookup(who)
    pools = rt.pool.pools()

    t_direct = _time(lambda: generate_grouped(
        params, cfg, prompts, pools, idx, max_new=gen, use_kernel=False,
        unroll=unroll,
    ))
    t_routed = _time(lambda: rt.serve(
        who, prompts, max_new=gen, unroll=unroll,
    ))
    toks = b * gen

    # -- interleaved session: serve -> ingest -> adapt -> serve -------------
    rt2 = _session(cfg, sl, params, n_tenants, n_per, seq)
    rng = jax.random.key(2)

    def session():
        # One continual round per tenant: serve, ingest (first trip fills
        # the partition; ingest cost then lives in session_cold_s), grouped
        # adapt, serve the freshly written-back slots.
        nonlocal rng
        rt2.serve([None] * b, prompts, max_new=gen, unroll=unroll)
        for name in names:
            if name in rt2._tenants and rt2.tenant(name).n_ingested >= n_per:
                continue
            rng, k1, k2 = jax.random.split(rng, 3)
            toks_in = jax.random.randint(k1, (n_per, seq), 0, cfg.vocab_size)
            labs = jax.random.randint(k2, (n_per, seq), 0, cfg.vocab_size)
            rt2.ingest(name, toks_in, labs)
        out = rt2.adapt(names, epochs=adapt_epochs, batch_per_tenant=4,
                        key=jax.random.key(3))
        rt2.serve([None] + who[1:], prompts, max_new=gen, unroll=unroll)
        return out["losses"][names[0]]

    t0 = time.perf_counter()
    session()  # compile + populate trip
    t_cold = time.perf_counter() - t0
    t_warm = _time(session, repeats=3)

    st = rt2.engine.stats
    return [
        (f"runtime/{arch}/direct_grouped_tok_s", toks / t_direct),
        (f"runtime/{arch}/routed_serve_tok_s", toks / t_routed),
        (f"runtime/{arch}/routed_overhead_x", t_routed / t_direct),
        (f"runtime/{arch}/session_cold_s", t_cold),
        (f"runtime/{arch}/session_tenant_rounds_per_s", n_tenants / t_warm),
        (f"runtime/{arch}/cache_hbm_hit_rate", st.hbm_hit_rate()),
        (f"runtime/{arch}/cache_spills", float(st.spills)),
        (f"runtime/{arch}/pool_tenants", float(len(rt2.pool))),
        (f"runtime/{arch}/pool_MiB", rt2.pool.nbytes() / 2**20),
        (f"runtime/{arch}/adapt_epochs", float(adapt_epochs)),
    ]
