"""Serving benchmarks: scan-fused decode and grouped multi-adapter batches.

Measures the two structural wins of the serving engine (DESIGN.md §7) on
the reduced stablelm-1.6b config:

- **decode dispatch**: three implementations of the same ``gen``-token
  decode, timed post-prefill —

    * ``loop``: the per-token Python loop as it shipped before the scan
      engine — a fresh ``jax.jit(lambda ...)`` closure per ``generate()``
      call, so every request pays a full retrace + compile on top of its
      ``gen`` dispatches;
    * ``cached_loop``: the same per-token loop after hoisting the jits
      into the compiled-function cache — ``gen`` XLA dispatches plus
      eager sampling between them;
    * ``scan``: one ``lax.scan`` dispatch for the whole generation,
      sampling folded into the carry, ``unroll`` steps fused per loop
      iteration.

  ``scan_speedup_x`` is scan vs the replaced loop; ``scan_vs_cached_loop_x``
  isolates the dispatch-count effect alone (1 scan dispatch vs ``gen``
  loop steps, shared per-step compute floor).
- **single vs grouped adapters**: one shared adapter stack via the inline
  per-layer tap vs a mixed-tenant batch through the stacked adapter pool
  (jnp oracle path on CPU — interpret-mode Pallas timing is
  correctness-grade only, see ``lm_bench.kernel_vs_einsum``).

The scan path donates its KV caches off-CPU, so each timed repeat feeds it
a fresh copy of the prefill caches (a no-op-sized cost next to the decode).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.adapter_pool import AdapterPool
from repro.launch.serve import (
    _decode_scan_fn,
    _decode_step_fn,
    _prefill_fn,
    generate,
    generate_grouped,
)
from repro.models.lm import (
    init_lm,
    init_serve_caches,
    sample_token,
    serve_decode,
)


def _time(fn, repeats: int = 5) -> float:
    """Best-of-N wall time: this container's scheduler jitter swings a
    Python dispatch loop ~3x between runs, and the minimum is the standard
    noise-robust estimator for dispatch-bound microbenchmarks."""
    jax.block_until_ready(fn())  # compile / warm caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def decode_dispatch(
    arch: str = "stablelm-1.6b", b: int = 2, prompt: int = 16, gen: int = 64,
    unroll: int = 8,
) -> list[tuple[str, float]]:
    """Tokens/sec + dispatch counts: rebuild-per-call vs cached loop vs scan."""
    cfg = reduce_config(get_config(arch))
    params = init_lm(jax.random.key(0), cfg)
    prompts = jax.random.randint(jax.random.key(1), (b, prompt), 0, cfg.vocab_size)
    caches = init_serve_caches(cfg, b, prompt + gen)
    logits, caches0 = _prefill_fn(cfg)(params, prompts, caches, None)
    tok0, key = sample_token(logits, jax.random.key(2), 0.0)
    pos0 = jnp.asarray(prompt, jnp.int32)

    decode = _decode_step_fn(cfg)

    def run_loop(dec):
        tok, c = tok0, caches0
        out = []
        for i in range(gen):
            out.append(tok)
            lg, c = dec(params, tok, jnp.asarray(prompt + i, jnp.int32), c, None)
            tok, _ = sample_token(lg, key, 0.0)
        return jnp.concatenate(out, axis=1)

    def loop_cached():
        return run_loop(decode)

    def loop_rebuild():
        # Fresh jit wrapper per request == fresh trace + compile per request.
        dec = jax.jit(
            lambda p, t, pos, c, a: serve_decode(p, cfg, t, pos, c, adapters=a)
        )
        return run_loop(dec)

    scan_fn = _decode_scan_fn(cfg)

    def scan():
        # The scan jit donates its caches off-CPU; hand it a fresh copy per
        # repeat so caches0 survives (the copy is tiny next to gen steps).
        c = jax.tree.map(jnp.copy, caches0)
        toks, _ = scan_fn(
            params, tok0, pos0, c, key, None, None, None, gen, 0.0, unroll
        )
        return toks

    t_loop = _time(loop_cached)
    t_scan = _time(scan)
    t_rebuild = _time(loop_rebuild, repeats=1)
    toks = b * gen
    return [
        (f"serve/{arch}/loop_tok_s", toks / t_rebuild),
        (f"serve/{arch}/cached_loop_tok_s", toks / t_loop),
        (f"serve/{arch}/scan_tok_s", toks / t_scan),
        # Headline: scan vs the per-token Python loop this engine replaced
        # (the seed ``generate()``, which re-jitted every call). The cached
        # loop isolates the remaining dispatch-count win; on a quiet CPU
        # the shared per-step compute floor bounds that ratio near ~1.5-2x,
        # while under scheduler jitter the 'gen' sequential dispatches are
        # hit far harder than the single scan (tail-latency win).
        (f"serve/{arch}/scan_speedup_x", t_rebuild / t_scan),
        (f"serve/{arch}/scan_vs_cached_loop_x", t_loop / t_scan),
        (f"serve/{arch}/loop_decode_dispatches", float(gen)),
        (f"serve/{arch}/scan_decode_dispatches", 1.0),
        (f"serve/{arch}/scan_unroll", float(unroll)),
    ]


def grouped_adapters(
    arch: str = "stablelm-1.6b", b: int = 4, prompt: int = 16, gen: int = 32,
    n_tenants: int = 3, rank: int = 8, unroll: int = 8,
) -> list[tuple[str, float]]:
    """Single shared stack vs a mixed-tenant batch from the adapter pool."""
    cfg = reduce_config(get_config(arch))
    params = init_lm(jax.random.key(0), cfg)
    prompts = jax.random.randint(jax.random.key(1), (b, prompt), 0, cfg.vocab_size)
    sl = SL.SkipLoRAConfig(rank=rank)

    pool = AdapterPool(n_tenants + 1, cfg, rank)
    first = None
    for t in range(n_tenants):
        ad = SL.init_adapters(jax.random.key(10 + t), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(20 + t), ad["B"].shape) * 0.02
        pool.register(f"u{t}", ad)
        first = ad if first is None else first
    stack = SL.adapters_to_stack(first, cfg)
    idx = pool.lookup([None] + [f"u{i % n_tenants}" for i in range(1, b)])

    t_single = _time(
        lambda: generate(
            params, cfg, prompts, max_new=gen, adapters_stack=stack, unroll=unroll
        )
    )
    t_grouped = _time(
        lambda: generate_grouped(
            params, cfg, prompts, pool.pools(), idx, max_new=gen,
            use_kernel=False, unroll=unroll,
        )
    )
    toks = b * gen
    return [
        (f"serve/{arch}/single_adapter_tok_s", toks / t_single),
        (f"serve/{arch}/grouped_adapter_tok_s", toks / t_grouped),
        (f"serve/{arch}/grouped_overhead_x", t_grouped / t_single),
        (f"serve/{arch}/pool_tenants", float(len(pool))),
        (f"serve/{arch}/pool_MiB", pool.nbytes() / 2**20),
    ]
