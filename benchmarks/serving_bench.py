"""Trace-driven serving benchmark: continuous batching vs one-at-a-time.

Replays one Poisson-arrival request trace (mixed tenants including base
traffic, mixed sampling temperatures) through ``core.scheduler`` twice —
``mode="continuous"`` (the scheduler's point: staggered admission into a
shared live batch, freed rows recycled) and ``mode="sequential"`` (the
one-request-at-a-time baseline: same machinery, batch occupancy capped at
one) — and reports the SLO view: p50/p99 request latency and sustained
tok/s per mode, plus the PR's three correctness gates:

  - ``speedup_tokps``: continuous >= 2x sequential on the saturating trace
    (the acceptance bar);
  - ``temp0_bitwise_match``: every temperature-0 request produced the SAME
    tokens in both modes — a row admitted mid-decode next to strangers
    decodes exactly as it does alone (batch-row independence + matched
    geometry);
  - ``decode_retraces_after_warmup``: 0 — the trace's distinct
    temperatures all run through one compiled dispatch (temperature is
    traced, never a static; ``runtime.TRACE_COUNTS``).

  PYTHONPATH=src python -m benchmarks.serving_bench            # full
  PYTHONPATH=src python -m benchmarks.serving_bench --quick    # CI smoke

Writes ``BENCH_serving_slo.json`` (``--json``); CI uploads it next to the
runtime benches.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

TEMPERATURES = (0.0, 0.7, 1.0)


def _make_runtime(n_tenants: int, rank: int = 4):
    from repro.configs import get_config, reduce_config
    from repro.core import lm_skiplora as SL
    from repro.core.runtime import SessionRuntime
    from repro.models.lm import init_lm

    cfg = reduce_config(get_config("stablelm-1.6b"))
    params = init_lm(jax.random.key(0), cfg)
    sl = SL.SkipLoRAConfig(rank=rank)
    rt = SessionRuntime(
        cfg, sl, params, max_tenants=n_tenants, samples_per_tenant=1, seq=8
    )
    for t in range(n_tenants):
        ad = SL.init_adapters(jax.random.key(100 + t), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(200 + t), ad["B"].shape) * 0.02
        rt.pool.register(f"tenant-{t}", ad)
    return rt


def make_trace(n: int, *, lam: float, n_tenants: int, prompt_len: int,
               max_new: int, vocab: int, seed: int = 7) -> list[dict]:
    """``n`` requests with Poisson (exponential inter-arrival) times at rate
    ``lam``/s: tenant cycles through base + adapted tenants, temperature
    cycles through {0, 0.7, 1.0}, prompts are seeded-random at the fixed
    pad bucket so both replay modes see identical inputs."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / lam, size=n))
    prompts = rng.integers(0, vocab, size=(n, prompt_len), dtype=np.int32)
    trace = []
    for i in range(n):
        tenant = None if i % (n_tenants + 1) == 0 else f"tenant-{i % n_tenants}"
        trace.append({
            "arrival": float(arrivals[i]),
            "tenant": tenant,
            "temperature": TEMPERATURES[i % len(TEMPERATURES)],
            "prompt": prompts[i],
            "max_new": max_new,
        })
    return trace


def replay(rt, trace: list[dict], *, mode: str, max_batch: int,
           prompt_len: int, max_new: int, chunk: int) -> dict:
    """Replay the trace in real time: submit each request once the clock
    passes its arrival, pump the scheduler otherwise. Returns latencies,
    per-request tokens, and sustained tok/s over the makespan."""
    from repro.core.scheduler import RequestScheduler

    sched = RequestScheduler(
        rt, max_batch=max_batch, max_prompt=prompt_len, max_new_cap=max_new,
        admit_bucket=min(2, max_batch), inflight_per_tenant=max_batch,
        chunk=chunk, mode=mode,
    )
    reqs = []
    t0 = time.perf_counter()
    i = 0
    while len(sched._completed) < len(trace):
        now = time.perf_counter() - t0
        while i < len(trace) and trace[i]["arrival"] <= now:
            e = trace[i]
            reqs.append(sched.submit(
                e["tenant"], e["prompt"], max_new=e["max_new"],
                temperature=e["temperature"],
            ))
            i += 1
        if sched.step() == 0:
            if i < len(trace):
                time.sleep(min(trace[i]["arrival"] - now, 1e-3))
    makespan = time.perf_counter() - t0
    lat = np.asarray([r.latency for r in reqs])
    return {
        "makespan_s": makespan,
        "tok_per_s": sum(r.max_new for r in reqs) / makespan,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "dispatches": int(sched.counters["dispatch/admit"]
                          + sched.counters["dispatch/step"]),
        "quality": sched.quality_metrics(),
        "tokens": [r.result().tolist() for r in reqs],
    }


def quality_section(*, n_samples: int = 4, seq: int = 8, rounds: int = 3) -> dict:
    """Gate events on the serving surface: a control plane set up so every
    write-back regresses past the threshold — each adapt round is rejected,
    the rejection streak trips the automatic rollback, and the scheduler's
    ``quality_metrics()`` view carries the whole ledger (decisions, rollback
    counters, quarantine set) into the SLO payload next to latency."""
    import jax.numpy as jnp

    from repro.configs import get_config, reduce_config
    from repro.core import lm_skiplora as SL
    from repro.core.control_plane import ControlConfig
    from repro.core.runtime import SessionRuntime
    from repro.models.lm import init_lm

    cfg = reduce_config(get_config("stablelm-1.6b"))
    params = init_lm(jax.random.key(0), cfg)
    rt = SessionRuntime(
        cfg, SL.SkipLoRAConfig(rank=4), params, max_tenants=2,
        samples_per_tenant=rounds * n_samples, seq=seq,
        control=ControlConfig(holdout_every=2, threshold=-1.0, mode="reject",
                              auto_rollback_after=2),
    )
    rng = np.random.default_rng(5)
    names = ["qa", "qb"]
    for _ in range(rounds):
        for t in names:
            rt.ingest(
                t,
                jnp.asarray(rng.integers(0, cfg.vocab_size, (n_samples, seq))),
                jnp.asarray(rng.integers(0, cfg.vocab_size, (n_samples, seq))),
            )
        rt.adapt(names, epochs=1, key=jax.random.key(6))
    sched = rt.attach_scheduler(max_batch=2, max_prompt=seq, max_new_cap=8)
    prompts = rng.integers(0, cfg.vocab_size, (2, seq), dtype=np.int32)
    reqs = [rt.enqueue_serve(t, prompts[i], max_new=4)
            for i, t in enumerate([None, names[0]])]
    rt.drain()
    for r in reqs:
        r.result()
    return sched.quality_metrics()


def serving_slo(*, quick: bool = False, requests: int = 24, lam: float = 200.0,
                max_batch: int = 8, prompt_len: int = 8, max_new: int = 16,
                chunk: int = 4, n_tenants: int = 3) -> tuple[list, dict]:
    """The benchmark body: returns (csv rows, the JSON payload)."""
    from repro.core.runtime import TRACE_COUNTS

    if quick:
        requests, max_new, max_batch = 8, 8, 4
    rt = _make_runtime(n_tenants)
    vocab = rt.cfg.vocab_size
    trace = make_trace(
        requests, lam=lam, n_tenants=n_tenants, prompt_len=prompt_len,
        max_new=max_new, vocab=vocab,
    )
    # Warm both compiled dispatches (admit + step, shared across modes) so
    # the timed replays measure serving, not tracing — and so the
    # zero-retrace gate below can hold the counter flat across every
    # temperature in the trace.
    warm = make_trace(
        3, lam=1e6, n_tenants=n_tenants, prompt_len=prompt_len,
        max_new=max_new, vocab=vocab, seed=11,
    )
    for m in ("continuous", "sequential"):
        replay(rt, warm, mode=m, max_batch=max_batch, prompt_len=prompt_len,
               max_new=max_new, chunk=chunk)
    traces0 = TRACE_COUNTS["sched_step"] + TRACE_COUNTS["sched_admit"]

    cont = replay(rt, trace, mode="continuous", max_batch=max_batch,
                  prompt_len=prompt_len, max_new=max_new, chunk=chunk)
    seq = replay(rt, trace, mode="sequential", max_batch=max_batch,
                 prompt_len=prompt_len, max_new=max_new, chunk=chunk)
    retraces = (TRACE_COUNTS["sched_step"] + TRACE_COUNTS["sched_admit"]
                - traces0)

    temp0 = [i for i, e in enumerate(trace) if e["temperature"] == 0.0]
    bitwise = all(
        cont["tokens"][i] == seq["tokens"][i] for i in temp0
    )
    speedup = cont["tok_per_s"] / seq["tok_per_s"]
    # Three rounds minimum even for --quick: the first write-back per tenant
    # always accepts (nothing to protect), so the 2-rejection streak that
    # trips the automatic rollback needs rounds 2 and 3.
    quality = quality_section(rounds=3)
    payload = {
        "quality_events": quality,
        "requests": requests,
        "poisson_rate_per_s": lam,
        "max_batch": max_batch,
        "chunk": chunk,
        "temperatures": list(TEMPERATURES),
        "continuous": {k: v for k, v in cont.items() if k != "tokens"},
        "sequential": {k: v for k, v in seq.items() if k != "tokens"},
        "speedup_tokps": speedup,
        "temp0_bitwise_match": bool(bitwise),
        "temp0_requests_checked": len(temp0),
        "decode_retraces_after_warmup": int(retraces),
    }
    rows = [
        ("serving/continuous_tok_per_s", cont["tok_per_s"]),
        ("serving/sequential_tok_per_s", seq["tok_per_s"]),
        ("serving/speedup_tokps", speedup),
        ("serving/continuous_latency_p50_s", cont["latency_p50_s"]),
        ("serving/continuous_latency_p99_s", cont["latency_p99_s"]),
        ("serving/sequential_latency_p50_s", seq["latency_p50_s"]),
        ("serving/sequential_latency_p99_s", seq["latency_p99_s"]),
        ("serving/temp0_bitwise_match", 1.0 if bitwise else 0.0),
        ("serving/decode_retraces_after_warmup", float(retraces)),
        ("serving/gate_rejected", float(quality["gate"]["rejected"])),
        ("serving/gate_auto_rollbacks", float(quality["gate"]["auto_rollbacks"])),
    ]
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small trace, small batch")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--lam", type=float, default=200.0,
                    help="Poisson arrival rate (requests/s); the default "
                         "saturates the sequential baseline so the speedup "
                         "measures batching, not idle waiting")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--json", default="BENCH_serving_slo.json")
    args = ap.parse_args()

    rows, payload = serving_slo(
        quick=args.quick, requests=args.requests, lam=args.lam,
        max_batch=args.batch, prompt_len=args.prompt_len, max_new=args.gen,
        chunk=args.chunk,
    )
    print("name,value,derived")
    for k, v in rows:
        print(f"{k},{v:.4f},")
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.json}")
    if not payload["temp0_bitwise_match"]:
        raise SystemExit("temperature-0 tokens diverged between modes")
    if payload["decode_retraces_after_warmup"]:
        raise SystemExit(
            f"{payload['decode_retraces_after_warmup']} decode retraces "
            "across the trace's temperatures"
        )
    q = payload["quality_events"]["gate"]
    if q["rejected"] == 0 or q["auto_rollbacks"] == 0:
        raise SystemExit(
            "quality section produced no gate events "
            f"(rejected={q['rejected']}, auto_rollbacks={q['auto_rollbacks']})"
        )


if __name__ == "__main__":
    main()
