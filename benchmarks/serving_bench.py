"""Trace-driven serving benchmark: continuous batching vs one-at-a-time.

Replays one Poisson-arrival request trace (mixed tenants including base
traffic, mixed sampling temperatures) through ``core.scheduler`` twice —
``mode="continuous"`` (the scheduler's point: staggered admission into a
shared live batch, freed rows recycled) and ``mode="sequential"`` (the
one-request-at-a-time baseline: same machinery, batch occupancy capped at
one) — and reports the SLO view: p50/p99 request latency and sustained
tok/s per mode, plus the PR's three correctness gates:

  - ``speedup_tokps``: continuous >= 2x sequential on the saturating trace
    (the acceptance bar);
  - ``temp0_bitwise_match``: every temperature-0 request produced the SAME
    tokens in both modes — a row admitted mid-decode next to strangers
    decodes exactly as it does alone (batch-row independence + matched
    geometry);
  - ``decode_retraces_after_warmup``: 0 — the trace's distinct
    temperatures all run through one compiled dispatch (temperature is
    traced, never a static; ``runtime.TRACE_COUNTS``).

A second section (``prefix_share_section``; standalone via
``--prefix-share``) replays a shared-prefix trace with the scheduler's
paged-KV prefix reuse on vs off and gates bitwise token equality, the
pool's ref-count no-leak invariant, and (full tier) >= 1.5x tok/s from
skipping the shared prefill.

  PYTHONPATH=src python -m benchmarks.serving_bench            # full
  PYTHONPATH=src python -m benchmarks.serving_bench --quick    # CI smoke

Writes ``BENCH_serving_slo.json`` (``--json``); CI uploads it next to the
runtime benches.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

TEMPERATURES = (0.0, 0.7, 1.0)


def _make_runtime(n_tenants: int, rank: int = 4):
    from repro.configs import get_config, reduce_config
    from repro.core import lm_skiplora as SL
    from repro.core.runtime import SessionRuntime
    from repro.models.lm import init_lm

    cfg = reduce_config(get_config("stablelm-1.6b"))
    params = init_lm(jax.random.key(0), cfg)
    sl = SL.SkipLoRAConfig(rank=rank)
    rt = SessionRuntime(
        cfg, sl, params, max_tenants=n_tenants, samples_per_tenant=1, seq=8
    )
    for t in range(n_tenants):
        ad = SL.init_adapters(jax.random.key(100 + t), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(200 + t), ad["B"].shape) * 0.02
        rt.pool.register(f"tenant-{t}", ad)
    return rt


def make_trace(n: int, *, lam: float, n_tenants: int, prompt_len: int,
               max_new: int, vocab: int, seed: int = 7) -> list[dict]:
    """``n`` requests with Poisson (exponential inter-arrival) times at rate
    ``lam``/s: tenant cycles through base + adapted tenants, temperature
    cycles through {0, 0.7, 1.0}, prompts are seeded-random at the fixed
    pad bucket so both replay modes see identical inputs."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / lam, size=n))
    prompts = rng.integers(0, vocab, size=(n, prompt_len), dtype=np.int32)
    trace = []
    for i in range(n):
        tenant = None if i % (n_tenants + 1) == 0 else f"tenant-{i % n_tenants}"
        trace.append({
            "arrival": float(arrivals[i]),
            "tenant": tenant,
            "temperature": TEMPERATURES[i % len(TEMPERATURES)],
            "prompt": prompts[i],
            "max_new": max_new,
        })
    return trace


def replay(rt, trace: list[dict], *, mode: str, max_batch: int,
           prompt_len: int, max_new: int, chunk: int,
           prefix_reuse: bool = False, kv_block=None) -> dict:
    """Replay the trace in real time: submit each request once the clock
    passes its arrival, pump the scheduler otherwise. Returns latencies,
    per-request tokens, and sustained tok/s over the makespan."""
    from repro.core.scheduler import RequestScheduler

    sched = RequestScheduler(
        rt, max_batch=max_batch, max_prompt=prompt_len, max_new_cap=max_new,
        admit_bucket=min(2, max_batch), inflight_per_tenant=max_batch,
        chunk=chunk, mode=mode, prefix_reuse=prefix_reuse, kv_block=kv_block,
    )
    reqs = []
    t0 = time.perf_counter()
    i = 0
    while len(sched._completed) < len(trace):
        now = time.perf_counter() - t0
        while i < len(trace) and trace[i]["arrival"] <= now:
            e = trace[i]
            reqs.append(sched.submit(
                e["tenant"], e["prompt"], max_new=e["max_new"],
                temperature=e["temperature"],
            ))
            i += 1
        if sched.step() == 0:
            if i < len(trace):
                time.sleep(min(trace[i]["arrival"] - now, 1e-3))
    makespan = time.perf_counter() - t0
    lat = np.asarray([r.latency for r in reqs])
    return {
        "makespan_s": makespan,
        "tok_per_s": sum(r.max_new for r in reqs) / makespan,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "dispatches": int(sched.counters["dispatch/admit"]
                          + sched.counters["dispatch/admit_reuse"]
                          + sched.counters["dispatch/step"]),
        "quality": sched.quality_metrics(),
        "prefix": sched.prefix_metrics(),
        "tokens": [r.result().tolist() for r in reqs],
    }


def make_prefix_trace(n: int, *, share_len: int, tail_len: int, max_new: int,
                      vocab: int, seed: int = 13) -> list[dict]:
    """``n`` simultaneous temp-0 base-traffic requests sharing a
    ``share_len``-token prefix, each with a distinct ``tail_len``-token
    random suffix — the shared-system-prompt traffic shape that paged
    prefix reuse exists for."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, size=share_len, dtype=np.int32)
    return [
        {
            "arrival": 0.0,
            "tenant": None,
            "temperature": 0.0,
            "prompt": np.concatenate(
                [shared, rng.integers(0, vocab, size=tail_len, dtype=np.int32)]
            ),
            "max_new": max_new,
        }
        for _ in range(n)
    ]


def prefix_share_section(*, quick: bool = False, requests: int = 8,
                         share: int = 448, tail: int = 64, max_new: int = 4,
                         max_batch: int = 4, chunk: int = 2,
                         kv_block: int = 32) -> tuple[list, dict]:
    """Shared-prefix trace, reuse-on vs reuse-off, three gates:

      - ``prefix_bitwise_match``: identical tokens either way (everything
        is temperature 0) — reused KV bytes ARE the recomputed bytes;
      - ``prefix_ref_leaks``: after the reuse-on drain every pool block is
        owned by exactly one radix node (no in-flight refs survive);
      - ``prefix_speedup_tokps`` >= 1.5 (full tier only): skipping the
        shared 87.5% of each prefill must show up in sustained tok/s. The
        full tier uses long prompts (448 shared + 64 tail) so the prefill
        this section is about dominates the makespan; the quick tier keeps
        prompts short (decode/dispatch-dominated, speedup ~1x) and gates
        only correctness: bitwise match, zero leaks, blocks actually reused.

    Reports analytic prefill-FLOPs saved (``launch.flops.reuse_saved_flops``
    over the reused tokens) and blocks-reused columns alongside."""
    from repro.core.runtime import TRACE_COUNTS
    from repro.launch.flops import model_flops, reuse_saved_flops

    if quick:
        requests, share, tail, max_new, kv_block = 6, 24, 8, 4, 8
    plen = share + tail
    rt = _make_runtime(2)
    vocab = rt.cfg.vocab_size
    trace = make_prefix_trace(requests, share_len=share, tail_len=tail,
                              max_new=max_new, vocab=vocab, seed=13)
    warm = make_prefix_trace(min(requests, 4), share_len=share, tail_len=tail,
                             max_new=max_new, vocab=vocab, seed=17)
    kw = dict(mode="continuous", max_batch=max_batch, prompt_len=plen,
              max_new=max_new, chunk=chunk, kv_block=kv_block)
    for reuse in (True, False):
        rt.reset_prefix_cache()
        replay(rt, warm, prefix_reuse=reuse, **kw)
    keys = ("sched_step", "sched_admit", "sched_admit_reuse")
    traces0 = sum(TRACE_COUNTS[k] for k in keys)

    rt.reset_prefix_cache()
    on = replay(rt, trace, prefix_reuse=True, **kw)
    leak = ""
    try:
        rt.check_prefix_no_leaks()     # BEFORE reset: refs must be clean now
    except RuntimeError as err:
        leak = str(err)
    rt.reset_prefix_cache()
    off = replay(rt, trace, prefix_reuse=False, **kw)
    retraces = sum(TRACE_COUNTS[k] for k in keys) - traces0

    bitwise = on["tokens"] == off["tokens"]
    speedup = on["tok_per_s"] / off["tok_per_s"]
    pm = on["prefix"]
    hits = int(pm.get("hits", 0))
    reused_tokens = int(pm.get("tokens_reused", 0))
    saved = (
        hits * reuse_saved_flops(rt.cfg, reused_tokens // hits) if hits else 0.0
    )
    dense_prefill = requests * model_flops(rt.cfg, (1, plen), "prefill")
    payload = {
        "requests": requests,
        "share_tokens": share,
        "tail_tokens": tail,
        "share_fraction": share / plen,
        "kv_block": kv_block,
        "reuse_on": {k: v for k, v in on.items() if k != "tokens"},
        "reuse_off": {k: v for k, v in off.items()
                      if k not in ("tokens", "prefix")},
        "prefix_speedup_tokps": speedup,
        "prefix_bitwise_match": bool(bitwise),
        "prefix_ref_leaks": leak,
        "prefill_flops_saved": saved,
        "prefill_flops_dense": dense_prefill,
        "prefill_flops_saved_frac": saved / dense_prefill,
        "blocks_reused": int(pm.get("blocks_reused", 0)),
        "retraces_after_warmup": int(retraces),
    }
    rows = [
        ("serving/prefix_reuse_tok_per_s", on["tok_per_s"]),
        ("serving/prefix_dense_tok_per_s", off["tok_per_s"]),
        ("serving/prefix_speedup_tokps", speedup),
        ("serving/prefix_bitwise_match", 1.0 if bitwise else 0.0),
        ("serving/prefix_ref_leaks", 0.0 if not leak else 1.0),
        ("serving/prefix_blocks_reused", float(payload["blocks_reused"])),
        ("serving/prefill_flops_saved", saved),
        ("serving/prefill_flops_saved_frac",
         payload["prefill_flops_saved_frac"]),
    ]
    return rows, payload


def quality_section(*, n_samples: int = 4, seq: int = 8, rounds: int = 3) -> dict:
    """Gate events on the serving surface: a control plane set up so every
    write-back regresses past the threshold — each adapt round is rejected,
    the rejection streak trips the automatic rollback, and the scheduler's
    ``quality_metrics()`` view carries the whole ledger (decisions, rollback
    counters, quarantine set) into the SLO payload next to latency."""
    import jax.numpy as jnp

    from repro.configs import get_config, reduce_config
    from repro.core import lm_skiplora as SL
    from repro.core.control_plane import ControlConfig
    from repro.core.runtime import SessionRuntime
    from repro.models.lm import init_lm

    cfg = reduce_config(get_config("stablelm-1.6b"))
    params = init_lm(jax.random.key(0), cfg)
    rt = SessionRuntime(
        cfg, SL.SkipLoRAConfig(rank=4), params, max_tenants=2,
        samples_per_tenant=rounds * n_samples, seq=seq,
        control=ControlConfig(holdout_every=2, threshold=-1.0, mode="reject",
                              auto_rollback_after=2),
    )
    rng = np.random.default_rng(5)
    names = ["qa", "qb"]
    for _ in range(rounds):
        for t in names:
            rt.ingest(
                t,
                jnp.asarray(rng.integers(0, cfg.vocab_size, (n_samples, seq))),
                jnp.asarray(rng.integers(0, cfg.vocab_size, (n_samples, seq))),
            )
        rt.adapt(names, epochs=1, key=jax.random.key(6))
    sched = rt.attach_scheduler(max_batch=2, max_prompt=seq, max_new_cap=8)
    prompts = rng.integers(0, cfg.vocab_size, (2, seq), dtype=np.int32)
    reqs = [rt.enqueue_serve(t, prompts[i], max_new=4)
            for i, t in enumerate([None, names[0]])]
    rt.drain()
    for r in reqs:
        r.result()
    return sched.quality_metrics()


def serving_slo(*, quick: bool = False, requests: int = 24, lam: float = 200.0,
                max_batch: int = 8, prompt_len: int = 8, max_new: int = 16,
                chunk: int = 4, n_tenants: int = 3) -> tuple[list, dict]:
    """The benchmark body: returns (csv rows, the JSON payload)."""
    from repro.core.runtime import TRACE_COUNTS

    if quick:
        requests, max_new, max_batch = 8, 8, 4
    rt = _make_runtime(n_tenants)
    vocab = rt.cfg.vocab_size
    trace = make_trace(
        requests, lam=lam, n_tenants=n_tenants, prompt_len=prompt_len,
        max_new=max_new, vocab=vocab,
    )
    # Warm both compiled dispatches (admit + step, shared across modes) so
    # the timed replays measure serving, not tracing — and so the
    # zero-retrace gate below can hold the counter flat across every
    # temperature in the trace.
    warm = make_trace(
        3, lam=1e6, n_tenants=n_tenants, prompt_len=prompt_len,
        max_new=max_new, vocab=vocab, seed=11,
    )
    for m in ("continuous", "sequential"):
        replay(rt, warm, mode=m, max_batch=max_batch, prompt_len=prompt_len,
               max_new=max_new, chunk=chunk)
    traces0 = TRACE_COUNTS["sched_step"] + TRACE_COUNTS["sched_admit"]

    cont = replay(rt, trace, mode="continuous", max_batch=max_batch,
                  prompt_len=prompt_len, max_new=max_new, chunk=chunk)
    seq = replay(rt, trace, mode="sequential", max_batch=max_batch,
                 prompt_len=prompt_len, max_new=max_new, chunk=chunk)
    retraces = (TRACE_COUNTS["sched_step"] + TRACE_COUNTS["sched_admit"]
                - traces0)

    temp0 = [i for i, e in enumerate(trace) if e["temperature"] == 0.0]
    bitwise = all(
        cont["tokens"][i] == seq["tokens"][i] for i in temp0
    )
    speedup = cont["tok_per_s"] / seq["tok_per_s"]
    # Three rounds minimum even for --quick: the first write-back per tenant
    # always accepts (nothing to protect), so the 2-rejection streak that
    # trips the automatic rollback needs rounds 2 and 3.
    quality = quality_section(rounds=3)
    payload = {
        "quality_events": quality,
        "requests": requests,
        "poisson_rate_per_s": lam,
        "max_batch": max_batch,
        "chunk": chunk,
        "temperatures": list(TEMPERATURES),
        "continuous": {k: v for k, v in cont.items() if k != "tokens"},
        "sequential": {k: v for k, v in seq.items() if k != "tokens"},
        "speedup_tokps": speedup,
        "temp0_bitwise_match": bool(bitwise),
        "temp0_requests_checked": len(temp0),
        "decode_retraces_after_warmup": int(retraces),
    }
    if not quick:
        # Nightly payload carries the shared-prefix section next to the
        # classic cont-vs-seq comparison; the quick tier runs it as its own
        # CI step (--quick --prefix-share) to keep the smoke fast.
        prows, ppayload = prefix_share_section(quick=False)
        payload["prefix_share"] = ppayload
    else:
        prows = []
    rows = [
        ("serving/continuous_tok_per_s", cont["tok_per_s"]),
        ("serving/sequential_tok_per_s", seq["tok_per_s"]),
        ("serving/speedup_tokps", speedup),
        ("serving/continuous_latency_p50_s", cont["latency_p50_s"]),
        ("serving/continuous_latency_p99_s", cont["latency_p99_s"]),
        ("serving/sequential_latency_p50_s", seq["latency_p50_s"]),
        ("serving/sequential_latency_p99_s", seq["latency_p99_s"]),
        ("serving/temp0_bitwise_match", 1.0 if bitwise else 0.0),
        ("serving/decode_retraces_after_warmup", float(retraces)),
        ("serving/gate_rejected", float(quality["gate"]["rejected"])),
        ("serving/gate_auto_rollbacks", float(quality["gate"]["auto_rollbacks"])),
    ]
    return rows + prows, payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small trace, small batch")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--lam", type=float, default=200.0,
                    help="Poisson arrival rate (requests/s); the default "
                         "saturates the sequential baseline so the speedup "
                         "measures batching, not idle waiting")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--prefix-share", action="store_true",
                    help="run ONLY the shared-prefix reuse section (the "
                         "quick tier's per-push smoke)")
    ap.add_argument("--json", default="BENCH_serving_slo.json")
    args = ap.parse_args()

    if args.prefix_share:
        rows, ppayload = prefix_share_section(quick=args.quick)
        print("name,value,derived")
        for k, v in rows:
            print(f"{k},{v:.4f},")
        with open(args.json, "w") as f:
            json.dump({"prefix_share": ppayload}, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
        _gate_prefix(ppayload, speedup_bar=None if args.quick else 1.5)
        return

    rows, payload = serving_slo(
        quick=args.quick, requests=args.requests, lam=args.lam,
        max_batch=args.batch, prompt_len=args.prompt_len, max_new=args.gen,
        chunk=args.chunk,
    )
    print("name,value,derived")
    for k, v in rows:
        print(f"{k},{v:.4f},")
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.json}")
    if not payload["temp0_bitwise_match"]:
        raise SystemExit("temperature-0 tokens diverged between modes")
    if payload["decode_retraces_after_warmup"]:
        raise SystemExit(
            f"{payload['decode_retraces_after_warmup']} decode retraces "
            "across the trace's temperatures"
        )
    q = payload["quality_events"]["gate"]
    if q["rejected"] == 0 or q["auto_rollbacks"] == 0:
        raise SystemExit(
            "quality section produced no gate events "
            f"(rejected={q['rejected']}, auto_rollbacks={q['auto_rollbacks']})"
        )
    if "prefix_share" in payload:
        _gate_prefix(payload["prefix_share"], speedup_bar=1.5)


def _gate_prefix(ps: dict, *, speedup_bar) -> None:
    """Shared-prefix acceptance gates: bitwise + no-leak always; the
    >= 1.5x tok/s bar only on the full tier (``speedup_bar=None`` skips —
    the quick smoke's trace is too small to measure throughput)."""
    if not ps["prefix_bitwise_match"]:
        raise SystemExit("prefix reuse changed temperature-0 tokens")
    if ps["prefix_ref_leaks"]:
        raise SystemExit(f"kv pool ref leak: {ps['prefix_ref_leaks']}")
    if ps["blocks_reused"] == 0:
        raise SystemExit("shared-prefix trace reused zero blocks")
    if speedup_bar is not None and ps["prefix_speedup_tokps"] < speedup_bar:
        raise SystemExit(
            f"prefix reuse speedup {ps['prefix_speedup_tokps']:.2f}x "
            f"< {speedup_bar}x"
        )


if __name__ == "__main__":
    main()
