"""End-to-end driver: Skip2-LoRA fine-tune a ~100M-parameter LM.

Builds a 12-layer / d=512 stablelm-family model (~100M params with its 100k
vocab), runs Algorithm 1 for several hundred steps — one populate epoch that
fills the activation cache, then cached epochs with ZERO backbone compute —
and reports the loss curve and the measured cached-epoch speedup. Each epoch
phase is one ``jax.lax.scan`` dispatch (see DESIGN.md §2), so the wall time
measures the paper's arithmetic rather than Python dispatch overhead.

  PYTHONPATH=src python examples/finetune_lm.py            # ~100M, slower
  PYTHONPATH=src python examples/finetune_lm.py --small    # CI-sized
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import lm_skiplora as SL
from repro.data.pipeline import DataConfig, epoch_permutation, make_pipeline
from repro.models.lm import init_lm
from repro.optim.optimizers import adamw


def build_100m_config(small: bool):
    base = get_config("stablelm-1.6b")
    if small:
        return dataclasses.replace(
            base, n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
            d_ff=352, vocab_size=2048, dtype="float32",
        )
    # ~100M: 14L x 576d x SwiGLU(1536) + 50k x 576 embeddings (untied x2).
    return dataclasses.replace(
        base, n_layers=14, d_model=576, n_heads=8, n_kv_heads=8,
        d_ff=1536, vocab_size=50304, dtype="float32",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--samples", type=int, default=0, help="0 -> default")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=0, help="0 -> default")
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--mode", default="full", choices=["full", "int8", "freeze_a"])
    args = ap.parse_args()

    cfg = build_100m_config(args.small)
    samples = args.samples or (32 if args.small else 64)
    seq = args.seq or (64 if args.small else 256)
    sl = SL.SkipLoRAConfig(rank=args.rank, mode=args.mode, cache_dtype="float32")
    steps_per_epoch = samples // args.batch
    print(
        f"model: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab_size} "
        f"params={cfg.param_count()/1e6:.1f}M | mode={sl.mode} rank={sl.rank} | "
        f"{args.epochs} epochs x {steps_per_epoch} steps | "
        f"cache {SL.cache_nbytes_per_sample(cfg, sl, seq)*samples/2**20:.1f} MiB"
    )

    params = init_lm(jax.random.key(0), cfg)
    adapters = SL.init_adapters(jax.random.key(1), cfg, sl)
    trainable, static = SL.split_trainable(adapters, sl)
    opt = adamw(2e-3)
    opt_state = opt.init(trainable)

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=args.batch, num_samples=samples)
    store, _ = make_pipeline(dcfg)
    cache = SL.init_lm_cache(samples, cfg, sl, seq)

    populate_epoch = SL.make_populate_epoch(cfg, sl, opt)
    cached_epoch = SL.make_cached_epoch(cfg, sl, opt)

    # Stage the fine-tune set once; every epoch is then a single dispatch.
    import numpy as np

    staged = store.batch(np.arange(samples))
    tokens = jnp.asarray(staged["tokens"])
    labels = jnp.asarray(staged["labels"])

    times = []
    for epoch in range(args.epochs):
        perm = epoch_permutation(0, 0, samples)
        idx_mat = jnp.asarray(
            perm[: steps_per_epoch * args.batch].reshape(steps_per_epoch, args.batch)
        )
        t0 = time.perf_counter()
        if epoch == 0:
            trainable, opt_state, cache, ls = populate_epoch(
                params, trainable, static, opt_state, cache, tokens, labels, idx_mat)
        else:
            trainable, opt_state, ls = cached_epoch(
                params, trainable, static, opt_state, cache, idx_mat)
        loss = ls[-1]
        jax.block_until_ready(loss)
        dt = time.perf_counter() - t0
        times.append(dt)
        kind = "populate" if epoch == 0 else "cached"
        print(f"epoch {epoch:2d} [{kind:8s}] loss={float(loss):.4f} {dt:6.2f}s")

    if len(times) > 2:
        cached_avg = sum(times[1:]) / len(times[1:])
        print(f"\ncached epoch speedup vs populate: {times[0]/cached_avg:.1f}x "
              f"(backbone forward fully skipped after epoch 0)")


if __name__ == "__main__":
    main()
