"""Quickstart: the paper end-to-end at its native scale (runs in ~2 min on CPU).

Reproduces the paper's storyline on a synthetic drifted dataset:
  1. pre-train a 3-layer DNN (256-96-96-3, BN+ReLU) on the pre-drift data;
  2. watch accuracy collapse on the drifted test set (Table 3 "Before");
  3. fine-tune with all eight methods (Table 4);
  4. time a train batch for each method and for the Skip2-LoRA cached fast
     path (Tables 6/7) — Skip-Cache makes the cached epochs ~free.

  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp

from repro.core import methods as M
from repro.core import skip_cache as C
from repro.core.finetune import _cached_step, _populate_step, evaluate, finetune
from repro.data.synthetic import make_drifted_dataset
from repro.models.mlp import MLPConfig, accuracy, mlp_forward, pretrain


def main() -> None:
    cfg = MLPConfig(in_dim=256, hidden_dim=96, out_dim=3, lora_rank=4)
    ds = make_drifted_dataset(jax.random.key(0), "damage1")

    print("=== 1. pre-train on the pre-drift distribution")
    bb = pretrain(jax.random.key(1), cfg, ds.x_pre, ds.y_pre, epochs=30, lr=0.05)
    logits, _ = mlp_forward(bb, ds.x_pre, cfg)
    print(f"  pre-drift train accuracy : {accuracy(logits, ds.y_pre):.3f}")

    print("=== 2. data drift hits (Table 3 'Before')")
    logits, _ = mlp_forward(bb, ds.x_test, cfg)
    print(f"  drifted test accuracy    : {accuracy(logits, ds.y_test):.3f}")

    print("=== 3. on-device fine-tuning, all eight methods (Table 4)")
    for method in M.METHODS:
        t0 = time.perf_counter()
        res = finetune(jax.random.key(2), method, cfg, bb, ds.x_ft, ds.y_ft,
                       epochs=40, batch_size=20, lr=0.05)
        acc = evaluate(method, cfg, res, ds.x_test, ds.y_test)
        print(f"  {method:12s} acc={acc:.3f}  wall={time.perf_counter()-t0:5.2f}s")

    print("=== 4. why Skip2-LoRA is fast: per-batch step time (Tables 6/7)")
    xb, yb = ds.x_ft[:20], ds.y_ft[:20]

    def timeit(f, n=100):
        jax.block_until_ready(f())
        t0 = time.perf_counter()
        for _ in range(n):
            out = f()
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n * 1e3

    trainable, frozen = M.init_method(jax.random.key(3), cfg, bb, "lora_all")
    t_lora_all = timeit(lambda: M.train_step("lora_all", cfg, trainable, frozen, xb, yb, 0.05))

    trainable, frozen = M.init_method(jax.random.key(3), cfg, bb, "skip2_lora")
    cache = C.cache_for_mlp(len(ds.x_ft), cfg.dims)
    pop = _populate_step(cfg)
    idx = jnp.arange(20)
    trainable, cache, _ = pop(trainable, frozen, cache, idx, xb, yb, 0.05)
    cached = _cached_step(cfg)
    t_cached = timeit(lambda: cached(trainable, cache, idx, xb, yb, 0.05))

    print(f"  LoRA-All train@batch      : {t_lora_all:.3f} ms")
    print(f"  Skip2-LoRA cached@batch   : {t_cached:.3f} ms")
    print(f"  reduction                 : {100 * (1 - t_cached / t_lora_all):.1f}% "
          f"(paper: ~90%)")

    print("=== 5. fused epoch loop (DESIGN.md §2): whole epochs in one dispatch")
    # At paper scale the per-batch step is dominated by Python dispatch, not
    # arithmetic; the lax.scan epoch loop amortises it away.
    from repro.core.finetune import epoch_index_matrix, make_skip2_epoch_fns

    trainable, frozen = M.init_method(jax.random.key(3), cfg, bb, "skip2_lora")
    cache = C.cache_for_mlp(len(ds.x_ft), cfg.dims)
    # donate=False: timeit() re-invokes the epoch on the same carry arrays.
    populate_epoch, cached_epoch = make_skip2_epoch_fns(cfg, donate=False)
    idx_mat = epoch_index_matrix(jax.random.key(5), len(ds.x_ft), 20)
    trainable, cache, ls = populate_epoch(
        trainable, frozen, cache, ds.x_ft, ds.y_ft, idx_mat, 0.05)  # compile
    jax.block_until_ready(ls)

    steps = int(idx_mat.shape[0])

    def loop_epoch():
        t, last = trainable, None
        for s in range(steps):
            idx = idx_mat[s]
            t, last = cached(t, cache, idx, ds.x_ft[idx], ds.y_ft[idx], 0.05)
        return last

    t_loop = timeit(loop_epoch, n=20)
    t_scan = timeit(lambda: cached_epoch(
        trainable, cache, ds.x_ft, ds.y_ft, idx_mat, 0.05)[1], n=20)
    print(f"  cached epoch, {steps} Python dispatches: {t_loop:.3f} ms")
    print(f"  cached epoch, 1 scan dispatch          : {t_scan:.3f} ms")
    print(f"  dispatch amortisation                  : {t_loop / t_scan:.1f}x")


if __name__ == "__main__":
    main()
