"""A continual-learning session: serve, ingest, adapt, serve — one engine.

The paper's deployment loop end to end (DESIGN.md §9): a fleet of devices
serves from a shared ``AdapterPool`` while each device's freshly collected
samples flow into its skip-cache partition; a periodic grouped fine-tune
advances every tenant's adapters with ZERO backbone compute and writes
them back into the live pool mid-session.

This example runs two tenants through the full loop and shows the three
properties that make the runtime coherent:

  1. ingestion doubles as serving — the populate forward returns adapted
     last-position logits while writing the cache;
  2. an ``adapt`` is visible to the very next ``serve`` (the write-back is
     an in-place donated pool update, and its slot is pinned against LRU
     churn);
  3. the interleaved trajectory IS the offline ``fleet_finetune``
     trajectory — bitwise, on the kernel path (§9 parity argument).

  PYTHONPATH=src python examples/runtime_session.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import fleet_finetune as FF
from repro.core import lm_skiplora as SL
from repro.core.runtime import SessionRuntime
from repro.models.lm import init_lm


def main() -> None:
    cfg = reduce_config(get_config("stablelm-1.6b"))
    sl = SL.SkipLoRAConfig(rank=8, mode="full", cache_dtype="float32",
                           use_fused_kernel=True)
    params = init_lm(jax.random.key(0), cfg)
    n_t, n_per, seq, bpt, epochs = 2, 8, 16, 4, 3

    rt = SessionRuntime(
        cfg, sl, params, max_tenants=n_t, samples_per_tenant=n_per,
        seq=seq, lr=1e-2, use_kernel=True,
    )
    prompts = jax.random.randint(jax.random.key(1), (n_t, 10), 0, cfg.vocab_size)
    tokens = jax.random.randint(jax.random.key(2), (n_t, n_per, seq), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.key(3), (n_t, n_per, seq), 0, cfg.vocab_size)

    # serve: nobody fine-tuned yet -> base model for everyone.
    base = rt.serve([None] * n_t, prompts, max_new=8)
    print(f"serve (base)  : {base.shape} tokens")

    # ingest: each device's collected batches; logits come back per batch.
    for t in range(n_t):
        for lo in range(0, n_per, bpt):
            logits = rt.ingest(f"device-{t}", tokens[t, lo:lo + bpt],
                               labels[t, lo:lo + bpt])
    print(f"ingest        : {n_t * n_per} rows cached "
          f"(+ {logits.shape} logits per batch, serving for free)")

    # adapt: grouped cached epochs, write-back + pin, ready to serve.
    out = rt.adapt(epochs=epochs, batch_per_tenant=bpt, key=jax.random.key(4))
    mean0 = float(np.mean([out["losses"][f"device-{t}"][0] for t in range(n_t)]))
    mean1 = float(np.mean([out["losses"][f"device-{t}"][-1] for t in range(n_t)]))
    print(f"adapt         : {epochs} epochs on the {out['path']} path, "
          f"mean loss {mean0:.4f} -> {mean1:.4f}, pinned={rt.pool.pinned()}")

    # serve again: same compiled decode entry, now with trained slots.
    adapted = rt.serve([f"device-{t}" for t in range(n_t)], prompts, max_new=8)
    changed = float(jnp.mean((adapted != base).astype(jnp.float32)))
    print(f"serve (tuned) : {adapted.shape} tokens, "
          f"{changed:.0%} of tokens steered by the adapters")

    # parity: the interleaved session == the offline fleet trainer, bitwise.
    ref = FF.fleet_finetune(
        jax.random.key(4), cfg, sl, params, tokens, labels,
        epochs=epochs, batch_per_tenant=bpt, lr=1e-2, use_kernel=True,
    )
    exact = all(
        np.array_equal(np.asarray(rt.tenant(f"device-{t}").adapters[k]),
                       np.asarray(ref.adapters[k][t]))
        for t in range(n_t) for k in ("A", "B")
    )
    print(f"offline parity: interleaved == fleet_finetune bitwise? {exact}")


if __name__ == "__main__":
    main()
