"""Serve a model with Skip-LoRA adapters attached (post-fine-tune deploy).

The skip topology can't be merged into the backbone (each adapter connects
layer-k input to the final output), so serving applies a running skip-sum —
cost 2*L*R*(D+D) MACs/token, <0.1% of a block forward. This example batches
requests, prefils, decodes with and without adapters, and checks the
adapter path changes logits while the base path is untouched.

  PYTHONPATH=src python examples/serve_adapted.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.launch.serve import generate
from repro.models.lm import init_lm


def main() -> None:
    cfg = reduce_config(get_config("gemma2-9b"))  # exercises softcaps + local/global
    params = init_lm(jax.random.key(0), cfg)

    sl = SL.SkipLoRAConfig(rank=8)
    adapters = SL.init_adapters(jax.random.key(1), cfg, sl)
    # Pretend we fine-tuned: give B a nonzero value.
    adapters["B"] = jax.random.normal(jax.random.key(2), adapters["B"].shape) * 0.02
    stack = SL.adapters_to_stack(adapters, cfg)

    batch, prompt_len, gen = 4, 24, 12
    prompts = jax.random.randint(jax.random.key(3), (batch, prompt_len), 0, cfg.vocab_size)

    t0 = time.perf_counter()
    base = generate(params, cfg, prompts, max_new=gen)
    t_base = time.perf_counter() - t0

    t0 = time.perf_counter()
    adapted = generate(params, cfg, prompts, max_new=gen, adapters_stack=stack)
    t_adapted = time.perf_counter() - t0

    diff = float(jnp.mean((base != adapted).astype(jnp.float32)))
    print(f"base     : {base[0, :10].tolist()}  ({t_base:.2f}s)")
    print(f"adapted  : {adapted[0, :10].tolist()}  ({t_adapted:.2f}s)")
    print(f"token divergence rate: {diff:.2f} (adapters steer the model)")
    print(f"adapter overhead: {(t_adapted / t_base - 1) * 100:+.1f}% wall "
          "(incl. compile; per-token cost is <0.1% of a block)")


if __name__ == "__main__":
    main()
