"""Multi-tenant serving of Skip-LoRA adapters (post-fine-tune deploy).

The skip topology can't be merged into the backbone (each adapter connects
layer-k input to the final output), so serving always pays a running
skip-sum. At fleet scale every request row belongs to a different user's
on-device fine-tune, so the flow is (DESIGN.md §7):

  1. register each tenant's fine-tuned stack in an ``AdapterPool``
     (slot-based, LRU-evicting, optionally int8-compressed);
  2. ``pool.lookup`` the batch's tenants into per-row slot indices
     (``None`` -> the pinned zero slot = base model);
  3. ``generate_grouped``: ONE backbone prefill + ONE scan-fused decode
     dispatch, the per-row skip-sums gathered from the pool by the grouped
     kernel (Pallas on TPU; jnp oracle path here on CPU).

This example registers three pretend tenants, serves a mixed batch
(base + three different adapters) in one call, and checks each row's
tokens match single-tenant serving of the same adapter stack.

  PYTHONPATH=src python examples/serve_adapted.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.adapter_pool import AdapterPool
from repro.launch.serve import generate, generate_grouped
from repro.models.lm import init_lm


def main() -> None:
    cfg = reduce_config(get_config("gemma2-9b"))  # exercises softcaps + local/global
    params = init_lm(jax.random.key(0), cfg)
    rank = 8

    # Pretend three users fine-tuned on-device: give each B a nonzero value.
    pool = AdapterPool(8, cfg, rank)
    sl = SL.SkipLoRAConfig(rank=rank)
    stacks = {}
    for t in range(3):
        ad = SL.init_adapters(jax.random.key(10 + t), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(20 + t), ad["B"].shape) * 0.02
        pool.register(f"user-{t}", ad)
        stacks[f"user-{t}"] = SL.adapters_to_stack(ad, cfg)

    batch, prompt_len, gen = 4, 24, 12
    prompts = jax.random.randint(
        jax.random.key(3), (batch, prompt_len), 0, cfg.vocab_size
    )

    # One mixed batch: row 0 serves the base model via the zero slot.
    who = [None, "user-0", "user-1", "user-2"]
    idx = pool.lookup(who)
    t0 = time.perf_counter()
    mixed = generate_grouped(
        params, cfg, prompts, pool.pools(), idx, max_new=gen, use_kernel=False
    )
    t_mixed = time.perf_counter() - t0

    # Reference: serve each row alone under its own stack.
    agree = 0
    for row, tenant in enumerate(who):
        stack = None if tenant is None else stacks[tenant]
        solo = generate(
            params, cfg, prompts[row : row + 1], max_new=gen, adapters_stack=stack
        )
        agree += int(jnp.array_equal(mixed[row], solo[0]))

    base_row, adapted_rows = mixed[0], mixed[1:]
    diverged = float(
        jnp.mean((adapted_rows != jnp.broadcast_to(base_row, adapted_rows.shape))
                 .astype(jnp.float32))
    )
    print(f"mixed batch {mixed.shape} in {t_mixed:.2f}s "
          f"(2 dispatches incl. compile; pool {pool.nbytes() / 2**20:.2f} MiB, "
          f"{len(pool)} tenants)")
    print(f"rows matching single-tenant serving: {agree}/{batch}")
    print(f"adapter-vs-base token divergence rate: {diverged:.2f} "
          "(adapters steer the model)")


if __name__ == "__main__":
    main()
