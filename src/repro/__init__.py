"""repro: Skip2-LoRA — production-grade JAX fine-tuning framework.

Implements Matsutani et al., "Skip2-LoRA: A Lightweight On-device DNN
Fine-tuning Method for Low-cost Edge Devices" (2024), scaled from the paper's
MLP/edge setting up to multi-pod LM fine-tuning with sharded activation
caches and Pallas TPU kernels.
"""

__version__ = "0.1.0"
