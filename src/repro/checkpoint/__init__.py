"""Checkpointing: sharded save/restore with manifest + elastic reshard."""

from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager,
    restore_checkpoint,
    save_checkpoint,
)
