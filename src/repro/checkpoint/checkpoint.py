"""Fault-tolerant checkpointing.

Design (multi-host production shape, exercised single-host here):

  - A checkpoint is a directory: ``manifest.json`` (step, tree structure,
    per-leaf dtype/shape/sharding spec, data-iterator state, RNG) + one
    ``.npz`` per host holding that host's addressable shards.
  - Writes are atomic: write to ``<dir>.tmp`` then rename; the manager keeps
    the last K checkpoints and garbage-collects older ones. A crashed write
    can never corrupt the latest-complete pointer.
  - **Elastic restore**: leaves are saved *unsharded per host* (gathered to
    host memory); restore re-shards onto whatever mesh the new job brings
    (possibly a different shape) via ``jax.device_put`` with the new
    sharding. Adapter-only checkpoints (Skip2-LoRA) are tiny, so elastic
    fine-tune restarts are near-instant — one of the paper-topology's
    operational wins.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


def _flatten_with_names(tree: Params) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, leaf))
    return out


def save_checkpoint(
    directory: str,
    step: int,
    tree: Params,
    *,
    extra: Optional[dict] = None,
) -> str:
    """Atomic save. Returns the final checkpoint path."""
    path = os.path.join(directory, f"ckpt_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    leaves = _flatten_with_names(tree)
    arrays = {}
    manifest_leaves = {}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
            # numpy can't serialise ml_dtypes natively: store a uint view,
            # record the logical dtype in the manifest.
            arr = arr.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[arr.dtype.itemsize])
        arrays[name] = arr
        manifest_leaves[name] = {"shape": list(arr.shape), "dtype": logical_dtype}

    np.savez(os.path.join(tmp, "host_0.npz"), **arrays)
    manifest = {
        "step": int(step),
        "time": time.time(),
        "leaves": manifest_leaves,
        "extra": extra or {},
        "format": 1,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    cands = sorted(
        d for d in os.listdir(directory)
        if d.startswith("ckpt_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(directory, d, "manifest.json"))
    )
    return os.path.join(directory, cands[-1]) if cands else None


def restore_checkpoint(
    path: str,
    like: Params,
    *,
    shardings: Optional[Params] = None,
) -> tuple[Params, dict]:
    """Restore into the structure of ``like``; reshard onto ``shardings``
    (a tree of jax.sharding.Sharding) if given — this is the elastic path:
    the saved mesh shape is irrelevant."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "host_0.npz"))

    names = [n for n, _ in _flatten_with_names(like)]
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    flat_sh = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        if shardings is not None
        else [None] * len(flat_like)
    )
    restored = []
    for name, leaf, sh in zip(names, flat_like, flat_sh):
        arr = data[name]
        logical = manifest["leaves"][name]["dtype"]
        if str(arr.dtype) != logical:
            # Saved as a uint view of an ml_dtypes type: view it back.
            arr = arr.view(jnp.dtype(logical))
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        if str(arr.dtype) != str(want_dtype):
            arr = np.asarray(jnp.asarray(arr).astype(want_dtype))
        if sh is not None:
            restored.append(jax.device_put(arr, sh))
        else:
            restored.append(jnp.asarray(arr))
    return treedef.unflatten(restored), manifest


# ---------------------------------------------------------------------------
# Session checkpoints: one capture of a whole SessionRuntime
# ---------------------------------------------------------------------------


def save_runtime_session(directory: str, step: int, runtime, *,
                         extra: Optional[dict] = None) -> str:
    """Checkpoint a whole continual-learning session (``core.runtime``):
    stacked fleet adapters + optimizer moments, the AdapterPool data plane
    and slot table, and every present skip-cache row — so an elastic
    restart resumes serve AND train without replaying ingestion. Atomic
    like ``save_checkpoint`` (which it rides on); the session's control
    plane travels in the manifest's ``extra["session"]``."""
    arrays, meta = runtime.session_state()
    return save_checkpoint(
        directory, step, arrays, extra={"session": meta, **(extra or {})}
    )


def _load_dict_tree(path: str) -> tuple[dict, dict]:
    """Rebuild the nested dict-of-arrays tree a session save flattened
    (name components never contain "/" — session trees are all-dict with
    plain slot/leaf names), applying the manifest's logical-dtype view-back
    for ml_dtypes leaves."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "host_0.npz"))
    tree: dict = {}
    for name in data.files:
        arr = data[name]
        logical = manifest["leaves"][name]["dtype"]
        if str(arr.dtype) != logical:
            arr = arr.view(jnp.dtype(logical))
        node = tree
        parts = name.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return tree, manifest


def restore_runtime_session(path: str, runtime) -> dict:
    """Restore a session checkpoint into a *fresh* ``SessionRuntime`` of
    identical configuration. Returns the manifest. Continuing the restored
    session (further ingest / adapt / serve) reproduces the uninterrupted
    run — the save -> restore -> continue equivalence is enforced by
    ``tests/test_runtime.py``."""
    tree, manifest = _load_dict_tree(path)
    runtime.load_session_state(tree, manifest["extra"]["session"])
    return manifest


class CheckpointManager:
    """Keep-K rotation + convenience save/restore-latest."""

    def __init__(
        self,
        directory: str,
        *,
        keep: int = 3,
        save_every: int = 100,
        tmp_grace_s: float = 3600.0,
    ):
        self.directory = directory
        self.keep = keep
        self.save_every = save_every
        #: How old (mtime) a ``ckpt_*.tmp`` dir must be before gc reaps it.
        #: Reaping unconditionally would race a concurrent atomic write: a
        #: supervisor-restarted sibling (or an overlapping async save) has a
        #: live tmp dir between ``makedirs`` and ``rename``, and deleting it
        #: mid-write corrupts that save. A *stale* tmp dir — older than any
        #: plausible in-flight write — really is a crash leftover.
        self.tmp_grace_s = float(tmp_grace_s)
        os.makedirs(directory, exist_ok=True)

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, tree: Params, *, extra: Optional[dict] = None) -> str:
        path = save_checkpoint(self.directory, step, tree, extra=extra)
        self._gc()
        return path

    def restore_latest(
        self, like: Params, *, shardings: Optional[Params] = None
    ) -> Optional[tuple[Params, dict]]:
        path = latest_checkpoint(self.directory)
        if path is None:
            return None
        return restore_checkpoint(path, like, shardings=shardings)

    def _gc(self) -> None:
        cands = sorted(
            d for d in os.listdir(self.directory) if d.startswith("ckpt_")
        )
        # Drop STALE tmp dirs (crashed writes) and old checkpoints. A fresh
        # tmp dir may be a concurrent write's staging area (see
        # ``tmp_grace_s``) — leave it alone until it ages past the grace
        # window.
        now = time.time()
        for d in cands:
            if d.endswith(".tmp"):
                full = os.path.join(self.directory, d)
                try:
                    age = now - os.path.getmtime(full)
                except OSError:
                    continue  # renamed/removed under us: someone finished it
                if age >= self.tmp_grace_s:
                    shutil.rmtree(full, ignore_errors=True)
        cands = [d for d in cands if not d.endswith(".tmp")]
        for d in cands[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)
