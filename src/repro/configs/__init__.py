"""Architecture registry: one module per assigned architecture."""

from repro.configs.registry import (  # noqa: F401
    ARCH_IDS,
    get_config,
    list_archs,
    reduce_config,
)
