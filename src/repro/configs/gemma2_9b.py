"""gemma2-9b [dense] — alternating local/global attention with logit
softcaps (arXiv:2408.00118; hf).

42L d_model=3584 16H (kv=8) d_ff=14336 vocab=256000, head_dim=256,
window 4096 on local layers, attn softcap 50, final softcap 30, GeGLU,
sandwich norms, tied + scaled embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    pattern=("attn_local", "attn"),
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    ffn_activation="gelu",
    ffn_gated=True,
    norm_type="rmsnorm",
    rmsnorm_unit_offset=True,
    use_post_norm=True,
    tie_embeddings=True,
    scale_embed_by_sqrt_dim=True,
)
