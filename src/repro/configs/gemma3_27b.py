"""gemma3-27b [dense] — 5:1 local:global attention, 128k context
(hf:google/gemma-3-*; unverified).

62L d_model=5376 32H (kv=16) d_ff=21504 vocab=262144, head_dim=128,
sliding window 1024 on local layers, GeGLU, sandwich norms, tied + scaled
embeddings. 62 = 10 full (5 local + 1 global) periods + 2 local remainder.
Global layers are full attention -> long_500k skipped (DESIGN.md §5).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    pattern=("attn_local",) * 5 + ("attn",),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    ffn_activation="gelu",
    ffn_gated=True,
    norm_type="rmsnorm",
    rmsnorm_unit_offset=True,
    use_post_norm=True,
    tie_embeddings=True,
    scale_embed_by_sqrt_dim=True,
)
