"""gemma-7b [dense] — GeGLU, head_dim=256 (arXiv:2403.08295; hf).

28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000. Full global causal
attention on every layer, tied + scaled embeddings, unit-offset RMSNorm.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    pattern=("attn",),
    ffn_activation="gelu",
    ffn_gated=True,
    norm_type="rmsnorm",
    rmsnorm_unit_offset=True,
    tie_embeddings=True,
    scale_embed_by_sqrt_dim=True,
)
