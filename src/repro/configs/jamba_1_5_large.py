"""jamba-1.5-large-398b [hybrid] — Mamba + attention 7:1 interleave with MoE
(arXiv:2403.19887; hf).

72L d_model=8192 64H (kv=8) d_ff=24576 vocab=65536. Period of 8 layers with
attention at position 3 (1 attn : 7 mamba), MoE (16 experts top-2,
expert d_ff 24576) on every second layer, dense SwiGLU (d_ff 24576)
otherwise. Mamba-dominant -> runs the long_500k shape.
"""

from repro.models.config import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=(
        "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba", "mamba",
    ),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, every_k_layers=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    ffn_activation="silu",
    ffn_gated=True,
    norm_type="rmsnorm",
    tie_embeddings=False,
)
