"""musicgen-medium [audio] — decoder-only over EnCodec tokens
(arXiv:2306.05284; hf).

48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048. Backbone only per the
assignment: the EnCodec encoder and the text-conditioning cross-attention
are stubbed — ``input_specs()`` provides a precomputed conditioning prefix
of 64 frame embeddings; the 4-codebook interleaving is flattened to a
single code stream (vocab 2048). Standard post-2017 decoder: LayerNorm,
ungated GELU FFN, untied output head.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    pattern=("attn",),
    ffn_activation="gelu",
    ffn_gated=False,
    norm_type="layernorm",
    norm_eps=1e-5,
    tie_embeddings=False,
    frontend="audio",
    frontend_seq=64,
)
