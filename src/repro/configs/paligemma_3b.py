"""paligemma-3b [vlm] — SigLIP + gemma backbone (arXiv:2407.07726; hf).

18L d_model=2048 8H (kv=1, MQA) d_ff=16384 vocab=257216, head_dim=256.
Backbone only: the SigLIP vision tower is stubbed — ``input_specs()``
supplies 256 precomputed patch embeddings as a prefix; loss is masked over
the prefix. Gemma-style GeGLU / unit-offset RMSNorm / tied scaled embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    pattern=("attn",),
    ffn_activation="gelu",
    ffn_gated=True,
    norm_type="rmsnorm",
    rmsnorm_unit_offset=True,
    tie_embeddings=True,
    scale_embed_by_sqrt_dim=True,
    frontend="vision",
    frontend_seq=256,
)
