"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2
(hf:microsoft/Phi-3.5-MoE-instruct; hf).

32L d_model=4096 32H (kv=8) d_ff_expert=6400 vocab=32064, MoE on every
layer, no shared experts. head_dim=128, SwiGLU experts, RMSNorm, untied.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    pattern=("attn",),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400),
    ffn_activation="silu",
    ffn_gated=True,
    norm_type="rmsnorm",
    tie_embeddings=False,
)
