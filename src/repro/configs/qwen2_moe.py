"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts top-4
(hf:Qwen/Qwen1.5-MoE-A2.7B; hf).

24L d_model=2048 16H (kv=16) d_ff_expert=1408 vocab=151936. Shared path is
the 4 always-on experts fused into one 5632-wide gated FFN. Untied.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    pattern=("attn",),
    moe=MoEConfig(
        n_experts=60,
        top_k=4,
        d_ff_expert=1408,
        n_shared=4,
        shared_d_ff=5632,
    ),
    ffn_activation="silu",
    ffn_gated=True,
    norm_type="rmsnorm",
    tie_embeddings=False,
)
