"""Architecture registry + reduced-config factory for smoke tests."""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import MambaConfig, ModelConfig, MoEConfig, XLSTMConfig

#: arch id -> config module
ARCH_IDS: dict[str, str] = {
    "xlstm-350m": "repro.configs.xlstm_350m",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "gemma-7b": "repro.configs.gemma_7b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large",
    "paligemma-3b": "repro.configs.paligemma_3b",
}

#: archs whose attention is sub-quadratic end-to-end (run long_500k).
SUBQUADRATIC_ARCHS = ("xlstm-350m", "jamba-1.5-large-398b")


def list_archs() -> list[str]:
    return sorted(ARCH_IDS)


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; options: {list_archs()}")
    return importlib.import_module(ARCH_IDS[arch]).CONFIG


def reduce_config(cfg: ModelConfig, *, n_periods: int = 2) -> ModelConfig:
    """Shrink a config for CPU smoke tests while preserving its *family
    structure* (pattern, GQA ratio, gating, softcaps, MoE top-k, frontend).
    """
    period = cfg.period
    heads = max(2, min(4, cfg.n_heads))
    kv_ratio = max(1, cfg.n_heads // cfg.n_kv_heads)
    kv = max(1, heads // kv_ratio)
    d_model = 16 * heads
    updates: dict = dict(
        n_layers=period * n_periods + len(cfg.remainder_pattern),
        d_model=d_model,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=(32 if cfg.head_dim else 0),
        d_ff=(64 if cfg.d_ff else 0),
        vocab_size=503,
        sliding_window=(8 if cfg.sliding_window else 0),
        frontend_seq=(8 if cfg.frontend else 0),
        dtype="float32",
    )
    if cfg.moe is not None:
        updates["moe"] = MoEConfig(
            n_experts=min(8, cfg.moe.n_experts),
            top_k=min(2, cfg.moe.top_k),
            d_ff_expert=32,
            n_shared=min(1, cfg.moe.n_shared),
            shared_d_ff=(64 if cfg.moe.n_shared else 0),
            capacity_factor=2.0,
            every_k_layers=cfg.moe.every_k_layers,
        )
    if cfg.mamba is not None:
        updates["mamba"] = MambaConfig(d_state=4, d_conv=4, expand=2)
    if cfg.xlstm is not None:
        updates["xlstm"] = cfg.xlstm
    return dataclasses.replace(cfg, **updates)
