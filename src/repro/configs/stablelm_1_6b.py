"""stablelm-1.6b [dense] (hf:stabilityai/stablelm-2-1_6b; unverified).

24L d_model=2048 32H (kv=32, full MHA) d_ff=5632 vocab=100352, partial
rotary (25%), LayerNorm, SwiGLU, untied embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    pattern=("attn",),
    rope_pct=0.25,
    rope_theta=10_000.0,
    ffn_activation="silu",
    ffn_gated=True,
    norm_type="layernorm",
    norm_eps=1e-5,
    tie_embeddings=False,
)
