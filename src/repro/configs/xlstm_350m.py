"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517; unverified).

24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304. xLSTM[7:1] ratio: seven mLSTM
blocks per sLSTM block (the paper's preferred mix). d_ff=0 -> no external
FFN; the cells carry their own up-projections (mLSTM x2, sLSTM ff 4/3).
Fully recurrent -> runs the long_500k shape.
"""

from repro.models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=("mlstm",) * 7 + ("slstm",),
    xlstm=XLSTMConfig(mlstm_proj_factor=2.0, slstm_ff_factor=4.0 / 3.0, conv_kernel=4),
    norm_type="layernorm",
    norm_eps=1e-5,
    tie_embeddings=True,
)
