"""Core of the paper's contribution: Skip-LoRA topology + Skip-Cache.

- ``compute_model``: Table-1 compute-type taxonomy with closed-form FLOPs.
- ``methods``: the eight fine-tuning methods of Sections 3-4 at MLP scale.
- ``skip_cache``: the forward-activation cache (Section 4.2), device-sharded.
- ``finetune``: Algorithm 1 (populate epoch + cached epochs).
- ``lm_adapters``: Skip-LoRA adapters for transformer LMs (framework scale).
- ``cache_engine``: tiered HBM/host cache placement (DESIGN.md §4).
- ``adapter_pool``: slot-based multi-tenant adapter registry for serving
  (DESIGN.md §7); feeds the grouped Pallas kernel.
- ``batch_plan``: the one epoch batch planner (wrap/mask tail semantics)
  behind every trainer's index matrices.
- ``runtime``: the session runtime — serve + ingest + fleet adapt
  interleaved over one pool/engine/compiled-fn cache (DESIGN.md §9),
  mesh-native since §10: sessions shard tenants over an explicit device
  mesh and restart from event-boundary checkpoints.
"""

import jax


def donate_argnums(*argnums: int) -> tuple[int, ...]:
    """Scan-carry donation policy for the fused epoch loops (DESIGN.md §2):
    donate off-CPU, where it enables in-place cache/optimizer updates; the
    CPU backend does not implement donation and would only warn."""
    return argnums if jax.default_backend() != "cpu" else ()
