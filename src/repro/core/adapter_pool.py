"""Adapter pool: slot-based registry of per-tenant Skip-LoRA stacks.

The serving half of the Skip2-LoRA story (DESIGN.md §7): every user
fine-tunes their own adapter stack on-device, and the serving fleet must
apply a *different* stack per batch row. Because the skip topology taps
every layer input into the final output, the adapters can never be merged
into the backbone — so serving keeps them in a stacked device-resident pool

    A: (n_slots, L, D, R)    B: (n_slots, L, R, D)

indexed per request row by the grouped Pallas kernel
(``kernels.skip_lora.ops.skip_lora_grouped``). The pool mirrors the
``TieredCacheEngine`` slot design (§4): rows are *slots*, a host-side LRU
map assigns tenant -> slot, and registration past capacity evicts the
least-recently-served tenant. Slot 0 is pinned all-zeros — the "no adapter"
tenant, so base-model traffic rides the same batched kernel for free.

``compress="int8"`` stores the pool rowwise-quantised (int8 payload + fp32
scales over the last axis, the same scheme as the activation cache). The
quantised slots feed ``skip_lora_grouped_int8`` *raw*: dequant happens on
the gathered per-tile blocks in VMEM, so an int8 pool holds 4x the resident
tenants of a bf16 pool for the same HBM.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import donate_argnums
from repro.core.lm_skiplora import quantize_int8
from repro.models.config import ModelConfig

Params = Any

#: In-place single-slot write: the pool array is donated (off-CPU), so a
#: registration costs one O(L*D*R) slot write, never a full-pool copy.
#: ``slot`` rides as a traced scalar so every slot shares one trace.
_set_slot = jax.jit(
    lambda arr, slot, val: arr.at[slot].set(val),
    donate_argnums=donate_argnums(0),
)

#: pinned all-zeros slot: rows with no registered adapter (base model).
ZERO_SLOT = 0


@dataclasses.dataclass
class PoolStats:
    registrations: int = 0
    evictions: int = 0
    lookups: int = 0
    misses: int = 0

    def as_rows(self, prefix: str = "adapter_pool") -> list[tuple[str, float]]:
        return [
            (f"{prefix}/registrations", float(self.registrations)),
            (f"{prefix}/evictions", float(self.evictions)),
            (f"{prefix}/lookups", float(self.lookups)),
            (f"{prefix}/misses", float(self.misses)),
        ]


class AdapterPool:
    """Fixed-capacity device pool of per-tenant adapter stacks.

    Data plane: stacked jnp arrays consumed directly by the grouped kernel.
    Control plane: host-side LRU tenant->slot map, like the cache engine.
    """

    def __init__(
        self,
        n_slots: int,
        cfg: ModelConfig,
        rank: int,
        *,
        compress: Optional[str] = None,
        dtype=jnp.float32,
    ):
        if n_slots < 2:
            raise ValueError("need >= 2 slots (slot 0 is pinned to zeros)")
        if compress not in (None, "int8"):
            raise ValueError(f"unknown compression {compress!r}")
        self.n_slots = n_slots
        self.rank = rank
        self.compress = compress
        l, d, r = cfg.n_layers, cfg.d_model, rank
        self._shape_a, self._shape_b = (l, d, r), (l, r, d)
        if compress == "int8":
            self._qa = jnp.zeros((n_slots, l, d, r), jnp.int8)
            self._sa = jnp.zeros((n_slots, l, d), jnp.float32)
            self._qb = jnp.zeros((n_slots, l, r, d), jnp.int8)
            self._sb = jnp.zeros((n_slots, l, r), jnp.float32)
        else:
            self._a = jnp.zeros((n_slots, l, d, r), dtype)
            self._b = jnp.zeros((n_slots, l, r, d), dtype)
        # Slot 0 never enters the LRU / free list: it is the zero tenant.
        self._lru: OrderedDict[Any, int] = OrderedDict()
        self._free: list[int] = list(range(n_slots - 1, 0, -1))
        self._pinned: set = set()
        #: bumps whenever the tenant->slot map changes (new assignment,
        #: eviction, restore) — NOT on LRU touches, which keep slots stable.
        #: Callers may cache ``lookup`` results keyed on this (the session
        #: runtime memoises its serve-batch index arrays against it).
        self.version = 0
        self.stats = PoolStats()

    # -- capacity -----------------------------------------------------------

    def __len__(self) -> int:
        return len(self._lru)

    def tenants(self) -> list:
        return list(self._lru.keys())

    def has(self, tenant) -> bool:
        return tenant in self._lru

    def nbytes(self) -> int:
        arrs = (
            (self._qa, self._sa, self._qb, self._sb)
            if self.compress == "int8"
            else (self._a, self._b)
        )
        return sum(a.size * a.dtype.itemsize for a in arrs)

    # -- registration -------------------------------------------------------

    def _write_slot(self, slot: int, adapters: Params) -> None:
        a = jnp.asarray(adapters["A"], jnp.float32)
        b = jnp.asarray(adapters["B"], jnp.float32)
        if a.shape != self._shape_a or b.shape != self._shape_b:
            raise ValueError(
                f"adapter shapes {a.shape}/{b.shape} != pool "
                f"{self._shape_a}/{self._shape_b}"
            )
        s = jnp.asarray(slot, jnp.int32)
        if self.compress == "int8":
            qa, sa = quantize_int8(a)
            qb, sb = quantize_int8(b)
            self._qa = _set_slot(self._qa, s, qa)
            self._sa = _set_slot(self._sa, s, sa)
            self._qb = _set_slot(self._qb, s, qb)
            self._sb = _set_slot(self._sb, s, sb)
        else:
            self._a = _set_slot(self._a, s, a.astype(self._a.dtype))
            self._b = _set_slot(self._b, s, b.astype(self._b.dtype))

    def _assign_slot(self, tenant) -> int:
        """Control-plane half of registration: LRU bookkeeping only.
        Re-registration keeps the tenant's slot; a full pool evicts the
        least-recently-served *unpinned* tenant — a pinned slot (in-flight
        training state, see ``pin``) is never an eviction victim."""
        if tenant in self._lru:
            slot = self._lru[tenant]
            self._lru.move_to_end(tenant)
        else:
            if not self._free:
                victim = next(
                    (t for t in self._lru if t not in self._pinned), None
                )
                if victim is None:
                    raise RuntimeError(
                        f"pool full and all {len(self._lru)} resident tenants "
                        "pinned: cannot evict for a new registration"
                    )
                slot = self._lru.pop(victim)
                self.stats.evictions += 1
            else:
                slot = self._free.pop()
            self._lru[tenant] = slot
            self.version += 1
        return slot

    # -- session pinning ----------------------------------------------------

    def pin(self, tenant) -> None:
        """Exclude a registered tenant's slot from LRU eviction. The session
        runtime pins every tenant with in-flight training state (adapters /
        optimizer moments mid-``adapt``), so a serve-traffic burst can never
        recycle a slot whose index is still baked into a queued fleet batch.
        Idempotent; raises KeyError for unregistered tenants."""
        if tenant not in self._lru:
            raise KeyError(f"tenant {tenant!r} has no registered adapters to pin")
        self._pinned.add(tenant)

    def unpin(self, tenant) -> None:
        """Re-admit a tenant's slot to LRU eviction (no-op if not pinned)."""
        self._pinned.discard(tenant)

    def pinned(self) -> set:
        return set(self._pinned)

    def register(self, tenant, adapters: Params) -> int:
        """Install a tenant's fine-tuned {"A": (L,D,R), "B": (L,R,D)} stack.

        Re-registering overwrites in place (a fresh on-device fine-tune).
        A full pool evicts the least-recently-served tenant.

        Off-CPU the slot write donates the pool buffers (an in-place
        O(L*D*R) write, never a full-pool copy) — any dict previously
        returned by ``pools()`` is invalidated; re-fetch it after
        registration and never register mid-flight of a computation that
        still holds the old arrays.
        """
        slot = self._assign_slot(tenant)
        self._write_slot(slot, adapters)
        self.stats.registrations += 1
        return slot

    def register_many(self, tenants, stacked: Params) -> list[int]:
        """Batched registration of a fleet-trained stack: tenant
        ``tenants[i]`` gets ``{"A": stacked["A"][i], "B": stacked["B"][i]}``
        installed via ONE donated scatter per pool array (the fleet
        trainer's write-back path — an in-place O(T*L*D*R) write, never a
        full-pool copy, same donation caveats as ``register``). Returns the
        assigned slots, LRU/eviction semantics identical to T sequential
        ``register`` calls."""
        tenants = list(tenants)
        if len(set(tenants)) != len(tenants):
            raise ValueError("duplicate tenants in batched registration")
        if len(tenants) > self.n_slots - 1:
            raise ValueError(
                f"{len(tenants)} tenants exceed pool capacity {self.n_slots - 1}"
            )
        a = jnp.asarray(stacked["A"], jnp.float32)
        b = jnp.asarray(stacked["B"], jnp.float32)
        if (
            a.shape != (len(tenants),) + self._shape_a
            or b.shape != (len(tenants),) + self._shape_b
        ):
            raise ValueError(
                f"stacked shapes {a.shape}/{b.shape} != "
                f"{(len(tenants),) + self._shape_a}/{(len(tenants),) + self._shape_b}"
            )
        slots = [self._assign_slot(t) for t in tenants]
        sv = jnp.asarray(slots, jnp.int32)
        if self.compress == "int8":
            # Rowwise (last-axis) quantisation is per-slot independent, so
            # quantising the whole stack at once matches per-slot writes.
            qa, sa = quantize_int8(a)
            qb, sb = quantize_int8(b)
            self._qa = _set_slot(self._qa, sv, qa)
            self._sa = _set_slot(self._sa, sv, sa)
            self._qb = _set_slot(self._qb, sv, qb)
            self._sb = _set_slot(self._sb, sv, sb)
        else:
            self._a = _set_slot(self._a, sv, a.astype(self._a.dtype))
            self._b = _set_slot(self._b, sv, b.astype(self._b.dtype))
        self.stats.registrations += len(tenants)
        return slots

    def evict(self, tenant) -> None:
        if tenant in self._pinned:
            raise ValueError(
                f"tenant {tenant!r} is pinned (in-flight training state); "
                "unpin before evicting"
            )
        slot = self._lru.pop(tenant)
        self._free.append(slot)
        self.version += 1
        self.stats.evictions += 1

    # -- lookup -------------------------------------------------------------

    def lookup(self, tenants) -> jax.Array:
        """Tenant ids -> (B,) int32 slot indices for the grouped kernel.

        ``None`` maps to the pinned zero slot (base model, no adapter);
        unknown tenants raise — the serving tier decides whether a miss
        means "fine-tune first" or "serve base", not the pool.
        """
        slots = []
        for t in tenants:
            self.stats.lookups += 1
            if t is None:
                slots.append(ZERO_SLOT)
            elif t in self._lru:
                self._lru.move_to_end(t)
                slots.append(self._lru[t])
            else:
                self.stats.misses += 1
                raise KeyError(f"tenant {t!r} has no registered adapters")
        return jnp.asarray(slots, jnp.int32)

    def touch(self, tenants) -> None:
        """LRU-refresh only (no slot-index build): the runtime's memoised
        serve path calls this on cache hits so recency still tracks real
        serving traffic."""
        for t in tenants:
            if t is not None and t in self._lru:
                self._lru.move_to_end(t)

    # -- data plane ---------------------------------------------------------

    def pools(self) -> dict[str, jax.Array]:
        """The stacked arrays the grouped kernel consumes, in storage layout.

        float pool: {"A", "B"}; int8 pool: {"qa", "sa", "qb", "sb"} — the
        int8 payload is handed over *raw* (dequant lives in the kernel).
        The dict is a snapshot of the live buffers: ``register`` donates
        them off-CPU, so re-fetch after any registration (see ``register``).
        """
        if self.compress == "int8":
            return {"qa": self._qa, "sa": self._sa, "qb": self._qb, "sb": self._sb}
        return {"A": self._a, "B": self._b}

    # -- session state (checkpoint plane) ------------------------------------

    def slot_table(self) -> dict:
        """JSON-able control plane: LRU-ordered (tenant, slot) pairs, free
        list, pinned tenants. Tenant ids must be JSON-serialisable for this
        to round-trip through a checkpoint manifest."""
        return {
            "lru": [[t, s] for t, s in self._lru.items()],
            "free": list(self._free),
            "pinned": [t for t in self._lru if t in self._pinned],
        }

    def load_state(self, arrays: dict[str, jax.Array], table: dict) -> None:
        """Restore the data plane (a ``pools()``-layout dict) and control
        plane (a ``slot_table()`` dict) saved from a pool of identical
        geometry — the checkpoint restore path."""
        want = set(self.pools())
        if set(arrays) != want:
            raise ValueError(f"pool arrays {set(arrays)} != expected {want}")
        for name, arr in arrays.items():
            cur = self.pools()[name]
            arr = jnp.asarray(arr, cur.dtype)
            if arr.shape != cur.shape:
                raise ValueError(
                    f"pool array {name}: {arr.shape} != {cur.shape}"
                )
            setattr(self, "_" + name.lower(), arr)
        self._lru = OrderedDict((t, int(s)) for t, s in table["lru"])
        self._free = [int(s) for s in table["free"]]
        self._pinned = set(table.get("pinned", ()))
        self.version += 1


def grouped_skip_sum(
    acts: jax.Array,
    pools: dict[str, jax.Array],
    idx: jax.Array,
    *,
    use_kernel: bool = True,
) -> jax.Array:
    """Per-row skip-sum over a stacked pool: unpacks the pool layout (float
    or raw-int8) and forwards to the grouped kernel wrappers, which own the
    row flattening, stop_gradient contract, and kernel/oracle dispatch.

    acts: (L, B, S, D); idx: (B,) int32 -> (B, S, D).
    """
    from repro.kernels.skip_lora.ops import (
        skip_lora_grouped,
        skip_lora_grouped_int8,
    )

    if "qa" in pools:
        return skip_lora_grouped_int8(
            acts, pools["qa"], pools["sa"], pools["qb"], pools["sb"], idx,
            use_kernel=use_kernel,
        )
    return skip_lora_grouped(
        acts, pools["A"], pools["B"], idx, use_kernel=use_kernel
    )
