"""Adapter pool: slot-based registry of per-tenant Skip-LoRA stacks.

The serving half of the Skip2-LoRA story (DESIGN.md §7): every user
fine-tunes their own adapter stack on-device, and the serving fleet must
apply a *different* stack per batch row. Because the skip topology taps
every layer input into the final output, the adapters can never be merged
into the backbone — so serving keeps them in a stacked device-resident pool

    A: (n_slots, L, D, R)    B: (n_slots, L, R, D)

indexed per request row by the grouped Pallas kernel
(``kernels.skip_lora.ops.skip_lora_grouped``). The pool mirrors the
``TieredCacheEngine`` slot design (§4): rows are *slots*, a host-side LRU
map assigns tenant -> slot, and registration past capacity evicts the
least-recently-served tenant. Slot 0 is pinned all-zeros — the "no adapter"
tenant, so base-model traffic rides the same batched kernel for free.

``compress="int8"`` stores the pool rowwise-quantised (int8 payload + fp32
scales over the last axis, the same scheme as the activation cache). The
quantised slots feed ``skip_lora_grouped_int8`` *raw*: dequant happens on
the gathered per-tile blocks in VMEM, so an int8 pool holds 4x the resident
tenants of a bf16 pool for the same HBM.

``compress="int4"`` / ``compress="nf4"`` halve the payload again: two 4-bit
codebook indices packed per byte (``kernels.skip_lora.quant``) + the same
fp32 rowwise scales, fed raw to ``skip_lora_grouped_q4`` (nibble unpack +
codebook dequant on the gathered blocks in VMEM). ``int4`` is uniform
symmetric; ``nf4`` uses the QLoRA NormalFloat4 levels, information-optimal
for the normally-distributed factors LoRA actually has. Either way the
zero slot stays EXACT zeros (scale 0), so base traffic is bitwise base.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import donate_argnums
from repro.core.lm_skiplora import quantize_int8
from repro.kernels.skip_lora import quant as q4
from repro.models.config import ModelConfig

Params = Any

#: In-place single-slot write: the pool array is donated (off-CPU), so a
#: registration costs one O(L*D*R) slot write, never a full-pool copy.
#: ``slot`` rides as a traced scalar so every slot shares one trace.
_set_slot = jax.jit(
    lambda arr, slot, val: arr.at[slot].set(val),
    donate_argnums=donate_argnums(0),
)

#: pinned all-zeros slot: rows with no registered adapter (base model).
ZERO_SLOT = 0


#: Regression-gate decisions a write-back can carry (DESIGN.md §13).
#: "accept" installs the payload; "reject" and "quarantine" both leave the
#: slot serving its current version (the difference — whether the caller's
#: training state advances — is session policy, not pool mechanism).
GATE_DECISIONS = ("accept", "reject", "quarantine")


@dataclasses.dataclass
class PoolStats:
    registrations: int = 0
    evictions: int = 0
    lookups: int = 0
    misses: int = 0
    rollbacks: int = 0
    gate_rejected: int = 0
    gate_quarantined: int = 0

    def as_rows(self, prefix: str = "adapter_pool") -> list[tuple[str, float]]:
        return [
            (f"{prefix}/registrations", float(self.registrations)),
            (f"{prefix}/evictions", float(self.evictions)),
            (f"{prefix}/lookups", float(self.lookups)),
            (f"{prefix}/misses", float(self.misses)),
            (f"{prefix}/rollbacks", float(self.rollbacks)),
            (f"{prefix}/gate_rejected", float(self.gate_rejected)),
            (f"{prefix}/gate_quarantined", float(self.gate_quarantined)),
        ]


class AdapterPool:
    """Fixed-capacity device pool of per-tenant adapter stacks.

    Data plane: stacked jnp arrays consumed directly by the grouped kernel.
    Control plane: host-side LRU tenant->slot map, like the cache engine.
    """

    def __init__(
        self,
        n_slots: int,
        cfg: ModelConfig,
        rank: int,
        *,
        compress: Optional[str] = None,
        dtype=jnp.float32,
        device=None,
        history: int = 0,
    ):
        if n_slots < 2:
            raise ValueError("need >= 2 slots (slot 0 is pinned to zeros)")
        if compress not in (None, "int8") + q4.Q4_KINDS:
            raise ValueError(f"unknown compression {compress!r}")
        if history < 0:
            raise ValueError(f"history depth {history} < 0")
        self.n_slots = n_slots
        self.rank = rank
        self.compress = compress
        #: Versioned slots: how many *previous* payloads each tenant keeps
        #: (0 = versioning off, the historical pool). Each re-registration
        #: pushes the outgoing payload (in pool storage layout, so restores
        #: are bitwise) onto the tenant's bounded history; ``rollback``
        #: pops it back into the slot.
        self.history_depth = history
        #: Device the data plane is committed to (``None``: jax default).
        #: A mesh-native session commits each shard's pool to that shard's
        #: device, so serve/adapt dispatches against it stay device-local.
        self.device = device

        def z(shape, dt):
            arr = jnp.zeros(shape, dt)
            return jax.device_put(arr, device) if device is not None else arr

        l, d, r = cfg.n_layers, cfg.d_model, rank
        self._shape_a, self._shape_b = (l, d, r), (l, r, d)
        if compress in q4.Q4_KINDS:
            if r % 2 or d % 2:
                raise ValueError(
                    f"4-bit pools pack two indices per byte along the last "
                    f"axis: rank {r} and d_model {d} must both be even"
                )
            # Zero-init payload is nibble 0 (NOT the zero level), but the
            # zero-init SCALES make every unwritten slot dequantise to
            # exact zeros — code[0] * 0.0.
            self._qa4 = z((n_slots, l, d, r // 2), jnp.uint8)
            self._sa = z((n_slots, l, d), jnp.float32)
            self._qb4 = z((n_slots, l, r, d // 2), jnp.uint8)
            self._sb = z((n_slots, l, r), jnp.float32)
            self._code = z((16,), jnp.float32) + q4.codebook(compress)
        elif compress == "int8":
            self._qa = z((n_slots, l, d, r), jnp.int8)
            self._sa = z((n_slots, l, d), jnp.float32)
            self._qb = z((n_slots, l, r, d), jnp.int8)
            self._sb = z((n_slots, l, r), jnp.float32)
        else:
            self._a = z((n_slots, l, d, r), dtype)
            self._b = z((n_slots, l, r, d), dtype)
        # Slot 0 never enters the LRU / free list: it is the zero tenant.
        self._lru: OrderedDict[Any, int] = OrderedDict()
        self._free: list[int] = list(range(n_slots - 1, 0, -1))
        self._pinned: set = set()
        #: tenant -> oldest..newest previous-version records, each
        #: {"payload": {pool-array name: np.ndarray slot slice},
        #:  "step": int, "eval_loss": float|None}; bounded at
        #: ``history_depth`` entries per tenant.
        self._hist: dict[Any, list[dict]] = {}
        #: tenant -> {"step", "eval_loss"} of the *current* slot payload.
        self._vmeta: dict[Any, dict] = {}
        #: bumps whenever the tenant->slot map changes (new assignment,
        #: eviction, restore) — NOT on LRU touches, which keep slots stable.
        #: Callers may cache ``lookup`` results keyed on this (the session
        #: runtime memoises its serve-batch index arrays against it).
        self.version = 0
        self.stats = PoolStats()

    # -- capacity -----------------------------------------------------------

    def __len__(self) -> int:
        return len(self._lru)

    def tenants(self) -> list:
        return list(self._lru.keys())

    def has(self, tenant) -> bool:
        return tenant in self._lru

    def nbytes(self) -> int:
        return sum(a.size * a.dtype.itemsize for a in self.pools().values())

    # -- registration -------------------------------------------------------

    def _write_slot(self, slot: int, adapters: Params) -> None:
        a = jnp.asarray(adapters["A"], jnp.float32)
        b = jnp.asarray(adapters["B"], jnp.float32)
        if a.shape != self._shape_a or b.shape != self._shape_b:
            raise ValueError(
                f"adapter shapes {a.shape}/{b.shape} != pool "
                f"{self._shape_a}/{self._shape_b}"
            )
        s = jnp.asarray(slot, jnp.int32)
        if self.compress in q4.Q4_KINDS:
            qa, sa = q4.quantize_q4(a, self.compress)
            qb, sb = q4.quantize_q4(b, self.compress)
            self._qa4 = _set_slot(self._qa4, s, qa)
            self._sa = _set_slot(self._sa, s, sa)
            self._qb4 = _set_slot(self._qb4, s, qb)
            self._sb = _set_slot(self._sb, s, sb)
        elif self.compress == "int8":
            qa, sa = quantize_int8(a)
            qb, sb = quantize_int8(b)
            self._qa = _set_slot(self._qa, s, qa)
            self._sa = _set_slot(self._sa, s, sa)
            self._qb = _set_slot(self._qb, s, qb)
            self._sb = _set_slot(self._sb, s, sb)
        else:
            self._a = _set_slot(self._a, s, a.astype(self._a.dtype))
            self._b = _set_slot(self._b, s, b.astype(self._b.dtype))

    def _assign_slot(self, tenant) -> int:
        """Control-plane half of registration: LRU bookkeeping only.
        Re-registration keeps the tenant's slot; a full pool evicts the
        least-recently-served *unpinned* tenant — a pinned slot (in-flight
        training state, see ``pin``) is never an eviction victim."""
        if tenant in self._lru:
            slot = self._lru[tenant]
            self._lru.move_to_end(tenant)
        else:
            if not self._free:
                victim = next(
                    (t for t in self._lru if t not in self._pinned), None
                )
                if victim is None:
                    raise RuntimeError(
                        f"pool full and all {len(self._lru)} resident tenants "
                        "pinned: cannot evict for a new registration"
                    )
                slot = self._lru.pop(victim)
                self._drop_versions(victim)
                self.stats.evictions += 1
            else:
                slot = self._free.pop()
            self._lru[tenant] = slot
            self.version += 1
        return slot

    # -- versioned slots (control plane, DESIGN.md §13) -----------------------

    def _payload_names(self) -> list[str]:
        """Per-slot pool arrays — everything ``pools()`` serves except the
        shared 4-bit codebook, which is a pool constant, not slot state."""
        return [n for n in self.pools() if n != "code"]

    def slot_payload(self, tenant) -> dict[str, jax.Array]:
        """The tenant's current slot content in storage layout (quantised
        pools stay quantised — the version a rollback would need to restore
        bitwise)."""
        slot = self._lru[tenant]
        return {n: self.pools()[n][slot] for n in self._payload_names()}

    def _push_history(self, tenant) -> None:
        """Archive the tenant's outgoing slot payload (+ its version meta)
        before an overwrite. Host copies: history must survive the donated
        in-place slot write that replaces the live buffers."""
        if self.history_depth < 1:
            return
        meta = self._vmeta.get(tenant, {})
        rec = {
            "payload": {
                n: np.asarray(v) for n, v in self.slot_payload(tenant).items()
            },
            "step": int(meta.get("step", 0)),
            "eval_loss": meta.get("eval_loss"),
        }
        h = self._hist.setdefault(tenant, [])
        h.append(rec)
        del h[: -self.history_depth]

    def _drop_versions(self, tenant) -> None:
        self._hist.pop(tenant, None)
        self._vmeta.pop(tenant, None)

    def history_len(self, tenant) -> int:
        return len(self._hist.get(tenant, ()))

    def version_info(self, tenant) -> dict:
        """{"step", "eval_loss", "history"} of the tenant's served version
        (KeyError if unregistered)."""
        if tenant not in self._lru:
            raise KeyError(f"tenant {tenant!r} has no registered adapters")
        meta = self._vmeta.get(tenant, {})
        return {
            "step": int(meta.get("step", 0)),
            "eval_loss": meta.get("eval_loss"),
            "history": self.history_len(tenant),
        }

    def set_eval_loss(self, tenant, eval_loss) -> None:
        """Stamp the served version's held-out loss (the gate's baseline
        record) without touching the payload."""
        if tenant not in self._lru:
            raise KeyError(f"tenant {tenant!r} has no registered adapters")
        meta = self._vmeta.setdefault(tenant, {"step": 0, "eval_loss": None})
        meta["eval_loss"] = None if eval_loss is None else float(eval_loss)

    def rollback(self, tenant) -> dict:
        """Restore the tenant's previous adapter version into its slot —
        bitwise, since history stores the storage-layout payload — and bump
        ``version`` so every slot-index memo keyed on it invalidates.
        Returns the restored version's {"step", "eval_loss"}. Raises
        KeyError when the tenant has no archived version to roll back to."""
        if tenant not in self._lru:
            raise KeyError(f"tenant {tenant!r} has no registered adapters")
        h = self._hist.get(tenant)
        if not h:
            raise KeyError(f"tenant {tenant!r} has no version history")
        rec = h.pop()
        if not h:
            del self._hist[tenant]
        s = jnp.asarray(self._lru[tenant], jnp.int32)
        for name, arr in rec["payload"].items():
            attr = "_" + name.lower()
            cur = getattr(self, attr)
            val = jnp.asarray(arr, cur.dtype)
            if self.device is not None:
                val = jax.device_put(val, self.device)
            setattr(self, attr, _set_slot(cur, s, val))
        self._vmeta[tenant] = {
            "step": rec["step"], "eval_loss": rec["eval_loss"]
        }
        self.version += 1
        self.stats.rollbacks += 1
        return {"step": rec["step"], "eval_loss": rec["eval_loss"]}

    # -- session pinning ----------------------------------------------------

    def pin(self, tenant) -> None:
        """Exclude a registered tenant's slot from LRU eviction. The session
        runtime pins every tenant with in-flight training state (adapters /
        optimizer moments mid-``adapt``), so a serve-traffic burst can never
        recycle a slot whose index is still baked into a queued fleet batch.
        Idempotent; raises KeyError for unregistered tenants."""
        if tenant not in self._lru:
            raise KeyError(f"tenant {tenant!r} has no registered adapters to pin")
        self._pinned.add(tenant)

    def unpin(self, tenant) -> None:
        """Re-admit a tenant's slot to LRU eviction (no-op if not pinned)."""
        self._pinned.discard(tenant)

    def pinned(self) -> set:
        return set(self._pinned)

    def register(self, tenant, adapters: Params, *, meta: Optional[dict] = None) -> int:
        """Install a tenant's fine-tuned {"A": (L,D,R), "B": (L,R,D)} stack.

        Re-registering overwrites in place (a fresh on-device fine-tune),
        archiving the outgoing payload when ``history > 0``. A full pool
        evicts the least-recently-served tenant. ``meta`` optionally stamps
        the new version's {"step", "eval_loss"}.

        Off-CPU the slot write donates the pool buffers (an in-place
        O(L*D*R) write, never a full-pool copy) — any dict previously
        returned by ``pools()`` is invalidated; re-fetch it after
        registration and never register mid-flight of a computation that
        still holds the old arrays.
        """
        if tenant in self._lru:
            self._push_history(tenant)
        slot = self._assign_slot(tenant)
        self._write_slot(slot, adapters)
        self._vmeta[tenant] = {
            "step": int((meta or {}).get("step", 0)),
            "eval_loss": (meta or {}).get("eval_loss"),
        }
        self.stats.registrations += 1
        return slot

    def register_many(
        self,
        tenants,
        stacked: Params,
        *,
        gate=None,
        meta: Optional[dict] = None,
    ) -> list[int]:
        """Batched registration of a fleet-trained stack: tenant
        ``tenants[i]`` gets ``{"A": stacked["A"][i], "B": stacked["B"][i]}``
        installed via ONE donated scatter per pool array (the fleet
        trainer's write-back path — an in-place O(T*L*D*R) write, never a
        full-pool copy, same donation caveats as ``register``). Returns the
        assigned slots, LRU/eviction semantics identical to T sequential
        ``register`` calls.

        ``gate`` is the control plane's write-back hook (DESIGN.md §13): a
        callable ``tenant -> decision`` drawn from ``GATE_DECISIONS``,
        consulted only for *re*-registrations (a fresh tenant has no served
        version to protect, so its first write-back always lands). A
        non-"accept" decision drops the tenant's rows from the scatter —
        the slot keeps serving the previous version bitwise — and bumps the
        matching gate counter. ``meta`` maps tenant -> {"step", "eval_loss"}
        stamped onto versions that do land."""
        tenants = list(tenants)
        if len(set(tenants)) != len(tenants):
            raise ValueError("duplicate tenants in batched registration")
        if len(tenants) > self.n_slots - 1:
            raise ValueError(
                f"{len(tenants)} tenants exceed pool capacity {self.n_slots - 1}"
            )
        a = jnp.asarray(stacked["A"], jnp.float32)
        b = jnp.asarray(stacked["B"], jnp.float32)
        if (
            a.shape != (len(tenants),) + self._shape_a
            or b.shape != (len(tenants),) + self._shape_b
        ):
            raise ValueError(
                f"stacked shapes {a.shape}/{b.shape} != "
                f"{(len(tenants),) + self._shape_a}/{(len(tenants),) + self._shape_b}"
            )
        write_idx: list[int] = []
        for i, t in enumerate(tenants):
            decision = "accept"
            if gate is not None and t in self._lru:
                decision = gate(t)
                if decision not in GATE_DECISIONS:
                    raise ValueError(f"gate decision {decision!r} for {t!r}")
            if decision == "accept":
                if t in self._lru:
                    self._push_history(t)
                write_idx.append(i)
            elif decision == "reject":
                self.stats.gate_rejected += 1
            else:
                self.stats.gate_quarantined += 1
        writes = set(write_idx)
        slots = []
        for i, t in enumerate(tenants):
            if i in writes:
                slots.append(self._assign_slot(t))
                self._vmeta[t] = {
                    "step": int((meta or {}).get(t, {}).get("step", 0)),
                    "eval_loss": (meta or {}).get(t, {}).get("eval_loss"),
                }
            else:
                # Gated out: slot, payload, and version meta all stay on the
                # previous version; still an LRU touch (the tenant was live).
                self._lru.move_to_end(t)
                slots.append(self._lru[t])
        if not write_idx:
            return slots
        if len(write_idx) < len(tenants):
            w = np.asarray(write_idx)
            a, b = a[w], b[w]
        sv = jnp.asarray([slots[i] for i in write_idx], jnp.int32)
        if self.compress in q4.Q4_KINDS:
            # Rowwise (last-axis) quantisation is per-slot independent, so
            # quantising the whole stack at once matches per-slot writes.
            qa, sa = q4.quantize_q4(a, self.compress)
            qb, sb = q4.quantize_q4(b, self.compress)
            self._qa4 = _set_slot(self._qa4, sv, qa)
            self._sa = _set_slot(self._sa, sv, sa)
            self._qb4 = _set_slot(self._qb4, sv, qb)
            self._sb = _set_slot(self._sb, sv, sb)
        elif self.compress == "int8":
            qa, sa = quantize_int8(a)
            qb, sb = quantize_int8(b)
            self._qa = _set_slot(self._qa, sv, qa)
            self._sa = _set_slot(self._sa, sv, sa)
            self._qb = _set_slot(self._qb, sv, qb)
            self._sb = _set_slot(self._sb, sv, sb)
        else:
            self._a = _set_slot(self._a, sv, a.astype(self._a.dtype))
            self._b = _set_slot(self._b, sv, b.astype(self._b.dtype))
        self.stats.registrations += len(write_idx)
        return slots

    def evict(self, tenant) -> None:
        if tenant in self._pinned:
            raise ValueError(
                f"tenant {tenant!r} is pinned (in-flight training state); "
                "unpin before evicting"
            )
        slot = self._lru.pop(tenant)
        self._drop_versions(tenant)
        self._free.append(slot)
        self.version += 1
        self.stats.evictions += 1

    # -- lookup -------------------------------------------------------------

    def lookup(self, tenants) -> jax.Array:
        """Tenant ids -> (B,) int32 slot indices for the grouped kernel.

        ``None`` maps to the pinned zero slot (base model, no adapter);
        unknown tenants raise — the serving tier decides whether a miss
        means "fine-tune first" or "serve base", not the pool.
        """
        slots = []
        for t in tenants:
            self.stats.lookups += 1
            if t is None:
                slots.append(ZERO_SLOT)
            elif t in self._lru:
                self._lru.move_to_end(t)
                slots.append(self._lru[t])
            else:
                self.stats.misses += 1
                raise KeyError(f"tenant {t!r} has no registered adapters")
        return jnp.asarray(slots, jnp.int32)

    def touch(self, tenants) -> None:
        """LRU-refresh only (no slot-index build): the runtime's memoised
        serve path calls this on cache hits so recency still tracks real
        serving traffic."""
        for t in tenants:
            if t is not None and t in self._lru:
                self._lru.move_to_end(t)

    # -- data plane ---------------------------------------------------------

    def pools(self) -> dict[str, jax.Array]:
        """The stacked arrays the grouped kernel consumes, in storage layout.

        float pool: {"A", "B"}; int8 pool: {"qa", "sa", "qb", "sb"};
        4-bit pool: {"qa4", "sa", "qb4", "sb", "code"} — quantised payloads
        are handed over *raw* (dequant lives in the kernel; ``code`` is the
        16-entry codebook that distinguishes int4 from nf4).
        The dict is a snapshot of the live buffers: ``register`` donates
        them off-CPU, so re-fetch after any registration (see ``register``).
        """
        if self.compress in q4.Q4_KINDS:
            return {
                "qa4": self._qa4, "sa": self._sa,
                "qb4": self._qb4, "sb": self._sb, "code": self._code,
            }
        if self.compress == "int8":
            return {"qa": self._qa, "sa": self._sa, "qb": self._qb, "sb": self._sb}
        return {"A": self._a, "B": self._b}

    # -- session state (checkpoint plane) ------------------------------------

    def slot_table(self) -> dict:
        """JSON-able control plane: LRU-ordered (tenant, slot) pairs, free
        list, pinned tenants, plus the versioning plane — per-tenant version
        meta and history *metadata* ([step, eval_loss] per archived version,
        oldest..newest; payload arrays travel via ``state_arrays``, keyed
        ``hist/h{j}`` in the same LRU x depth enumeration order). Tenant ids
        must be JSON-serialisable for this to round-trip through a
        checkpoint manifest."""
        return {
            "lru": [[t, s] for t, s in self._lru.items()],
            "free": list(self._free),
            "pinned": [t for t in self._lru if t in self._pinned],
            "history_depth": self.history_depth,
            "meta": [
                [t, [m["step"], m["eval_loss"]]]
                for t, m in ((t, self._vmeta[t]) for t in self._lru)
                if t in self._vmeta
            ],
            "history": [
                [t, [[r["step"], r["eval_loss"]] for r in self._hist[t]]]
                for t in self._lru
                if self._hist.get(t)
            ],
        }

    def _hist_enumeration(self) -> list[tuple[Any, int]]:
        """(tenant, depth-index) pairs in the deterministic order history
        payload arrays are keyed under in ``state_arrays`` — LRU order,
        oldest..newest within a tenant — matching ``slot_table()``'s
        "history" entry row for row."""
        out = []
        for t in self._lru:
            for j in range(len(self._hist.get(t, ()))):
                out.append((t, j))
        return out

    def state_arrays(self) -> dict:
        """Everything array-valued a checkpoint must carry: the data plane
        under "data" (``pools()`` layout) and archived version payloads
        under "hist" as flat ``h{k}/{name}`` sub-dicts (enumeration order
        per ``_hist_enumeration``; metadata to reassemble lives in
        ``slot_table()``)."""
        hist = {}
        for k, (t, j) in enumerate(self._hist_enumeration()):
            hist[f"h{k}"] = dict(self._hist[t][j]["payload"])
        return {"data": dict(self.pools()), "hist": hist}

    def load_state(self, arrays: dict, table: dict) -> None:
        """Restore the data plane and control plane saved from a pool of
        identical geometry — the checkpoint restore path. ``arrays`` is a
        ``state_arrays()`` layout ({"data": ..., "hist": ...}); a flat
        ``pools()`` dict (the pre-versioning layout) is also accepted, with
        no history."""
        if "data" in arrays:
            data = arrays["data"]
            hist_payloads = arrays.get("hist", {})
        else:
            data, hist_payloads = arrays, {}
        want = set(self.pools())
        if set(data) != want:
            raise ValueError(f"pool arrays {set(data)} != expected {want}")
        for name, arr in data.items():
            cur = self.pools()[name]
            arr = jnp.asarray(arr, cur.dtype)
            if arr.shape != cur.shape:
                raise ValueError(
                    f"pool array {name}: {arr.shape} != {cur.shape}"
                )
            if self.device is not None:
                arr = jax.device_put(arr, self.device)
            setattr(self, "_" + name.lower(), arr)
        self._lru = OrderedDict((t, int(s)) for t, s in table["lru"])
        self._free = [int(s) for s in table["free"]]
        self._pinned = set(table.get("pinned", ()))
        self._vmeta = {
            t: {"step": int(step), "eval_loss": loss}
            for t, (step, loss) in table.get("meta", [])
        }
        self._hist = {}
        hist_meta = {t: metas for t, metas in table.get("history", [])}
        k = 0
        for t in self._lru:
            for step, loss in hist_meta.get(t, ()):
                payload = hist_payloads.get(f"h{k}")
                if payload is None:
                    raise ValueError(
                        f"history payload h{k} (tenant {t!r}) missing from "
                        "checkpoint arrays"
                    )
                self._hist.setdefault(t, []).append({
                    "payload": {n: np.asarray(v) for n, v in payload.items()},
                    "step": int(step),
                    "eval_loss": loss,
                })
                k += 1
        if k != len(hist_payloads):
            raise ValueError(
                f"{len(hist_payloads)} history payloads in checkpoint, "
                f"manifest accounts for {k}"
            )
        self.version += 1


# ---------------------------------------------------------------------------
# Mesh-native pool: slot -> shard placement over per-shard AdapterPools
# ---------------------------------------------------------------------------


class ShardedAdapterPool:
    """Adapter registry sharded along the mesh's ``data`` axis by tenant.

    Owns the slot->shard placement rule of the mesh-native session
    (DESIGN.md §10): every tenant is *placed* on a logical shard the first
    time the session sees it (balanced round-robin — the shard with the
    fewest placed tenants, lowest index on ties), and its pool slot, cache
    partition, training state, and serve rows live on that shard for the
    rest of the session. Each logical shard holds its own fixed-capacity
    ``AdapterPool`` committed to the shard's physical device, so grouped
    serve/adapt batches route rows to the shard holding their slot and
    never gather adapters across devices.

    Placement is *logical*: the number of shards is a session-layout
    property, fixed at construction and carried through checkpoints, while
    the physical device of shard ``s`` is ``devices[s % len(devices)]`` —
    which is what makes an elastic restore onto a different device count
    bitwise (same group traces, different placement only).

    With ``n_shards == 1`` every delegating method is exactly the wrapped
    single ``AdapterPool`` — the PR 4 serving path, bitwise.
    """

    def __init__(
        self,
        n_slots_per_shard: int,
        cfg: ModelConfig,
        rank: int,
        *,
        n_shards: int = 1,
        devices: Optional[list] = None,
        compress: Optional[str] = None,
        dtype=jnp.float32,
        history: int = 0,
    ):
        if n_shards < 1:
            raise ValueError(f"need >= 1 shard, got {n_shards}")
        devs = list(devices) if devices else [None]
        self.n_shards = n_shards
        self.compress = compress
        self.history_depth = history
        self.shards = [
            AdapterPool(
                n_slots_per_shard, cfg, rank, compress=compress, dtype=dtype,
                device=devs[s % len(devs)], history=history,
            )
            for s in range(n_shards)
        ]
        self._placement: dict[Any, int] = {}

    # -- placement (the rule this class owns) --------------------------------

    def place(self, tenant) -> int:
        """Assign (or return) the tenant's logical shard: balanced
        round-robin at first sight, sticky afterwards."""
        s = self._placement.get(tenant)
        if s is None:
            counts = [0] * self.n_shards
            for sh in self._placement.values():
                counts[sh] += 1
            s = min(range(self.n_shards), key=lambda i: (counts[i], i))
            self._placement[tenant] = s
        return s

    def shard_of(self, tenant) -> int:
        """The tenant's placed shard (``None`` -> shard 0, the zero slot)."""
        if tenant is None:
            return 0
        s = self._placement.get(tenant)
        if s is None:
            raise KeyError(f"tenant {tenant!r} has no shard placement")
        return s

    def unplace(self, tenant) -> None:
        self._placement.pop(tenant, None)

    def placement(self) -> dict:
        return dict(self._placement)

    def route(self, tenants) -> list[tuple[list[int], list]]:
        """Split a serve batch by slot shard: returns, per shard, the
        (original row positions, tenants) of the rows it owns. Base rows
        (``None``) ride shard 0's pinned zero slot."""
        out: list[tuple[list[int], list]] = [([], []) for _ in range(self.n_shards)]
        for pos, t in enumerate(tenants):
            rows, subs = out[self.shard_of(t)]
            rows.append(pos)
            subs.append(t)
        return out

    # -- single-shard delegation (the PR 4 surface) ---------------------------

    def _only(self) -> AdapterPool:
        if self.n_shards != 1:
            raise RuntimeError(
                "multi-shard pool: use route()/shard_pools(s)/lookup_local()"
            )
        return self.shards[0]

    def pools(self) -> dict[str, jax.Array]:
        return self._only().pools()

    def lookup(self, tenants) -> jax.Array:
        return self._only().lookup(tenants)

    def shard_pools(self, s: int) -> dict[str, jax.Array]:
        return self.shards[s].pools()

    def lookup_local(self, s: int, tenants) -> jax.Array:
        """Shard-local slot indices for a routed sub-batch."""
        return self.shards[s].lookup(tenants)

    # -- registry surface (routed by placement) -------------------------------

    def has(self, tenant) -> bool:
        s = self._placement.get(tenant)
        return s is not None and self.shards[s].has(tenant)

    def tenants(self) -> list:
        return [t for p in self.shards for t in p.tenants()]

    def __len__(self) -> int:
        return sum(len(p) for p in self.shards)

    def nbytes(self) -> int:
        return sum(p.nbytes() for p in self.shards)

    @property
    def version(self) -> int:
        """Monotone under every shard's slot-map change (memo key)."""
        return sum(p.version for p in self.shards)

    @property
    def stats(self) -> PoolStats:
        agg = PoolStats()
        for p in self.shards:
            agg.registrations += p.stats.registrations
            agg.evictions += p.stats.evictions
            agg.lookups += p.stats.lookups
            agg.misses += p.stats.misses
            agg.rollbacks += p.stats.rollbacks
            agg.gate_rejected += p.stats.gate_rejected
            agg.gate_quarantined += p.stats.gate_quarantined
        return agg

    def register(self, tenant, adapters: Params, *, meta: Optional[dict] = None) -> int:
        return self.shards[self.place(tenant)].register(
            tenant, adapters, meta=meta
        )

    def register_many(
        self,
        tenants,
        stacked: Params,
        *,
        gate=None,
        meta: Optional[dict] = None,
    ) -> list[int]:
        """Batched write-back, routed by placement. The mesh-native adapt
        path calls this with a same-shard group (one donated scatter on that
        shard's device); mixed groups split into one write per shard.
        ``gate``/``meta`` semantics per ``AdapterPool.register_many`` —
        both are tenant-keyed, so they pass through to shards unsplit."""
        tenants = list(tenants)
        by_shard: dict[int, list[int]] = {}
        for i, t in enumerate(tenants):
            by_shard.setdefault(self.place(t), []).append(i)
        slots = [0] * len(tenants)
        for s, rows in by_shard.items():
            if len(rows) == len(tenants):
                sub = stacked  # same-shard fast path: no gather
            else:
                # Route each shard's rows to ITS device: the source stack
                # may be committed elsewhere, and a committed-input scatter
                # into another shard's pool would be rejected by jit.
                ridx = jnp.asarray(rows)
                sub = jax.tree.map(lambda x: x[ridx], stacked)
                if self.shards[s].device is not None:
                    sub = jax.device_put(sub, self.shards[s].device)
            for i, slot in zip(rows, self.shards[s].register_many(
                    [tenants[i] for i in rows], sub, gate=gate, meta=meta)):
                slots[i] = slot
        return slots

    # -- versioned slots (routed by placement) --------------------------------

    def rollback(self, tenant) -> dict:
        return self.shards[self.shard_of(tenant)].rollback(tenant)

    def version_info(self, tenant) -> dict:
        return self.shards[self.shard_of(tenant)].version_info(tenant)

    def history_len(self, tenant) -> int:
        return self.shards[self.shard_of(tenant)].history_len(tenant)

    def set_eval_loss(self, tenant, eval_loss) -> None:
        self.shards[self.shard_of(tenant)].set_eval_loss(tenant, eval_loss)

    def evict(self, tenant) -> None:
        self.shards[self.shard_of(tenant)].evict(tenant)

    def pin(self, tenant) -> None:
        self.shards[self.shard_of(tenant)].pin(tenant)

    def unpin(self, tenant) -> None:
        s = self._placement.get(tenant)
        if s is not None:
            self.shards[s].unpin(tenant)

    def pinned(self) -> set:
        return set().union(*(p.pinned() for p in self.shards))

    def touch(self, tenants) -> None:
        for t in tenants:
            if t is not None and t in self._placement:
                self.shards[self._placement[t]].touch([t])

    # -- session state (checkpoint plane) ------------------------------------

    def state_arrays(self) -> dict:
        """Per-shard state (data plane + archived version payloads), keyed
        ``"s<shard>"`` (checkpoint layout)."""
        return {f"s{i}": p.state_arrays() for i, p in enumerate(self.shards)}

    def slot_table(self) -> dict:
        """JSON-able control plane: the placement map + per-shard tables."""
        return {
            "n_shards": self.n_shards,
            "placement": [[t, s] for t, s in self._placement.items()],
            "shards": [p.slot_table() for p in self.shards],
        }

    def load_state(self, arrays: dict, table: dict) -> None:
        if int(table["n_shards"]) != self.n_shards:
            raise ValueError(
                f"checkpoint has {table['n_shards']} pool shards, "
                f"this session is laid out for {self.n_shards} "
                "(logical shard count is a session-layout property; "
                "elastic restarts change devices, not shards)"
            )
        self._placement = {t: int(s) for t, s in table["placement"]}
        for i, p in enumerate(self.shards):
            p.load_state(arrays[f"s{i}"], table["shards"][i])


def grouped_skip_sum(
    acts: jax.Array,
    pools: dict[str, jax.Array],
    idx: jax.Array,
    *,
    use_kernel: bool = True,
    fused: bool = False,
) -> jax.Array:
    """Per-row skip-sum over a stacked pool: unpacks the pool layout (float,
    raw-int8, or packed-4-bit) and forwards to the grouped kernel wrappers,
    which own the row flattening, stop_gradient contract, and kernel/oracle
    dispatch.

    acts: (L, B, S, D); idx: (B,) int32 -> (B, S, D).

    ``fused=True`` skips the grouped Pallas dispatch and inlines the dense
    per-row gather + einsum instead — XLA then fuses the skip term straight
    into the enclosing (decode) program: no kernel-launch boundary, no
    sort/pad/scatter of B rows up to a (1 + groups) x tile buffer. At decode
    shape (a handful of rows) the padding dominates the kernel's work, so
    the fused form is the fast path; at prefill shape the grouped kernel
    wins and ``fused`` should stay off.
    """
    from repro.kernels.skip_lora.ops import (
        skip_lora_grouped,
        skip_lora_grouped_int8,
        skip_lora_grouped_q4,
    )
    from repro.runtime.sharding import constrain

    # Under a model-axis scope the stacked activations stay partitioned over
    # L: each shard contracts only its resident blocks' skip terms and GSPMD
    # stitches the (B, S, D) result with one reduce. No-op on 1-D meshes.
    acts = constrain(acts, "layers", None, None, None)
    use_kernel = use_kernel and not fused
    if "qa4" in pools:
        return skip_lora_grouped_q4(
            acts, pools["qa4"], pools["sa"], pools["qb4"], pools["sb"],
            pools["code"], idx, use_kernel=use_kernel,
        )
    if "qa" in pools:
        return skip_lora_grouped_int8(
            acts, pools["qa"], pools["sa"], pools["qb"], pools["sb"], idx,
            use_kernel=use_kernel,
        )
    return skip_lora_grouped(
        acts, pools["A"], pools["B"], idx, use_kernel=use_kernel
    )
