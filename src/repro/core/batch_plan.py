"""Shared epoch batch planner: one index-matrix helper for every trainer.

The repo grew two copies of the same planning logic — the single-tenant
``finetune.epoch_index_matrix`` (jax PRNG permutation, wrap tail) and the
fleet ``fleet_finetune.fleet_index_matrix`` (numpy per-tenant streams, wrap
tail, partition offsets). Both reduce to: *visit a permutation in batches,
and decide what to do with a non-dividing tail*. This module is that one
decision, with both tail semantics explicit:

  - ``tail="wrap"``: the last batch wraps around to the front of the
    permutation, so every row is visited at least once and every batch is
    full. This is the populate-safe choice — dropping the remainder would
    leave rows unpopulated in epoch 0 that a later epoch's different
    permutation would then read back as garbage (or a KeyError on the
    tiered-engine path). Wrapped rows are visited twice in that epoch.
  - ``tail="mask"``: the tail is padded (with wrapped ids, so every gather
    stays in-bounds) and a boolean validity mask flags the padding. Every
    row is visited *exactly once*; callers that can mask per-row work
    (e.g. ``lm_loss_rows`` with label ``-1``) use this to avoid the double
    visit without silently dropping the tail.

``core.finetune`` and ``core.fleet_finetune`` re-export their historical
entry points as thin wrappers over this module; the session runtime
(``core.runtime``) plans through it directly with explicit tenant
partitions.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.data.pipeline import epoch_permutation


def index_matrix(
    perm, batch_size: int, *, tail: str = "wrap"
) -> np.ndarray | tuple[np.ndarray, np.ndarray]:
    """Batch a visitation order. ``perm``: (n,) row ids (any integer dtype).

    ``tail="wrap"`` -> (steps, batch) ids;
    ``tail="mask"`` -> ((steps, batch) ids, (steps, batch) bool validity).
    ``batch_size`` is clamped to n; steps = ceil(n / batch).
    """
    if tail not in ("wrap", "mask"):
        raise ValueError(f"unknown tail semantics {tail!r}")
    perm = np.asarray(perm)
    n = perm.shape[0]
    if n == 0:
        raise ValueError("empty permutation")
    bs = min(batch_size, n)
    steps = -(-n // bs)  # ceil
    pad = steps * bs - n
    ids = np.concatenate([perm, perm[:pad]]) if pad else perm
    ids = ids.reshape(steps, bs)
    if tail == "wrap":
        return ids
    valid = np.ones(steps * bs, bool)
    if pad:
        valid[n:] = False
    return ids, valid.reshape(steps, bs)


def shadow_split(
    n_rows: int, *, every: Optional[int]
) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic held-out split of a tenant's ingested rows: local row
    ``r`` is held out iff ``(r + 1) % every == 0`` (every ``every``-th row).

    The rule is a pure function of the row id — no RNG — which gives the
    control plane (DESIGN.md §13) the two properties shadow eval needs:

      - *stable under append*: ingesting more rows never reassigns an
        existing row between the train and eval sides, so a tenant's eval
        set only ever grows, and a restored session splits identically;
      - *trainer-visible*: the train side is exactly the complement, so the
        epoch planner can permute train rows only (``holdout_every`` below)
        while eval rows stay untouched by any optimizer step.

    Row 0 is always a train row (``every >= 2`` enforced), so a tenant with
    any data can always train; tenants with ``n_rows < every`` simply have
    an empty eval set (the regression gate stays inactive for them).
    Returns (train_ids, eval_ids), both sorted ascending.
    """
    ids = np.arange(n_rows)
    if every is None:
        return ids, np.empty(0, dtype=ids.dtype)
    if every < 2:
        raise ValueError(f"holdout every {every} < 2 leaves no train rows")
    hold = (ids + 1) % every == 0
    return ids[~hold], ids[hold]


def fleet_eval_index(
    n_tenants: int,
    samples_per_tenant: int,
    *,
    holdout_every: int,
    partitions: Optional[Sequence[int]] = None,
    partition_stride: Optional[int] = None,
) -> np.ndarray:
    """(N * n_eval,) global sample ids of every tenant's held-out rows,
    tenant-contiguous in fleet order (the layout ``per_tenant_loss``
    reduces over). Deterministic — the eval visitation is the identity
    order of ``shadow_split``'s eval side, no RNG stream — so pre- and
    post-adapt eval read the identical rows. Partition/stride semantics
    match ``fleet_index_matrix``."""
    stride = (
        partition_stride if partition_stride is not None else samples_per_tenant
    )
    parts = list(partitions) if partitions is not None else list(range(n_tenants))
    if len(parts) != n_tenants:
        raise ValueError(f"{len(parts)} partitions for {n_tenants} tenants")
    _, eval_ids = shadow_split(samples_per_tenant, every=holdout_every)
    if eval_ids.size == 0:
        raise ValueError(
            f"no held-out rows: {samples_per_tenant} rows at "
            f"holdout_every={holdout_every}"
        )
    return np.concatenate([part * stride + eval_ids for part in parts])


def fleet_index_matrix(
    epoch: int,
    n_tenants: int,
    samples_per_tenant: int,
    batch_per_tenant: int,
    *,
    seed: int = 0,
    partitions: Optional[Sequence[int]] = None,
    partition_stride: Optional[int] = None,
    streams: Optional[Sequence[int]] = None,
    tail: str = "wrap",
    holdout_every: Optional[int] = None,
) -> np.ndarray | tuple[np.ndarray, np.ndarray]:
    """(steps, N * bpt) global sample ids of a tenant-contiguous fleet epoch.

    Column block g belongs to the tenant in fleet position g, who owns cache
    partition ``partitions[g]`` (default: position g owns partition g, the
    offline ``fleet_finetune`` convention). Each tenant has its own RNG
    stream (``seed + streams[g]``, default ``streams = partitions``), so a
    tenant sees the same visitation order it would training alone regardless
    of who else is in the fleet — the session runtime relies on this when an
    ``adapt`` group is a subset (or reordering) of the ingested tenants.
    Sharded sessions split stream from partition: the stream follows the
    tenant's *global* partition id (so a re-sharded session replays the same
    orders) while ``partitions`` offsets into the shard-local id space.

    ``samples_per_tenant`` is the *visited fill* (the rows each tenant has
    actually ingested this epoch); ``partition_stride`` is the *allocated*
    partition width in the global id space (default: equal to the fill, the
    offline trainer's fully-packed layout). The runtime passes its fixed
    allocation stride so partially-filled partitions still address their
    own rows. Tail semantics per ``index_matrix``; ``tail="mask"``
    additionally returns the stacked validity mask.

    ``holdout_every`` activates the shadow split (``shadow_split``): each
    tenant's epoch permutes its *train* rows only — every ``holdout_every``-
    th ingested row is reserved for held-out eval and never appears in a
    training batch. ``None`` (the default) is bitwise the historical plan.
    """
    stride = partition_stride if partition_stride is not None else samples_per_tenant
    if stride < samples_per_tenant:
        raise ValueError(
            f"partition stride {stride} < fill {samples_per_tenant}"
        )
    parts = list(partitions) if partitions is not None else list(range(n_tenants))
    if len(parts) != n_tenants:
        raise ValueError(f"{len(parts)} partitions for {n_tenants} tenants")
    strm = list(streams) if streams is not None else parts
    if len(strm) != n_tenants:
        raise ValueError(f"{len(strm)} streams for {n_tenants} tenants")
    train_rows, _ = shadow_split(samples_per_tenant, every=holdout_every)
    if train_rows.size == 0:
        raise ValueError("shadow split left no train rows")
    cols, masks = [], []
    for part, stream in zip(parts, strm):
        # The permutation is drawn over the train count and mapped through
        # the (sorted) train ids, so the holdout-free plan (train_rows ==
        # arange(n)) is bitwise the historical one.
        perm = train_rows[
            epoch_permutation(seed + stream, epoch, train_rows.size)
        ]
        planned = index_matrix(perm, batch_per_tenant, tail=tail)
        if tail == "mask":
            planned, valid = planned
            masks.append(valid)
        cols.append(part * stride + planned)
    ids = np.concatenate(cols, axis=1)
    if tail == "mask":
        return ids, np.concatenate(masks, axis=1)
    return ids


def plan_admissions(
    pending: Sequence,
    in_flight,
    free_rows: int,
    *,
    cap: int,
    bucket: int,
) -> list[int]:
    """Pick which queued requests the scheduler admits into the live batch.

    ``pending`` is the arrival-ordered queue, each element exposing a
    ``tenant`` attribute; ``in_flight`` maps tenant -> rows it currently
    occupies; ``free_rows`` is how many batch rows are open; ``cap`` bounds
    a single tenant's total rows (in-flight + admitted now); ``bucket`` is
    the admission width of one dispatch. Returns indices into ``pending``
    in arrival order.

    The walk is a single pass over the global FIFO that *skips* (rather
    than waits on) requests whose tenant is at cap, which yields exactly
    the ISSUE's fairness contract: FIFO within each tenant (a tenant's own
    requests are only ever admitted in arrival order), a hard per-tenant
    occupancy bound, and no head-of-line blocking — one chatty tenant at
    cap cannot stall the tenants queued behind it.
    """
    if cap < 1:
        raise ValueError(f"per-tenant in-flight cap {cap} < 1")
    budget = min(free_rows, bucket)
    counts = dict(in_flight)
    admitted: list[int] = []
    for i, req in enumerate(pending):
        if len(admitted) >= budget:
            break
        c = counts.get(req.tenant, 0)
        if c >= cap:
            continue
        counts[req.tenant] = c + 1
        admitted.append(i)
    return admitted
