"""Tiered Skip-Cache engine: one placement policy over HBM and host tiers.

The repo grew two cache implementations — the device-resident ``SkipCache``
pytree (``repro/core/skip_cache.py``) and the disk-backed ``HostCacheStore``
(``repro/core/cache_store.py``). ``TieredCacheEngine`` unifies them behind a
single read / write / prefetch API (DESIGN.md §4):

  - **HBM tier**: a fixed-capacity ``SkipCache`` whose rows are *slots*, not
    sample ids; a host-side LRU map assigns sample -> slot. All data-plane
    gathers/scatters stay the jitted ``cache_read``/``cache_write`` ops.
  - **Host tier**: receives LRU spills. In-memory (numpy) by default, or the
    crash-safe mmap'd ``HostCacheStore`` when a directory is given — the
    same bytes either way, so a spilled row reads back bit-identical.
  - **Placement**: capacity-driven. ``capacity`` rows directly, or derived
    from ``hbm_budget_bytes`` and the per-row footprint. Reads promote host
    rows back into HBM, evicting the least-recently-used resident rows.
  - **Compression**: ``compress="int8"`` stores float slots rowwise-quantised
    (int8 payload + fp32 scales) in *both* tiers. ``read`` dequantises;
    ``read_raw`` hands the quantised payload straight to the fused
    ``skip_lora_fwd_int8`` Pallas kernel so dequant never round-trips HBM.
  - **Prefetch**: ``prefetch(ids)`` stages the next batch's host-tier rows
    on a background thread (double buffering) so a cached step overlapped
    with it only ever sees a host->device copy, not disk/IO latency.

The engine is the orchestration plane; it owns no math. Equivalence with the
untiered paths is enforced by ``tests/test_cache_engine.py``.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.cache_store import HostCacheStore
from repro.core.skip_cache import SkipCache, cache_read, cache_write

Layout = dict[str, tuple[tuple, Any]]  # name -> (per-sample shape, dtype)


@dataclasses.dataclass
class CacheStats:
    """Per-engine counters (sample granularity, not batch granularity)."""

    hbm_hits: int = 0
    host_hits: int = 0
    staged_hits: int = 0
    spills: int = 0  # rows evicted from HBM to the host tier
    writes: int = 0

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, f.default)

    def reads(self) -> int:
        return self.hbm_hits + self.host_hits + self.staged_hits

    def hbm_hit_rate(self) -> float:
        r = self.reads()
        return self.hbm_hits / r if r else 0.0

    def as_rows(self, prefix: str = "cache_engine") -> list[tuple[str, float]]:
        return [
            (f"{prefix}/hbm_hits", float(self.hbm_hits)),
            (f"{prefix}/host_hits", float(self.host_hits)),
            (f"{prefix}/staged_hits", float(self.staged_hits)),
            (f"{prefix}/spills", float(self.spills)),
            (f"{prefix}/hbm_hit_rate", self.hbm_hit_rate()),
        ]


# ---------------------------------------------------------------------------
# Host tiers
# ---------------------------------------------------------------------------


class MemoryHostTier:
    """In-memory host tier: per-sample numpy rows (the fast default)."""

    def __init__(self, layout: Layout):
        self.layout = layout
        self._rows: dict[int, dict[str, np.ndarray]] = {}

    def write(self, ids, values: dict[str, np.ndarray]) -> None:
        for pos, sid in enumerate(int(i) for i in ids):
            self._rows[sid] = {name: np.asarray(values[name][pos]) for name in values}

    def read(self, ids) -> dict[str, np.ndarray]:
        rows = [self._rows[int(i)] for i in ids]
        return {name: np.stack([r[name] for r in rows]) for name in self.layout}

    def has(self, sample_id: int) -> bool:
        return int(sample_id) in self._rows


class DiskHostTier:
    """Disk-backed host tier: thin adapter over ``HostCacheStore``."""

    def __init__(self, directory: str, layout: Layout):
        self.layout = layout
        self.store = HostCacheStore(directory, layout)

    def write(self, ids, values: dict[str, np.ndarray]) -> None:
        self.store.flush_batch(np.asarray(list(ids)), values)

    def read(self, ids) -> dict[str, np.ndarray]:
        return self.store._read_batch_sync(tuple(int(i) for i in ids))

    def has(self, sample_id: int) -> bool:
        return self.store.has(int(sample_id))


# ---------------------------------------------------------------------------
# int8 slot compression (shared by both tiers)
# ---------------------------------------------------------------------------


def _is_compressible(shape: tuple, dtype) -> bool:
    return len(shape) >= 1 and jnp.issubdtype(jnp.dtype(dtype), jnp.floating)


def storage_layout(layout: Layout, compress: Optional[str]) -> Layout:
    """The physical layout both tiers store. int8: float slot ``x`` becomes
    ``x/q`` (int8 payload) + ``x/s`` (fp32 rowwise scales, last axis dropped)."""
    if compress is None:
        return dict(layout)
    if compress != "int8":
        raise ValueError(f"unknown compression {compress!r}")
    out: Layout = {}
    for name, (shape, dtype) in layout.items():
        if _is_compressible(shape, dtype):
            out[f"{name}/q"] = (tuple(shape), jnp.int8)
            out[f"{name}/s"] = (tuple(shape[:-1]), jnp.float32)
        else:
            out[name] = (tuple(shape), dtype)
    return out


def _quantize_slot(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    from repro.core.lm_skiplora import quantize_int8

    return quantize_int8(x)


def _dequantize_slot(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    from repro.core.lm_skiplora import dequantize_int8

    return dequantize_int8(q, scale, dtype)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class TieredCacheEngine:
    def __init__(
        self,
        num_samples: int,
        layout: Layout,
        *,
        capacity: Optional[int] = None,
        hbm_budget_bytes: Optional[int] = None,
        directory: Optional[str] = None,
        compress: Optional[str] = None,
        device=None,
    ):
        if (capacity is None) == (hbm_budget_bytes is None):
            raise ValueError("pass exactly one of capacity / hbm_budget_bytes")
        self.num_samples = num_samples
        self.layout = {n: (tuple(s), jnp.dtype(d)) for n, (s, d) in layout.items()}
        self.compress = compress
        #: Device the HBM tier is committed to (``None``: jax default). A
        #: mesh-native session gives every shard its own engine committed to
        #: the shard's device, so cached adapt dispatches never gather rows
        #: across devices.
        self.device = device
        self._storage = storage_layout(self.layout, compress)
        if capacity is None:
            capacity = max(1, hbm_budget_bytes // self.row_nbytes())
        self.capacity = min(int(capacity), num_samples)

        slots = {
            name: self._commit(jnp.zeros((self.capacity,) + shape, dtype))
            for name, (shape, dtype) in self._storage.items()
        }
        self._device = SkipCache(
            slots=slots, valid=self._commit(jnp.zeros((self.capacity,), jnp.bool_))
        )
        self._host = (
            DiskHostTier(directory, self._storage)
            if directory is not None
            else MemoryHostTier(self._storage)
        )
        self._lru: OrderedDict[int, int] = OrderedDict()  # sample id -> HBM row
        self._free: list[int] = list(range(self.capacity - 1, -1, -1))
        self._present: set[int] = set()

        self._staged: dict[int, dict[str, np.ndarray]] = {}
        self._prefetch_thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self.stats = CacheStats()

    def _commit(self, arr: jax.Array) -> jax.Array:
        return jax.device_put(arr, self.device) if self.device is not None else arr

    # -- footprint ----------------------------------------------------------

    def row_nbytes(self) -> int:
        total = 0
        for shape, dtype in self._storage.values():
            n = 1
            for s in shape:
                n *= s
            total += n * jnp.dtype(dtype).itemsize
        return total

    def hbm_nbytes(self) -> int:
        return self.capacity * self.row_nbytes()

    def resident_ids(self) -> list[int]:
        return list(self._lru.keys())

    def has(self, sample_id: int) -> bool:
        return int(sample_id) in self._present

    # -- compression --------------------------------------------------------

    def _encode(self, values: dict[str, jax.Array]) -> dict[str, jax.Array]:
        """Logical batch values -> storage-layout values."""
        if self.compress is None:
            return {n: values[n] for n in self.layout}
        out = {}
        for name, (shape, dtype) in self.layout.items():
            if _is_compressible(shape, dtype):
                q, s = _quantize_slot(values[name])
                out[f"{name}/q"] = q
                out[f"{name}/s"] = s
            else:
                out[name] = values[name]
        return out

    def _decode(self, stored: dict[str, jax.Array]) -> dict[str, jax.Array]:
        """Storage-layout batch values -> logical values (dequantised)."""
        if self.compress is None:
            return stored
        out = {}
        for name, (shape, dtype) in self.layout.items():
            if _is_compressible(shape, dtype):
                out[name] = _dequantize_slot(
                    stored[f"{name}/q"], stored[f"{name}/s"], dtype
                )
            else:
                out[name] = stored[name]
        return out

    # -- placement ----------------------------------------------------------

    def _evict_rows(self, count: int, pinned: set[int]) -> None:
        """Spill the ``count`` least-recently-used unpinned rows to host."""
        victims = [sid for sid in self._lru if sid not in pinned][:count]
        if len(victims) < count:
            raise RuntimeError(
                f"HBM tier too small: need {count} free rows, capacity "
                f"{self.capacity}, pinned {len(pinned)}"
            )
        rows = jnp.asarray([self._lru[sid] for sid in victims])
        vals = cache_read(self._device, rows)
        host_vals = {k: np.asarray(v) for k, v in vals.items()}
        self._host.write(victims, host_vals)
        for sid in victims:
            self._free.append(self._lru.pop(sid))
        self.stats.spills += len(victims)

    def _allocate(self, ids: list[int], pinned: set[int]) -> list[int]:
        need = len(ids) - len(self._free)
        if need > 0:
            self._evict_rows(need, pinned)
        return [self._free.pop() for _ in ids]

    def _ensure_resident(self, ids: list[int]) -> None:
        missing = list(dict.fromkeys(i for i in ids if i not in self._lru))
        if not missing:
            return
        staged_rows, host_ids = {}, []
        with self._lock:
            for i in missing:
                if i in self._staged:
                    staged_rows[i] = self._staged.pop(i)
                else:
                    host_ids.append(i)
        if host_ids:
            self.wait()  # a prefetch in flight may be racing for the same ids
            with self._lock:
                for i in list(host_ids):
                    if i in self._staged:
                        staged_rows[i] = self._staged.pop(i)
                        host_ids.remove(i)
        host_vals = self._host.read(host_ids) if host_ids else None
        self.stats.staged_hits += len(staged_rows)
        self.stats.host_hits += len(host_ids)

        rows = self._allocate(missing, pinned=set(ids))
        batch = {}
        for name in self._storage:
            parts = []
            for i in missing:
                if i in staged_rows:
                    parts.append(staged_rows[i][name])
                else:
                    parts.append(host_vals[name][host_ids.index(i)])
            batch[name] = jnp.asarray(np.stack(parts))
        self._device = cache_write(self._device, jnp.asarray(rows), batch)
        for i, r in zip(missing, rows):
            self._lru[i] = r

    def _touch(self, ids: list[int]) -> None:
        for i in ids:
            self._lru.move_to_end(i)

    # -- public API ---------------------------------------------------------

    def write(self, idx, values: dict[str, jax.Array]) -> None:
        """Place a batch (populate step output). values[name]: (B, *shape).

        New ids land in the HBM tier; if it is full, LRU rows spill to host
        first. Ids already resident are overwritten in place.
        """
        ids = [int(i) for i in np.asarray(idx).tolist()]
        stored = self._encode(values)
        # A write supersedes any prefetch staged (or in flight) before it.
        self.wait()
        with self._lock:
            for i in ids:
                self._staged.pop(i, None)
        if len(ids) > self.capacity:
            # Batch can never be HBM-resident: write straight to host tier.
            self._host.write(ids, {k: np.asarray(v) for k, v in stored.items()})
            for i in dict.fromkeys(ids):
                if i in self._lru:
                    self._free.append(self._lru.pop(i))  # host copy is newer
            self._present.update(ids)
            self.stats.writes += len(ids)  # host-direct, not an HBM spill
            return
        resident = [i for i in ids if i in self._lru]
        fresh = list(dict.fromkeys(i for i in ids if i not in self._lru))
        rows_of: dict[int, int] = {i: self._lru[i] for i in resident}
        if fresh:
            for i, r in zip(fresh, self._allocate(fresh, pinned=set(ids))):
                rows_of[i] = r
        rows = jnp.asarray([rows_of[i] for i in ids])
        self._device = cache_write(self._device, rows, stored)
        for i in ids:
            self._lru[i] = rows_of[i]
            self._lru.move_to_end(i)
        self._present.update(ids)
        self.stats.writes += len(ids)

    def _read_oversized(self, ids: list[int]) -> dict[str, jax.Array]:
        """Batch larger than the HBM tier: assemble without promotion (the
        batch could never become resident anyway)."""
        resident = [i for i in ids if i in self._lru]
        missing = list(dict.fromkeys(i for i in ids if i not in self._lru))
        self.stats.hbm_hits += len(resident)
        parts: dict[int, dict[str, np.ndarray]] = {}
        if resident:
            rows = jnp.asarray([self._lru[i] for i in resident])
            vals = cache_read(self._device, rows)
            for pos, i in enumerate(resident):
                parts[i] = {k: np.asarray(v[pos]) for k, v in vals.items()}
        if missing:
            self.wait()
            with self._lock:
                for i in list(missing):
                    if i in self._staged:
                        parts[i] = self._staged.pop(i)
                        missing.remove(i)
                        self.stats.staged_hits += 1
        if missing:
            self.stats.host_hits += len(missing)
            vals = self._host.read(missing)
            for pos, i in enumerate(missing):
                parts[i] = {k: vals[k][pos] for k in self._storage}
        return {
            name: jnp.asarray(np.stack([parts[i][name] for i in ids]))
            for name in self._storage
        }

    def _read_stored(self, idx) -> dict[str, jax.Array]:
        ids = [int(i) for i in np.asarray(idx).tolist()]
        unknown = [i for i in ids if i not in self._present]
        if unknown:
            raise KeyError(f"sample ids never written: {unknown[:8]}")
        if len(ids) > self.capacity:
            return self._read_oversized(ids)
        self.stats.hbm_hits += sum(1 for i in ids if i in self._lru)
        self._ensure_resident(ids)
        self._touch(ids)
        rows = jnp.asarray([self._lru[i] for i in ids])
        return cache_read(self._device, rows)

    def read(self, idx) -> dict[str, jax.Array]:
        """Gather a batch in logical layout (dequantised), promoting any
        host-tier rows into HBM."""
        return self._decode(self._read_stored(idx))

    def read_raw(self, idx) -> dict[str, jax.Array]:
        """Gather a batch in *storage* layout. With ``compress="int8"`` this
        returns ``name/q`` / ``name/s`` slots ready for the fused
        ``skip_lora_fwd_int8`` kernel — dequant stays inside the kernel."""
        return self._read_stored(idx)

    def prefetch(self, idx) -> None:
        """Stage host-tier rows for an upcoming batch on a background thread
        (double buffering: overlap with the in-flight adapter step)."""
        ids = [int(i) for i in np.asarray(idx).tolist()]
        with self._lock:
            todo = [
                i
                for i in ids
                if i in self._present and i not in self._lru and i not in self._staged
            ]
        if not todo:
            return

        def work():
            vals = self._host.read(todo)
            with self._lock:
                for pos, i in enumerate(todo):
                    self._staged[i] = {
                        name: vals[name][pos] for name in self._storage
                    }

        if self._prefetch_thread is not None and self._prefetch_thread.is_alive():
            self._prefetch_thread.join()
        self._prefetch_thread = threading.Thread(target=work, daemon=True)
        self._prefetch_thread.start()

    def wait(self) -> None:
        if self._prefetch_thread is not None:
            self._prefetch_thread.join()

    def flush_to_host(self) -> None:
        """Write every resident row through to the host tier (persistence
        point; resident rows stay readable from HBM)."""
        ids = list(self._lru.keys())
        if not ids:
            return
        rows = jnp.asarray([self._lru[i] for i in ids])
        vals = cache_read(self._device, rows)
        self._host.write(ids, {k: np.asarray(v) for k, v in vals.items()})

    def stream_batches(self, idx_mat):
        """Iterate a (steps, batch) id matrix as ``(idx_row, values)`` pairs
        with double-buffered prefetch: batch i+1 is staged on the background
        thread while the caller's step for batch i runs. The canonical
        streaming-epoch loop — all engine-driven epochs go through this."""
        idx_np = np.asarray(idx_mat)
        self.prefetch(idx_np[0])
        for i in range(idx_np.shape[0]):
            vals = self.read(idx_np[i])
            if i + 1 < idx_np.shape[0]:
                self.prefetch(idx_np[i + 1])
            yield idx_np[i], vals

    def tenant_view(self, tenant: int, samples_per_tenant: int) -> "TenantView":
        """Per-tenant window for fleet partitioning (DESIGN.md §8): tenant
        ``t`` owns the contiguous global id range
        ``[t * samples_per_tenant, (t+1) * samples_per_tenant)``. Views
        share this engine's tiers, LRU and stats — the partition is an id
        convention, not a data split, so a fleet batch mixing every
        tenant's rows is still one engine read."""
        if (tenant + 1) * samples_per_tenant > self.num_samples:
            raise ValueError(
                f"tenant {tenant} x {samples_per_tenant} rows exceeds "
                f"engine size {self.num_samples}"
            )
        return TenantView(self, tenant, samples_per_tenant)

    def export_skipcache(self) -> SkipCache:
        """Materialise an id-indexed ``SkipCache`` over all present samples
        (logical layout). This is the scan fast path: when the whole set fits
        HBM, epochs run as one fused dispatch over this pytree."""
        slots = {
            name: self._commit(jnp.zeros((self.num_samples,) + shape, dtype))
            for name, (shape, dtype) in self.layout.items()
        }
        out = SkipCache(
            slots=slots, valid=self._commit(jnp.zeros((self.num_samples,), jnp.bool_))
        )
        ids = sorted(self._present)
        for lo in range(0, len(ids), max(1, self.capacity)):
            chunk = ids[lo : lo + max(1, self.capacity)]
            vals = self.read(jnp.asarray(chunk))
            out = cache_write(out, jnp.asarray(chunk), vals)
        return out


# ---------------------------------------------------------------------------
# Fleet partitioning: per-tenant views over one engine
# ---------------------------------------------------------------------------


class TenantView:
    """A tenant's cache partition: local ids ``0..samples_per_tenant-1``
    offset into the owning engine's global id space. The fleet trainer
    (``core.fleet_finetune``) populates per tenant through views and reads
    fleet batches (all tenants at once) through the engine directly."""

    def __init__(self, engine: TieredCacheEngine, tenant: int, samples_per_tenant: int):
        self.engine = engine
        self.tenant = tenant
        self.samples_per_tenant = samples_per_tenant
        self.offset = tenant * samples_per_tenant

    def global_ids(self, idx) -> np.ndarray:
        local = np.asarray(idx)
        if local.size and (local.min() < 0 or local.max() >= self.samples_per_tenant):
            raise IndexError(
                f"local ids outside tenant partition of {self.samples_per_tenant}"
            )
        return local + self.offset

    def write(self, idx, values) -> None:
        self.engine.write(self.global_ids(idx), values)

    def read(self, idx):
        return self.engine.read(self.global_ids(idx))

    def read_raw(self, idx):
        return self.engine.read_raw(self.global_ids(idx))

    def prefetch(self, idx) -> None:
        self.engine.prefetch(self.global_ids(idx))

    def has(self, sample_id: int) -> bool:
        return self.engine.has(int(sample_id) + self.offset)
