"""Host-offloaded Skip-Cache store with double-buffered prefetch.

The device-resident ``SkipCache`` works when the whole activation cache fits
HBM (freeze_a mode, or small fine-tune sets). At production scale the full
cache is host memory / disk territory: gemma3-27b at seq 4096 is 2.6 GiB
per sample (bf16) — a 10k-sample fine-tune set is ~26 TiB, striped across
hosts.

``HostCacheStore`` is that tier for a single host (the multi-host version
stripes by ``sample_id % host_count``, which the data pipeline already
guarantees aligns with batch host-slicing):

  - slots are memory-mapped per-sample binary files (O(1) random access,
    crash-safe: a sample is visible only after an fsync'd flush),
  - ``prefetch(ids)`` stages the *next* batch into pinned host buffers on a
    background thread while the current step runs (double buffering), so
    the cached step sees host->device transfer, never disk latency,
  - reads return the exact pytree the cached step consumes.

The populate step writes through the device cache path; ``flush_batch``
moves it host-side. Works with every cache mode (full / int8 / freeze_a).
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import numpy as np

import jax

Params = Any


class HostCacheStore:
    def __init__(self, directory: str, slot_spec: dict[str, tuple[tuple, Any]]):
        """slot_spec: name -> (per-sample shape, dtype) — from
        ``lm_skiplora.lm_cache_layout``."""
        self.directory = directory
        self.slot_spec = {
            name: (tuple(shape), np.dtype(str(np.dtype(dt))))
            for name, (shape, dt) in slot_spec.items()
        }
        os.makedirs(directory, exist_ok=True)
        self._write_manifest()
        self._prefetch_thread: Optional[threading.Thread] = None
        self._prefetched: Optional[tuple[tuple[int, ...], dict[str, np.ndarray]]] = None
        self._lock = threading.Lock()

    # -- layout ------------------------------------------------------------

    def _write_manifest(self) -> None:
        manifest = {
            name: {"shape": list(shape), "dtype": dt.name}
            for name, (shape, dt) in self.slot_spec.items()
        }
        path = os.path.join(self.directory, "cache_manifest.json")
        if not os.path.exists(path):
            with open(path, "w") as f:
                json.dump(manifest, f)

    def _sample_path(self, sample_id: int) -> str:
        return os.path.join(self.directory, f"s{sample_id:08d}.bin")

    def _nbytes(self) -> dict[str, int]:
        return {
            name: int(np.prod(shape, dtype=np.int64)) * dt.itemsize
            for name, (shape, dt) in self.slot_spec.items()
        }

    # -- write path ---------------------------------------------------------

    def flush_batch(self, ids, values: dict[str, Any]) -> None:
        """Persist a populate-step batch. values[name]: (B, *slot shape)
        device or host arrays (device_get happens here)."""
        host_vals = {k: np.asarray(jax.device_get(v)) for k, v in values.items()}
        for row, sample_id in enumerate(np.asarray(ids).tolist()):
            tmp = self._sample_path(sample_id) + ".tmp"
            with open(tmp, "wb") as f:
                for name in sorted(self.slot_spec):
                    arr = host_vals[name][row]
                    want_shape, want_dt = self.slot_spec[name]
                    assert tuple(arr.shape) == want_shape, (name, arr.shape)
                    f.write(np.ascontiguousarray(arr, dtype=want_dt).tobytes())
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self._sample_path(sample_id))

    def has(self, sample_id: int) -> bool:
        return os.path.exists(self._sample_path(sample_id))

    # -- read path ------------------------------------------------------------

    def _read_one(self, sample_id: int) -> dict[str, np.ndarray]:
        out = {}
        with open(self._sample_path(sample_id), "rb") as f:
            mm = f.read()
        off = 0
        for name in sorted(self.slot_spec):
            shape, dt = self.slot_spec[name]
            n = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
            out[name] = np.frombuffer(mm[off : off + n], dtype=dt).reshape(shape)
            off += n
        return out

    def read_batch(self, ids) -> dict[str, np.ndarray]:
        """Batch read: uses the prefetched staging buffer when it matches."""
        key = tuple(int(i) for i in np.asarray(ids).tolist())
        with self._lock:
            if self._prefetched is not None and self._prefetched[0] == key:
                vals = self._prefetched[1]
                self._prefetched = None
                return vals
        return self._read_batch_sync(key)

    def _read_batch_sync(self, key: tuple[int, ...]) -> dict[str, np.ndarray]:
        rows = [self._read_one(i) for i in key]
        return {
            name: np.stack([r[name] for r in rows])
            for name in sorted(self.slot_spec)
        }

    def prefetch(self, ids) -> None:
        """Stage the next batch on a background thread (double buffering)."""
        key = tuple(int(i) for i in np.asarray(ids).tolist())

        def work():
            vals = self._read_batch_sync(key)
            with self._lock:
                self._prefetched = (key, vals)

        if self._prefetch_thread is not None and self._prefetch_thread.is_alive():
            self._prefetch_thread.join()
        self._prefetch_thread = threading.Thread(target=work, daemon=True)
        self._prefetch_thread.start()

    def wait(self) -> None:
        if self._prefetch_thread is not None:
            self._prefetch_thread.join()

    def nbytes_on_disk(self) -> int:
        return sum(
            os.path.getsize(os.path.join(self.directory, f))
            for f in os.listdir(self.directory)
            if f.endswith(".bin")
        )
