"""Analytic compute/memory cost model for FC layers and LoRA adapters.

Implements the taxonomy of Table 1 of the paper (the paper omits the
closed-form costs "due to the page limitation"; we derive them from
Equations 1-16):

FC layer, input N, output M, batch B (MACs counted as 2 FLOPs):
    y  = G(x W + b)        : 2 B N M            (Eq. 1)
    gW = x^T gy            : 2 B N M            (Eq. 2)
    gb = sum_B gy          : B M                (Eq. 3)
    gx = gy W^T            : 2 B N M            (Eq. 4)
    update W,b             : 2 (N M + M)        (Eq. 5-6)

LoRA adapter rank R on that FC:
    y_A = x W_A            : 2 B N R            (Eq. 7)
    y_B = y_A W_B ; y+=y_B : 2 B R M + B M      (Eq. 8-9)
    gW_B = y_A^T gy        : 2 B R M            (Eq. 10)
    gx_B = gy W_B^T        : 2 B R M            (Eq. 11)
    gW_A = x^T gx_B        : 2 B N R            (Eq. 12)
    gx_A = gx_B W_A^T      : 2 B N R            (Eq. 13)
    gx += gx_A             : B N                (Eq. 14)
    update W_A,W_B         : 2 (N R + R M)      (Eq. 15-16)

Compute types (Table 1) select which of these terms a layer pays under a
given fine-tuning method. These closed forms back the Table-2/6/7 ratio
reproduction in benchmarks/ and the roofline sanity checks.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Sequence


class FCType(enum.Enum):
    """Compute types of FC layers (upper half of Table 1)."""

    Y = "fc_y"          # forward only
    YWBX = "fc_ywbx"    # y, gW, gb, gx
    YWB = "fc_ywb"      # y, gW, gb      (first layer: gx not propagated)
    YBX = "fc_ybx"      # y, gb, gx
    YB = "fc_yb"        # y, gb
    YX = "fc_yx"        # y, gx
    NONE = "fc_none"    # layer skipped entirely (cache hit)


class LoRAType(enum.Enum):
    """Compute types of LoRA adapters (lower half of Table 1)."""

    NONE = "lora_none"   # no adapter (phi in the paper)
    Y = "lora_y"         # forward only (serving with adapters)
    YWX = "lora_ywx"     # yA, yB, gWB, gWA, gxB, gxA
    YW = "lora_yw"       # yA, yB, gWB, gWA, gxB (no gx propagation needed)


@dataclasses.dataclass(frozen=True)
class LayerCost:
    """FLOPs for one layer under one compute type, split by phase."""

    forward: float
    backward: float
    update: float

    @property
    def total(self) -> float:
        return self.forward + self.backward + self.update

    def __add__(self, other: "LayerCost") -> "LayerCost":
        return LayerCost(
            self.forward + other.forward,
            self.backward + other.backward,
            self.update + other.update,
        )


ZERO_COST = LayerCost(0.0, 0.0, 0.0)


def fc_cost(fc_type: FCType, batch: int, n: int, m: int) -> LayerCost:
    """FLOPs of an FC layer of shape (n -> m) under ``fc_type``."""
    b = float(batch)
    fwd_y = 2.0 * b * n * m
    bwd_gw = 2.0 * b * n * m
    bwd_gb = b * m
    bwd_gx = 2.0 * b * n * m
    upd = 2.0 * (n * m + m)
    if fc_type is FCType.NONE:
        return ZERO_COST
    if fc_type is FCType.Y:
        return LayerCost(fwd_y, 0.0, 0.0)
    if fc_type is FCType.YWBX:
        return LayerCost(fwd_y, bwd_gw + bwd_gb + bwd_gx, upd)
    if fc_type is FCType.YWB:
        return LayerCost(fwd_y, bwd_gw + bwd_gb, upd)
    if fc_type is FCType.YBX:
        return LayerCost(fwd_y, bwd_gb + bwd_gx, 2.0 * m)
    if fc_type is FCType.YB:
        return LayerCost(fwd_y, bwd_gb, 2.0 * m)
    if fc_type is FCType.YX:
        return LayerCost(fwd_y, bwd_gx, 0.0)
    raise ValueError(f"unknown fc type {fc_type}")


def lora_cost(lora_type: LoRAType, batch: int, n: int, m: int, rank: int) -> LayerCost:
    """FLOPs of a rank-``rank`` LoRA adapter on an (n -> m) FC."""
    b = float(batch)
    fwd = 2.0 * b * n * rank + 2.0 * b * rank * m + b * m
    bwd_gwb = 2.0 * b * rank * m
    bwd_gxb = 2.0 * b * rank * m
    bwd_gwa = 2.0 * b * n * rank
    bwd_gxa = 2.0 * b * n * rank + b * n
    upd = 2.0 * (n * rank + rank * m)
    if lora_type is LoRAType.NONE:
        return ZERO_COST
    if lora_type is LoRAType.Y:
        return LayerCost(fwd, 0.0, 0.0)
    if lora_type is LoRAType.YWX:
        return LayerCost(fwd, bwd_gwb + bwd_gxb + bwd_gwa + bwd_gxa, upd)
    if lora_type is LoRAType.YW:
        return LayerCost(fwd, bwd_gwb + bwd_gxb + bwd_gwa, upd)
    raise ValueError(f"unknown lora type {lora_type}")


def bn_cost(batch: int, m: int, trainable: bool, needs_gx: bool) -> LayerCost:
    """Inference-mode batchnorm: y = gamma * (x - mu) / sigma + beta."""
    b = float(batch)
    fwd = 4.0 * b * m
    bwd = 0.0
    if needs_gx:
        bwd += 2.0 * b * m          # gx = gy * gamma / sigma
    if trainable:
        bwd += 3.0 * b * m          # g_gamma = sum(gy * xhat), g_beta = sum(gy)
    upd = 4.0 * m if trainable else 0.0
    return LayerCost(fwd, bwd, upd)


def act_cost(batch: int, m: int, needs_gx: bool) -> LayerCost:
    """ReLU: 1 FLOP/elt forward, 1 FLOP/elt backward mask."""
    b = float(batch)
    return LayerCost(b * m, (b * m) if needs_gx else 0.0, 0.0)


# ---------------------------------------------------------------------------
# Method-level compositions (Section 3 of the paper).
# ---------------------------------------------------------------------------

#: method name -> (fc types per layer position, lora types per layer position)
#: Layer positions are described for an n-layer net as first / middle / last.


def method_layer_types(
    method: str, n_layers: int
) -> tuple[list[FCType], list[LoRAType]]:
    """FC/LoRA compute types per layer for each fine-tuning method.

    Mirrors Section 3 / Figure 1 of the paper for arbitrary depth n:
    e.g. FT-All is {FC_ywb, FC_ywbx, ..., FC_ywbx}.
    """
    n = n_layers
    if method == "ft_all":
        fcs = [FCType.YWB] + [FCType.YWBX] * (n - 1)
        loras = [LoRAType.NONE] * n
    elif method == "ft_last":
        fcs = [FCType.Y] * (n - 1) + [FCType.YWB]
        loras = [LoRAType.NONE] * n
    elif method == "ft_bias":
        fcs = [FCType.YB] + [FCType.YBX] * (n - 1)
        loras = [LoRAType.NONE] * n
    elif method == "ft_all_lora":
        # FT-All + LoRA-All (the paper's full-cost upper bound, Table 2).
        fcs = [FCType.YWB] + [FCType.YWBX] * (n - 1)
        loras = [LoRAType.YW] + [LoRAType.YWX] * (n - 1)
    elif method == "lora_all":
        fcs = [FCType.Y] + [FCType.YX] * (n - 1)
        loras = [LoRAType.YW] + [LoRAType.YWX] * (n - 1)
    elif method == "lora_last":
        fcs = [FCType.Y] * n
        loras = [LoRAType.NONE] * (n - 1) + [LoRAType.YW]
    elif method in ("skip_lora", "skip2_lora"):
        fcs = [FCType.Y] * n
        loras = [LoRAType.YW] * n
    else:
        raise ValueError(f"unknown method {method!r}")
    return fcs, loras


def method_cost(
    method: str,
    batch: int,
    dims: Sequence[int],
    rank: int,
    *,
    bn: bool = True,
    cache_hit_rate: float = 0.0,
) -> LayerCost:
    """Total per-batch FLOPs for ``method`` on an MLP with layer ``dims``.

    ``dims`` is (d0, d1, ..., dn): layer k maps dims[k-1] -> dims[k].
    ``cache_hit_rate`` only affects skip2_lora: a hit skips the FC forward of
    all layers; the last layer's base output is reused from cache and only
    the adapter sum + re-add is recomputed (Section 4.2).
    """
    n = len(dims) - 1
    fcs, loras = method_layer_types(method, n)
    total = ZERO_COST
    for k in range(n):
        nk, mk = dims[k], dims[k + 1]
        fck = fc_cost(fcs[k], batch, nk, mk)
        if method == "skip2_lora":
            # Expected cost: miss fraction pays full FC forward; hits skip it.
            fck = LayerCost(
                fck.forward * (1.0 - cache_hit_rate), fck.backward, fck.update
            )
        total = total + fck
        # Skip-LoRA adapters map layer-k INPUT -> last-layer output: (nk -> dims[n]).
        if method in ("skip_lora", "skip2_lora"):
            total = total + lora_cost(loras[k], batch, nk, dims[n], rank)
        else:
            total = total + lora_cost(loras[k], batch, nk, mk, rank)
        if bn and k < n - 1:
            # Hidden layers have BN + ReLU (Table 2 structure).
            trainable = method == "ft_bias"
            needs_gx = fcs[k + 1] not in (FCType.Y, FCType.YB, FCType.NONE) or (
                loras[k + 1] in (LoRAType.YWX,)
            )
            bnk = bn_cost(batch, mk, trainable, needs_gx)
            actk = act_cost(batch, mk, needs_gx)
            if method == "skip2_lora":
                bnk = LayerCost(bnk.forward * (1.0 - cache_hit_rate), bnk.backward, bnk.update)
                actk = LayerCost(actk.forward * (1.0 - cache_hit_rate), actk.backward, actk.update)
            total = total + bnk + actk
    return total


def expected_hit_rate(epochs: int) -> float:
    """Expected cache hit rate over an E-epoch run: epoch 1 misses, rest hit."""
    if epochs <= 0:
        return 0.0
    return (epochs - 1.0) / float(epochs)


def trainable_param_count(method: str, dims: Sequence[int], rank: int) -> int:
    """Number of trainable parameters for a method (paper parity checks)."""
    n = len(dims) - 1
    total = 0
    if method == "ft_all":
        total = sum(dims[k] * dims[k + 1] + dims[k + 1] for k in range(n))
        total += sum(2 * dims[k + 1] for k in range(n - 1))  # BN gamma/beta
    elif method == "ft_last":
        total = dims[n - 1] * dims[n] + dims[n]
    elif method == "ft_bias":
        total = sum(dims[k + 1] for k in range(n))
        total += sum(2 * dims[k + 1] for k in range(n - 1))
    elif method == "ft_all_lora":
        total = trainable_param_count("ft_all", dims, rank) + trainable_param_count(
            "lora_all", dims, rank
        )
    elif method == "lora_all":
        total = sum(dims[k] * rank + rank * dims[k + 1] for k in range(n))
    elif method == "lora_last":
        total = dims[n - 1] * rank + rank * dims[n]
    elif method in ("skip_lora", "skip2_lora"):
        total = sum(dims[k] * rank + rank * dims[n] for k in range(n))
    else:
        raise ValueError(f"unknown method {method!r}")
    return int(total)
