"""Adapter control plane: shadow eval, regression gate, rollback policy.

The bitwise-parity story (DESIGN.md §9–§10) guarantees *reproducibility*
of online adaptation, not *quality*: a fleet that adapts millions of
tenant adapters in place has no way to notice when an ``adapt`` step made
a tenant worse. This module is the policy half of the fix (DESIGN.md §13):

  - **shadow eval** — every tenant reserves a deterministic held-out slice
    of its ingested rows (``batch_plan.shadow_split``: local row ``r`` is
    held out iff ``(r + 1) % holdout_every == 0``). The session runtime
    computes pre-/post-adapt held-out loss inside the same fused scan
    dispatch as training, reading the *cached* activations — shadow eval
    never runs the frozen backbone again.
  - **regression gate** — a write-back whose held-out loss regressed by
    more than ``threshold`` is not installed. ``mode="reject"`` also
    freezes the tenant's training state (the next adapt retrains the same
    rows from the served version); ``mode="quarantine"`` lets training
    state advance but keeps serving the old version and flags the tenant
    for operator attention.
  - **rollback ledger** — gate decisions, eval deltas, and rollback counts
    per tenant, surfaced through ``launch/run.py --json`` and
    ``benchmarks/control_bench.py``.

The *mechanism* lives elsewhere: ``AdapterPool`` owns versioned slots and
enforces the gate inside ``register_many`` (a non-accept decision drops
the tenant's rows from the donated scatter), ``SessionRuntime`` owns the
eval dispatch and the reject/quarantine state semantics. This module only
decides and records — it holds no device arrays, so its whole state is a
small JSON-able dict that rides a checkpoint manifest.

Everything here is opt-in: a session without a ``ControlConfig`` plans,
trains, and writes back bitwise as before.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

GATE_MODES = ("reject", "quarantine")


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    """Control-plane policy knobs (DESIGN.md §13).

    holdout_every: every N-th ingested row per tenant is held out for
        shadow eval (>= 2; row 0 always trains). Tenants with fewer rows
        than ``holdout_every`` have an empty eval set and pass ungated.
    threshold: max tolerated held-out regression, ``post - pre`` in nats.
        0.0 = any regression gates; ``float("inf")`` = gate never fires
        (eval/metrics only).
    mode: what a gated write-back does to the tenant's *training* state —
        "reject" freezes it (retrain from the served version next adapt),
        "quarantine" advances it but keeps serving the old payload and
        flags the tenant.
    history_depth: previous adapter versions kept per tenant for
        ``rollback`` (>= 1 so the gate always has a version to protect).
    auto_rollback_after: N consecutive gated (non-accept) write-backs for
        the same tenant trigger an automatic ``rollback(tenant)`` plus an
        optimizer-state reset in the runtime — the served version is
        presumed stale-bad, not merely one noisy epoch. ``None`` (default)
        disables the policy; the manual ``rollback`` path is unaffected.
    """

    holdout_every: int = 4
    threshold: float = 0.0
    mode: str = "reject"
    history_depth: int = 2
    auto_rollback_after: Optional[int] = None

    def __post_init__(self):
        if self.auto_rollback_after is not None and self.auto_rollback_after < 1:
            raise ValueError(
                f"auto_rollback_after {self.auto_rollback_after} < 1 would "
                "roll back unconditionally"
            )
        if self.holdout_every < 2:
            raise ValueError(
                f"holdout_every {self.holdout_every} < 2 leaves no train rows"
            )
        if self.mode not in GATE_MODES:
            raise ValueError(f"unknown gate mode {self.mode!r}")
        if self.history_depth < 1:
            raise ValueError(
                f"history_depth {self.history_depth} < 1: the gate needs at "
                "least one archived version to protect"
            )


class ControlPlane:
    """Per-tenant gate ledger: decides write-backs, records the outcomes.

    One instance per session. Tenant keys are whatever the session uses
    (ints or strings); state round-trips through JSON as lists of pairs,
    so int tenant ids survive a manifest (JSON objects would stringify
    them).
    """

    def __init__(self, config: ControlConfig):
        self.config = config
        #: tenant -> {"pre", "post", "delta", "decision", "step"} of the
        #: most recent gated adapt (None fields while no eval ran).
        self._last: dict[Any, dict] = {}
        #: tenants currently quarantined (served from the pre-adapt
        #: version, flagged for re-adapt / operator attention).
        self._quarantined: set = set()
        #: tenant -> consecutive non-accept gate decisions (the
        #: auto-rollback trigger; reset by an accept or any rollback).
        self._consec_gated: dict[Any, int] = {}
        self.accepted = 0
        self.rejected = 0
        self.quarantined = 0
        self.rollbacks = 0
        self.auto_rollbacks = 0

    # -- decisions -----------------------------------------------------------

    def decide(self, tenant, pre: Optional[float], post: Optional[float]) -> str:
        """Gate one tenant's write-back from its held-out losses.

        ``None`` (no eval rows, or first-ever version) always accepts: a
        fresh tenant has no served version to protect, and a tenant below
        ``holdout_every`` rows has nothing to measure. Otherwise the
        write-back is gated iff ``post - pre > threshold``.
        """
        if pre is None or post is None:
            return "accept"
        if post - pre > self.config.threshold:
            return self.config.mode
        return "accept"

    def record(
        self,
        tenant,
        decision: str,
        *,
        pre: Optional[float] = None,
        post: Optional[float] = None,
        step: int = 0,
    ) -> None:
        """Ledger one gate outcome (the runtime calls this right after
        write-back, whatever ``decide`` said)."""
        self._last[tenant] = {
            "pre": pre,
            "post": post,
            "delta": None if pre is None or post is None else post - pre,
            "decision": decision,
            "step": int(step),
        }
        if decision == "accept":
            self.accepted += 1
            self._quarantined.discard(tenant)
            self._consec_gated.pop(tenant, None)
        elif decision == "reject":
            self.rejected += 1
            self._consec_gated[tenant] = self._consec_gated.get(tenant, 0) + 1
        elif decision == "quarantine":
            self.quarantined += 1
            self._quarantined.add(tenant)
            self._consec_gated[tenant] = self._consec_gated.get(tenant, 0) + 1
        else:
            raise ValueError(f"unknown gate decision {decision!r}")

    def should_auto_rollback(self, tenant) -> bool:
        """True when the auto-rollback policy fires for this tenant: the
        config enables it and the tenant's consecutive non-accept streak
        reached ``auto_rollback_after``. The runtime consults this right
        after ``record``; the streak resets on accept or on any rollback."""
        after = self.config.auto_rollback_after
        return after is not None and self._consec_gated.get(tenant, 0) >= after

    def record_rollback(self, tenant, *, auto: bool = False) -> None:
        self.rollbacks += 1
        if auto:
            self.auto_rollbacks += 1
        self._quarantined.discard(tenant)
        self._consec_gated.pop(tenant, None)
        self._last.pop(tenant, None)

    # -- introspection -------------------------------------------------------

    def is_quarantined(self, tenant) -> bool:
        return tenant in self._quarantined

    def quarantined_tenants(self) -> list:
        return sorted(self._quarantined, key=repr)

    def last(self, tenant) -> Optional[dict]:
        rec = self._last.get(tenant)
        return dict(rec) if rec is not None else None

    def metrics(self) -> dict:
        """JSON-able ledger snapshot (the ``--json`` / bench surface)."""
        return {
            "config": {
                "holdout_every": self.config.holdout_every,
                "threshold": self.config.threshold,
                "mode": self.config.mode,
                "history_depth": self.config.history_depth,
                "auto_rollback_after": self.config.auto_rollback_after,
            },
            "accepted": self.accepted,
            "rejected": self.rejected,
            "quarantined": self.quarantined,
            "rollbacks": self.rollbacks,
            "auto_rollbacks": self.auto_rollbacks,
            "quarantined_tenants": self.quarantined_tenants(),
            "tenants": [[t, dict(rec)] for t, rec in self._last.items()],
        }

    # -- checkpoint plane ----------------------------------------------------

    def state(self) -> dict:
        """JSON-able state for a checkpoint manifest. Tenant-keyed maps go
        as lists of pairs so int tenant ids round-trip."""
        return {
            "last": [[t, dict(rec)] for t, rec in self._last.items()],
            "quarantined": list(self._quarantined),
            "gated_streaks": [[t, n] for t, n in self._consec_gated.items()],
            "counters": [
                self.accepted, self.rejected, self.quarantined, self.rollbacks,
                self.auto_rollbacks,
            ],
        }

    def load_state(self, state: dict) -> None:
        self._last = {t: dict(rec) for t, rec in state.get("last", [])}
        self._quarantined = set(state.get("quarantined", ()))
        self._consec_gated = {
            t: int(n) for t, n in state.get("gated_streaks", [])
        }
        # Pre-auto-rollback manifests stored 4 counters; pad the 5th.
        counters = list(state.get("counters", ())) + [0] * 5
        acc, rej, quar, rb, arb = counters[:5]
        self.accepted, self.rejected = int(acc), int(rej)
        self.quarantined, self.rollbacks = int(quar), int(rb)
        self.auto_rollbacks = int(arb)
