"""Algorithm 1: fine-tuning with Skip2-LoRA (and the seven baselines).

The paper's loop (per epoch, per batch): forward FCs consulting C_skip,
add new results to C_skip, forward LoRA, backward LoRA, update LoRA weights.

TPU-shaped realisation (DESIGN.md §2): epoch 0 runs the *populate* phase
(backbone forward + cache scatter + adapter SGD step); epochs >= 1 run the
*cached* phase (cache gather + adapter SGD step, zero backbone compute).

Each epoch phase is a single ``jax.lax.scan`` over a pre-permuted batch
index matrix — one XLA dispatch per epoch instead of ``n / batch_size``
Python round-trips, which at MLP scale is the difference between dispatch
overhead dominating and the paper's arithmetic actually being the cost.
The per-batch ``_populate_step`` / ``_cached_step`` factories remain as the
step-granular API (examples, streaming ingestion, and the tiered-engine
path in ``cached_epoch_via_engine`` use them).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import donate_argnums
from repro.core import methods as M
from repro.core import skip_cache as C
from repro.models.mlp import MLPConfig, accuracy, cross_entropy

Params = Any


@dataclasses.dataclass
class FinetuneResult:
    trainable: Params
    frozen: Params
    losses: list[float]
    epoch_times_s: list[float]
    cache: C.SkipCache | None = None

    def predict_fn(self, method: str, cfg: MLPConfig) -> Callable:
        def predict(x):
            logits, _ = M.forward(method, self.trainable, self.frozen, x, cfg)
            return logits

        return predict


def epoch_index_matrix(key, n: int, batch_size: int) -> jax.Array:
    """Pre-permuted batch indices, shape (steps, batch). The whole epoch's
    visitation order is decided up front so the epoch can run as one scan.

    Covers ALL n samples via the shared planner's ``tail="wrap"`` semantics
    (``core.batch_plan.index_matrix``): a non-dividing last batch wraps
    around to the front of the permutation. Dropping the remainder would
    leave samples unpopulated in epoch 0, and a later epoch's different
    permutation would then gather all-zero cache rows for them."""
    from repro.core.batch_plan import index_matrix

    perm = np.asarray(jax.random.permutation(key, n))
    return jnp.asarray(index_matrix(perm, batch_size, tail="wrap"))


#: Back-compat alias (pre-fleet name); the fleet trainer and benchmarks
#: made the epoch-order helper part of the public surface.
_epoch_index_matrix = epoch_index_matrix


@functools.cache
def make_epoch_fn(method: str, cfg: MLPConfig) -> Callable:
    """Full-forward epoch as one fused dispatch: scan of train_step.

    Cached per (method, cfg) so repeated ``finetune`` calls (benchmark
    trials) reuse the compiled epoch instead of re-tracing it."""

    def epoch(trainable, frozen, x, y, idx_mat, lr):
        def body(t, idx):
            t, loss = M.train_step(method, cfg, t, frozen, x[idx], y[idx], lr)
            return t, loss

        return jax.lax.scan(body, trainable, idx_mat)

    return jax.jit(epoch, donate_argnums=donate_argnums(0))


def finetune(
    key: jax.Array,
    method: str,
    cfg: MLPConfig,
    backbone: Params,
    x_ft: jax.Array,
    y_ft: jax.Array,
    *,
    epochs: int,
    batch_size: int = 20,
    lr: float = 0.05,
) -> FinetuneResult:
    """Fine-tune with any of the eight methods. Dispatches to the cached
    Algorithm-1 loop for skip2_lora."""
    if method == "skip2_lora":
        return finetune_skip2_lora(
            key, cfg, backbone, x_ft, y_ft, epochs=epochs, batch_size=batch_size, lr=lr
        )
    ikey, lkey = jax.random.split(key)
    trainable, frozen = M.init_method(ikey, cfg, backbone, method)
    n = x_ft.shape[0]
    epoch_fn = make_epoch_fn(method, cfg)
    losses, times = [], []
    rng = lkey
    for _ in range(epochs):
        rng, sk = jax.random.split(rng)
        idx_mat = epoch_index_matrix(sk, n, batch_size)
        t0 = time.perf_counter()
        trainable, ls = epoch_fn(trainable, frozen, x_ft, y_ft, idx_mat, lr)
        jax.block_until_ready(ls)
        losses.append(float(ls[-1]))
        times.append(time.perf_counter() - t0)
    return FinetuneResult(trainable, frozen, losses, times)


# ---------------------------------------------------------------------------
# Skip2-LoRA: Algorithm 1
# ---------------------------------------------------------------------------


def _populate_body(cfg: MLPConfig, trainable, frozen, cache, idx, xb, yb, lr):
    """Backbone forward + cache write + adapter step (first encounter)."""
    # Full forward once; xs[k] is the input feature map of FC layer k and
    # logits_base would require re-running without adapters — instead we
    # exploit linearity: y_base = logits - sum_k x^k A_k B_k.
    logits, xs = M.forward("skip_lora", trainable, frozen, xb, cfg)
    skip = jnp.zeros_like(logits)
    for k, lora in enumerate(trainable["lora"]):
        skip = skip + M.lora_apply(lora, xs[k])
    y_base = logits - skip
    values = {f"x{k}": xs[k] for k in range(1, cfg.n_layers)}
    values["y_base"] = y_base
    cache = C.cache_write(cache, idx, values)

    def loss_fn(t):
        out, _ = M.forward("skip_lora", t, frozen, xb, cfg)
        return cross_entropy(out, yb)

    loss, grads = jax.value_and_grad(loss_fn)(trainable)
    trainable = jax.tree.map(lambda a, b: a - lr * b, trainable, grads)
    return trainable, cache, loss


def _cached_body(cfg: MLPConfig, trainable, cache, idx, xb, yb, lr):
    """Adapter-only step from cached activations (zero backbone compute)."""
    vals = C.cache_read(cache, idx)
    xs = [xb] + [vals[f"x{k}"] for k in range(1, cfg.n_layers)]

    def loss_fn(t):
        out = M.skip_forward_cached(t, vals["y_base"], xs)
        return cross_entropy(out, yb)

    loss, grads = jax.value_and_grad(loss_fn)(trainable)
    trainable = jax.tree.map(lambda a, b: a - lr * b, trainable, grads)
    return trainable, loss


def _populate_step(cfg: MLPConfig):
    """Per-batch jitted populate step (step-granular API)."""

    @jax.jit
    def step(trainable, frozen, cache, idx, xb, yb, lr):
        return _populate_body(cfg, trainable, frozen, cache, idx, xb, yb, lr)

    return step


def _cached_step(cfg: MLPConfig):
    """Per-batch jitted cached step (step-granular API)."""

    @jax.jit
    def step(trainable, cache, idx, xb, yb, lr):
        return _cached_body(cfg, trainable, cache, idx, xb, yb, lr)

    return step


def masked_populate_step(cfg: MLPConfig):
    """Streaming variant: batch may mix cache hits and misses. The backbone
    runs for the whole batch, but only miss rows are written; hit rows keep
    their cached values (bitwise identical activations either way since the
    backbone is frozen — the write is for first-seen samples)."""

    @jax.jit
    def step(trainable, frozen, cache, idx, xb, yb, lr):
        logits, xs = M.forward("skip_lora", trainable, frozen, xb, cfg)
        skip = jnp.zeros_like(logits)
        for k, lora in enumerate(trainable["lora"]):
            skip = skip + M.lora_apply(lora, xs[k])
        values = {f"x{k}": xs[k] for k in range(1, cfg.n_layers)}
        values["y_base"] = logits - skip
        miss = ~C.cache_hits(cache, idx)
        cache = C.cache_write_masked(cache, idx, values, miss)

        def loss_fn(t):
            out, _ = M.forward("skip_lora", t, frozen, xb, cfg)
            return cross_entropy(out, yb)

        loss, grads = jax.value_and_grad(loss_fn)(trainable)
        trainable = jax.tree.map(lambda a, b: a - lr * b, trainable, grads)
        return trainable, cache, loss

    return step


@functools.cache
def make_skip2_epoch_fns(cfg: MLPConfig, *, donate: bool = True) -> tuple[Callable, Callable]:
    """(populate_epoch, cached_epoch), each one fused scan dispatch.

    ``donate=False`` keeps carries alive for callers that re-invoke an epoch
    on the same arrays (benchmark re-timing) on backends with real donation.

    populate_epoch: (trainable, frozen, cache, x, y, idx_mat, lr)
        -> (trainable, cache, losses)
    cached_epoch:   (trainable, cache, x, y, idx_mat, lr)
        -> (trainable, losses)
    """

    def populate_epoch(trainable, frozen, cache, x, y, idx_mat, lr):
        def body(carry, idx):
            t, c = carry
            t, c, loss = _populate_body(cfg, t, frozen, c, idx, x[idx], y[idx], lr)
            return (t, c), loss

        (trainable, cache), losses = jax.lax.scan(body, (trainable, cache), idx_mat)
        return trainable, cache, losses

    def cached_epoch(trainable, cache, x, y, idx_mat, lr):
        def body(t, idx):
            t, loss = _cached_body(cfg, t, cache, idx, x[idx], y[idx], lr)
            return t, loss

        return jax.lax.scan(body, trainable, idx_mat)

    d = donate_argnums if donate else (lambda *a: ())
    return (
        jax.jit(populate_epoch, donate_argnums=d(0, 2)),
        jax.jit(cached_epoch, donate_argnums=d(0)),
    )


@functools.cache
def _engine_step(cfg: MLPConfig) -> Callable:
    """Per-batch cached step from engine-read values (jitted once per cfg)."""

    @jax.jit
    def step(t, vals, xb, yb, lr):
        xs = [xb] + [vals[f"x{k}"] for k in range(1, cfg.n_layers)]

        def loss_fn(tt):
            out = M.skip_forward_cached(tt, vals["y_base"], xs)
            return cross_entropy(out, yb)

        loss, grads = jax.value_and_grad(loss_fn)(t)
        return jax.tree.map(lambda a, b: a - lr * b, t, grads), loss

    return step


def cached_epoch_via_engine(
    cfg: MLPConfig,
    trainable: Params,
    engine,
    x_ft: jax.Array,
    y_ft: jax.Array,
    idx_mat,
    lr: float,
) -> tuple[Params, jax.Array]:
    """Streaming cached epoch through a ``TieredCacheEngine`` — the path
    when the activation cache exceeds the HBM budget. Per-batch engine reads
    with double-buffered prefetch of the *next* batch overlapped with the
    in-flight adapter step."""
    step = _engine_step(cfg)
    loss = jnp.zeros(())
    for idx, vals in engine.stream_batches(idx_mat):
        trainable, loss = step(trainable, vals, x_ft[idx], y_ft[idx], lr)
    return trainable, loss


def finetune_skip2_lora(
    key: jax.Array,
    cfg: MLPConfig,
    backbone: Params,
    x_ft: jax.Array,
    y_ft: jax.Array,
    *,
    epochs: int,
    batch_size: int = 20,
    lr: float = 0.05,
) -> FinetuneResult:
    """Algorithm 1. Epoch 0 populates C_skip; epochs 1..E-1 skip the
    backbone. Every epoch phase is one compiled dispatch (lax.scan)."""
    ikey, lkey = jax.random.split(key)
    trainable, frozen = M.init_method(ikey, cfg, backbone, "skip2_lora")
    n = x_ft.shape[0]
    cache = C.cache_for_mlp(n, cfg.dims, cfg.dtype)
    populate_epoch, cached_epoch = make_skip2_epoch_fns(cfg)
    losses, times = [], []
    rng = lkey
    for e in range(epochs):
        rng, sk = jax.random.split(rng)
        idx_mat = epoch_index_matrix(sk, n, batch_size)
        t0 = time.perf_counter()
        if e == 0:
            trainable, cache, ls = populate_epoch(
                trainable, frozen, cache, x_ft, y_ft, idx_mat, lr
            )
        else:
            trainable, ls = cached_epoch(trainable, cache, x_ft, y_ft, idx_mat, lr)
        jax.block_until_ready(ls)
        losses.append(float(ls[-1]))
        times.append(time.perf_counter() - t0)
    return FinetuneResult(trainable, frozen, losses, times, cache=cache)


def evaluate(
    method: str,
    cfg: MLPConfig,
    result: FinetuneResult,
    x_test: jax.Array,
    y_test: jax.Array,
) -> float:
    logits, _ = M.forward(
        "skip_lora" if method == "skip2_lora" else method,
        result.trainable,
        result.frozen,
        x_test,
        cfg,
    )
    return float(accuracy(logits, y_test))
