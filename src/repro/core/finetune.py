"""Algorithm 1: fine-tuning with Skip2-LoRA (and the seven baselines).

The paper's loop (per epoch, per batch): forward FCs consulting C_skip,
add new results to C_skip, forward LoRA, backward LoRA, update LoRA weights.

TPU-shaped realisation (DESIGN.md §4): epoch 0 runs ``populate_step``
(backbone forward + cache scatter + adapter SGD step); epochs >= 1 run
``cached_step`` (cache gather + adapter SGD step, zero backbone compute).
A masked variant supports streams where batches mix hits and misses.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import methods as M
from repro.core import skip_cache as C
from repro.models.mlp import MLPConfig, accuracy, cross_entropy

Params = Any


@dataclasses.dataclass
class FinetuneResult:
    trainable: Params
    frozen: Params
    losses: list[float]
    epoch_times_s: list[float]
    cache: C.SkipCache | None = None

    def predict_fn(self, method: str, cfg: MLPConfig) -> Callable:
        def predict(x):
            logits, _ = M.forward(method, self.trainable, self.frozen, x, cfg)
            return logits

        return predict


def _epoch_batches(key, n, batch_size):
    perm = jax.random.permutation(key, n)
    steps = n // batch_size
    return [perm[s * batch_size : (s + 1) * batch_size] for s in range(max(1, steps))]


def finetune(
    key: jax.Array,
    method: str,
    cfg: MLPConfig,
    backbone: Params,
    x_ft: jax.Array,
    y_ft: jax.Array,
    *,
    epochs: int,
    batch_size: int = 20,
    lr: float = 0.05,
) -> FinetuneResult:
    """Fine-tune with any of the eight methods. Dispatches to the cached
    Algorithm-1 loop for skip2_lora."""
    if method == "skip2_lora":
        return finetune_skip2_lora(
            key, cfg, backbone, x_ft, y_ft, epochs=epochs, batch_size=batch_size, lr=lr
        )
    ikey, lkey = jax.random.split(key)
    trainable, frozen = M.init_method(ikey, cfg, backbone, method)
    n = x_ft.shape[0]
    losses, times = [], []
    rng = lkey
    for _ in range(epochs):
        rng, sk = jax.random.split(rng)
        t0 = time.perf_counter()
        for idx in _epoch_batches(sk, n, batch_size):
            trainable, loss = M.train_step(
                method, cfg, trainable, frozen, x_ft[idx], y_ft[idx], lr
            )
        losses.append(float(loss))
        times.append(time.perf_counter() - t0)
    return FinetuneResult(trainable, frozen, losses, times)


# ---------------------------------------------------------------------------
# Skip2-LoRA: Algorithm 1
# ---------------------------------------------------------------------------


def _populate_step(cfg: MLPConfig):
    """Backbone forward + cache write + adapter step (first encounter)."""

    @jax.jit
    def step(trainable, frozen, cache, idx, xb, yb, lr):
        # Full forward once; xs[k] is the input feature map of FC layer k and
        # logits_base would require re-running without adapters — instead we
        # exploit linearity: y_base = logits - sum_k x^k A_k B_k.
        logits, xs = M.forward("skip_lora", trainable, frozen, xb, cfg)
        skip = jnp.zeros_like(logits)
        for k, lora in enumerate(trainable["lora"]):
            skip = skip + M.lora_apply(lora, xs[k])
        y_base = logits - skip
        values = {f"x{k}": xs[k] for k in range(1, cfg.n_layers)}
        values["y_base"] = y_base
        cache = C.cache_write(cache, idx, values)

        def loss_fn(t):
            out, _ = M.forward("skip_lora", t, frozen, xb, cfg)
            return cross_entropy(out, yb)

        loss, grads = jax.value_and_grad(loss_fn)(trainable)
        trainable = jax.tree.map(lambda a, b: a - lr * b, trainable, grads)
        return trainable, cache, loss

    return step


def _cached_step(cfg: MLPConfig):
    """Adapter-only step from cached activations (zero backbone compute)."""

    @jax.jit
    def step(trainable, cache, idx, xb, yb, lr):
        vals = C.cache_read(cache, idx)
        xs = [xb] + [vals[f"x{k}"] for k in range(1, cfg.n_layers)]

        def loss_fn(t):
            out = M.skip_forward_cached(t, vals["y_base"], xs)
            return cross_entropy(out, yb)

        loss, grads = jax.value_and_grad(loss_fn)(trainable)
        trainable = jax.tree.map(lambda a, b: a - lr * b, trainable, grads)
        return trainable, loss

    return step


def masked_populate_step(cfg: MLPConfig):
    """Streaming variant: batch may mix cache hits and misses. The backbone
    runs for the whole batch, but only miss rows are written; hit rows keep
    their cached values (bitwise identical activations either way since the
    backbone is frozen — the write is for first-seen samples)."""

    @jax.jit
    def step(trainable, frozen, cache, idx, xb, yb, lr):
        logits, xs = M.forward("skip_lora", trainable, frozen, xb, cfg)
        skip = jnp.zeros_like(logits)
        for k, lora in enumerate(trainable["lora"]):
            skip = skip + M.lora_apply(lora, xs[k])
        values = {f"x{k}": xs[k] for k in range(1, cfg.n_layers)}
        values["y_base"] = logits - skip
        miss = ~C.cache_hits(cache, idx)
        cache = C.cache_write_masked(cache, idx, values, miss)

        def loss_fn(t):
            out, _ = M.forward("skip_lora", t, frozen, xb, cfg)
            return cross_entropy(out, yb)

        loss, grads = jax.value_and_grad(loss_fn)(trainable)
        trainable = jax.tree.map(lambda a, b: a - lr * b, trainable, grads)
        return trainable, cache, loss

    return step


def finetune_skip2_lora(
    key: jax.Array,
    cfg: MLPConfig,
    backbone: Params,
    x_ft: jax.Array,
    y_ft: jax.Array,
    *,
    epochs: int,
    batch_size: int = 20,
    lr: float = 0.05,
) -> FinetuneResult:
    """Algorithm 1. Epoch 0 populates C_skip; epochs 1..E-1 skip the backbone."""
    ikey, lkey = jax.random.split(key)
    trainable, frozen = M.init_method(ikey, cfg, backbone, "skip2_lora")
    n = x_ft.shape[0]
    cache = C.cache_for_mlp(n, cfg.dims, cfg.dtype)
    populate = _populate_step(cfg)
    cached = _cached_step(cfg)
    losses, times = [], []
    rng = lkey
    for e in range(epochs):
        rng, sk = jax.random.split(rng)
        t0 = time.perf_counter()
        for idx in _epoch_batches(sk, n, batch_size):
            if e == 0:
                trainable, cache, loss = populate(
                    trainable, frozen, cache, idx, x_ft[idx], y_ft[idx], lr
                )
            else:
                trainable, loss = cached(trainable, cache, idx, x_ft[idx], y_ft[idx], lr)
        losses.append(float(loss))
        times.append(time.perf_counter() - t0)
    return FinetuneResult(trainable, frozen, losses, times, cache=cache)


def evaluate(
    method: str,
    cfg: MLPConfig,
    result: FinetuneResult,
    x_test: jax.Array,
    y_test: jax.Array,
) -> float:
    logits, _ = M.forward(
        "skip_lora" if method == "skip2_lora" else method,
        result.trainable,
        result.frozen,
        x_test,
        cfg,
    )
    return float(accuracy(logits, y_test))
