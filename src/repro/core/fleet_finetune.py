"""Fleet fine-tuning: N tenants' Skip2-LoRA adapters trained in ONE dispatch.

Skip2-LoRA's premise is a fleet of devices each fine-tuning its own adapter
stack against a shared frozen backbone. The server-side mirror of that story
(DESIGN.md §8) is *grouped* training: instead of N ``finetune()`` calls —
N scan dispatches per epoch, N optimizer states marched separately — one
``lax.scan``-stepped loop advances every tenant at once:

  - **Fleet batch**: each step concatenates one batch per tenant
    (``batch_per_tenant`` rows each, tenant-contiguous), so the row->slot
    map is the static ``repeat(arange(N), bpt)``.
  - **Grouped VJP**: the skip-sum over the whole fleet batch is one
    ``skip_lora_grouped_train`` call (trainable custom VJP over the stacked
    pool); its backward lands per-tenant ``dA[t]/dB[t]`` blocks directly
    into the stacked gradient — no per-tenant loop anywhere.
  - **Per-tenant losses**: ``lm_loss_rows`` exposes per-row log-likelihood
    sums; reducing per contiguous tenant group makes tenant t's loss (and
    hence its gradient) *identical* to training t alone — the fleet sum of
    per-tenant means decouples, so ``n_tenants=1`` reproduces the
    single-tenant trajectory step for step.
  - **Stacked optimizer states**: elementwise optimizers (SGD/Adam) over
    the stacked ``(N, ...)`` pytree are exactly N independent optimizers
    (shared step counter; no cross-element coupling).
  - **Cache partitions**: tenant t owns sample ids ``[t*n_per, (t+1)*n_per)``
    of one ``SkipCache`` / ``TieredCacheEngine`` — an id convention, which is
    why the populate epoch shares a single backbone dispatch per fleet batch
    and cached epochs gather all tenants' rows in one read (the trainer
    addresses globally-offset ids directly; ``cache_engine.TenantView`` is
    the per-tenant accessor for callers that stream one tenant's data).
  - **Write-back**: trained slots install into a serving ``AdapterPool``
    via one batched donated write (``AdapterPool.register_many``).

The tenant axis is embarrassingly parallel (the backbone is frozen and
replicated), which is what the mesh-native ``SessionRuntime`` exploits:
tenants place onto logical shards and every (trajectory, shard) group's
cached epochs dispatch on that shard's device (DESIGN.md §10) — the one
multi-device fine-tuning path since the bespoke ``shard_map`` launcher
collapsed into it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import donate_argnums
from repro.core import lm_skiplora as SL
from repro.core.skip_cache import SkipCache, cache_read, cache_write
from repro.kernels.skip_lora.ops import (
    skip_lora_grouped_train,
    skip_lora_grouped_train_int8,
)
from repro.models.config import ModelConfig
from repro.models.lm import lm_forward, lm_loss_rows, model_dtype
from repro.optim.optimizers import adamw, apply_updates
from repro.runtime.sharding import constrain

Params = Any


# ---------------------------------------------------------------------------
# Stacked adapters and fleet batches
# ---------------------------------------------------------------------------


def init_fleet_adapters(
    key: jax.Array, cfg: ModelConfig, sl: SL.SkipLoRAConfig, n_tenants: int
) -> Params:
    """Stacked per-tenant adapters {"A": (N, L, D, R), "B": (N, L, R, D)},
    each tenant initialised as an independent ``init_adapters`` draw."""
    keys = jax.random.split(key, n_tenants)
    return jax.vmap(lambda k: SL.init_adapters(k, cfg, sl))(keys)


def tenant_adapters(stacked: Params, t: int) -> Params:
    """Slice tenant t's flat {"A": (L, D, R), "B": (L, R, D)} stack."""
    return jax.tree.map(lambda x: x[t], stacked)


def stack_tenant_adapters(adapters: list[Params]) -> Params:
    """Inverse of ``tenant_adapters`` over a full fleet."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *adapters)


def fleet_row_tenant(n_tenants: int, batch_per_tenant: int) -> jax.Array:
    """(N * bpt,) int32 row->tenant map of a tenant-contiguous fleet batch."""
    return jnp.repeat(jnp.arange(n_tenants, dtype=jnp.int32), batch_per_tenant)


def fleet_index_matrix(
    epoch: int,
    n_tenants: int,
    samples_per_tenant: int,
    batch_per_tenant: int,
    *,
    seed: int = 0,
) -> np.ndarray:
    """(steps, N * bpt) global sample ids: column block t is tenant t's
    pre-permuted epoch visitation (its own RNG stream, so tenant t sees the
    same order it would training alone), offset into its cache partition.

    Thin wrapper over the shared planner (``core.batch_plan``) with the
    offline convention: fleet position t owns cache partition t. Covers ALL
    samples_per_tenant rows via ``tail="wrap"`` — dropping the remainder
    would leave rows unpopulated in epoch 0 that a later epoch's different
    permutation would then read as garbage (or a KeyError on the engine
    path)."""
    from repro.core import batch_plan

    return batch_plan.fleet_index_matrix(
        epoch, n_tenants, samples_per_tenant, batch_per_tenant, seed=seed
    )


def per_tenant_loss(
    params: Params, cfg: ModelConfig, h: jax.Array, labels: jax.Array, n_tenants: int
) -> jax.Array:
    """(N,) masked-mean CE per tenant over a tenant-contiguous batch —
    tenant t's entry equals ``lm_loss`` on t's rows alone (the decoupling
    that makes fleet == per-tenant training)."""
    ll, cnt = lm_loss_rows(params, cfg, h, labels)
    ll = jnp.sum(ll.reshape(n_tenants, -1), axis=1)
    cnt = jnp.sum(cnt.reshape(n_tenants, -1), axis=1)
    return -ll / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Losses and steps
# ---------------------------------------------------------------------------


def blocked_skip_sum(
    acts: jax.Array, a_pool: jax.Array, b_pool: jax.Array, n_tenants: int
) -> jax.Array:
    """Grouped skip-sum specialised to the fleet's batch structure: rows are
    tenant-contiguous with a uniform per-tenant count, so the per-row pool
    gather of the general oracle collapses into a *batched einsum* over the
    tenant axis — the efficient dense (XLA) lowering on CPU/GPU, while the
    grouped Pallas kernel is the TPU one. Differentiable in the pools;
    activations are data.

    acts: (L, B, S, D) with B = n_tenants * bpt, tenant-major;
    a_pool: (N, L, D, R); b_pool: (N, L, R, D) -> (B, S, D).
    """
    acts = jax.lax.stop_gradient(acts)
    # Model-axis sessions keep the cached activations partitioned over L so
    # each shard sums its resident blocks' terms; the tenant-major einsum
    # below then needs exactly one cross-shard reduce for the (tmd) output.
    acts = constrain(acts, "layers", None, None, None)
    l, b, s, d = acts.shape
    at = acts.reshape(l, n_tenants, (b // n_tenants) * s, d)
    z = jnp.einsum("ltmd,tldr->tlmr", at, a_pool.astype(acts.dtype))
    out = jnp.einsum("tlmr,tlrd->tmd", z, b_pool.astype(acts.dtype))
    return out.astype(acts.dtype).reshape(b, s, d)


def _check_fleet_mode(sl: SL.SkipLoRAConfig) -> None:
    if sl.mode not in ("full", "int8"):
        raise ValueError(
            f"fleet training supports modes 'full' and 'int8', not {sl.mode!r}"
        )


def _fleet_skip_sum(
    stacked: Params,
    row_tenant: jax.Array,
    n_tenants: int,
    dtype,
    *,
    acts: Optional[jax.Array] = None,          # (L, B, S, D) float
    acts_q: Optional[jax.Array] = None,        # (L, B, S, D) int8
    acts_scale: Optional[jax.Array] = None,    # (L, B, S) fp32
    use_kernel: bool = True,
    freeze_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """One grouped skip-sum for a fleet batch, kernel or dense path.

    ``use_kernel=True`` routes to the grouped custom-VJP kernels (raw int8
    payload stays raw — dequant fused); ``use_kernel=False`` takes the
    ``blocked_skip_sum`` batched einsum the fleet's uniform tenant-
    contiguous batches allow (int8 payloads dequantise first)."""
    if use_kernel:
        if acts_q is not None:
            return skip_lora_grouped_train_int8(
                acts_q, acts_scale, stacked["A"], stacked["B"], row_tenant,
                freeze_mask=freeze_mask,
            )
        return skip_lora_grouped_train(
            acts, stacked["A"], stacked["B"], row_tenant, freeze_mask=freeze_mask
        )
    a_pool, b_pool = stacked["A"], stacked["B"]
    if freeze_mask is not None:
        from repro.kernels.skip_lora.ops import freeze_pool_slots

        a_pool = freeze_pool_slots(a_pool, freeze_mask)
        b_pool = freeze_pool_slots(b_pool, freeze_mask)
    if acts_q is not None:
        acts = (acts_q.astype(jnp.float32) * acts_scale[..., None]).astype(dtype)
    return blocked_skip_sum(acts, a_pool, b_pool, n_tenants)


def fleet_cached_loss(
    params: Params,
    cfg: ModelConfig,
    sl: SL.SkipLoRAConfig,
    stacked: Params,
    vals: dict[str, jax.Array],
    row_tenant: jax.Array,
    n_tenants: int,
    dtype,
    *,
    use_kernel: bool = True,
    freeze_mask: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Fleet loss from cached values: one grouped skip-sum for the whole
    batch, per-tenant reduction. Returns (sum of per-tenant losses,
    (N,) per-tenant losses)."""
    _check_fleet_mode(sl)
    if sl.mode == "int8":
        skip = _fleet_skip_sum(
            stacked, row_tenant, n_tenants, dtype,
            acts_q=jnp.swapaxes(vals["acts_q"], 0, 1),
            acts_scale=jnp.swapaxes(vals["acts_scale"], 0, 1),
            use_kernel=use_kernel, freeze_mask=freeze_mask,
        )
    else:
        skip = _fleet_skip_sum(
            stacked, row_tenant, n_tenants, dtype,
            acts=jnp.swapaxes(vals["acts"], 0, 1).astype(dtype),
            use_kernel=use_kernel, freeze_mask=freeze_mask,
        )
    h = vals["y_base"].astype(dtype) + skip.astype(dtype)
    per = per_tenant_loss(params, cfg, h, vals["labels"], n_tenants)
    return jnp.sum(per), per


def make_fleet_cached_step_from_vals(
    cfg: ModelConfig,
    sl: SL.SkipLoRAConfig,
    optimizer,
    n_tenants: int,
    *,
    use_kernel: bool = True,
    freeze_mask: Optional[jax.Array] = None,
):
    """One fleet adapter step from already-gathered cache values (the
    granularity the tiered engine's streaming read path feeds)."""
    dtype = model_dtype(cfg)

    def step(params, stacked, opt_state, vals, row_tenant):
        def loss_fn(t):
            return fleet_cached_loss(
                params, cfg, sl, t, vals, row_tenant, n_tenants, dtype,
                use_kernel=use_kernel, freeze_mask=freeze_mask,
            )

        (_, per), grads = jax.value_and_grad(loss_fn, has_aux=True)(stacked)
        updates, opt_state = optimizer.update(grads, opt_state, stacked)
        return apply_updates(stacked, updates), opt_state, per

    return step


def make_fleet_cached_epoch(
    cfg: ModelConfig,
    sl: SL.SkipLoRAConfig,
    optimizer,
    n_tenants: int,
    *,
    use_kernel: bool = True,
    freeze_mask: Optional[jax.Array] = None,
    donate: bool = True,
    jit: bool = True,
):
    """Whole fleet cached epoch as one ``lax.scan`` dispatch: cache gathers
    + grouped adapter steps, zero backbone compute, every tenant advanced
    per step. ``jit=False`` returns the raw function for callers that wrap
    the epoch themselves (e.g. a ``shard_map`` body).

    epoch: (params, stacked, opt_state, cache, idx_mat, row_tenant)
        -> (stacked, opt_state, losses (steps, N))
    """
    step = make_fleet_cached_step_from_vals(
        cfg, sl, optimizer, n_tenants,
        use_kernel=use_kernel, freeze_mask=freeze_mask,
    )

    def epoch(params, stacked, opt_state, cache, idx_mat, row_tenant):
        def body(carry, idx):
            t, o = carry
            t, o, per = step(params, t, o, cache_read(cache, idx), row_tenant)
            return (t, o), per

        (stacked, opt_state), losses = jax.lax.scan(
            body, (stacked, opt_state), idx_mat
        )
        return stacked, opt_state, losses

    if not jit:
        return epoch
    d = donate_argnums if donate else (lambda *a: ())
    return jax.jit(epoch, donate_argnums=d(1, 2))


def make_fleet_eval_loss(
    cfg: ModelConfig,
    sl: SL.SkipLoRAConfig,
    n_tenants: int,
    *,
    use_kernel: bool = True,
    jit: bool = True,
):
    """Per-tenant held-out loss from cached values — the shadow-eval body
    (DESIGN.md §13). The backbone term (``y_base``) is already in the cache
    from the populate forward, so eval is the same backbone-free grouped
    skip-sum + CE a cached training step runs, minus the gradient: zero
    extra forwards over the backbone, ever.

    eval_loss: (params, stacked, vals, row_tenant) -> (N,) per-tenant loss.
    """
    dtype = model_dtype(cfg)

    def eval_loss(params, stacked, vals, row_tenant):
        _, per = fleet_cached_loss(
            params, cfg, sl, stacked, vals, row_tenant, n_tenants, dtype,
            use_kernel=use_kernel,
        )
        return per

    return jax.jit(eval_loss) if jit else eval_loss


def make_fleet_cached_epoch_eval(
    cfg: ModelConfig,
    sl: SL.SkipLoRAConfig,
    optimizer,
    n_tenants: int,
    *,
    use_kernel: bool = True,
    eval_pre: bool = True,
    eval_post: bool = True,
    donate: bool = False,
):
    """``make_fleet_cached_epoch`` with shadow eval folded into the SAME
    fused dispatch: the held-out per-tenant loss is computed from the
    cached rows immediately before the epoch's scan (``eval_pre``) and/or
    immediately after it (``eval_post``) — one compiled program, so eval
    adds two cache gathers and two grouped skip-sums to an epoch of
    training steps, not an extra dispatch (and never a backbone forward).

    epoch: (params, stacked, opt_state, cache, idx_mat, row_tenant,
            eval_idx, eval_row_tenant)
        -> (stacked, opt_state, losses (steps, N), pre (N,)|None, post (N,)|None)
    """
    step = make_fleet_cached_step_from_vals(
        cfg, sl, optimizer, n_tenants, use_kernel=use_kernel
    )
    ev = make_fleet_eval_loss(
        cfg, sl, n_tenants, use_kernel=use_kernel, jit=False
    )

    def epoch(params, stacked, opt_state, cache, idx_mat, row_tenant,
              eval_idx, eval_row_tenant):
        def held_out(t):
            return ev(params, t, cache_read(cache, eval_idx), eval_row_tenant)

        pre = held_out(stacked) if eval_pre else None

        def body(carry, idx):
            t, o = carry
            t, o, per = step(params, t, o, cache_read(cache, idx), row_tenant)
            return (t, o), per

        (stacked, opt_state), losses = jax.lax.scan(
            body, (stacked, opt_state), idx_mat
        )
        post = held_out(stacked) if eval_post else None
        return stacked, opt_state, losses, pre, post

    d = donate_argnums if donate else (lambda *a: ())
    return jax.jit(epoch, donate_argnums=d(1, 2))


def make_fleet_populate_epoch(
    cfg: ModelConfig,
    sl: SL.SkipLoRAConfig,
    optimizer,
    n_tenants: int,
    *,
    use_kernel: bool = True,
    freeze_mask: Optional[jax.Array] = None,
    donate: bool = True,
    jit: bool = True,
):
    """Fleet populate epoch: ONE adapter-free backbone forward per fleet
    batch serves every tenant's rows (the backbone is tenant-independent —
    DESIGN.md §7), activations scatter into each tenant's cache partition,
    and the adapter step runs on the just-collected full-precision
    activations via the grouped VJP (``int8`` mode quantises only the cache
    write, like the single-tenant populate step).

    epoch: (params, stacked, opt_state, cache, tokens, labels, idx_mat,
            row_tenant) -> (stacked, opt_state, cache, losses (steps, N))
    """
    dtype = model_dtype(cfg)
    _check_fleet_mode(sl)

    def epoch(params, stacked, opt_state, cache, tokens, labels, idx_mat, row_tenant):
        def body(carry, idx):
            t, o, c = carry
            out = lm_forward(params, cfg, tokens[idx], mode="train", collect_acts=True)
            acts = jax.lax.stop_gradient(out["acts"])       # (L, B, S, D)
            y_base = jax.lax.stop_gradient(out["y_base"])   # (B, S, D)
            lab = labels[idx]
            values = SL._encode_acts(acts, None, sl)
            values["y_base"] = y_base
            values["labels"] = lab
            c = cache_write(c, idx, values)

            def loss_fn(tt):
                skip = _fleet_skip_sum(
                    tt, row_tenant, n_tenants, dtype, acts=acts.astype(dtype),
                    use_kernel=use_kernel, freeze_mask=freeze_mask,
                )
                h = y_base.astype(dtype) + skip.astype(dtype)
                per = per_tenant_loss(params, cfg, h, lab, n_tenants)
                return jnp.sum(per), per

            (_, per), grads = jax.value_and_grad(loss_fn, has_aux=True)(t)
            updates, o = optimizer.update(grads, o, t)
            return (apply_updates(t, updates), o, c), per

        (stacked, opt_state, cache), losses = jax.lax.scan(
            body, (stacked, opt_state, cache), idx_mat
        )
        return stacked, opt_state, cache, losses

    if not jit:
        return epoch
    d = donate_argnums if donate else (lambda *a: ())
    return jax.jit(epoch, donate_argnums=d(1, 2, 3))


def fleet_cached_epoch_via_engine(
    step,
    params: Params,
    stacked: Params,
    opt_state,
    engine,
    idx_mat,
    row_tenant: jax.Array,
) -> tuple[Params, Any, jax.Array]:
    """Streaming fleet cached epoch through a ``TieredCacheEngine`` — the
    path when the fleet's pooled activation cache exceeds the HBM budget.
    Per-batch engine reads with the *next* fleet batch prefetched on the
    background thread while the in-flight grouped step runs. ``step`` is a
    (jitted) ``make_fleet_cached_step_from_vals`` product."""
    pers = []
    for _, vals in engine.stream_batches(idx_mat):
        stacked, opt_state, per = step(params, stacked, opt_state, vals, row_tenant)
        pers.append(per)
    return stacked, opt_state, jnp.stack(pers)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetResult:
    adapters: Params                  # stacked {"A": (N, L, D, R), "B": ...}
    opt_state: Any
    losses: np.ndarray                # (epochs, steps, n_tenants)
    epoch_times_s: list[float]
    cache: SkipCache | None = None
    engine: Any = None


def fleet_finetune(
    key: jax.Array,
    cfg: ModelConfig,
    sl: SL.SkipLoRAConfig,
    params: Params,
    tokens: jax.Array,                # (n_tenants, n_per, seq) int32
    labels: jax.Array,                # (n_tenants, n_per, seq) int32
    *,
    epochs: int,
    batch_per_tenant: int,
    lr: float = 1e-3,
    optimizer=None,
    use_kernel: bool = True,
    freeze_mask: Optional[jax.Array] = None,
    engine=None,
    seed: int = 0,
) -> FleetResult:
    """Algorithm 1 for a whole fleet: epoch 0 populates every tenant's
    cache partition (one shared backbone dispatch per fleet batch); epochs
    >= 1 run cached grouped steps with zero backbone compute. Every epoch
    phase is one compiled dispatch. With ``engine`` (a ``TieredCacheEngine``
    laid out for ``n_tenants * n_per`` samples), populated rows are handed
    to the engine after epoch 0 and cached epochs run the streaming
    prefetch path instead of the fused scan.

    Like ``launch/finetune.py --hbm-mb``, the populate epoch itself still
    materialises the full fleet cache once (the fused populate scan carries
    it); the engine's budget governs the *steady state* — cached epochs.
    Fleets whose single populate epoch already exceeds device memory need
    a streaming populate (per-batch ``engine.write``), which trades the
    one-dispatch epoch for per-batch Python — not implemented here."""
    n_tenants, n_per, seq = tokens.shape
    batch_per_tenant = min(batch_per_tenant, n_per)  # fleet_index_matrix clamp
    stacked = init_fleet_adapters(key, cfg, sl, n_tenants)
    opt = optimizer if optimizer is not None else adamw(lr)
    opt_state = opt.init(stacked)
    row_tenant = fleet_row_tenant(n_tenants, batch_per_tenant)

    tokens_flat = tokens.reshape(n_tenants * n_per, seq)
    labels_flat = labels.reshape(n_tenants * n_per, seq)
    cache = SL.init_lm_cache(n_tenants * n_per, cfg, sl, seq)

    populate_epoch = make_fleet_populate_epoch(
        cfg, sl, opt, n_tenants, use_kernel=use_kernel, freeze_mask=freeze_mask
    )
    cached_epoch = make_fleet_cached_epoch(
        cfg, sl, opt, n_tenants, use_kernel=use_kernel, freeze_mask=freeze_mask
    )
    engine_step = None
    if engine is not None:
        engine_step = jax.jit(
            make_fleet_cached_step_from_vals(
                cfg, sl, opt, n_tenants,
                use_kernel=use_kernel, freeze_mask=freeze_mask,
            )
        )

    losses, times = [], []
    for e in range(epochs):
        idx_mat = fleet_index_matrix(
            e, n_tenants, n_per, batch_per_tenant, seed=seed
        )
        t0 = time.perf_counter()
        if e == 0:
            stacked, opt_state, cache, ls = populate_epoch(
                params, stacked, opt_state, cache,
                tokens_flat, labels_flat, jnp.asarray(idx_mat), row_tenant,
            )
        elif engine is None:
            stacked, opt_state, ls = cached_epoch(
                params, stacked, opt_state, cache, jnp.asarray(idx_mat), row_tenant
            )
        else:
            stacked, opt_state, ls = fleet_cached_epoch_via_engine(
                engine_step, params, stacked, opt_state, engine, idx_mat, row_tenant
            )
        jax.block_until_ready(ls)
        times.append(time.perf_counter() - t0)
        losses.append(np.asarray(ls))
        if e == 0 and engine is not None:
            # Hand the populated partitions to the placement engine (a
            # one-off staging cost, outside the epoch loop's steady state);
            # rows past the HBM budget spill to the host tier.
            for row in idx_mat:
                idx = jnp.asarray(row)
                engine.write(idx, cache_read(cache, idx))
            cache = None  # engine owns placement now

    return FleetResult(
        adapters=stacked,
        opt_state=opt_state,
        losses=np.stack(losses),
        epoch_times_s=times,
        cache=cache,
        engine=engine,
    )


def write_back_to_pool(pool, tenants, stacked: Params) -> list[int]:
    """Install a fleet's trained slots into a serving ``AdapterPool`` as one
    batched in-place (donated) write; tenant ``tenants[i]`` gets stack row
    i. Returns the assigned slot indices."""
    return pool.register_many(tenants, stacked)
