"""Paged KV block pool: the serve-path analog of the Skip-Cache.

Skip2-LoRA's thesis is cache-to-skip-compute: pay for a forward once,
then reuse its intermediate state instead of recomputing. The adapt path
does it with cached activations; this module does it for *prefill* — a
fixed pool of KV blocks (vLLM-style paged layout) that the request
scheduler's radix prefix index (``core.prefix_index``) maps token
prefixes onto, so an admitted prompt whose prefix is already pooled
copies blocks instead of running the backbone over them.

Layout
------
The device data plane is exactly ``init_serve_caches(cfg, n_blocks,
block)`` — the periods/remainder pytree the whole serve path already
speaks, with the *batch* axis reinterpreted as the block-id axis:
period leaves ``(n_per, n_blocks, block, n_kv, hd)``, remainder leaves
``(n_blocks, block, n_kv, hd)``. Every per-leaf move addresses axis
``-4``, which is the batch/block axis in both layouts, so gather/store
code is layout-agnostic (the same trick the scheduler's admission
scatter uses).

Control plane (host-side, like the AdapterPool's slot table):

  - ``refs[i]``: reference count per block. The radix index holds one
    ref per indexed block; every in-flight admission that reused the
    block holds one more. 0 <=> on the free list.
  - ``free``: LIFO free list (allocation order is deterministic).
  - ``version``: bumped on every data-plane write (publish/copy/reset)
    — anything memoising derived state keys off it.
  - ``generation``: bumped on reset/restore. Block-id handles carry the
    generation they were minted under; stale handles no-op on release
    instead of corrupting a reborn block's refcount.

Copy-on-write rule: pooled blocks are IMMUTABLE while shared. Live rows
decode into private dense cache rows (divergence materialises privately,
so the classic vLLM mid-block COW degenerates to publish-on-retire);
``copy_block`` is the primitive for any future in-pool writer — it
returns the block itself when exclusively held and a fresh copy when
shared, moving the caller's ref.
"""

from __future__ import annotations

from collections import Counter
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import donate_argnums
from repro.core import runtime as RT
from repro.kernels.flash_attn import paged

Params = Any

#: Fallback block size (tokens per KV block). ``kernels.autotune``'s
#: ``tune_kv_block`` measures the gather+publish round-trip per candidate
#: and installs the winner here via ``set_default_block`` (resolution at
#: pool construction, like the kernel tile defaults).
DEFAULT_BLOCK = 8

_DEFAULT: dict = {"block": None}


def set_default_block(block: Optional[int]) -> None:
    """Install an autotuned block size as the process-wide default
    (``None`` resets to the untuned ``DEFAULT_BLOCK``)."""
    if block is not None and block < 1:
        raise ValueError(f"kv block {block} must be >= 1")
    _DEFAULT["block"] = block


def get_default_block() -> int:
    return _DEFAULT["block"] or DEFAULT_BLOCK


class KVPoolExhausted(RuntimeError):
    """Allocation failed even after the caller's eviction pass."""


def _leaf_gather(leaf: jax.Array, tables: jax.Array, block: int,
                 use_kernel: bool) -> jax.Array:
    """(..., NB, block, n_kv, hd) + (B, T) ids -> (..., B, T*block, n_kv, hd)."""
    b, t = tables.shape
    if use_kernel:
        if leaf.ndim == 4:
            return paged.gather(leaf, tables, use_kernel=True)
        return jax.vmap(
            lambda p: paged.gather(p, tables, use_kernel=True)
        )(leaf)
    out = jnp.take(leaf, tables.reshape(-1), axis=-4)
    lead = leaf.shape[:-4]
    return out.reshape(lead + (b, t * block) + leaf.shape[-2:])


def gather_blocks(data: Params, tables: jax.Array, *, block: int,
                  use_kernel: bool = False) -> Params:
    """Gather a batch of block tables out of the pool tree: every leaf
    (..., NB, block, n_kv, hd) -> (..., B, T*block, n_kv, hd). Traced —
    call inside the admission jit so the copies fuse with the tail
    prefill. Padded table entries must be valid ids (callers mask the
    padded key positions; see ``attn_prefill_ext``'s garbage doctrine)."""
    return jax.tree.map(
        lambda x: _leaf_gather(x, tables, block, use_kernel), data
    )


class KVBlockPool:
    """One shard's paged KV block pool (device data + host accounting)."""

    def __init__(self, cfg, *, n_blocks: int, block: int, device=None):
        from repro.models.lm import init_serve_caches

        if n_blocks < 1 or block < 1:
            raise ValueError(f"kv pool needs n_blocks, block >= 1; "
                             f"got {n_blocks}, {block}")
        self.cfg = cfg
        self.n_blocks = int(n_blocks)
        self.block = int(block)
        self.device = device
        # Commit the data plane to its device explicitly (never rely on
        # default placement): publish/copy donate and return committed
        # buffers, so an *uncommitted* fresh pool would give the very first
        # publish per geometry a different argument layout than every later
        # one — two compiles of the same program, one of them mid-replay.
        self.data = jax.device_put(
            init_serve_caches(cfg, self.n_blocks, self.block),
            device if device is not None else jax.devices()[0],
        )
        self.refs = np.zeros((self.n_blocks,), np.int32)
        #: LIFO over descending ids so allocation pops block 0 first.
        self.free: list[int] = list(range(self.n_blocks - 1, -1, -1))
        self.version = 0
        self.generation = 0
        self.counters: Counter = Counter()

    # -- accounting ----------------------------------------------------------

    def n_free(self) -> int:
        return len(self.free)

    def alloc(self, n: int) -> list[int]:
        """Pop ``n`` free blocks (ref = 1 each). Raises ``KVPoolExhausted``
        when the free list is short — the caller (prefix index) evicts
        unreferenced radix leaves and retries."""
        if n > len(self.free):
            raise KVPoolExhausted(
                f"kv pool needs {n} blocks, {len(self.free)} free "
                f"of {self.n_blocks}"
            )
        ids = [self.free.pop() for _ in range(n)]
        self.refs[ids] += 1
        self.counters["alloc"] += n
        return ids

    def ref(self, ids) -> None:
        ids = np.asarray(ids, np.int64).reshape(-1)
        if ids.size == 0:
            return
        if np.any(self.refs[ids] <= 0):
            raise RuntimeError(f"ref of unallocated kv block(s) {ids.tolist()}")
        self.refs[ids] += 1

    def deref(self, ids, generation: Optional[int] = None) -> None:
        """Drop one reference per id; blocks hitting zero return to the
        free list. A ``generation`` older than the pool's means the handle
        predates a reset/restore — released silently (the block it named
        no longer exists)."""
        if generation is not None and generation != self.generation:
            self.counters["stale_release"] += 1
            return
        ids = np.asarray(ids, np.int64).reshape(-1)
        if ids.size == 0:
            return
        if np.any(self.refs[ids] <= 0):
            raise RuntimeError(f"deref of free kv block(s) {ids.tolist()}")
        self.refs[ids] -= 1
        freed = [int(i) for i in ids if self.refs[i] == 0]
        self.free.extend(sorted(freed, reverse=True))
        self.counters["freed"] += len(freed)

    def check_no_leaks(self, expected_held: int) -> None:
        """Ref-count invariant: every allocated block holds exactly
        ``refs`` counted references, and free-list + held == n_blocks.
        ``expected_held`` is the number of blocks the radix index (plus
        any in-flight rows) should account for."""
        held = int((self.refs > 0).sum())
        if held + len(self.free) != self.n_blocks:
            raise RuntimeError(
                f"kv pool leak: {held} held + {len(self.free)} free "
                f"!= {self.n_blocks}"
            )
        if held != expected_held:
            raise RuntimeError(
                f"kv pool leak: {held} blocks held, expected {expected_held}"
            )

    def reset(self) -> None:
        """Forget every block (refcounts to zero, full free list). Data
        stays on device — unreferenced blocks are unreachable garbage.
        Bumps ``generation`` so outstanding handles no-op on release."""
        self.refs[:] = 0
        self.free = list(range(self.n_blocks - 1, -1, -1))
        self.version += 1
        self.generation += 1

    # -- data plane ----------------------------------------------------------

    def publish(self, caches: Params, row: int, ids, slots) -> None:
        """Copy live cache row ``row``'s prompt blocks into the pool:
        block ``slots[j]`` of the row (token span [slots[j]*block,
        (slots[j]+1)*block)) lands in pool block ``ids[j]``. One fused
        dispatch per (m, geometry); the pool tree is donated off-CPU."""
        ids = np.asarray(ids, np.int32).reshape(-1)
        slots = np.asarray(slots, np.int32).reshape(-1)
        if ids.size == 0:
            return
        m, blk = int(ids.size), self.block
        seq = jax.tree.leaves(caches)[0].shape[-3]
        fn = RT.compiled(
            ("kv_publish", self.cfg, m, blk, seq, self.n_blocks), self._make_publish(m, blk)
        )
        self.data = fn(self.data, caches, jnp.asarray(int(row), jnp.int32),
                       jnp.asarray(ids), jnp.asarray(slots))
        self.version += 1
        self.counters["published"] += m

    def _make_publish(self, m: int, blk: int):
        def make():
            def f(data, caches, row, ids, slots):
                cols = (slots[:, None] * blk
                        + jnp.arange(blk, dtype=jnp.int32)[None]).reshape(-1)

                def leaf(pool, live):
                    src = jnp.take(live, row, axis=-4)       # drop batch axis
                    blocks = jnp.take(src, cols, axis=-3)
                    blocks = blocks.reshape(
                        src.shape[:-3] + (m, blk) + src.shape[-2:]
                    )
                    return pool.at[..., ids, :, :, :].set(
                        blocks.astype(pool.dtype)
                    )

                return jax.tree.map(leaf, data, caches)

            return jax.jit(f, donate_argnums=donate_argnums(0))

        return make

    def copy_block(self, src: int) -> int:
        """Copy-on-write primitive: exclusive blocks are returned as-is;
        shared blocks are duplicated into a fresh allocation and the
        caller's reference moves to the copy."""
        if self.refs[src] < 1:
            raise RuntimeError(f"copy_block of free block {src}")
        if self.refs[src] == 1:
            return src
        dst = self.alloc(1)[0]
        fn = RT.compiled(("kv_copy", self.cfg, self.n_blocks, self.block),
                         self._make_copy)
        self.data = fn(self.data, jnp.asarray([src], jnp.int32),
                       jnp.asarray([dst], jnp.int32))
        self.deref([src])
        self.version += 1
        self.counters["cow_copies"] += 1
        return dst

    def _make_copy(self):
        def f(data, src, dst):
            return jax.tree.map(
                lambda x: x.at[..., dst, :, :, :].set(
                    jnp.take(x, src, axis=-4)
                ),
                data,
            )

        return jax.jit(f, donate_argnums=donate_argnums(0))

    # -- checkpoint ----------------------------------------------------------

    def state_arrays(self) -> dict:
        """String-keyed dict tree of the data plane (the checkpoint loader
        only rebuilds dict nesting, so the periods list becomes
        ``{"0": ..., "1": ...}``)."""
        return {
            "periods": {
                str(i): p for i, p in enumerate(self.data["periods"])
            },
            "remainder": {
                str(j): r for j, r in enumerate(self.data["remainder"])
            },
        }

    def state_meta(self) -> dict:
        return {
            "n_blocks": self.n_blocks,
            "block": self.block,
            "refs": [int(r) for r in self.refs],
            "free": list(self.free),
            "version": self.version,
        }

    def load_state(self, arrays: dict, meta: dict) -> None:
        if (int(meta["n_blocks"]), int(meta["block"])) != (
            self.n_blocks, self.block
        ):
            raise ValueError(
                f"checkpoint kv pool ({meta['n_blocks']} x {meta['block']}) "
                f"!= this pool ({self.n_blocks} x {self.block}): restore "
                "requires an identically-sized block pool"
            )
        periods = [
            arrays["periods"][str(i)] for i in range(len(self.data["periods"]))
        ]
        remainder = [
            arrays["remainder"][str(j)]
            for j in range(len(self.data["remainder"]))
        ]
        data = {"periods": periods, "remainder": remainder}
        data = jax.tree.map(
            lambda ref, x: jnp.asarray(x, ref.dtype), self.data, data
        )
        # Same commitment rule as construction: restored data must land on
        # a concrete device so post-restore publishes reuse the jit cache.
        self.data = jax.device_put(
            data, self.device if self.device is not None else jax.devices()[0]
        )
        self.refs = np.asarray(meta["refs"], np.int32).copy()
        self.free = [int(i) for i in meta["free"]]
        self.version += 1
        self.generation += 1
