"""Skip2-LoRA at LM scale: adapters, sharded activation cache, train steps.

The paper's topology mapped onto a transformer (DESIGN.md §2): for every
layer k an adapter (A_k: D->R, B_k: R->D) taps the *residual-stream input*
of block k and its output is accumulated into the final hidden state:

    h_final <- y_base + sum_k x^k A_k B_k        (Eq. 17 at LM scale)

Because the backbone (including the readout table) is frozen, x^k and
y_base are constant across the fine-tuning run, so a populate epoch caches
them and every later epoch runs *zero backbone compute* — only the skip
aggregation, the readout loss, and the adapter backward.

Cache modes (``SkipLoRAConfig``):
  - ``full``      : cache x^k as-is (paper-faithful; D-wide).
  - ``int8``      : cache x^k rowwise-quantised int8 + per-token scales
                    (4x smaller than bf16-widths; beyond-paper).
  - ``freeze_a``  : freeze A_k (LoRA-FA style) and cache z^k = x^k A_k —
                    R-wide, a D/R ~ 100-1300x cache compression; only B_k
                    trains (beyond-paper).

Adapters live in a *flat* layout {"A": (L, D, R), "B": (L, R, D)} (what the
fused Pallas kernel consumes, and the per-slot layout of the serving
``AdapterPool`` — DESIGN.md §7) with converters to and from the
LayerStack's periodic layout (``adapters_to_stack`` / ``stack_to_adapters``)
for populate/serve forwards.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import donate_argnums
from repro.core.skip_cache import SkipCache, cache_read, cache_write, init_cache
from repro.models.config import ModelConfig
from repro.models.lm import lm_forward, lm_loss

Params = Any


@dataclasses.dataclass(frozen=True)
class SkipLoRAConfig:
    rank: int = 16
    mode: str = "full"             # full | int8 | freeze_a
    cache_dtype: str = "bfloat16"  # dtype for unquantised slots
    use_fused_kernel: bool = False  # Pallas skip-sum (repro.kernels.skip_lora)

    def __post_init__(self):
        if self.mode not in ("full", "int8", "freeze_a"):
            raise ValueError(self.mode)


# ---------------------------------------------------------------------------
# Adapters
# ---------------------------------------------------------------------------


def init_adapters(key: jax.Array, cfg: ModelConfig, sl: SkipLoRAConfig) -> Params:
    """Flat adapters: A ~ Kaiming (fp32 master), B = 0 (identity at init)."""
    l, d, r = cfg.n_layers, cfg.d_model, sl.rank
    ka, _ = jax.random.split(key)
    return {
        "A": jax.random.normal(ka, (l, d, r), jnp.float32) / jnp.sqrt(d),
        "B": jnp.zeros((l, r, d), jnp.float32),
    }


def adapters_to_stack(adapters: Params, cfg: ModelConfig) -> Params:
    """Flat (L, ...) -> LayerStack periodic layout for stack_forward."""
    period, n_per = cfg.period, cfg.n_periods
    lp = period * n_per
    a, b = adapters["A"], adapters["B"]
    ap = a[:lp].reshape((n_per, period) + a.shape[1:])
    bp = b[:lp].reshape((n_per, period) + b.shape[1:])
    periods = [{"A": ap[:, i], "B": bp[:, i]} for i in range(period)]
    remainder = [
        {"A": a[lp + j], "B": b[lp + j]} for j in range(len(cfg.remainder_pattern))
    ]
    return {"periods": periods, "remainder": remainder}


def stack_to_adapters(stack: Params, cfg: ModelConfig) -> Params:
    """LayerStack periodic layout -> flat {"A": (L, D, R), "B": (L, R, D)}.

    Inverse of ``adapters_to_stack``; the serve-time handoff — a fine-tuned
    stack registers into an ``AdapterPool`` slot in flat layout (DESIGN.md
    §7), which is also what the grouped kernel's pool gather consumes."""
    period = cfg.period
    parts_a, parts_b = [], []
    for p in range(cfg.n_periods):
        for i in range(period):
            parts_a.append(stack["periods"][i]["A"][p])
            parts_b.append(stack["periods"][i]["B"][p])
    for rem in stack["remainder"]:
        parts_a.append(rem["A"])
        parts_b.append(rem["B"])
    return {"A": jnp.stack(parts_a), "B": jnp.stack(parts_b)}


def split_trainable(adapters: Params, sl: SkipLoRAConfig) -> tuple[Params, Params]:
    """(trainable, static). freeze_a trains only B (A folded into the cache)."""
    if sl.mode == "freeze_a":
        return {"B": adapters["B"]}, {"A": adapters["A"]}
    return adapters, {}


def merge_adapters(trainable: Params, static: Params) -> Params:
    return {**static, **trainable}


# ---------------------------------------------------------------------------
# Skip aggregation (reference path; the Pallas kernel is a drop-in)
# ---------------------------------------------------------------------------


def skip_sum_ref(acts: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """sum_k x^k A_k B_k. acts: (L,B,S,D); a: (L,D,R); b: (L,R,D) -> (B,S,D)."""
    dtype = acts.dtype
    z = jnp.einsum("lbsd,ldr->lbsr", acts, a.astype(dtype))
    return jnp.einsum("lbsr,lrd->bsd", z, b.astype(dtype))


def skip_sum(acts, a, b, *, use_kernel: bool = False) -> jax.Array:
    if use_kernel:
        from repro.kernels.skip_lora.ops import skip_lora_fused

        return skip_lora_fused(acts, a, b)
    return skip_sum_ref(acts, a, b)


def skip_sum_compressed(z: jax.Array, b: jax.Array) -> jax.Array:
    """freeze_a: z = x A cached. z: (L,B,S,R); b: (L,R,D) -> (B,S,D)."""
    return jnp.einsum("lbsr,lrd->bsd", z, b.astype(z.dtype))


# ---------------------------------------------------------------------------
# int8 rowwise quantisation (per token per layer)
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Quantise over the last axis. Returns (q int8, scale fp32 without last axis)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# LM Skip-Cache layout
# ---------------------------------------------------------------------------


def lm_cache_layout(
    cfg: ModelConfig, sl: SkipLoRAConfig, seq: int
) -> dict[str, tuple[tuple, Any]]:
    """slot name -> (per-sample shape, dtype)."""
    l, d, r = cfg.n_layers, cfg.d_model, sl.rank
    cdt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[sl.cache_dtype]
    if sl.mode == "freeze_a":
        slots = {"z": ((l, seq, r), cdt)}
    elif sl.mode == "int8":
        slots = {"acts_q": ((l, seq, d), jnp.int8), "acts_scale": ((l, seq), jnp.float32)}
    else:
        slots = {"acts": ((l, seq, d), cdt)}
    slots["y_base"] = ((seq, d), cdt)
    slots["labels"] = ((seq,), jnp.int32)
    return slots


def init_lm_cache(
    num_samples: int, cfg: ModelConfig, sl: SkipLoRAConfig, seq: int
) -> SkipCache:
    layout = lm_cache_layout(cfg, sl, seq)
    slots = {
        name: jnp.zeros((num_samples,) + shape, dtype)
        for name, (shape, dtype) in layout.items()
    }
    return SkipCache(slots=slots, valid=jnp.zeros((num_samples,), jnp.bool_))


def cache_nbytes_per_sample(cfg: ModelConfig, sl: SkipLoRAConfig, seq: int) -> int:
    layout = lm_cache_layout(cfg, sl, seq)
    total = 0
    for shape, dtype in layout.values():
        n = 1
        for s in shape:
            n *= s
        total += n * jnp.dtype(dtype).itemsize
    return total


def _encode_acts(
    acts: jax.Array, adapters: Params, sl: SkipLoRAConfig
) -> dict[str, jax.Array]:
    """acts (L,B,S,D) -> cache slot values keyed per sample (B leading)."""
    acts_b = jnp.swapaxes(acts, 0, 1)  # (B, L, S, D)
    if sl.mode == "freeze_a":
        z = jnp.einsum("blsd,ldr->blsr", acts_b, adapters["A"].astype(acts_b.dtype))
        return {"z": z}
    if sl.mode == "int8":
        q, scale = quantize_int8(acts_b)
        return {"acts_q": q, "acts_scale": scale}
    return {"acts": acts_b}


def _decode_acts(vals: dict[str, jax.Array], sl: SkipLoRAConfig, dtype) -> jax.Array:
    """cache slots -> acts (L,B,S,D) (or z (L,B,S,R) in freeze_a mode)."""
    if sl.mode == "freeze_a":
        return jnp.swapaxes(vals["z"], 0, 1).astype(dtype)
    if sl.mode == "int8":
        acts_b = dequantize_int8(vals["acts_q"], vals["acts_scale"], dtype)
        return jnp.swapaxes(acts_b, 0, 1)
    return jnp.swapaxes(vals["acts"], 0, 1).astype(dtype)


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def populate_loss_fn(
    params: Params,
    cfg: ModelConfig,
    adapters: Params,
    batch: dict[str, jax.Array],
):
    """Full forward with activation collection. Returns (loss, (acts, y_base))."""
    out = lm_forward(
        params,
        cfg,
        batch["tokens"],
        mode="train",
        adapters=adapters_to_stack(adapters, cfg),
        collect_acts=True,
        prefix_embeds=batch.get("prefix_embeds"),
    )
    labels = batch["labels"]
    if batch.get("prefix_embeds") is not None:
        p = batch["prefix_embeds"].shape[1]
        pad = -jnp.ones((labels.shape[0], p), labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    loss = lm_loss(params, cfg, out["h"], labels)
    return loss, (jax.lax.stop_gradient(out["acts"]), jax.lax.stop_gradient(out["y_base"]), labels)


def make_populate_step(cfg: ModelConfig, sl: SkipLoRAConfig, optimizer):
    """jit-able: backbone fwd + cache write + adapter optimizer step."""

    def step(params, trainable, static, opt_state, cache, batch, idx):
        def loss_fn(t):
            return populate_loss_fn(params, cfg, merge_adapters(t, static), batch)

        (loss, (acts, y_base, labels)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(trainable)
        values = _encode_acts(acts, merge_adapters(trainable, static), sl)
        values["y_base"] = y_base
        values["labels"] = labels
        cache = cache_write(cache, idx, values)
        updates, opt_state = optimizer.update(grads, opt_state, trainable)
        from repro.optim.optimizers import apply_updates

        trainable = apply_updates(trainable, updates)
        return trainable, opt_state, cache, loss

    return step


def cached_loss_fn(
    params: Params,
    cfg: ModelConfig,
    sl: SkipLoRAConfig,
    adapters: Params,
    vals: dict[str, jax.Array],
    dtype,
) -> jax.Array:
    """Loss from cached activations only — zero backbone compute."""
    if sl.mode == "int8" and sl.use_fused_kernel:
        # int8 payload goes straight into the Pallas kernel: dequant is fused
        # into the A-projection, never round-tripping HBM as bf16.
        from repro.kernels.skip_lora.ops import skip_lora_fused_int8

        q = jnp.swapaxes(vals["acts_q"], 0, 1)        # (L, B, S, D)
        scale = jnp.swapaxes(vals["acts_scale"], 0, 1)  # (L, B, S)
        skip = skip_lora_fused_int8(q, scale, adapters["A"], adapters["B"])
    else:
        acts = _decode_acts(vals, sl, dtype)
        if sl.mode == "freeze_a":
            skip = skip_sum_compressed(acts, adapters["B"])
        else:
            skip = skip_sum(
                acts, adapters["A"], adapters["B"], use_kernel=sl.use_fused_kernel
            )
    h = vals["y_base"].astype(dtype) + skip.astype(dtype)
    return lm_loss(params, cfg, h, vals["labels"])


def make_cached_step_from_vals(cfg: ModelConfig, sl: SkipLoRAConfig, optimizer):
    """Adapter step from already-gathered cache values. This granularity is
    what the tiered engine's streaming read path feeds."""
    from repro.models.lm import model_dtype

    def step(params, trainable, static, opt_state, vals):
        def loss_fn(t):
            return cached_loss_fn(
                params, cfg, sl, merge_adapters(t, static), vals, model_dtype(cfg)
            )

        loss, grads = jax.value_and_grad(loss_fn)(trainable)
        updates, opt_state = optimizer.update(grads, opt_state, trainable)
        from repro.optim.optimizers import apply_updates

        trainable = apply_updates(trainable, updates)
        return trainable, opt_state, loss

    return step


def make_cached_step(cfg: ModelConfig, sl: SkipLoRAConfig, optimizer):
    """jit-able: cache gather + adapter step. This is the paper's fast path."""
    from_vals = make_cached_step_from_vals(cfg, sl, optimizer)

    def step(params, trainable, static, opt_state, cache, idx):
        return from_vals(params, trainable, static, opt_state, cache_read(cache, idx))

    return step


# ---------------------------------------------------------------------------
# Fused epoch loops: one XLA dispatch per epoch phase (DESIGN.md §2)
# ---------------------------------------------------------------------------


def make_populate_epoch(cfg: ModelConfig, sl: SkipLoRAConfig, optimizer, *,
                        donate: bool = True):
    """Whole populate epoch as one lax.scan dispatch over a pre-permuted
    batch index matrix. tokens/labels: (num_samples, seq) device arrays;
    idx_mat: (steps, batch). Carries (trainable, opt_state, cache) are
    donated so the cache updates in place across scan iterations —
    ``donate=False`` for callers that reuse the carry arrays afterwards."""
    step = make_populate_step(cfg, sl, optimizer)
    d = donate_argnums if donate else (lambda *a: ())

    def epoch(params, trainable, static, opt_state, cache, tokens, labels, idx_mat):
        def body(carry, idx):
            t, o, c = carry
            batch = {"tokens": tokens[idx], "labels": labels[idx]}
            t, o, c, loss = step(params, t, static, o, c, batch, idx)
            return (t, o, c), loss

        (trainable, opt_state, cache), losses = jax.lax.scan(
            body, (trainable, opt_state, cache), idx_mat
        )
        return trainable, opt_state, cache, losses

    return jax.jit(epoch, donate_argnums=d(1, 3, 4))


def make_cached_epoch(cfg: ModelConfig, sl: SkipLoRAConfig, optimizer, *,
                      donate: bool = True):
    """Whole cached epoch as one lax.scan dispatch: cache gathers + adapter
    steps only, zero backbone compute and zero Python in the loop."""
    step = make_cached_step(cfg, sl, optimizer)
    d = donate_argnums if donate else (lambda *a: ())

    def epoch(params, trainable, static, opt_state, cache, idx_mat):
        def body(carry, idx):
            t, o = carry
            t, o, loss = step(params, t, static, o, cache, idx)
            return (t, o), loss

        (trainable, opt_state), losses = jax.lax.scan(
            body, (trainable, opt_state), idx_mat
        )
        return trainable, opt_state, losses

    return jax.jit(epoch, donate_argnums=d(1, 3))
