"""The eight fine-tuning methods of the paper (Sections 3-4), MLP scale.

Each method is a (init, forward) pair over an explicit trainable/frozen
parameter split, so ``jax.grad`` differentiates *only* the trainable subtree
and XLA emits exactly the backward ops the paper's Table-1 compute types
prescribe (e.g. LoRA-Last's backward never touches FC weights; Skip-LoRA's
backward never chains through the backbone).

Methods:
    ft_all       : all FC weights/biases + BN affine trainable
    ft_last      : last FC layer trainable
    ft_bias      : biases + BN affine trainable
    ft_all_lora  : ft_all + per-layer LoRA (paper's full-cost reference)
    lora_all     : per-layer LoRA adapters (backbone frozen)
    lora_last    : LoRA adapter on the last layer only
    skip_lora    : adapters from every layer's input to the LAST layer output
    skip2_lora   : skip_lora + Skip-Cache (cached forward variant)
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.mlp import MLPConfig, bn_apply, cross_entropy

Params = Any

METHODS = (
    "ft_all",
    "ft_last",
    "ft_bias",
    "ft_all_lora",
    "lora_all",
    "lora_last",
    "skip_lora",
    "skip2_lora",
)


# ---------------------------------------------------------------------------
# Adapter initialisation
# ---------------------------------------------------------------------------


def init_lora(key: jax.Array, n: int, m: int, rank: int, dtype=jnp.float32) -> Params:
    """Standard LoRA init: A ~ Kaiming, B = 0 (adapter starts as identity)."""
    a = jax.random.normal(key, (n, rank), dtype) * jnp.sqrt(1.0 / n)
    return {"A": a, "B": jnp.zeros((rank, m), dtype)}


def init_per_layer_loras(key: jax.Array, cfg: MLPConfig) -> list[Params]:
    """LoRA-All adapters: layer k gets (dims[k] -> dims[k+1])."""
    dims = cfg.dims
    keys = jax.random.split(key, cfg.n_layers)
    return [
        init_lora(keys[k], dims[k], dims[k + 1], cfg.lora_rank)
        for k in range(cfg.n_layers)
    ]


def init_skip_loras(key: jax.Array, cfg: MLPConfig) -> list[Params]:
    """Skip-LoRA adapters: layer k input -> LAST layer output (dims[k] -> dims[n])."""
    dims = cfg.dims
    n = cfg.n_layers
    keys = jax.random.split(key, n)
    return [init_lora(keys[k], dims[k], dims[n], cfg.lora_rank) for k in range(n)]


def lora_apply(lora: Params, x: jax.Array) -> jax.Array:
    """y_B = (x W_A) W_B  (Eqs. 7-8)."""
    return (x @ lora["A"]) @ lora["B"]


# ---------------------------------------------------------------------------
# Parameter partitioning per method
# ---------------------------------------------------------------------------


def init_method(
    key: jax.Array, cfg: MLPConfig, backbone: Params, method: str
) -> tuple[Params, Params]:
    """Split a pre-trained backbone into (trainable, frozen) for ``method``.

    The returned trees are disjoint; ``forward`` recombines them. BN running
    statistics are always frozen during fine-tuning (inference-mode BN), which
    is what makes activations sample-deterministic and hence cacheable.
    """
    fc = backbone["fc"]
    bn = backbone["bn"]
    bn_affine = [{"gamma": b["gamma"], "beta": b["beta"]} for b in bn]
    bn_stats = [{"mean": b["mean"], "var": b["var"]} for b in bn]

    if method == "ft_all":
        trainable = {"fc": fc, "bn": bn_affine}
        frozen = {"bn_stats": bn_stats}
    elif method == "ft_last":
        trainable = {"fc_last": fc[-1]}
        frozen = {"fc": fc[:-1], "bn": bn_affine, "bn_stats": bn_stats}
    elif method == "ft_bias":
        trainable = {"b": [layer["b"] for layer in fc], "bn": bn_affine}
        frozen = {"W": [layer["W"] for layer in fc], "bn_stats": bn_stats}
    elif method == "ft_all_lora":
        trainable = {
            "fc": fc,
            "bn": bn_affine,
            "lora": init_per_layer_loras(key, cfg),
        }
        frozen = {"bn_stats": bn_stats}
    elif method == "lora_all":
        trainable = {"lora": init_per_layer_loras(key, cfg)}
        frozen = {"fc": fc, "bn": bn_affine, "bn_stats": bn_stats}
    elif method == "lora_last":
        dims = cfg.dims
        trainable = {"lora": init_lora(key, dims[-2], dims[-1], cfg.lora_rank)}
        frozen = {"fc": fc, "bn": bn_affine, "bn_stats": bn_stats}
    elif method in ("skip_lora", "skip2_lora"):
        trainable = {"lora": init_skip_loras(key, cfg)}
        frozen = {"fc": fc, "bn": bn_affine, "bn_stats": bn_stats}
    else:
        raise ValueError(f"unknown method {method!r}")
    return trainable, frozen


def _bn_act(h: jax.Array, affine: Params, stats: Params) -> jax.Array:
    merged = {**affine, **stats}
    return jax.nn.relu(bn_apply(merged, h))


# ---------------------------------------------------------------------------
# Forward passes (full). Each returns (logits, xs) with xs[k] = input of FC k.
# ---------------------------------------------------------------------------


def forward(
    method: str, trainable: Params, frozen: Params, x: jax.Array, cfg: MLPConfig
) -> tuple[jax.Array, list[jax.Array]]:
    n = cfg.n_layers
    xs: list[jax.Array] = []
    h = x

    if method == "ft_all":
        for k in range(n):
            xs.append(h)
            h = h @ trainable["fc"][k]["W"] + trainable["fc"][k]["b"]
            if k < n - 1:
                h = _bn_act(h, trainable["bn"][k], frozen["bn_stats"][k])
        return h, xs

    if method == "ft_last":
        for k in range(n - 1):
            xs.append(h)
            h = h @ frozen["fc"][k]["W"] + frozen["fc"][k]["b"]
            h = _bn_act(h, frozen["bn"][k], frozen["bn_stats"][k])
        xs.append(h)
        h = h @ trainable["fc_last"]["W"] + trainable["fc_last"]["b"]
        return h, xs

    if method == "ft_bias":
        for k in range(n):
            xs.append(h)
            h = h @ frozen["W"][k] + trainable["b"][k]
            if k < n - 1:
                h = _bn_act(h, trainable["bn"][k], frozen["bn_stats"][k])
        return h, xs

    if method == "ft_all_lora":
        for k in range(n):
            xs.append(h)
            h = h @ trainable["fc"][k]["W"] + trainable["fc"][k]["b"] + lora_apply(
                trainable["lora"][k], h
            )
            if k < n - 1:
                h = _bn_act(h, trainable["bn"][k], frozen["bn_stats"][k])
        return h, xs

    if method == "lora_all":
        for k in range(n):
            xs.append(h)
            h = h @ frozen["fc"][k]["W"] + frozen["fc"][k]["b"] + lora_apply(
                trainable["lora"][k], h
            )
            if k < n - 1:
                h = _bn_act(h, frozen["bn"][k], frozen["bn_stats"][k])
        return h, xs

    if method == "lora_last":
        for k in range(n):
            xs.append(h)
            y = h @ frozen["fc"][k]["W"] + frozen["fc"][k]["b"]
            if k == n - 1:
                y = y + lora_apply(trainable["lora"], h)
            else:
                y = _bn_act(y, frozen["bn"][k], frozen["bn_stats"][k])
            h = y
        return h, xs

    if method in ("skip_lora", "skip2_lora"):
        # Backbone forward is entirely frozen; adapters tap every x^k and add
        # into the LAST layer's output (Eq. 17).
        for k in range(n):
            xs.append(h)
            h = h @ frozen["fc"][k]["W"] + frozen["fc"][k]["b"]
            if k < n - 1:
                h = _bn_act(h, frozen["bn"][k], frozen["bn_stats"][k])
        skip = jnp.zeros_like(h)
        for k in range(n):
            skip = skip + lora_apply(trainable["lora"][k], xs[k])
        return h + skip, xs

    raise ValueError(f"unknown method {method!r}")


def skip_forward_cached(
    trainable: Params, y_base: jax.Array, xs: list[jax.Array]
) -> jax.Array:
    """Skip2-LoRA cached forward (Section 4.2): y^n <- c^n + sum_k x^k A_k B_k.

    ``y_base`` is the cached frozen-backbone last-layer output c_i^n; ``xs``
    are the cached per-layer inputs. No backbone compute at all.
    """
    out = y_base
    for k, lora in enumerate(trainable["lora"]):
        out = out + lora_apply(lora, xs[k])
    return out


# ---------------------------------------------------------------------------
# Train steps (plain SGD, Eq. 5-6 / 15-16)
# ---------------------------------------------------------------------------


def _sgd(p: Params, g: Params, lr: float) -> Params:
    return jax.tree.map(lambda a, b: a - lr * b, p, g)


@functools.partial(jax.jit, static_argnames=("method", "cfg"))
def train_step(
    method: str,
    cfg: MLPConfig,
    trainable: Params,
    frozen: Params,
    xb: jax.Array,
    yb: jax.Array,
    lr: float,
) -> tuple[Params, jax.Array]:
    """One full-forward SGD step (all methods)."""

    def loss_fn(t):
        logits, _ = forward(method, t, frozen, xb, cfg)
        return cross_entropy(logits, yb)

    loss, grads = jax.value_and_grad(loss_fn)(trainable)
    return _sgd(trainable, grads, lr), loss


@jax.jit
def cached_train_step(
    trainable: Params,
    y_base: jax.Array,
    xs: list[jax.Array],
    yb: jax.Array,
    lr: float,
) -> tuple[Params, jax.Array]:
    """One Skip2-LoRA step from cached activations: zero backbone compute."""

    def loss_fn(t):
        logits = skip_forward_cached(t, y_base, xs)
        return cross_entropy(logits, yb)

    loss, grads = jax.value_and_grad(loss_fn)(trainable)
    return _sgd(trainable, grads, lr), loss


# Convenience: phase-split callables for the timing benchmarks (Table 6/7).


def make_phase_fns(
    method: str, cfg: MLPConfig
) -> dict[str, Callable]:
    """Separately-jitted forward / backward / update, mirroring the paper's
    per-phase timing rows."""

    @jax.jit
    def fwd(trainable, frozen, xb):
        logits, _ = forward(method, trainable, frozen, xb, cfg)
        return logits

    @jax.jit
    def bwd(trainable, frozen, xb, yb):
        def loss_fn(t):
            logits, _ = forward(method, t, frozen, xb, cfg)
            return cross_entropy(logits, yb)

        return jax.grad(loss_fn)(trainable)

    @jax.jit
    def upd(trainable, grads, lr):
        return _sgd(trainable, grads, lr)

    return {"forward": fwd, "backward": bwd, "update": upd}
