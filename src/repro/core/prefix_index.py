"""Radix-tree prefix index over the paged KV block pool.

Maps ``(tenant, token-prefix)`` onto ordered KV pool block ids (SGLang's
radix-cache idea, at block granularity): one tree node per *block* of
tokens, keyed by that block's token tuple, scoped per tenant (a tenant's
system prompt never collides with another's, and dropping a tenant drops
its subtree). The scheduler:

  - ``match`` on admission — the longest indexed block-prefix of the
    prompt (capped so at least one tail token always remains: the tail
    prefill produces the next-token logits, so an exact-full-prompt hit
    still dispatches a 1-token tail);
  - ``acquire``/``release`` around a reusing row's lifetime (pool refs
    protect blocks from eviction while in flight);
  - ``insert`` after a dense admission — missing blocks allocate from
    the pool (evicting LRU unreferenced leaves under pressure) and the
    scheduler publishes the row's fresh K/V into them.

Invariants:

  - every node holds exactly ONE pool ref on its block for its lifetime;
    extra refs on the same block are in-flight admissions.
  - a node exists only if its parent does (paths are complete prefixes),
    so eviction removes leaves only — a freed parent would orphan the
    descendants' token paths.
  - eviction never touches a block with in-flight refs (refs > 1).
"""

from __future__ import annotations

from collections import Counter
from typing import Any, Optional

import numpy as np

from repro.core.kv_pool import KVBlockPool, KVPoolExhausted


class _Node:
    __slots__ = ("key", "block", "children", "parent", "last_used")

    def __init__(self, key, block: int, parent: "_Node", last_used: int):
        self.key = key                      # token tuple of THIS block
        self.block = block                  # pool block id
        self.children: dict[tuple, _Node] = {}
        self.parent = parent
        self.last_used = last_used


class _Root:
    __slots__ = ("children",)

    def __init__(self):
        self.children: dict[tuple, _Node] = {}


class RadixPrefixIndex:
    def __init__(self, pool: KVBlockPool):
        self.pool = pool
        self.roots: dict[Any, _Root] = {}
        self._clock = 0
        self.counters: Counter = Counter()

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _chunks(self, tokens, n: int) -> list[tuple]:
        blk = self.pool.block
        t = np.asarray(tokens).reshape(-1)
        return [tuple(int(x) for x in t[i * blk:(i + 1) * blk])
                for i in range(n)]

    # -- queries -------------------------------------------------------------

    def match(self, tenant, tokens) -> list[int]:
        """Longest indexed block-prefix of ``tokens``: ordered pool block
        ids, capped at ``(len(tokens) - 1) // block`` so >= 1 tail token
        survives for the tail prefill. Bumps recency on the matched path."""
        n = np.asarray(tokens).reshape(-1).size
        cap = max(0, (n - 1) // self.pool.block)
        root = self.roots.get(tenant)
        if root is None or cap == 0:
            return []
        ids: list[int] = []
        node: Any = root
        for key in self._chunks(tokens, cap):
            child = node.children.get(key)
            if child is None:
                break
            child.last_used = self._tick()
            ids.append(child.block)
            node = child
        return ids

    def acquire(self, ids) -> tuple[int, np.ndarray]:
        """Pin matched blocks for an in-flight row: +1 pool ref each.
        Returns the release handle (pool generation + ids) — release via
        ``release`` when the row retires (stale handles after a pool
        reset no-op)."""
        ids = np.asarray(ids, np.int32).reshape(-1)
        self.pool.ref(ids)
        return (self.pool.generation, ids)

    def release(self, handle: Optional[tuple]) -> None:
        if handle is None:
            return
        generation, ids = handle
        self.pool.deref(ids, generation=generation)

    # -- growth --------------------------------------------------------------

    def insert(self, tenant, tokens) -> list[tuple[int, int]]:
        """Index every full block of ``tokens``, allocating pool blocks for
        the missing suffix. Returns ``[(pool_id, slot)]`` for the NEWLY
        created nodes (slot = block index within the prompt) — the caller
        must publish those slots' K/V into the pool. Under pool pressure,
        evicts LRU unreferenced leaves; if allocation still fails the
        insert stops at the last indexable block (paths stay complete
        prefixes) and the tail simply isn't indexed."""
        n_full = np.asarray(tokens).reshape(-1).size // self.pool.block
        if n_full == 0:
            return []
        root = self.roots.setdefault(tenant, _Root())
        node: Any = root
        created: list[tuple[int, int]] = []
        for slot, key in enumerate(self._chunks(tokens, n_full)):
            child = node.children.get(key)
            if child is None:
                try:
                    bid = self.pool.alloc(1)[0]
                except KVPoolExhausted:
                    if self.evict(1) == 0 or not self.pool.free:
                        self.counters["insert_stopped"] += 1
                        break
                    bid = self.pool.alloc(1)[0]
                child = _Node(key, bid, node, self._tick())
                node.children[key] = child
                created.append((bid, slot))
                self.counters["nodes_created"] += 1
            else:
                child.last_used = self._tick()
            node = child
        return created

    # -- shrinkage -----------------------------------------------------------

    def _leaves(self) -> list[tuple[Any, _Node]]:
        out = []
        stack = [
            (tenant, node)
            for tenant, root in self.roots.items()
            for node in root.children.values()
        ]
        while stack:
            tenant, node = stack.pop()
            if node.children:
                stack.extend((tenant, c) for c in node.children.values())
            else:
                out.append((tenant, node))
        return out

    def evict(self, n: int) -> int:
        """Free up to ``n`` pool blocks by removing least-recently-used
        *unreferenced* leaves (refs == 1: only the index holds them).
        Removing a leaf can expose its parent — the loop re-ranks until
        ``n`` blocks came free or nothing is evictable."""
        freed = 0
        while freed < n:
            victims = [
                (t, nd) for t, nd in self._leaves()
                if self.pool.refs[nd.block] == 1
            ]
            if not victims:
                break
            _, victim = min(victims, key=lambda tn: tn[1].last_used)
            self._remove(victim)
            freed += 1
            self.counters["evicted"] += 1
        return freed

    def _remove(self, node: _Node) -> None:
        parent = node.parent
        del parent.children[node.key]
        self.pool.deref([node.block])

    def drop_tenant(self, tenant) -> int:
        """Forget a tenant's whole subtree (``SessionRuntime.release``
        hook). Blocks still pinned by in-flight rows stay allocated until
        those rows retire; the index's own refs drop now."""
        root = self.roots.pop(tenant, None)
        if root is None:
            return 0
        dropped = 0
        stack = list(root.children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            self.pool.deref([node.block])
            dropped += 1
        self.counters["dropped"] += dropped
        return dropped

    def reset(self) -> None:
        """Drop every scope and reset the pool (generation bump: handles
        held by in-flight rows become stale no-ops)."""
        self.roots.clear()
        self.pool.reset()

    def n_nodes(self) -> int:
        return sum(
            1 for _ in self._iter_nodes()
        )

    def _iter_nodes(self):
        for tenant, root in self.roots.items():
            stack = [(node, [node.key]) for node in root.children.values()]
            while stack:
                node, path = stack.pop()
                yield tenant, node, path
                stack.extend(
                    (c, path + [c.key]) for c in node.children.values()
                )

    # -- checkpoint ----------------------------------------------------------

    def state(self) -> list[dict]:
        """JSON-serialisable node list: tenant scope, full token path
        (flattened), block id, recency."""
        return [
            {
                "tenant": tenant,
                "tokens": [int(x) for key in path for x in key],
                "block": int(node.block),
                "used": int(node.last_used),
            }
            for tenant, node, path in self._iter_nodes()
        ]

    def load_state(self, entries: list[dict]) -> None:
        """Rebuild the tree from ``state()`` output and make the pool's
        accounting agree: exactly one ref per restored node (in-flight
        refs never survive a restore — there are no in-flight rows in a
        fresh session). Entries are sorted shortest-path-first so parents
        restore before children."""
        self.roots.clear()
        self.pool.refs[:] = 0
        self.pool.free = list(range(self.pool.n_blocks - 1, -1, -1))
        blk = self.pool.block
        for ent in sorted(entries, key=lambda e: len(e["tokens"])):
            tokens = ent["tokens"]
            if len(tokens) % blk:
                raise ValueError(
                    f"radix entry path length {len(tokens)} not a multiple "
                    f"of block {blk}"
                )
            root = self.roots.setdefault(ent["tenant"], _Root())
            node: Any = root
            n_full = len(tokens) // blk
            for slot, key in enumerate(self._chunks(tokens, n_full)):
                child = node.children.get(key)
                if child is None:
                    if slot != n_full - 1:
                        raise ValueError(
                            "radix entry restored before its parent: "
                            f"{ent!r}"
                        )
                    bid = int(ent["block"])
                    if self.pool.refs[bid] != 0:
                        raise ValueError(
                            f"radix restore: block {bid} claimed twice"
                        )
                    self.pool.refs[bid] = 1
                    self.pool.free.remove(bid)
                    child = _Node(key, bid, node, int(ent["used"]))
                    node.children[key] = child
                node = child
        self._clock = max(
            [int(e["used"]) for e in entries], default=self._clock
        )
