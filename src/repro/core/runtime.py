"""Unified continual-learning runtime: serve + fleet fine-tune, one engine.

The paper's deployment story is continual (DESIGN.md §9): a device serves
with its adapter, accumulates new samples into the skip-cache, and
periodically fine-tunes. After PR 2/3 the repo had three disjoint entry
points (``launch/serve.py``, ``launch/finetune.py``, ``launch/fleet.py``)
that each rebuilt their own compiled functions, cache views, and pool
bookkeeping — serve and train could not interleave over one adapter pool.

``SessionRuntime`` is the single engine behind all three launchers. It owns

  - ONE ``AdapterPool`` (slot-based serving registry, now with session
    pinning so LRU eviction can never drop in-flight training state),
  - ONE ``TieredCacheEngine`` (every tenant's skip-cache partition), and
  - ONE compiled-function cache (module-level ``compiled``; the serve
    prefill/decode jits previously private to ``launch/serve.py`` live
    here, alongside the fleet epoch/step jits),

and processes an interleaved event stream:

  - ``serve(tenants, prompts)``: scan-fused generation, routed per batch —
    single-stack when every row is the base model, grouped (float or raw
    int8 pool layout) otherwise. Same compiled entries as PR 2's
    ``decode_scan`` benchmarks, so routing adds only a pool lookup.
  - ``ingest(tenant, tokens, labels)``: populate-phase forward that writes
    the tenant's skip-cache partition *and* returns last-position adapted
    logits — ingestion doubles as serving (``models.lm.ingest_prefill``).
  - ``adapt(tenants, epochs)``: cached-phase fleet epochs over the grouped
    custom-VJP kernels, write-back through ``AdapterPool.register_many``.
    Because the backbone is frozen, cached values equal the populate
    epoch's in-flight activations bitwise (full mode, matching cache
    dtype), so an interleaved serve -> ingest -> adapt session reproduces
    the offline ``fleet_finetune`` adapters *bitwise* on the kernel path —
    the §9 parity bar, enforced by ``tests/test_runtime.py``.

Batch planning goes through ``core.batch_plan`` with explicit tenant
partitions, so an ``adapt`` group that is a subset or reordering of the
ingested tenants still replays each tenant's own RNG stream.

Since the mesh-native refactor (DESIGN.md §10) every session is
constructed over an explicit device ``Mesh``:

  - a 1-device mesh (the default) reproduces the single-device session
    *bitwise* — the sharded paths collapse to the PR 4 code path;
  - on an N-way ``data`` axis the stacked adapter pool, optimizer moments,
    and skip-cache partitions shard **by tenant**: ``ShardedAdapterPool``
    owns the slot->shard placement, each logical shard's pool + cache
    engine + backbone replica is committed to its physical device, and
    serve/adapt batches route rows to the shard holding their slot;
  - ``adapt`` groups tenants by (trajectory, shard) and dispatches each
    group's fused epochs entirely on its shard — the same compiled entries
    as the 1-device path, with committed inputs, so there is never a
    cross-device gather of cache rows or adapter grads, and moving a group
    between devices is *bitwise free* (measured; this is why the sharded
    session hand-rolls its SPMD instead of using ``shard_map``, whose
    repartitioned programs drift at ~1e-6 — see §10);
  - the logical shard count (``placement_shards``) is a session-*layout*
    property carried through checkpoints: an elastic restart restores onto
    however many devices survive (shard ``s`` -> ``devices[s % n]``) and
    continues bitwise.
"""

from __future__ import annotations

import dataclasses
from collections import Counter, OrderedDict
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import donate_argnums
from repro.core import batch_plan
from repro.core import fleet_finetune as FF
from repro.core import lm_skiplora as SL
from repro.core.adapter_pool import ShardedAdapterPool
from repro.core.cache_engine import CacheStats, TieredCacheEngine
from repro.core.control_plane import ControlConfig, ControlPlane
from repro.models.config import ModelConfig
from repro.runtime.sharding import (
    ShardScope,
    make_mesh,
    replicate_backbone,
    scope_ctx,
    session_devices,
    session_mesh_layout,
    session_param_specs,
    shard_backbone,
    shard_submesh,
    specs_all_replicated,
)
from repro.models.lm import (
    decode_scan,
    ingest_prefill,
    init_serve_caches,
    pipeline_stage_params,
    sample_token,
    serve_decode,
    serve_prefill,
    serve_prefill_grouped,
)
from repro.optim.optimizers import OptState, adamw

Params = Any

# ---------------------------------------------------------------------------
# Shared compiled-function cache (one per process, every engine routes here)
# ---------------------------------------------------------------------------

#: (name, cfg, extras) -> jitted callable. cfg is a frozen dataclass and
#: hashes by value; jax.jit then keys compiled traces by argument shape
#: below this cache, so repeated calls at a new (batch, seq) retrace but
#: never rebuild the jit wrapper itself.
_FN_CACHE: dict[tuple, Any] = {}

#: Trace-time retrace counter: ``_mark_trace(name)`` runs as a Python side
#: effect INSIDE a jitted function body, so it fires exactly once per trace
#: (first call and every shape/static-arg retrace) and never on cache hits.
#: Tests assert e.g. that serving three distinct temperatures leaves
#: ``TRACE_COUNTS["decode_scan"]`` unchanged after warmup — the
#: recompile-per-temperature bug regression bar — without reaching into
#: jit's private ``_cache_size``.
TRACE_COUNTS: Counter = Counter()


def _mark_trace(name: str) -> None:
    TRACE_COUNTS[name] += 1


def compiled(key: tuple, make: Callable[[], Any]):
    """Fetch-or-build a jitted callable under a hashable key. The single
    compiled-fn cache behind serve, ingest, and adapt — the per-launcher
    caches of PR 2/3 collapsed here."""
    fn = _FN_CACHE.get(key)
    if fn is None:
        fn = _FN_CACHE[key] = make()
    return fn


def _cached_fn(name: str, cfg, make, extras: tuple = ()):
    return compiled((name, cfg, *extras), make)


# Every compiled-fn factory takes an optional ``scope`` (a hashable
# ``ShardScope`` or None): the fn body runs under ``scope_ctx(scope)`` so the
# model's ``constrain`` calls see the scope AT TRACE TIME — whenever jit
# retraces (new shapes, new statics), not just on the first call — and the
# scope rides the cache key so a 2-D session and a 1-device session never
# share a trace. ``scope=None`` traces with no constraints: bitwise the
# historical single/data-axis programs.


def _prefill_fn(cfg, scope=None):
    def make():
        def f(params, tokens, caches, adapters):
            with scope_ctx(scope):
                return serve_prefill(
                    params, cfg, tokens, caches, adapters=adapters
                )

        return jax.jit(f)

    return _cached_fn("prefill", cfg, make, (scope,))


def _prefill_grouped_fn(cfg, use_kernel: bool, scope=None):
    def make():
        def f(params, tokens, caches, pools, idx):
            with scope_ctx(scope):
                return serve_prefill_grouped(
                    params, cfg, tokens, caches, pools, idx,
                    use_kernel=use_kernel,
                )

        return jax.jit(f)

    return _cached_fn("prefill_grouped", cfg, make, (use_kernel, scope))


def _decode_scan_fn(cfg, use_kernel: bool = True, fuse_skip: bool = False,
                    scope=None):
    def make():
        def f(params, tok0, pos0, caches, key, adapters, pools, idx,
              max_new, temperature, unroll):
            _mark_trace("decode_scan")
            with scope_ctx(scope):
                return decode_scan(
                    params, cfg, tok0, pos0, caches, key,
                    max_new=max_new, temperature=temperature,
                    adapters=adapters, pools=pools, idx=idx,
                    use_kernel=use_kernel, fuse_skip=fuse_skip, unroll=unroll,
                )

        # Donate the KV caches: the scan's carry updates them in place
        # (off-CPU; the CPU backend has no donation and would only warn).
        # ``temperature`` (arg 9) is deliberately NOT static: baking it into
        # the trace cache meant one full decode recompile per distinct
        # sampling temperature under live traffic. It is traced now (the
        # greedy/temperature select runs inside ``sample_token``), so every
        # temperature shares one compiled decode.
        return jax.jit(
            f,
            static_argnums=(8, 10),
            donate_argnums=donate_argnums(3),
        )

    return _cached_fn("decode_scan", cfg, make, (use_kernel, fuse_skip, scope))


def _decode_step_fn(cfg, scope=None):
    def make():
        def f(params, tok, pos, caches, adapters):
            with scope_ctx(scope):
                return serve_decode(
                    params, cfg, tok, pos, caches, adapters=adapters
                )

        return jax.jit(f)

    return _cached_fn("decode_step", cfg, make, (scope,))


def _ingest_fn(cfg, use_kernel: bool, scope=None):
    def make():
        def f(params, tokens, pools, idx):
            with scope_ctx(scope):
                return ingest_prefill(
                    params, cfg, tokens, pools, idx, use_kernel=use_kernel
                )

        return jax.jit(f)

    return _cached_fn("ingest", cfg, make, (use_kernel, scope))


# ---------------------------------------------------------------------------
# Generation entry points (moved from launch/serve.py; the CLI re-exports)
# ---------------------------------------------------------------------------

#: Monotone counter behind ``_default_rng``: calls that omit ``rng`` used to
#: all fall back to ``jax.random.key(0)``, so every temperature>0 serve
#: without an explicit key replayed the SAME sample stream. Each omission now
#: folds a fresh counter value into the base key — still deterministic for a
#: fresh process (call N always sees fold_in(key(0), N)), never shared
#: between calls.
_DEFAULT_RNG_CALLS = 0


def _default_rng() -> jax.Array:
    global _DEFAULT_RNG_CALLS
    key = jax.random.fold_in(jax.random.key(0), _DEFAULT_RNG_CALLS)
    _DEFAULT_RNG_CALLS += 1
    return key


def generate(
    params,
    cfg,
    tokens,
    *,
    max_new: int,
    adapters_stack=None,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
    unroll: int = 1,
    scope=None,
):
    """Batched generation, scan-fused: 1 prefill dispatch + 1 decode-scan
    dispatch for all ``max_new`` tokens. Returns (B, max_new) int32.
    ``scope`` (a ``ShardScope``) traces the dispatches with that mesh's
    activation constraints — required when ``params`` is model-axis
    sharded."""
    b, s = tokens.shape
    caches = init_serve_caches(cfg, b, s + max_new)
    logits, caches = _prefill_fn(cfg, scope)(
        params, tokens, caches, adapters_stack
    )
    tok0, key = sample_token(
        logits, rng if rng is not None else _default_rng(), temperature
    )
    toks, _ = _decode_scan_fn(cfg, scope=scope)(
        params, tok0, jnp.asarray(s, jnp.int32), caches, key,
        adapters_stack, None, None, max_new,
        jnp.asarray(temperature, jnp.float32), unroll,
    )
    return toks


def generate_grouped(
    params,
    cfg,
    tokens,
    pools: dict[str, jax.Array],
    idx: jax.Array,
    *,
    max_new: int,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
    use_kernel: bool = True,
    fuse_skip: bool = False,
    unroll: int = 1,
    scope=None,
):
    """Multi-tenant generation: batch row b decodes under adapter slot
    idx[b] gathered from the stacked pool (float, raw-int8, or packed-4-bit
    layout, see ``AdapterPool.pools()``). Same two-dispatch structure as
    ``generate``. ``fuse_skip`` inlines the decode skip term as dense math
    (one fused XLA step program instead of backbone + grouped kernel);
    prefill keeps the grouped kernel either way. ``scope`` traces with a
    model-axis mesh's activation constraints (sharded-backbone serving)."""
    b, s = tokens.shape
    caches = init_serve_caches(cfg, b, s + max_new)
    logits, caches = _prefill_grouped_fn(cfg, use_kernel, scope)(
        params, tokens, caches, pools, idx
    )
    tok0, key = sample_token(
        logits, rng if rng is not None else _default_rng(), temperature
    )
    toks, _ = _decode_scan_fn(cfg, use_kernel, fuse_skip, scope)(
        params, tok0, jnp.asarray(s, jnp.int32), caches, key,
        None, pools, idx, max_new,
        jnp.asarray(temperature, jnp.float32), unroll,
    )
    return toks


def generate_loop(
    params,
    cfg,
    tokens,
    *,
    max_new: int,
    adapters_stack=None,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
):
    """Per-token Python decode loop (the pre-scan path, kept for the
    loop-vs-scan benchmark): ``max_new`` dispatches, cached step jits."""
    b, s = tokens.shape
    caches = init_serve_caches(cfg, b, s + max_new)
    prefill = _prefill_fn(cfg)
    decode = _decode_step_fn(cfg)
    logits, caches = prefill(params, tokens, caches, adapters_stack)
    key = rng if rng is not None else _default_rng()
    tok, key = sample_token(logits, key, temperature)
    out = []
    for i in range(max_new):
        out.append(tok)
        logits, caches = decode(
            params, tok, jnp.asarray(s + i, jnp.int32), caches, adapters_stack
        )
        tok, key = sample_token(logits, key, temperature)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Session runtime
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TenantState:
    """Per-tenant continual-learning state the runtime tracks between
    events. ``adapters``/``opt_*`` are per-tenant slices of the stacked
    fleet trees (flat {"A": (L,D,R), "B": (L,R,D)} layout)."""

    partition: int                      # cache partition index
    n_ingested: int = 0                 # rows written into the partition
    epochs_done: int = 0                # planner epoch stream position
    step: int = 0                       # optimizer step count
    adapters: Optional[Params] = None
    opt_mu: Optional[Params] = None
    opt_nu: Optional[Params] = None

    @property
    def trained(self) -> bool:
        return self.adapters is not None


class SessionRuntime:
    """One session engine for serve + ingest + adapt over a shared pool,
    constructed over an explicit device mesh.

    ``max_tenants`` bounds the cache partitions (``samples_per_tenant``
    rows each, global id = partition * samples_per_tenant + local id — the
    PR 3 fleet convention, so offline and interleaved training address
    identical cache rows). The pool defaults to ``max_tenants/shards + 1``
    slots per shard (slot 0 pinned zero, ``pool_slots`` overrides the
    per-shard count); the engine to fully HBM-resident — pass
    ``cache_capacity`` / ``hbm_budget_bytes`` to force tiered placement,
    which flips ``adapt`` from the fused-scan epoch to the streaming
    prefetch path (DESIGN.md §9 path table).

    ``mesh`` (default: a 1-device ``("data",)`` mesh — today's behaviour,
    bitwise) supplies the physical devices; ``placement_shards`` fixes the
    *logical* shard count (default: the mesh's device count). Partition
    ``p`` belongs to logical shard ``p % placement_shards``, logical shard
    ``s`` lives on ``devices[s % n_devices]`` — so a checkpoint restored
    onto a different device count keeps its layout, its group traces, and
    therefore its trajectory, bitwise (DESIGN.md §10). Backbone placement
    is derived from the ``runtime.sharding`` rule table
    (``session_param_specs``): all-replicated on a data-only mesh, realised
    as per-shard committed replicas.

    On a 2-D ``(data, model)`` mesh each logical shard instead owns a
    model-axis device *group* holding ONE Megatron-sharded backbone replica
    (``shard_backbone`` over the shard's submesh): per-device backbone
    bytes drop ~Mx and every serve/ingest/adapt dispatch traces under the
    shard's ``ShardScope`` so activations carry the matching constraints.
    ``pipeline_stages=N`` (N == model-axis size) additionally precomputes a
    GPipe stage split of the backbone for the scheduler's pipelined
    admission prefill (``models.lm.pipeline_sched_prefill``).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        sl: SL.SkipLoRAConfig,
        params: Params,
        *,
        max_tenants: int,
        samples_per_tenant: int,
        seq: int,
        lr: float = 1e-3,
        optimizer=None,
        pool_slots: Optional[int] = None,
        pool_compress: Optional[str] = None,
        cache_capacity: Optional[int] = None,
        hbm_budget_bytes: Optional[int] = None,
        cache_dir: Optional[str] = None,
        use_kernel: bool = True,
        decode_fuse: bool = False,
        seed: int = 0,
        mesh=None,
        placement_shards: Optional[int] = None,
        pipeline_stages: int = 0,
        idx_memo_slots: int = 256,
        control: Optional[ControlConfig] = None,
    ):
        if sl.mode not in ("full", "int8"):
            raise ValueError(
                f"the session runtime trains fleet modes 'full'/'int8', "
                f"not {sl.mode!r}"
            )
        self.cfg, self.sl = cfg, sl
        self.max_tenants = max_tenants
        self.samples_per_tenant = samples_per_tenant
        self.seq = seq
        self.use_kernel = use_kernel
        # Inline the decode skip term as dense math (one fused step program)
        # instead of a grouped kernel dispatch — temp-0 tokens are identical
        # either way; see models.lm.decode_step.
        self.decode_fuse = decode_fuse
        self.seed = seed
        self.optimizer = optimizer if optimizer is not None else adamw(lr)
        self._opt_key = ("adamw", lr) if optimizer is None else ("custom", id(optimizer))
        #: Adapter control plane (DESIGN.md §13) — strictly opt-in: with
        #: ``control=None`` (the default) the session plans, trains, and
        #: writes back bitwise the historical trajectory. With a
        #: ``ControlConfig``: every tenant's epoch plan excludes its
        #: held-out rows, ``adapt`` computes pre/post shadow-eval loss in
        #: the same fused dispatch as training, and write-back is gated.
        self.control_cfg = control
        self.control = ControlPlane(control) if control is not None else None

        # -- mesh + logical shard layout ------------------------------------
        if mesh is None:
            mesh = make_mesh((1,), ("data",), devices=jax.devices()[:1])
        self.mesh = mesh
        self.devices = session_devices(mesh)
        n_groups = len(self.devices)
        _, n_model, _ = session_mesh_layout(mesh)
        self.model_parallel = n_model
        self.pipeline_stages = int(pipeline_stages)
        self.n_shards = (
            int(placement_shards) if placement_shards is not None
            else n_groups
        )
        if self.n_shards < 1:
            raise ValueError(f"placement_shards {self.n_shards} < 1")
        if max_tenants % self.n_shards:
            raise ValueError(
                f"max_tenants {max_tenants} must divide over "
                f"{self.n_shards} shards"
            )
        if self.pipeline_stages:
            if self.pipeline_stages != n_model or n_model < 2:
                raise ValueError(
                    f"pipeline_stages={self.pipeline_stages} must equal the "
                    f"mesh's model-axis size ({n_model}, >= 2): the stages "
                    "repurpose each shard's tensor-parallel device group"
                )
            if pool_compress is not None:
                raise ValueError(
                    "pipeline serve reads the adapter pool per stage and "
                    "needs the float layout: pool_compress must be None"
                )
        if n_model > 1:
            # 2-D (data x model) mesh: each logical shard's backbone is ONE
            # Megatron-sharded replica over its model-axis device group (the
            # ``data`` axis still shards tenants exactly as PR 5). The
            # grouped Pallas kernels don't partition under GSPMD, so 2-D
            # sessions take the dense skip-sum paths.
            if use_kernel:
                raise ValueError(
                    "grouped Pallas kernels do not partition over a model "
                    "axis; build (data, model) sessions with use_kernel=False"
                )
            submeshes = [
                shard_submesh(mesh, s % n_groups) for s in range(self.n_shards)
            ]
            self._scope = [ShardScope(sm) for sm in submeshes]
            # Per-shard "device" becomes a replicated NamedSharding over the
            # shard's submesh: every existing device_put call site (pool,
            # cache engine, adapt state) then commits its arrays onto the
            # whole group, which is what lets them enter one jit alongside
            # the model-sharded backbone.
            self._shard_device = [
                jax.sharding.NamedSharding(sm, jax.sharding.PartitionSpec())
                for sm in submeshes
            ]
            self._shard_params = []
            for s in range(self.n_shards):
                self._shard_params.append(
                    shard_backbone(params, submeshes[s]) if s < n_groups
                    else self._shard_params[s % n_groups]
                )
        else:
            self._scope = [None] * self.n_shards
            self._shard_device = [
                self.devices[s % n_groups] for s in range(self.n_shards)
            ]
            # Backbone placement from the runtime.sharding rule table: on a
            # data-only session mesh every AxisRules-derived spec resolves to
            # replication, which replicate_backbone realises as one committed
            # replica per device.
            assert specs_all_replicated(session_param_specs(params, mesh))
            replicas = replicate_backbone(params, self.devices)
            self._shard_params = [
                replicas[s % n_groups] for s in range(self.n_shards)
            ]
        self.params = self._shard_params[0]
        # Pipeline partitioning of the same submesh devices: the backbone
        # re-stacked into n_stages contiguous layer blocks, leading axis
        # sharded over the (renamed-in-place) model axis so stage i's block
        # lives wholly on device i of each shard's group.
        self._stage_blocks: list = [None] * self.n_shards
        self._stage_valid: list = [None] * self.n_shards
        if self.pipeline_stages:
            blocks, valid = pipeline_stage_params(
                params, cfg, self.pipeline_stages
            )
            for s in range(self.n_shards):
                if s < n_groups:
                    stage_sh = jax.sharding.NamedSharding(
                        submeshes[s], jax.sharding.PartitionSpec("model")
                    )
                    self._stage_blocks[s] = jax.tree.map(
                        lambda x: jax.device_put(x, stage_sh), blocks
                    )
                    self._stage_valid[s] = jax.device_put(valid, stage_sh)
                else:
                    self._stage_blocks[s] = self._stage_blocks[s % n_groups]
                    self._stage_valid[s] = self._stage_valid[s % n_groups]

        # -- per-shard engines, pools, partitions ---------------------------
        tenants_per_shard = max_tenants // self.n_shards
        shard_samples = tenants_per_shard * samples_per_tenant
        if cache_capacity is None and hbm_budget_bytes is None:
            shard_capacity = shard_samples  # fully resident: fused-scan adapt
        elif cache_capacity is not None:
            shard_capacity = max(1, cache_capacity // self.n_shards)
        else:
            shard_capacity = None
        shard_budget = (
            None if hbm_budget_bytes is None
            else max(1, hbm_budget_bytes // self.n_shards)
        )
        layout = SL.lm_cache_layout(cfg, sl, seq)
        self.engines = [
            TieredCacheEngine(
                shard_samples,
                layout,
                capacity=shard_capacity,
                hbm_budget_bytes=shard_budget,
                directory=(
                    cache_dir if cache_dir is None or self.n_shards == 1
                    else f"{cache_dir}/shard_{s}"
                ),
                device=self._shard_device[s],
            )
            for s in range(self.n_shards)
        ]
        self.engine = self.engines[0]  # 1-shard alias (the PR 4 surface)
        self.pool = ShardedAdapterPool(
            pool_slots if pool_slots is not None else tenants_per_shard + 1,
            cfg, sl.rank, n_shards=self.n_shards,
            devices=self._shard_device, compress=pool_compress,
            history=control.history_depth if control is not None else 0,
        )
        self._tenants: dict[Any, TenantState] = {}
        #: Per-shard free cache partitions (global partition ids; partition
        #: p belongs to shard p % n_shards). Popped smallest-first, like the
        #: PR 4 single list.
        self._free_partitions = [
            [p for p in range(max_tenants - 1, -1, -1) if p % self.n_shards == s]
            for s in range(self.n_shards)
        ]
        #: Per-shard adapt scan-path cache views (export_skipcache memo).
        self._export: list[Optional[Any]] = [None] * self.n_shards
        #: (shard, tenant tuple, shard version) -> device idx array, LRU.
        #: Repeated serve batches skip the per-call host->device slot-index
        #: transfer; any slot-map change bumps the version and invalidates.
        #: Live traffic produces unboundedly many distinct tenant orderings
        #: (and version bumps strand old entries), so the memo is bounded at
        #: ``idx_memo_slots``: hits refresh recency, misses evict the
        #: least-recently-used entry once full. ``counters`` tracks
        #: ``idx_memo/{hits,misses,evictions}``.
        if idx_memo_slots < 1:
            raise ValueError(f"idx_memo_slots {idx_memo_slots} < 1")
        self._idx_cache: OrderedDict[tuple, jax.Array] = OrderedDict()
        self._idx_cache_cap = int(idx_memo_slots)
        #: Serve-call counter behind the per-session default rng: serve()
        #: with rng=None derives fold_in(key(seed), counter) — deterministic
        #: replay for an identically-seeded fresh session, never the same
        #: key twice within one session.
        self._serve_calls = 0
        self._scheduler = None
        #: Per-shard paged KV block pools + radix prefix indexes (the
        #: scheduler's prefix-reuse state; see ``core.kv_pool`` /
        #: ``core.prefix_index``). Lazily built by ``kv_pool()`` so
        #: reuse-off sessions pay nothing.
        self._kv_pools: dict[int, Any] = {}
        self._prefix_indexes: dict[int, Any] = {}
        self.counters = Counter()

    # -- shard arithmetic ----------------------------------------------------

    def _shard_of_partition(self, partition: int) -> int:
        return partition % self.n_shards

    def _local_ids(self, partition: int, rows) -> jax.Array:
        """Global partition + partition-local row ids -> shard-engine ids."""
        local_part = partition // self.n_shards
        return jnp.asarray(rows) + local_part * self.samples_per_tenant

    def _global_id(self, shard: int, local_id: int) -> int:
        part = (local_id // self.samples_per_tenant) * self.n_shards + shard
        return part * self.samples_per_tenant + local_id % self.samples_per_tenant

    # -- tenant bookkeeping --------------------------------------------------

    def tenant(self, tenant) -> TenantState:
        st = self._tenants.get(tenant)
        if st is None:
            raise KeyError(f"unknown tenant {tenant!r}")
        return st

    def _add_tenant(self, tenant) -> TenantState:
        shard = self.pool.place(tenant)
        if not self._free_partitions[shard]:
            raise RuntimeError(
                f"session full: all "
                f"{self.max_tenants // self.n_shards} cache partitions of "
                f"shard {shard} in use ({self.max_tenants} session-wide)"
            )
        st = TenantState(partition=self._free_partitions[shard].pop())
        self._tenants[tenant] = st
        return st

    def release(self, tenant) -> None:
        """Drop a tenant's training state and cache partition (its pool slot
        — if any — stays registered but is unpinned, so normal LRU applies
        again; a slot-less tenant loses its shard placement too)."""
        st = self._tenants.pop(tenant)
        self._free_partitions[self._shard_of_partition(st.partition)].append(
            st.partition
        )
        if self.pool.has(tenant):
            self.pool.unpin(tenant)
        else:
            self.pool.unplace(tenant)
        for idx in self._prefix_indexes.values():
            idx.drop_tenant(tenant)

    # -- paged KV prefix cache ----------------------------------------------

    def kv_pool(self, shard: int, *, block: Optional[int] = None,
                n_blocks: Optional[int] = None):
        """The shard's paged KV block pool, built on first call (on the
        shard's device). ``block`` is the pool's identity — a later caller
        asking for a different block size gets a loud error (tables and
        radix paths are block-granular); ``n_blocks`` is only a sizing
        hint for construction and is ignored once the pool exists."""
        from repro.core.kv_pool import KVBlockPool, get_default_block

        pool = self._kv_pools.get(shard)
        if pool is not None:
            if block is not None and int(block) != pool.block:
                raise ValueError(
                    f"kv pool shard {shard} already built with block="
                    f"{pool.block}; requested {block}"
                )
            return pool
        if n_blocks is None:
            raise ValueError(
                "first kv_pool() call for a shard must size it (n_blocks)"
            )
        pool = KVBlockPool(
            self.cfg, n_blocks=int(n_blocks),
            block=int(block) if block else get_default_block(),
            device=self._shard_device[shard],
        )
        self._kv_pools[shard] = pool
        return pool

    def prefix_index(self, shard: int):
        from repro.core.prefix_index import RadixPrefixIndex

        idx = self._prefix_indexes.get(shard)
        if idx is None:
            pool = self._kv_pools.get(shard)
            if pool is None:
                raise ValueError(
                    f"prefix_index({shard}) needs kv_pool({shard}, ...) "
                    "built first"
                )
            idx = self._prefix_indexes[shard] = RadixPrefixIndex(pool)
        return idx

    def reset_prefix_cache(self) -> None:
        """Forget every pooled prefix (all shards): radix trees cleared,
        pool refcounts zeroed, generations bumped so in-flight handles
        turn stale. The benchmark calls this between replays so each
        measurement starts cold."""
        for shard, pool in self._kv_pools.items():
            idx = self._prefix_indexes.get(shard)
            if idx is not None:
                idx.reset()
            else:
                pool.reset()

    def check_prefix_no_leaks(self) -> None:
        """Drained-state ref invariant, raised on violation: every held
        block is owned by exactly one radix node and nothing else (no
        in-flight refs survive a drain; free + held == n_blocks)."""
        for shard, pool in self._kv_pools.items():
            idx = self._prefix_indexes.get(shard)
            pool.check_no_leaks(idx.n_nodes() if idx is not None else 0)
            extra = int(pool.refs.sum()) - int((pool.refs > 0).sum())
            if extra:
                raise RuntimeError(
                    f"kv pool shard {shard}: {extra} in-flight ref(s) "
                    "outstanding after drain"
                )

    # -- events --------------------------------------------------------------

    def serve(
        self,
        tenants: Sequence,
        prompts: jax.Array,
        *,
        max_new: int,
        temperature: float = 0.0,
        rng: Optional[jax.Array] = None,
        unroll: int = 1,
    ) -> jax.Array:
        """Scan-fused generation for a mixed-tenant batch. Row b decodes
        under ``tenants[b]``'s pool slot (``None`` -> base model). Routes
        the single-stack path when the whole batch is base traffic, the
        grouped (float/int8) path otherwise — always through the shared
        compiled-fn cache, so the runtime adds only a pool lookup over
        calling ``generate``/``generate_grouped`` directly. On a
        multi-shard session the batch additionally splits by slot shard:
        each shard decodes its own rows against its local pool segment on
        its own device (one async dispatch per shard, no cross-device
        adapter gather), and the rows stitch back in order."""
        if len(tenants) != prompts.shape[0]:
            raise ValueError(
                f"{len(tenants)} tenants for batch {prompts.shape[0]}"
            )
        if rng is None:
            # Counter-derived per-session key: repeated temperature>0 serves
            # without an explicit rng must not replay one sample stream, but
            # an identically-seeded fresh session must still reproduce this
            # one (the multi-shard fold_in(rng, s) split below then stays
            # consistent with the single-shard stream by construction).
            rng = jax.random.fold_in(
                jax.random.key(self.seed), self._serve_calls
            )
        self._serve_calls += 1
        if all(t is None for t in tenants):
            path = "serve/single/base"
            toks = generate(
                self.params, self.cfg, prompts, max_new=max_new,
                temperature=temperature, rng=rng, unroll=unroll,
                scope=self._scope[0],
            )
        else:
            variant = "int8" if self.pool.compress == "int8" else "float"
            path = f"serve/grouped/{variant}"
            if self.n_shards == 1:
                toks = self._serve_shard(
                    0, tenants, prompts, max_new=max_new,
                    temperature=temperature, rng=rng, unroll=unroll,
                )
            else:
                parts = []
                for s, (rows, subs) in enumerate(self.pool.route(tenants)):
                    if not rows:
                        continue
                    sub_rng = None if rng is None else jax.random.fold_in(rng, s)
                    parts.append((rows, self._serve_shard(
                        s, subs, prompts[np.asarray(rows)], max_new=max_new,
                        temperature=temperature, rng=sub_rng, unroll=unroll,
                    )))
                    self.counters["serve/shard_dispatches"] += 1
                out = np.zeros((len(tenants), max_new), np.int32)
                for rows, sub_toks in parts:  # dispatched above, sync here
                    out[np.asarray(rows)] = np.asarray(sub_toks)
                toks = jnp.asarray(out)
        self.counters[path] += 1
        self.counters["serve/tokens"] += int(toks.size)
        return toks

    def _serve_shard(
        self, s: int, tenants, prompts, *, max_new, temperature, rng, unroll
    ) -> jax.Array:
        """Grouped decode of one shard's rows against its pool segment (on
        a 1-shard session this IS the PR 4 grouped path, bitwise)."""
        key_ = (s, tuple(tenants), self.pool.shards[s].version)
        idx = self._idx_cache.get(key_)
        if idx is None:
            self.counters["idx_memo/misses"] += 1
            while len(self._idx_cache) >= self._idx_cache_cap:
                self._idx_cache.popitem(last=False)  # evict LRU, keep rest
                self.counters["idx_memo/evictions"] += 1
            idx = self._idx_cache[key_] = self.pool.lookup_local(s, tenants)
        else:
            self.counters["idx_memo/hits"] += 1
            self._idx_cache.move_to_end(key_)
            self.pool.touch(tenants)  # recency still tracks traffic
        return generate_grouped(
            self._shard_params[s], self.cfg, prompts,
            self.pool.shard_pools(s), idx,
            max_new=max_new, temperature=temperature, rng=rng,
            use_kernel=self.use_kernel, fuse_skip=self.decode_fuse,
            unroll=unroll, scope=self._scope[s],
        )

    # -- request-level surface (continuous batching; core.scheduler) ---------

    def attach_scheduler(self, **kw):
        """Construct the session's ``RequestScheduler`` with explicit
        limits (see ``core.scheduler.RequestScheduler``). The batch-level
        ``serve``/``ingest`` calls above stay available alongside it —
        the scheduler is a front door, not a replacement."""
        from repro.core.scheduler import RequestScheduler

        if self._scheduler is not None:
            raise RuntimeError("session already has a scheduler attached")
        self._scheduler = RequestScheduler(self, **kw)
        return self._scheduler

    @property
    def scheduler(self):
        """The attached scheduler (default limits if never configured)."""
        if self._scheduler is None:
            self.attach_scheduler()
        return self._scheduler

    def enqueue_serve(self, tenant, prompt, *, max_new: int,
                      temperature: float = 0.0):
        """Queue one generation request; returns its ``Request`` future.
        Admission (per-tenant fairness, shard routing, row recycling) is
        the scheduler's; pump with ``drain()`` or ``scheduler.step()``."""
        return self.scheduler.submit(
            tenant, prompt, max_new=max_new, temperature=temperature
        )

    def enqueue_ingest(self, tenant, tokens, labels):
        """Queue fine-tuning ingestion to run at a step boundary between
        decode dispatches; returns its ``IngestRequest``."""
        return self.scheduler.submit_ingest(tenant, tokens, labels)

    def drain(self):
        """Run the scheduler until every queued request completes."""
        return self.scheduler.drain()

    def ingest(self, tenant, tokens: jax.Array, labels: jax.Array) -> jax.Array:
        """Populate-phase forward for new on-device samples: writes the
        batch into the tenant's skip-cache partition AND returns the
        last-position logits under the tenant's current adapters (zero slot
        until the first ``adapt`` write-back) — ingestion doubles as
        serving. Returns (B, 1, V) logits."""
        # Validate BEFORE registering: a rejected batch must not leak a
        # cache partition or leave a zombie tenant that poisons adapt().
        st = self._tenants.get(tenant)
        b, s = tokens.shape
        if s != self.seq:
            raise ValueError(f"seq {s} != session cache layout seq {self.seq}")
        filled = st.n_ingested if st is not None else 0
        if filled + b > self.samples_per_tenant:
            raise ValueError(
                f"tenant {tenant!r} partition full: {filled}+{b} > "
                f"{self.samples_per_tenant}"
            )
        if st is None:
            st = self._add_tenant(tenant)
        s = self._shard_of_partition(st.partition)
        who = [tenant if self.pool.has(tenant) else None] * b
        idx = self.pool.lookup_local(s, who)
        logits, acts, y_base = _ingest_fn(
            self.cfg, self.use_kernel, self._scope[s]
        )(self._shard_params[s], tokens, self.pool.shard_pools(s), idx)
        values = SL._encode_acts(acts, None, self.sl)
        values["y_base"] = y_base
        values["labels"] = labels
        ids = self._local_ids(
            st.partition, np.arange(st.n_ingested, st.n_ingested + b)
        )
        self.engines[s].write(ids, values)
        self._export[s] = None  # new rows: invalidate adapt's exported view
        st.n_ingested += b
        self.counters["ingest/rows"] += b
        return logits

    def adapt(
        self,
        tenants: Optional[Sequence] = None,
        *,
        epochs: int = 1,
        batch_per_tenant: int = 4,
        key: Optional[jax.Array] = None,
    ) -> dict:
        """Cached-phase fleet fine-tune over the tenants' ingested
        partitions: every epoch is grouped custom-VJP adapter steps with
        ZERO backbone compute (the cache already holds what the populate
        forward saw), then one batched donated write-back into the serving
        pool (``register_many``) and a pin on every trained slot.

        Tenants new to training draw initial adapters from ``key`` exactly
        like ``fleet_finetune`` (``init_fleet_adapters`` row i -> i-th
        tenant), and the planner replays each tenant's own RNG stream, so a
        fresh session's first ``adapt`` reproduces the offline trainer
        bitwise on the kernel path. Tenants are grouped by (optimizer step,
        epoch position, partition fill, shard) — only same-trajectory
        tenants can share a stacked optimizer's scalar step counter, and
        only same-shard tenants share a device. Every group's fused epochs
        dispatch entirely on its shard's device (committed inputs, the same
        compiled entries on every shard); groups on different shards
        overlap through jax's async dispatch — losses are pulled to host
        only after every group has been issued.

        Returns {"losses": {tenant: (epochs, steps) np.ndarray}, "groups":
        [group tenant lists], "path": "scan" | "stream"}.
        """
        order = [t for t in self._tenants] if tenants is None else list(tenants)
        if not order:
            raise ValueError("no tenants to adapt")
        for t in order:
            if self.tenant(t).n_ingested == 0:
                raise ValueError(f"tenant {t!r} has no ingested samples")

        # Fresh tenants draw stacked inits from one key, in call order.
        fresh = [t for t in order if not self.tenant(t).trained]
        if fresh:
            stacked0 = FF.init_fleet_adapters(
                key if key is not None else jax.random.key(self.seed),
                self.cfg, self.sl, len(fresh),
            )
            opt0 = self.optimizer.init(stacked0)
            for i, t in enumerate(fresh):
                st = self.tenant(t)
                st.adapters = jax.tree.map(lambda x: x[i], stacked0)
                st.opt_mu = _maybe_slice(opt0.mu, i)
                st.opt_nu = _maybe_slice(opt0.nu, i)
                st.step = 0

        groups: dict[tuple, list] = {}
        for t in order:
            st = self.tenant(t)
            groups.setdefault(
                (st.step, st.epochs_done, st.n_ingested,
                 self._shard_of_partition(st.partition)), []
            ).append(t)

        pending = []
        for (step0, epoch0, spt, shard), group in groups.items():
            ls_epochs, path = self._adapt_group(
                group, spt, shard, epochs=epochs, epoch0=epoch0, step0=step0,
                batch_per_tenant=batch_per_tenant,
            )
            pending.append((group, ls_epochs, path))
        losses: dict[Any, np.ndarray] = {}
        paths = set()
        for group, ls_epochs, path in pending:  # sync AFTER all dispatches
            ls = np.stack([np.asarray(l) for l in ls_epochs])
            paths.add(path)
            for g, t in enumerate(group):
                losses[t] = ls[:, :, g]
        self.counters["adapt/epochs"] += epochs * len(groups)
        return {
            "losses": losses,
            "groups": list(groups.values()),
            "path": "stream" if "stream" in paths else "scan",
        }

    def _adapt_group(
        self, group, spt, shard, *, epochs, epoch0, step0, batch_per_tenant
    ) -> tuple[list, str]:
        """Dispatch one same-(trajectory, shard) group's cached epochs on
        its shard. Returns the per-epoch (steps, N) loss arrays *without*
        host synchronisation — the caller converts after every group is in
        flight."""
        n = len(group)
        device = self._shard_device[shard]
        engine = self.engines[shard]
        states = [self.tenant(t) for t in group]
        stacked = jax.device_put(jax.tree.map(
            lambda *xs: jnp.stack(xs), *[st.adapters for st in states]
        ), device)
        opt_state = jax.device_put(OptState(
            step=jnp.asarray(step0, jnp.int32),
            mu=_maybe_stack([st.opt_mu for st in states]),
            nu=_maybe_stack([st.opt_nu for st in states]),
        ), device)
        # Shadow split (DESIGN.md §13): with a control plane, each tenant's
        # epoch permutes its TRAIN rows only; every holdout_every-th ingested
        # row is reserved for held-out eval. holdout=None is bitwise the
        # historical plan.
        holdout = (
            self.control_cfg.holdout_every if self.control is not None else None
        )
        train_rows, eval_rows = batch_plan.shadow_split(spt, every=holdout)
        do_eval = self.control is not None and eval_rows.size > 0
        bpt = min(batch_per_tenant, train_rows.size)
        row_tenant = FF.fleet_row_tenant(n, bpt)
        partitions = [st.partition for st in states]
        local_parts = [p // self.n_shards for p in partitions]
        # The shard's scope rides the compiled-fn key AND wraps every
        # dispatch below: the fleet-epoch jits trace lazily (first call, and
        # every shape retrace), so the model-axis constrains must be in the
        # ambient context whenever a trace can happen.
        scope = self._scope[shard]
        fn_key = (self.cfg, self.sl, n, self.use_kernel, self._opt_key, scope)
        resident = engine.capacity >= engine.num_samples

        if do_eval:
            eval_idx = jnp.asarray(batch_plan.fleet_eval_index(
                n, spt, holdout_every=holdout, partitions=local_parts,
                partition_stride=self.samples_per_tenant,
            ))
            eval_row_tenant = FF.fleet_row_tenant(n, eval_rows.size)

        if resident:
            epoch_fn = compiled(
                ("fleet_cached_epoch", *fn_key),
                lambda: FF.make_fleet_cached_epoch(
                    self.cfg, self.sl, self.optimizer, n,
                    use_kernel=self.use_kernel, donate=False,
                ),
            )
            if self._export[shard] is None:
                # Id-indexed view for the fused scan; reused across adapt
                # calls until the next ingest writes new rows.
                self._export[shard] = engine.export_skipcache()
            cache = self._export[shard]
        else:
            step_fn = compiled(
                ("fleet_cached_step", *fn_key),
                lambda: jax.jit(FF.make_fleet_cached_step_from_vals(
                    self.cfg, self.sl, self.optimizer, n,
                    use_kernel=self.use_kernel,
                )),
            )
            if do_eval:
                ev_fn = compiled(
                    ("fleet_eval", *fn_key),
                    lambda: FF.make_fleet_eval_loss(
                        self.cfg, self.sl, n, use_kernel=self.use_kernel,
                    ),
                )

        pre_loss = post_loss = None
        if do_eval and not resident:
            # Streaming path: eval rides separate (still backbone-free)
            # dispatches over the engine-read cached rows.
            with scope_ctx(scope):
                pre_loss = ev_fn(
                    self._shard_params[shard], stacked,
                    engine.read(eval_idx), eval_row_tenant,
                )

        all_losses = []
        steps_per_epoch = 0
        for e in range(epochs):
            # The batch plan offsets into the shard-local id space while the
            # RNG stream follows the GLOBAL partition, so a re-sharded (or
            # elastically restored) session replays identical orders.
            idx_mat = batch_plan.fleet_index_matrix(
                epoch0 + e, n, spt, bpt, seed=self.seed,
                partitions=local_parts,
                streams=partitions,
                partition_stride=self.samples_per_tenant,
                holdout_every=holdout,
            )
            steps_per_epoch = idx_mat.shape[0]
            want_pre = do_eval and resident and e == 0
            want_post = do_eval and resident and e == epochs - 1
            if want_pre or want_post:
                # Shadow eval folded into the SAME fused dispatch as the
                # training scan (one jit per (pre, post) flag pair).
                eval_epoch_fn = compiled(
                    ("fleet_cached_epoch_eval", *fn_key, want_pre, want_post),
                    lambda: FF.make_fleet_cached_epoch_eval(
                        self.cfg, self.sl, self.optimizer, n,
                        use_kernel=self.use_kernel,
                        eval_pre=want_pre, eval_post=want_post, donate=False,
                    ),
                )
                with scope_ctx(scope):
                    stacked, opt_state, ls, pre, post = eval_epoch_fn(
                        self._shard_params[shard], stacked, opt_state, cache,
                        jnp.asarray(idx_mat), row_tenant,
                        eval_idx, eval_row_tenant,
                    )
                if want_pre:
                    pre_loss = pre
                if want_post:
                    post_loss = post
            elif resident:
                with scope_ctx(scope):
                    stacked, opt_state, ls = epoch_fn(
                        self._shard_params[shard], stacked, opt_state, cache,
                        jnp.asarray(idx_mat), row_tenant,
                    )
            else:
                with scope_ctx(scope):
                    stacked, opt_state, ls = FF.fleet_cached_epoch_via_engine(
                        step_fn, self._shard_params[shard], stacked, opt_state,
                        engine, idx_mat, row_tenant,
                    )
            all_losses.append(ls)

        if do_eval and not resident:
            with scope_ctx(scope):
                post_loss = ev_fn(
                    self._shard_params[shard], stacked,
                    engine.read(eval_idx), eval_row_tenant,
                )

        # Deterministic from the plan — int(opt_state.step) would sync the
        # device and serialise the per-shard groups we just overlapped.
        step_after = step0 + steps_per_epoch * epochs

        if self.control is None:
            for g, (t, st) in enumerate(zip(group, states)):
                st.adapters = jax.tree.map(lambda x: x[g], stacked)
                st.opt_mu = _maybe_slice(opt_state.mu, g)
                st.opt_nu = _maybe_slice(opt_state.nu, g)
                st.step = step_after
                st.epochs_done = epoch0 + epochs
            self.pool.register_many(group, stacked)
            for t in group:
                self.pool.pin(t)  # in-flight session state: never LRU-evicted
            return all_losses, "scan" if resident else "stream"

        # -- gated write-back (control plane on) -----------------------------
        # The gate needs the eval losses on host NOW, which synchronises this
        # group before the next one dispatches — the (documented, opt-in)
        # price of deciding a write-back on its measured outcome.
        pre_np = None if pre_loss is None else np.asarray(pre_loss)
        post_np = None if post_loss is None else np.asarray(post_loss)
        decisions: dict[Any, str] = {}
        meta: dict[Any, dict] = {}
        for g, t in enumerate(group):
            pre_g = None if pre_np is None else float(pre_np[g])
            post_g = None if post_np is None else float(post_np[g])
            if not self.pool.has(t):
                # First-ever write-back: no served version to protect (and
                # the pool would have no slot to keep serving from).
                dec = "accept"
            else:
                dec = self.control.decide(t, pre_g, post_g)
            decisions[t] = dec
            meta[t] = {"step": step_after, "eval_loss": post_g}
            self.control.record(t, dec, pre=pre_g, post=post_g, step=step_after)
            self.counters[f"control/{dec}"] += 1
        for g, (t, st) in enumerate(zip(group, states)):
            if decisions[t] == "reject":
                # Training state frozen with the served version: the next
                # adapt retrains the same plan from the same state.
                continue
            st.adapters = jax.tree.map(lambda x: x[g], stacked)
            st.opt_mu = _maybe_slice(opt_state.mu, g)
            st.opt_nu = _maybe_slice(opt_state.nu, g)
            st.step = step_after
            st.epochs_done = epoch0 + epochs
        self.pool.register_many(
            group, stacked, gate=decisions.__getitem__, meta=meta,
        )
        for t in group:
            self.pool.pin(t)  # in-flight session state: never LRU-evicted
        # Auto-rollback policy (ControlConfig.auto_rollback_after): a tenant
        # whose last N gated write-backs all failed is presumed to be
        # diverging, not noisy — restore its previous served version (when
        # the slot has archived history; a first-version tenant has nothing
        # older) and reset its optimizer trajectory so the next adapt
        # restarts clean from the adapters it actually serves.
        for g, (t, st) in enumerate(zip(group, states)):
            if decisions[t] == "accept" or not self.control.should_auto_rollback(t):
                continue
            if self.pool.has(t) and self.pool.history_len(t) > 0:
                self.pool.rollback(t)
            st.opt_mu = _maybe_zeros(st.opt_mu)
            st.opt_nu = _maybe_zeros(st.opt_nu)
            st.step = 0
            self.control.record_rollback(t, auto=True)
            self.counters["control/rollbacks"] += 1
            self.counters["control/auto_rollbacks"] += 1
        return all_losses, "scan" if resident else "stream"

    # -- control plane -------------------------------------------------------

    def rollback(self, tenant) -> dict:
        """Serve-plane rollback: restore the tenant's previous adapter
        version into its pool slot — bitwise, from the slot's archived
        storage-layout payload — and bump the pool version so every serve
        slot-index memo (the runtime's ``_idx_cache``, the scheduler's
        refresh key) invalidates. Training state is NOT rewound: quantised
        pools are lossy, so the archived payload cannot reconstruct float
        training state — a rolled-back tenant keeps its optimizer
        trajectory and simply *serves* the older version until a future
        gated adapt produces an acceptable one. Requires a pool built with
        version history (a session with a ``ControlConfig``)."""
        meta = self.pool.rollback(tenant)
        if self.control is not None:
            self.control.record_rollback(tenant)
        self.counters["control/rollbacks"] += 1
        return meta

    def control_metrics(self) -> Optional[dict]:
        """The control plane's JSON-able ledger (None when disabled)."""
        return None if self.control is None else self.control.metrics()

    # -- introspection -------------------------------------------------------

    def _engine_stats(self) -> CacheStats:
        agg = CacheStats()
        for eng in self.engines:
            agg.hbm_hits += eng.stats.hbm_hits
            agg.host_hits += eng.stats.host_hits
            agg.staged_hits += eng.stats.staged_hits
            agg.spills += eng.stats.spills
            agg.writes += eng.stats.writes
        return agg

    def stats(self) -> dict[str, float]:
        out = {f"runtime/{k}": float(v) for k, v in sorted(self.counters.items())}
        eng = self._engine_stats()
        out.update(dict(eng.as_rows()))
        out.update(dict(self.pool.stats.as_rows()))
        out["cache_engine/hbm_hit_rate"] = eng.hbm_hit_rate()
        return out

    # -- checkpoint plane ----------------------------------------------------

    def session_state(self) -> tuple[dict, dict]:
        """(arrays, meta) for ``checkpoint.save_runtime_session``: stacked
        trained adapters + optimizer moments (tenant order in meta), every
        shard's pool data plane + the placement/slot tables, and every
        present skip-cache row in logical layout under *global* ids (the
        shard-local engines are a placement detail; the capture is
        layout-addressed so a restore re-places it). Tenant ids must be
        JSON-serialisable."""
        order = list(self._tenants)
        trained = [t for t in order if self._tenants[t].trained]
        arrays: dict[str, Any] = {}
        if trained:
            sts = [self._tenants[t] for t in trained]
            # Trained tenants may live on different shards: stack on host.
            arrays["adapters"] = jax.tree.map(
                lambda *xs: jnp.asarray(np.stack([np.asarray(x) for x in xs])),
                *[st.adapters for st in sts]
            )
            mu = _maybe_stack_host([st.opt_mu for st in sts])
            nu = _maybe_stack_host([st.opt_nu for st in sts])
            if mu is not None:
                arrays["opt_mu"] = mu
            if nu is not None:
                arrays["opt_nu"] = nu
        arrays["pool"] = self.pool.state_arrays()
        rows: dict[int, dict[str, np.ndarray]] = {}
        for s, eng in enumerate(self.engines):
            pres = sorted(eng._present)
            chunk = max(1, eng.capacity)
            for lo in range(0, len(pres), chunk):
                ids = pres[lo:lo + chunk]
                # One device->host transfer per chunk array, then numpy
                # slicing — never per-row syncs.
                vals = {
                    name: np.asarray(v)
                    for name, v in eng.read(jnp.asarray(ids)).items()
                }
                for pos, lid in enumerate(ids):
                    rows[self._global_id(s, lid)] = {
                        name: v[pos] for name, v in vals.items()
                    }
        present = sorted(rows)
        if present:
            arrays["cache"] = {
                name: jnp.asarray(np.stack([rows[g][name] for g in present]))
                for name in rows[present[0]]
            }
        meta = {
            "tenants": [
                {
                    "id": t,
                    "partition": self._tenants[t].partition,
                    "n_ingested": self._tenants[t].n_ingested,
                    "epochs_done": self._tenants[t].epochs_done,
                    "step": self._tenants[t].step,
                }
                for t in order
            ],
            "trained": trained,
            "pool_table": self.pool.slot_table(),
            "present": present,
            "layout": {"seq": self.seq, "rank": self.sl.rank,
                       "mode": self.sl.mode,
                       "samples_per_tenant": self.samples_per_tenant,
                       "n_shards": self.n_shards,
                       # Restore-compatibility keys: a restore into a
                       # differently-configured session must fail loudly,
                       # not silently reinterpret packed pool bytes.
                       "pool_compress": self.pool.compress,
                       "pool_slots": self.pool.shards[0].n_slots,
                       "max_tenants": self.max_tenants,
                       # Informational (NOT restore-compared): the mesh a
                       # session ran on is a placement detail — an elastic
                       # restart restores the same logical layout onto any
                       # (data, model) mesh with matching logical shards.
                       "mesh_shape": [int(n) for n in np.shape(
                           np.asarray(self.mesh.devices))],
                       "mesh_axes": list(self.mesh.axis_names),
                       "model_parallel": self.model_parallel,
                       "pipeline_stages": self.pipeline_stages},
        }
        if self.control is not None:
            meta["control"] = self.control.state()
        if self._kv_pools:
            arrays["kv_pool"] = {
                str(s): p.state_arrays() for s, p in self._kv_pools.items()
            }
            meta["kv_pool"] = {
                str(s): {
                    **p.state_meta(),
                    "radix": (
                        self._prefix_indexes[s].state()
                        if s in self._prefix_indexes else []
                    ),
                }
                for s, p in self._kv_pools.items()
            }
        return arrays, meta

    def load_session_state(self, arrays: dict, meta: dict) -> None:
        """Restore a ``session_state`` capture into this (fresh) runtime.
        Geometry (config shapes, seq, partition layout, logical shard
        count) must match the saving session — the *mesh* need not: an
        elastic restart restores the same logical layout onto however many
        devices this runtime was built over, and the engines re-place the
        cache rows under THEIR budgets (placement is policy, the bytes are
        identical)."""
        if self._tenants:
            raise RuntimeError("restore requires a fresh runtime")
        lay = meta["layout"]
        saved = (lay["seq"], lay["rank"], lay["mode"],
                 lay["samples_per_tenant"], int(lay.get("n_shards", 1)))
        if saved != (self.seq, self.sl.rank, self.sl.mode,
                     self.samples_per_tenant, self.n_shards):
            raise ValueError(f"session layout {lay} != runtime configuration")
        # Pool layout must match EXACTLY: an int4/nf4 checkpoint restored
        # into an int8 (or float) pool would silently reinterpret packed
        # payload bytes; a different slot count scrambles slot indices.
        # (Keys absent from pre-control checkpoints are not checked.)
        for k, mine in (
            ("pool_compress", self.pool.compress),
            ("pool_slots", self.pool.shards[0].n_slots),
            ("max_tenants", self.max_tenants),
        ):
            if k in lay and lay[k] != mine:
                raise ValueError(
                    f"checkpoint {k}={lay[k]!r} != this runtime's {mine!r}: "
                    "restore requires an identically-configured session"
                )
        if "control" in meta:
            if self.control is None:
                raise ValueError(
                    "checkpoint carries control-plane state (gate ledger, "
                    "quarantine set) but this runtime was built without a "
                    "ControlConfig — restoring would silently drop it"
                )
            self.control.load_state(meta["control"])
        for ent in meta["tenants"]:
            st = TenantState(
                partition=int(ent["partition"]),
                n_ingested=int(ent["n_ingested"]),
                epochs_done=int(ent["epochs_done"]),
                step=int(ent["step"]),
            )
            self._tenants[ent["id"]] = st
            self._free_partitions[
                self._shard_of_partition(st.partition)
            ].remove(st.partition)
        for i, t in enumerate(meta["trained"]):
            st = self._tenants[t]
            st.adapters = jax.tree.map(lambda x: jnp.asarray(x)[i], arrays["adapters"])
            if "opt_mu" in arrays:
                st.opt_mu = jax.tree.map(lambda x: jnp.asarray(x)[i], arrays["opt_mu"])
            if "opt_nu" in arrays:
                st.opt_nu = jax.tree.map(lambda x: jnp.asarray(x)[i], arrays["opt_nu"])
        self.pool.load_state(arrays["pool"], meta["pool_table"])
        present = [int(i) for i in meta["present"]]
        by_shard: dict[int, list[tuple[int, int]]] = {}
        for pos, gid in enumerate(present):
            part = gid // self.samples_per_tenant
            local = (part // self.n_shards) * self.samples_per_tenant + (
                gid % self.samples_per_tenant
            )
            by_shard.setdefault(self._shard_of_partition(part), []).append(
                (pos, local)
            )
        for s, entries in by_shard.items():
            eng = self.engines[s]
            chunk = max(1, eng.capacity)
            for lo in range(0, len(entries), chunk):
                sub = entries[lo:lo + chunk]
                pos_idx = np.asarray([p for p, _ in sub])
                vals = {
                    name: jnp.asarray(np.asarray(arr)[pos_idx])
                    for name, arr in arrays["cache"].items()
                }
                eng.write(jnp.asarray([l for _, l in sub]), vals)
        # Paged prefix cache: pool bytes + radix tree round-trip, with the
        # refcounts recomputed from the restored tree (exactly one ref per
        # node — a fresh session has no in-flight rows, so saved in-flight
        # refs must NOT survive). Geometry mismatches fail loudly inside
        # ``KVBlockPool.load_state``.
        for s_str, pmeta in meta.get("kv_pool", {}).items():
            s = int(s_str)
            pool = self.kv_pool(
                s, block=int(pmeta["block"]), n_blocks=int(pmeta["n_blocks"])
            )
            pool.load_state(arrays["kv_pool"][s_str], pmeta)
            self.prefix_index(s).load_state(pmeta.get("radix", []))


def _maybe_stack(trees: list) -> Optional[Params]:
    if trees[0] is None:
        return None
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _maybe_stack_host(trees: list) -> Optional[Params]:
    """Like ``_maybe_stack`` but via host memory — the checkpoint capture
    stacks tenants from *different* shards, whose leaves are committed to
    different devices."""
    if trees[0] is None:
        return None
    return jax.tree.map(
        lambda *xs: jnp.asarray(np.stack([np.asarray(x) for x in xs])), *trees
    )


def _maybe_slice(tree: Optional[Params], i: int) -> Optional[Params]:
    if tree is None:
        return None
    return jax.tree.map(lambda x: x[i], tree)


def _maybe_zeros(tree: Optional[Params]) -> Optional[Params]:
    if tree is None:
        return None
    return jax.tree.map(jnp.zeros_like, tree)
