"""Unified continual-learning runtime: serve + fleet fine-tune, one engine.

The paper's deployment story is continual (DESIGN.md §9): a device serves
with its adapter, accumulates new samples into the skip-cache, and
periodically fine-tunes. After PR 2/3 the repo had three disjoint entry
points (``launch/serve.py``, ``launch/finetune.py``, ``launch/fleet.py``)
that each rebuilt their own compiled functions, cache views, and pool
bookkeeping — serve and train could not interleave over one adapter pool.

``SessionRuntime`` is the single engine behind all three launchers. It owns

  - ONE ``AdapterPool`` (slot-based serving registry, now with session
    pinning so LRU eviction can never drop in-flight training state),
  - ONE ``TieredCacheEngine`` (every tenant's skip-cache partition), and
  - ONE compiled-function cache (module-level ``compiled``; the serve
    prefill/decode jits previously private to ``launch/serve.py`` live
    here, alongside the fleet epoch/step jits),

and processes an interleaved event stream:

  - ``serve(tenants, prompts)``: scan-fused generation, routed per batch —
    single-stack when every row is the base model, grouped (float or raw
    int8 pool layout) otherwise. Same compiled entries as PR 2's
    ``decode_scan`` benchmarks, so routing adds only a pool lookup.
  - ``ingest(tenant, tokens, labels)``: populate-phase forward that writes
    the tenant's skip-cache partition *and* returns last-position adapted
    logits — ingestion doubles as serving (``models.lm.ingest_prefill``).
  - ``adapt(tenants, epochs)``: cached-phase fleet epochs over the grouped
    custom-VJP kernels, write-back through ``AdapterPool.register_many``.
    Because the backbone is frozen, cached values equal the populate
    epoch's in-flight activations bitwise (full mode, matching cache
    dtype), so an interleaved serve -> ingest -> adapt session reproduces
    the offline ``fleet_finetune`` adapters *bitwise* on the kernel path —
    the §9 parity bar, enforced by ``tests/test_runtime.py``.

Batch planning goes through ``core.batch_plan`` with explicit tenant
partitions, so an ``adapt`` group that is a subset or reordering of the
ingested tenants still replays each tenant's own RNG stream.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import donate_argnums
from repro.core import batch_plan
from repro.core import fleet_finetune as FF
from repro.core import lm_skiplora as SL
from repro.core.adapter_pool import AdapterPool
from repro.core.cache_engine import TieredCacheEngine
from repro.models.config import ModelConfig
from repro.models.lm import (
    decode_scan,
    ingest_prefill,
    init_serve_caches,
    sample_token,
    serve_decode,
    serve_prefill,
    serve_prefill_grouped,
)
from repro.optim.optimizers import OptState, adamw

Params = Any

# ---------------------------------------------------------------------------
# Shared compiled-function cache (one per process, every engine routes here)
# ---------------------------------------------------------------------------

#: (name, cfg, extras) -> jitted callable. cfg is a frozen dataclass and
#: hashes by value; jax.jit then keys compiled traces by argument shape
#: below this cache, so repeated calls at a new (batch, seq) retrace but
#: never rebuild the jit wrapper itself.
_FN_CACHE: dict[tuple, Any] = {}


def compiled(key: tuple, make: Callable[[], Any]):
    """Fetch-or-build a jitted callable under a hashable key. The single
    compiled-fn cache behind serve, ingest, and adapt — the per-launcher
    caches of PR 2/3 collapsed here."""
    fn = _FN_CACHE.get(key)
    if fn is None:
        fn = _FN_CACHE[key] = make()
    return fn


def _cached_fn(name: str, cfg, make, extras: tuple = ()):
    return compiled((name, cfg, *extras), make)


def _prefill_fn(cfg):
    def make():
        def f(params, tokens, caches, adapters):
            return serve_prefill(params, cfg, tokens, caches, adapters=adapters)

        return jax.jit(f)

    return _cached_fn("prefill", cfg, make)


def _prefill_grouped_fn(cfg, use_kernel: bool):
    def make():
        def f(params, tokens, caches, pools, idx):
            return serve_prefill_grouped(
                params, cfg, tokens, caches, pools, idx, use_kernel=use_kernel
            )

        return jax.jit(f)

    return _cached_fn("prefill_grouped", cfg, make, (use_kernel,))


def _decode_scan_fn(cfg, use_kernel: bool = True):
    def make():
        def f(params, tok0, pos0, caches, key, adapters, pools, idx,
              max_new, temperature, unroll):
            return decode_scan(
                params, cfg, tok0, pos0, caches, key,
                max_new=max_new, temperature=temperature, adapters=adapters,
                pools=pools, idx=idx, use_kernel=use_kernel, unroll=unroll,
            )

        # Donate the KV caches: the scan's carry updates them in place
        # (off-CPU; the CPU backend has no donation and would only warn).
        return jax.jit(
            f,
            static_argnums=(8, 9, 10),
            donate_argnums=donate_argnums(3),
        )

    return _cached_fn("decode_scan", cfg, make, (use_kernel,))


def _decode_step_fn(cfg):
    def make():
        def f(params, tok, pos, caches, adapters):
            return serve_decode(params, cfg, tok, pos, caches, adapters=adapters)

        return jax.jit(f)

    return _cached_fn("decode_step", cfg, make)


def _ingest_fn(cfg, use_kernel: bool):
    def make():
        def f(params, tokens, pools, idx):
            return ingest_prefill(
                params, cfg, tokens, pools, idx, use_kernel=use_kernel
            )

        return jax.jit(f)

    return _cached_fn("ingest", cfg, make, (use_kernel,))


# ---------------------------------------------------------------------------
# Generation entry points (moved from launch/serve.py; the CLI re-exports)
# ---------------------------------------------------------------------------


def generate(
    params,
    cfg,
    tokens,
    *,
    max_new: int,
    adapters_stack=None,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
    unroll: int = 1,
):
    """Batched generation, scan-fused: 1 prefill dispatch + 1 decode-scan
    dispatch for all ``max_new`` tokens. Returns (B, max_new) int32."""
    b, s = tokens.shape
    caches = init_serve_caches(cfg, b, s + max_new)
    logits, caches = _prefill_fn(cfg)(params, tokens, caches, adapters_stack)
    tok0, key = sample_token(
        logits, rng if rng is not None else jax.random.key(0), temperature
    )
    toks, _ = _decode_scan_fn(cfg)(
        params, tok0, jnp.asarray(s, jnp.int32), caches, key,
        adapters_stack, None, None, max_new, float(temperature), unroll,
    )
    return toks


def generate_grouped(
    params,
    cfg,
    tokens,
    pools: dict[str, jax.Array],
    idx: jax.Array,
    *,
    max_new: int,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
    use_kernel: bool = True,
    unroll: int = 1,
):
    """Multi-tenant generation: batch row b decodes under adapter slot
    idx[b] gathered from the stacked pool (float or raw-int8 layout, see
    ``AdapterPool.pools()``). Same two-dispatch structure as ``generate``."""
    b, s = tokens.shape
    caches = init_serve_caches(cfg, b, s + max_new)
    logits, caches = _prefill_grouped_fn(cfg, use_kernel)(
        params, tokens, caches, pools, idx
    )
    tok0, key = sample_token(
        logits, rng if rng is not None else jax.random.key(0), temperature
    )
    toks, _ = _decode_scan_fn(cfg, use_kernel)(
        params, tok0, jnp.asarray(s, jnp.int32), caches, key,
        None, pools, idx, max_new, float(temperature), unroll,
    )
    return toks


def generate_loop(
    params,
    cfg,
    tokens,
    *,
    max_new: int,
    adapters_stack=None,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
):
    """Per-token Python decode loop (the pre-scan path, kept for the
    loop-vs-scan benchmark): ``max_new`` dispatches, cached step jits."""
    b, s = tokens.shape
    caches = init_serve_caches(cfg, b, s + max_new)
    prefill = _prefill_fn(cfg)
    decode = _decode_step_fn(cfg)
    logits, caches = prefill(params, tokens, caches, adapters_stack)
    key = rng if rng is not None else jax.random.key(0)
    tok, key = sample_token(logits, key, temperature)
    out = []
    for i in range(max_new):
        out.append(tok)
        logits, caches = decode(
            params, tok, jnp.asarray(s + i, jnp.int32), caches, adapters_stack
        )
        tok, key = sample_token(logits, key, temperature)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Session runtime
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TenantState:
    """Per-tenant continual-learning state the runtime tracks between
    events. ``adapters``/``opt_*`` are per-tenant slices of the stacked
    fleet trees (flat {"A": (L,D,R), "B": (L,R,D)} layout)."""

    partition: int                      # cache partition index
    n_ingested: int = 0                 # rows written into the partition
    epochs_done: int = 0                # planner epoch stream position
    step: int = 0                       # optimizer step count
    adapters: Optional[Params] = None
    opt_mu: Optional[Params] = None
    opt_nu: Optional[Params] = None

    @property
    def trained(self) -> bool:
        return self.adapters is not None


class SessionRuntime:
    """One session engine for serve + ingest + adapt over a shared pool.

    ``max_tenants`` bounds the cache partitions (``samples_per_tenant``
    rows each, global id = partition * samples_per_tenant + local id — the
    PR 3 fleet convention, so offline and interleaved training address
    identical cache rows). The pool defaults to ``max_tenants + 1`` slots
    (slot 0 pinned zero); the engine to fully HBM-resident — pass
    ``cache_capacity`` / ``hbm_budget_bytes`` to force tiered placement,
    which flips ``adapt`` from the fused-scan epoch to the streaming
    prefetch path (DESIGN.md §9 path table).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        sl: SL.SkipLoRAConfig,
        params: Params,
        *,
        max_tenants: int,
        samples_per_tenant: int,
        seq: int,
        lr: float = 1e-3,
        optimizer=None,
        pool_slots: Optional[int] = None,
        pool_compress: Optional[str] = None,
        cache_capacity: Optional[int] = None,
        hbm_budget_bytes: Optional[int] = None,
        cache_dir: Optional[str] = None,
        use_kernel: bool = True,
        seed: int = 0,
    ):
        if sl.mode not in ("full", "int8"):
            raise ValueError(
                f"the session runtime trains fleet modes 'full'/'int8', "
                f"not {sl.mode!r}"
            )
        self.cfg, self.sl, self.params = cfg, sl, params
        self.max_tenants = max_tenants
        self.samples_per_tenant = samples_per_tenant
        self.seq = seq
        self.use_kernel = use_kernel
        self.seed = seed
        self.optimizer = optimizer if optimizer is not None else adamw(lr)
        self._opt_key = ("adamw", lr) if optimizer is None else ("custom", id(optimizer))

        num_samples = max_tenants * samples_per_tenant
        if cache_capacity is None and hbm_budget_bytes is None:
            cache_capacity = num_samples  # fully resident: fused-scan adapt
        self.engine = TieredCacheEngine(
            num_samples,
            SL.lm_cache_layout(cfg, sl, seq),
            capacity=cache_capacity,
            hbm_budget_bytes=hbm_budget_bytes,
            directory=cache_dir,
        )
        self.pool = AdapterPool(
            pool_slots if pool_slots is not None else max_tenants + 1,
            cfg, sl.rank, compress=pool_compress,
        )
        self._tenants: dict[Any, TenantState] = {}
        self._free_partitions = list(range(max_tenants - 1, -1, -1))
        self._export: Optional[Any] = None  # adapt's scan-path cache view
        #: (tenant tuple, pool.version) -> device idx array. Repeated serve
        #: batches skip the per-call host->device slot-index transfer; any
        #: slot-map change bumps pool.version and invalidates naturally.
        self._idx_cache: dict[tuple, jax.Array] = {}
        self.counters = Counter()

    # -- tenant bookkeeping --------------------------------------------------

    def tenant(self, tenant) -> TenantState:
        st = self._tenants.get(tenant)
        if st is None:
            raise KeyError(f"unknown tenant {tenant!r}")
        return st

    def _add_tenant(self, tenant) -> TenantState:
        if not self._free_partitions:
            raise RuntimeError(
                f"session full: {self.max_tenants} cache partitions in use"
            )
        st = TenantState(partition=self._free_partitions.pop())
        self._tenants[tenant] = st
        return st

    def release(self, tenant) -> None:
        """Drop a tenant's training state and cache partition (its pool slot
        — if any — stays registered but is unpinned, so normal LRU applies
        again)."""
        st = self._tenants.pop(tenant)
        self._free_partitions.append(st.partition)
        if self.pool.has(tenant):
            self.pool.unpin(tenant)

    # -- events --------------------------------------------------------------

    def serve(
        self,
        tenants: Sequence,
        prompts: jax.Array,
        *,
        max_new: int,
        temperature: float = 0.0,
        rng: Optional[jax.Array] = None,
        unroll: int = 1,
    ) -> jax.Array:
        """Scan-fused generation for a mixed-tenant batch. Row b decodes
        under ``tenants[b]``'s pool slot (``None`` -> base model). Routes
        the single-stack path when the whole batch is base traffic, the
        grouped (float/int8) path otherwise — always through the shared
        compiled-fn cache, so the runtime adds only a pool lookup over
        calling ``generate``/``generate_grouped`` directly."""
        if len(tenants) != prompts.shape[0]:
            raise ValueError(
                f"{len(tenants)} tenants for batch {prompts.shape[0]}"
            )
        if all(t is None for t in tenants):
            path = "serve/single/base"
            toks = generate(
                self.params, self.cfg, prompts, max_new=max_new,
                temperature=temperature, rng=rng, unroll=unroll,
            )
        else:
            key_ = (tuple(tenants), self.pool.version)
            idx = self._idx_cache.get(key_)
            if idx is None:
                if len(self._idx_cache) > 256:
                    self._idx_cache.clear()
                idx = self._idx_cache[key_] = self.pool.lookup(tenants)
            else:
                self.pool.touch(tenants)  # recency still tracks traffic
            variant = "int8" if self.pool.compress == "int8" else "float"
            path = f"serve/grouped/{variant}"
            toks = generate_grouped(
                self.params, self.cfg, prompts, self.pool.pools(), idx,
                max_new=max_new, temperature=temperature, rng=rng,
                use_kernel=self.use_kernel, unroll=unroll,
            )
        self.counters[path] += 1
        self.counters["serve/tokens"] += int(toks.size)
        return toks

    def ingest(self, tenant, tokens: jax.Array, labels: jax.Array) -> jax.Array:
        """Populate-phase forward for new on-device samples: writes the
        batch into the tenant's skip-cache partition AND returns the
        last-position logits under the tenant's current adapters (zero slot
        until the first ``adapt`` write-back) — ingestion doubles as
        serving. Returns (B, 1, V) logits."""
        # Validate BEFORE registering: a rejected batch must not leak a
        # cache partition or leave a zombie tenant that poisons adapt().
        st = self._tenants.get(tenant)
        b, s = tokens.shape
        if s != self.seq:
            raise ValueError(f"seq {s} != session cache layout seq {self.seq}")
        filled = st.n_ingested if st is not None else 0
        if filled + b > self.samples_per_tenant:
            raise ValueError(
                f"tenant {tenant!r} partition full: {filled}+{b} > "
                f"{self.samples_per_tenant}"
            )
        if st is None:
            st = self._add_tenant(tenant)
        who = [tenant if self.pool.has(tenant) else None] * b
        idx = self.pool.lookup(who)
        logits, acts, y_base = _ingest_fn(self.cfg, self.use_kernel)(
            self.params, tokens, self.pool.pools(), idx
        )
        values = SL._encode_acts(acts, None, self.sl)
        values["y_base"] = y_base
        values["labels"] = labels
        ids = np.arange(st.n_ingested, st.n_ingested + b) + (
            st.partition * self.samples_per_tenant
        )
        self.engine.write(jnp.asarray(ids), values)
        self._export = None  # new rows: invalidate adapt's exported view
        st.n_ingested += b
        self.counters["ingest/rows"] += b
        return logits

    def adapt(
        self,
        tenants: Optional[Sequence] = None,
        *,
        epochs: int = 1,
        batch_per_tenant: int = 4,
        key: Optional[jax.Array] = None,
    ) -> dict:
        """Cached-phase fleet fine-tune over the tenants' ingested
        partitions: every epoch is grouped custom-VJP adapter steps with
        ZERO backbone compute (the cache already holds what the populate
        forward saw), then one batched donated write-back into the serving
        pool (``register_many``) and a pin on every trained slot.

        Tenants new to training draw initial adapters from ``key`` exactly
        like ``fleet_finetune`` (``init_fleet_adapters`` row i -> i-th
        tenant), and the planner replays each tenant's own RNG stream, so a
        fresh session's first ``adapt`` reproduces the offline trainer
        bitwise on the kernel path. Tenants are grouped by (optimizer step,
        epoch position, partition fill) — only same-trajectory tenants can
        share a stacked optimizer's scalar step counter.

        Returns {"losses": {tenant: (epochs, steps) np.ndarray}, "groups":
        [group tenant lists], "path": "scan" | "stream"}.
        """
        order = [t for t in self._tenants] if tenants is None else list(tenants)
        if not order:
            raise ValueError("no tenants to adapt")
        for t in order:
            if self.tenant(t).n_ingested == 0:
                raise ValueError(f"tenant {t!r} has no ingested samples")

        # Fresh tenants draw stacked inits from one key, in call order.
        fresh = [t for t in order if not self.tenant(t).trained]
        if fresh:
            stacked0 = FF.init_fleet_adapters(
                key if key is not None else jax.random.key(self.seed),
                self.cfg, self.sl, len(fresh),
            )
            opt0 = self.optimizer.init(stacked0)
            for i, t in enumerate(fresh):
                st = self.tenant(t)
                st.adapters = jax.tree.map(lambda x: x[i], stacked0)
                st.opt_mu = _maybe_slice(opt0.mu, i)
                st.opt_nu = _maybe_slice(opt0.nu, i)
                st.step = 0

        groups: dict[tuple, list] = {}
        for t in order:
            st = self.tenant(t)
            groups.setdefault(
                (st.step, st.epochs_done, st.n_ingested), []
            ).append(t)

        resident = self.engine.capacity >= self.engine.num_samples
        losses: dict[Any, np.ndarray] = {}
        for (step0, epoch0, spt), group in groups.items():
            ls = self._adapt_group(
                group, spt, epochs=epochs, epoch0=epoch0, step0=step0,
                batch_per_tenant=batch_per_tenant, resident=resident,
            )
            for g, t in enumerate(group):
                losses[t] = ls[:, :, g]
        self.counters["adapt/epochs"] += epochs * len(groups)
        return {
            "losses": losses,
            "groups": list(groups.values()),
            "path": "scan" if resident else "stream",
        }

    def _adapt_group(
        self, group, spt, *, epochs, epoch0, step0, batch_per_tenant, resident
    ) -> np.ndarray:
        n = len(group)
        states = [self.tenant(t) for t in group]
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[st.adapters for st in states]
        )
        opt_state = OptState(
            step=jnp.asarray(step0, jnp.int32),
            mu=_maybe_stack([st.opt_mu for st in states]),
            nu=_maybe_stack([st.opt_nu for st in states]),
        )
        bpt = min(batch_per_tenant, spt)
        row_tenant = FF.fleet_row_tenant(n, bpt)
        partitions = [st.partition for st in states]
        fn_key = (self.cfg, self.sl, n, self.use_kernel, self._opt_key)

        if resident:
            epoch_fn = compiled(
                ("fleet_cached_epoch", *fn_key),
                lambda: FF.make_fleet_cached_epoch(
                    self.cfg, self.sl, self.optimizer, n,
                    use_kernel=self.use_kernel, donate=False,
                ),
            )
            if self._export is None:
                # Id-indexed view for the fused scan; reused across adapt
                # calls until the next ingest writes new rows.
                self._export = self.engine.export_skipcache()
            cache = self._export
        else:
            step_fn = compiled(
                ("fleet_cached_step", *fn_key),
                lambda: jax.jit(FF.make_fleet_cached_step_from_vals(
                    self.cfg, self.sl, self.optimizer, n,
                    use_kernel=self.use_kernel,
                )),
            )

        all_losses = []
        for e in range(epochs):
            idx_mat = batch_plan.fleet_index_matrix(
                epoch0 + e, n, spt, bpt, seed=self.seed, partitions=partitions,
                partition_stride=self.samples_per_tenant,
            )
            if resident:
                stacked, opt_state, ls = epoch_fn(
                    self.params, stacked, opt_state, cache,
                    jnp.asarray(idx_mat), row_tenant,
                )
            else:
                stacked, opt_state, ls = FF.fleet_cached_epoch_via_engine(
                    step_fn, self.params, stacked, opt_state, self.engine,
                    idx_mat, row_tenant,
                )
            all_losses.append(np.asarray(ls))

        step_after = int(opt_state.step)
        for g, (t, st) in enumerate(zip(group, states)):
            st.adapters = jax.tree.map(lambda x: x[g], stacked)
            st.opt_mu = _maybe_slice(opt_state.mu, g)
            st.opt_nu = _maybe_slice(opt_state.nu, g)
            st.step = step_after
            st.epochs_done = epoch0 + epochs
        self.pool.register_many(group, stacked)
        for t in group:
            self.pool.pin(t)  # in-flight session state: never LRU-evicted
        return np.stack(all_losses)

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict[str, float]:
        out = {f"runtime/{k}": float(v) for k, v in sorted(self.counters.items())}
        out.update(dict(self.engine.stats.as_rows()))
        out.update(dict(self.pool.stats.as_rows()))
        out["cache_engine/hbm_hit_rate"] = self.engine.stats.hbm_hit_rate()
        return out

    # -- checkpoint plane ----------------------------------------------------

    def session_state(self) -> tuple[dict, dict]:
        """(arrays, meta) for ``checkpoint.save_runtime_session``: stacked
        trained adapters + optimizer moments (tenant order in meta), the
        pool's data plane + slot table, and every present skip-cache row in
        logical layout. Tenant ids must be JSON-serialisable."""
        order = list(self._tenants)
        trained = [t for t in order if self._tenants[t].trained]
        arrays: dict[str, Any] = {}
        if trained:
            sts = [self._tenants[t] for t in trained]
            arrays["adapters"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *[st.adapters for st in sts]
            )
            mu = _maybe_stack([st.opt_mu for st in sts])
            nu = _maybe_stack([st.opt_nu for st in sts])
            if mu is not None:
                arrays["opt_mu"] = mu
            if nu is not None:
                arrays["opt_nu"] = nu
        arrays["pool"] = dict(self.pool.pools())
        present = sorted(self.engine._present)
        if present:
            chunk = max(1, self.engine.capacity)
            parts = [
                self.engine.read(jnp.asarray(present[lo:lo + chunk]))
                for lo in range(0, len(present), chunk)
            ]
            arrays["cache"] = {
                name: jnp.concatenate([p[name] for p in parts])
                for name in parts[0]
            }
        meta = {
            "tenants": [
                {
                    "id": t,
                    "partition": self._tenants[t].partition,
                    "n_ingested": self._tenants[t].n_ingested,
                    "epochs_done": self._tenants[t].epochs_done,
                    "step": self._tenants[t].step,
                }
                for t in order
            ],
            "trained": trained,
            "pool_table": self.pool.slot_table(),
            "present": present,
            "layout": {"seq": self.seq, "rank": self.sl.rank,
                       "mode": self.sl.mode,
                       "samples_per_tenant": self.samples_per_tenant},
        }
        return arrays, meta

    def load_session_state(self, arrays: dict, meta: dict) -> None:
        """Restore a ``session_state`` capture into this (fresh) runtime.
        Geometry (config shapes, seq, partition layout) must match the
        saving session; the engine re-places cache rows under ITS budget
        (placement is policy, the bytes are identical)."""
        if self._tenants:
            raise RuntimeError("restore requires a fresh runtime")
        lay = meta["layout"]
        if (lay["seq"], lay["rank"], lay["mode"], lay["samples_per_tenant"]) != (
            self.seq, self.sl.rank, self.sl.mode, self.samples_per_tenant
        ):
            raise ValueError(f"session layout {lay} != runtime configuration")
        for ent in meta["tenants"]:
            st = TenantState(
                partition=int(ent["partition"]),
                n_ingested=int(ent["n_ingested"]),
                epochs_done=int(ent["epochs_done"]),
                step=int(ent["step"]),
            )
            self._tenants[ent["id"]] = st
            self._free_partitions.remove(st.partition)
        for i, t in enumerate(meta["trained"]):
            st = self._tenants[t]
            st.adapters = jax.tree.map(lambda x: jnp.asarray(x)[i], arrays["adapters"])
            if "opt_mu" in arrays:
                st.opt_mu = jax.tree.map(lambda x: jnp.asarray(x)[i], arrays["opt_mu"])
            if "opt_nu" in arrays:
                st.opt_nu = jax.tree.map(lambda x: jnp.asarray(x)[i], arrays["opt_nu"])
        self.pool.load_state(arrays["pool"], meta["pool_table"])
        present = [int(i) for i in meta["present"]]
        if present:
            chunk = max(1, self.engine.capacity)
            for lo in range(0, len(present), chunk):
                ids = present[lo:lo + chunk]
                vals = {
                    name: jnp.asarray(arr)[lo:lo + chunk]
                    for name, arr in arrays["cache"].items()
                }
                self.engine.write(jnp.asarray(ids), vals)


def _maybe_stack(trees: list) -> Optional[Params]:
    if trees[0] is None:
        return None
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _maybe_slice(tree: Optional[Params], i: int) -> Optional[Params]:
    if tree is None:
        return None
    return jax.tree.map(lambda x: x[i], tree)
