"""Continuous-batching request scheduler over ``SessionRuntime``.

The runtime's ``serve()``/``ingest()`` take pre-formed batches: every row
starts together, decodes in lockstep, and finishes together, so a device
serving live traffic either waits to fill a batch (latency) or decodes
alone (throughput). This module closes that gap — ROADMAP open item 1 —
with the request-level event loop the paper's deployment story assumes:
asynchronous per-tenant requests in, step-synchronous dispatches out,
fine-tuning interleaved at step boundaries.

Event model (one ``step()`` = one dispatch per shard with work):

  1. *Harvest*: pull the previous dispatch's token chunk to host, append
     per row, retire rows whose requests hit ``max_new`` (their batch rows
     are immediately recyclable).
  2. *Admit*: ``batch_plan.plan_admissions`` walks the arrival-ordered
     queue under the per-tenant in-flight cap (FIFO within tenant, no
     head-of-line blocking across tenants) and fills up to ``admit_bucket``
     free rows.
  3. *Dispatch*: with admissions, ONE fused jit runs the padded admission
     prefill (``lm.sched_prefill``), samples each new row's first token,
     scatters caches/tokens/positions into the live batch, and decodes a
     ``chunk``-step scan; without admissions, the chunk scan alone. Either
     way the decode is a scan of ``lm.decode_step`` — the same carry the
     fused ``decode_scan`` threads (the Lingvo ``Step`` idiom, SNIPPETS.md
     §3) — over per-row positions, per-row temperatures, and per-row
     adapter slots.
  4. *Train*: queued ``submit_ingest`` work runs between dispatches via
     ``SessionRuntime.ingest`` — the step-boundary interleaving bar.

Rows never wait for each other: a row admitted at step k decodes from its
own position while its neighbours are mid-sequence. Dead rows keep their
state frozen in-trace (``where(active, ...)``) so the dispatch geometry —
and therefore the compiled program — never changes: one ``sched_admit``
trace and one ``sched_step`` trace per (cfg, chunk, bucket) serve the whole
session, across every temperature in the traffic (temperature is traced,
never a static).

Determinism bars (tests/test_scheduler.py):

  - scan-of-``decode_step`` reproduces the fused ``decode_scan`` bitwise;
  - at temperature 0 a row admitted mid-decode produces exactly the tokens
    it produces decoded alone (batch-row independence + matched geometry:
    the live batch and the solo path see the same pad bucket and the same
    ``max_seq``);
  - sampling keys are counter-derived per dispatch (``fold_in(key(seed),
    n)``) — deterministic replay for a fresh identically-seeded scheduler,
    never a shared key between dispatches.

``mode="sequential"`` runs the SAME machinery but admits a request only
when the batch is empty — the one-request-at-a-time baseline the serving
benchmark compares against (``benchmarks/serving_bench.py``).
"""

from __future__ import annotations

import dataclasses
import time
from collections import Counter, deque
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_plan, donate_argnums
from repro.core import runtime as RT
from repro.models.blocks import ATTN_KINDS
from repro.models.lm import (
    decode_step,
    pipeline_sched_prefill,
    sample_token,
    sched_prefill,
    sched_prefill_reuse,
)
from repro.runtime.sharding import scope_ctx

Params = Any

#: Sentinel batch row for admission padding: scatters with ``mode="drop"``
#: silently discard out-of-bounds rows, so padding an admission up to the
#: bucket width costs nothing and never perturbs live rows.
_DROP_ROW = 1 << 30


@dataclasses.dataclass
class Request:
    """One in-flight generation request (the scheduler's future)."""

    rid: int
    tenant: Any                        # None -> base model
    prompt: np.ndarray                 # (len,) int32
    max_new: int
    temperature: float = 0.0
    submitted_at: float = 0.0
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    #: Terminal failure: the dispatch this request was admitted into raised.
    #: The request is ``done`` (it will never produce tokens) and ``result``
    #: re-raises the stored error.
    error: Optional[BaseException] = None

    def result(self) -> np.ndarray:
        if self.error is not None:
            raise RuntimeError(
                f"request {self.rid} failed in dispatch"
            ) from self.error
        if not self.done:
            raise RuntimeError(f"request {self.rid} still in flight")
        return np.asarray(self.tokens[: self.max_new], np.int32)

    @property
    def latency(self) -> float:
        if self.finished_at is None:
            raise RuntimeError(f"request {self.rid} still in flight")
        return self.finished_at - self.submitted_at


@dataclasses.dataclass
class IngestRequest:
    """Queued fine-tuning work, executed at the next step boundary."""

    rid: int
    tenant: Any
    tokens: jax.Array
    labels: jax.Array
    logits: Optional[jax.Array] = None
    done: bool = False


# ---------------------------------------------------------------------------
# Fused dispatch bodies (shared compiled-fn cache: one trace per geometry)
# ---------------------------------------------------------------------------


def _chunk_scan(params, cfg, use_kernel, fuse, chunk, pools, idx, caches, tok,
                pos, active, temps, key, max_seq):
    """``chunk`` decode steps over the live batch: a scan of ``decode_step``
    with per-row positions/temperatures/slots, dead rows frozen in place.
    Emits the token sampled at each step ((chunk, B)), unlike the fused
    ``decode_scan`` which emits the carried token — the host has already
    received every carried token, so emitting the new one means each chunk
    hands back exactly the tokens the host has not seen. ``fuse`` inlines
    each step's skip term as dense math (no grouped kernel dispatch inside
    the scan body) — temp-0 tokens are identical either way (tested)."""

    def body(carry, _):
        tok, pos, caches, key = carry
        (ntok, npos, caches, key), _ = decode_step(
            params, cfg, (tok, pos, caches, key),
            temperature=temps, pools=pools, idx=idx, use_kernel=use_kernel,
            fuse_skip=fuse,
        )
        # Freeze retired rows (their cache writes land at a frozen, clamped
        # position nobody will read) and clamp live positions so a chunk
        # overshooting a finishing row never scatters out of bounds.
        ntok = jnp.where(active[:, None], ntok, tok)
        npos = jnp.where(active, jnp.minimum(npos, max_seq - 1), pos)
        return (ntok, npos, caches, key), ntok[:, 0]

    (tok, pos, caches, key), toks = jax.lax.scan(
        body, (tok, pos, caches, key), None, length=chunk
    )
    return caches, tok, pos, toks


def _sched_step_fn(cfg, use_kernel: bool, chunk: int, max_seq: int,
                   fuse: bool = False, scope=None):
    def make():
        def f(params, pools, idx, caches, tok, pos, active, temps, key):
            RT._mark_trace("sched_step")
            with scope_ctx(scope):
                return _chunk_scan(
                    params, cfg, use_kernel, fuse, chunk, pools, idx, caches,
                    tok, pos, active, temps, key, max_seq,
                )

        return jax.jit(f, donate_argnums=donate_argnums(3))

    return RT.compiled(
        ("sched_step", cfg, use_kernel, chunk, max_seq, fuse, scope), make
    )


def _sched_admit_fn(cfg, use_kernel: bool, chunk: int, max_seq: int,
                    bucket: int, prompt: int, fuse: bool = False, scope=None):
    def make():
        def f(params, pools, idx, new_tokens, new_lens, new_idx, new_rows,
              caches, tok, pos, active, temps, key):
            RT._mark_trace("sched_admit")
            with scope_ctx(scope):
                akey, key = jax.random.split(key)
                logits, new_caches = sched_prefill(
                    params, cfg, new_tokens, new_lens, pools, new_idx,
                    use_kernel=use_kernel,
                )
                b = tok.shape[0]
                row_t = jnp.take(temps, jnp.clip(new_rows, 0, b - 1))
                tok0, _ = sample_token(logits, akey, row_t)
                tok = tok.at[new_rows].set(tok0, mode="drop")
                pos = pos.at[new_rows].set(
                    new_lens.astype(pos.dtype), mode="drop"
                )
                caches = jax.tree.map(
                    lambda live, new: live.at[
                        ..., new_rows, 0:prompt, :, :
                    ].set(new.astype(live.dtype), mode="drop"),
                    caches, new_caches,
                )
                caches, tok, pos, toks = _chunk_scan(
                    params, cfg, use_kernel, fuse, chunk, pools, idx, caches,
                    tok, pos, active, temps, key, max_seq,
                )
                return caches, tok, pos, toks, tok0

        return jax.jit(f, donate_argnums=donate_argnums(7))

    return RT.compiled(
        ("sched_admit", cfg, use_kernel, chunk, max_seq, bucket, prompt, fuse,
         scope),
        make,
    )


def _sched_admit_pipe_fn(cfg, use_kernel: bool, chunk: int, max_seq: int,
                         bucket: int, prompt: int, fuse: bool, scope,
                         n_micro: int):
    """Pipelined admission: the prefill runs as ``n_micro`` GPipe
    microbatches over the stage-split backbone (``pipeline_sched_prefill``,
    stages = the shard's model-axis devices), then the identical
    sample/scatter/chunk-scan tail as ``_sched_admit_fn``. The stage
    params/valid mask are jit *arguments* (leading axis sharded over the
    model axis), never trace constants."""

    def make():
        def f(params, stage_blocks, valid, pools, idx, new_tokens, new_lens,
              new_idx, new_rows, caches, tok, pos, active, temps, key):
            RT._mark_trace("sched_admit_pipe")
            with scope_ctx(scope):
                akey, key = jax.random.split(key)
                logits, new_caches = pipeline_sched_prefill(
                    params, cfg, stage_blocks, valid, new_tokens, new_lens,
                    pools, new_idx, mesh=scope.mesh, n_micro=n_micro,
                )
                b = tok.shape[0]
                row_t = jnp.take(temps, jnp.clip(new_rows, 0, b - 1))
                tok0, _ = sample_token(logits, akey, row_t)
                tok = tok.at[new_rows].set(tok0, mode="drop")
                pos = pos.at[new_rows].set(
                    new_lens.astype(pos.dtype), mode="drop"
                )
                caches = jax.tree.map(
                    lambda live, new: live.at[
                        ..., new_rows, 0:prompt, :, :
                    ].set(new.astype(live.dtype), mode="drop"),
                    caches, new_caches,
                )
                caches, tok, pos, toks = _chunk_scan(
                    params, cfg, use_kernel, fuse, chunk, pools, idx, caches,
                    tok, pos, active, temps, key, max_seq,
                )
                return caches, tok, pos, toks, tok0

        return jax.jit(f, donate_argnums=donate_argnums(9))

    return RT.compiled(
        ("sched_admit_pipe", cfg, use_kernel, chunk, max_seq, bucket, prompt,
         fuse, scope, n_micro),
        make,
    )


def _sched_admit_reuse_fn(cfg, use_kernel: bool, chunk: int, max_seq: int,
                          bucket: int, prompt: int, tail: int, max_nb: int,
                          block: int, fuse: bool = False, scope=None):
    """Prefix-reuse admission: the wave's prompts all matched >= 1 pooled
    KV block, so the dispatch gathers their block tables out of the paged
    pool into fresh (A, P) admission caches (pure data movement — zero
    forward FLOPs for the prefix), prefills ONLY the (A, PT << P) tails
    through ``sched_prefill_reuse``, then runs the identical sample /
    scatter / chunk-scan epilogue as ``_sched_admit_fn``. Bitwise doctrine:
    cache dtype == compute dtype, so a gathered key is exactly the key a
    dense prefill would recompute — temp-0 tokens match reuse-off (gated in
    tests and ``benchmarks/serving_bench.py --prefix-share``)."""

    def make():
        def f(params, pools, idx, pool_data, tables, tail_tokens, tail_lens,
              prefix_lens, new_idx, new_rows, caches, tok, pos, active,
              temps, key):
            RT._mark_trace("sched_admit_reuse")
            with scope_ctx(scope):
                from repro.core.kv_pool import gather_blocks
                from repro.models.lm import init_serve_caches

                akey, key = jax.random.split(key)
                adm = init_serve_caches(cfg, bucket, prompt)
                prefix = gather_blocks(
                    pool_data, tables, block=block, use_kernel=use_kernel
                )
                span = max_nb * block
                adm = jax.tree.map(
                    lambda dst, src: dst.at[..., 0:span, :, :].set(
                        src.astype(dst.dtype)
                    ),
                    adm, prefix,
                )
                logits, new_caches = sched_prefill_reuse(
                    params, cfg, tail_tokens, tail_lens, prefix_lens, adm,
                    pools, new_idx, use_kernel=use_kernel,
                )
                b = tok.shape[0]
                row_t = jnp.take(temps, jnp.clip(new_rows, 0, b - 1))
                tok0, _ = sample_token(logits, akey, row_t)
                tok = tok.at[new_rows].set(tok0, mode="drop")
                pos = pos.at[new_rows].set(
                    (prefix_lens + tail_lens).astype(pos.dtype), mode="drop"
                )
                caches = jax.tree.map(
                    lambda live, new: live.at[
                        ..., new_rows, 0:prompt, :, :
                    ].set(new.astype(live.dtype), mode="drop"),
                    caches, new_caches,
                )
                caches, tok, pos, toks = _chunk_scan(
                    params, cfg, use_kernel, fuse, chunk, pools, idx, caches,
                    tok, pos, active, temps, key, max_seq,
                )
                return caches, tok, pos, toks, tok0

        return jax.jit(f, donate_argnums=donate_argnums(10))

    return RT.compiled(
        ("sched_admit_reuse", cfg, use_kernel, chunk, max_seq, bucket, prompt,
         tail, max_nb, block, fuse, scope),
        make,
    )


# ---------------------------------------------------------------------------
# Live batch (per shard)
# ---------------------------------------------------------------------------


class _LiveBatch:
    """One shard's resident decode state: device carries (caches, tok, pos)
    plus host-side row bookkeeping. ``rows[i]`` is the request occupying
    batch row ``i`` (None = free)."""

    def __init__(self, cfg, max_batch: int, max_seq: int, device):
        from repro.models.lm import init_serve_caches

        if isinstance(device, jax.sharding.Sharding):
            # 2-D shard: the "device" is a replicated NamedSharding over the
            # shard's model-axis group (jax.default_device only accepts a
            # bare Device) — commit the fresh state onto the whole group.
            self.caches = jax.device_put(
                init_serve_caches(cfg, max_batch, max_seq), device
            )
            self.tok = jax.device_put(
                jnp.zeros((max_batch, 1), jnp.int32), device
            )
            self.pos = jax.device_put(jnp.zeros((max_batch,), jnp.int32), device)
        else:
            with jax.default_device(device):
                self.caches = init_serve_caches(cfg, max_batch, max_seq)
                self.tok = jnp.zeros((max_batch, 1), jnp.int32)
                self.pos = jnp.zeros((max_batch,), jnp.int32)
        self.rows: list[Optional[Request]] = [None] * max_batch
        self.active = np.zeros((max_batch,), bool)
        self.temps = np.zeros((max_batch,), np.float32)
        self.idx = np.zeros((max_batch,), np.int32)
        self.idx_version: Optional[int] = None
        #: Per-row prefix pin: ``(index, handle)`` while the row reuses
        #: pooled KV blocks, released when the row retires.
        self.blocks: list[Optional[tuple]] = [None] * max_batch

    def free_rows(self) -> list[int]:
        return [i for i, r in enumerate(self.rows) if r is None]

    def n_active(self) -> int:
        return int(self.active.sum())


class RequestScheduler:
    """Admission queue + continuous-batching event loop over a runtime.

    ``max_prompt`` is the single pad bucket every prompt is right-padded
    to; ``max_seq = max_prompt + max_new_cap`` sizes the live KV caches.
    ``inflight_per_tenant`` caps one tenant's simultaneous batch rows;
    ``admit_bucket`` is the (padded, so geometry-stable) admission width of
    one dispatch; ``chunk`` is how many decode steps each dispatch scans.
    ``mode="sequential"`` degrades the same loop to one-request-at-a-time
    (the benchmark baseline)."""

    def __init__(
        self,
        runtime,
        *,
        max_batch: int = 8,
        max_prompt: int = 16,
        max_new_cap: int = 32,
        admit_bucket: int = 2,
        inflight_per_tenant: int = 2,
        chunk: int = 4,
        mode: str = "continuous",
        microbatch: int = 0,
        prefix_reuse: bool = True,
        kv_block: Optional[int] = None,
        kv_pool_blocks: Optional[int] = None,
    ):
        if mode not in ("continuous", "sequential"):
            raise ValueError(f"unknown scheduler mode {mode!r}")
        kinds = set(runtime.cfg.layer_kinds())
        if not kinds <= set(ATTN_KINDS):
            raise NotImplementedError(
                f"scheduler needs per-row decode positions, which only the "
                f"attention cache supports today; config has {sorted(kinds)}"
            )
        if admit_bucket > max_batch:
            raise ValueError(f"admit_bucket {admit_bucket} > max_batch {max_batch}")
        self.rt = runtime
        self.max_batch = max_batch
        self.max_prompt = max_prompt
        self.max_new_cap = max_new_cap
        self.max_seq = max_prompt + max_new_cap
        self.admit_bucket = admit_bucket
        self.inflight_per_tenant = inflight_per_tenant
        self.chunk = chunk
        self.mode = mode
        # Pipelined admission (runtime built with pipeline_stages=N): the
        # admission prefill runs as GPipe microbatches of ``microbatch``
        # rows each, so the dispatch width pads up to n_micro * microbatch
        # (_DROP_ROW rows, free). More microbatches per dispatch -> smaller
        # bubble: predicted_bubble() = (P-1)/(n_micro+P-1).
        stages = int(getattr(runtime, "pipeline_stages", 0) or 0)
        self.pipeline = stages > 1
        if self.pipeline:
            mb = int(microbatch) if microbatch else 1
            if mb < 1:
                raise ValueError(f"microbatch {microbatch} < 1")
            self.pipe_microbatch = mb
            self.n_micro = -(-admit_bucket // mb)
            self.admit_pad = self.n_micro * mb
        elif microbatch:
            raise ValueError(
                "microbatch is a pipelined-admission knob; the runtime was "
                "built without pipeline_stages"
            )
        else:
            self.admit_pad = admit_bucket
        # Paged-KV prefix reuse (both modes; pipelined admission keeps the
        # dense prefill — the GPipe stage split owns its own cache layout).
        # ``kv_block`` overrides the autotuned/default block size;
        # ``kv_pool_blocks`` overrides the pool sizing heuristic. The pool
        # and radix index live on the RUNTIME (one per shard), so a later
        # scheduler on the same runtime reuses what an earlier one
        # published; ``runtime.reset_prefix_cache()`` clears them.
        self.prefix_reuse = bool(prefix_reuse) and not self.pipeline
        self.kv_block = int(kv_block) if kv_block else None
        self.kv_pool_blocks = int(kv_pool_blocks) if kv_pool_blocks else None
        self.counters = Counter()
        self._pending: deque[Request] = deque()
        self._ingest_queue: deque[IngestRequest] = deque()
        self._completed: list[Request] = []
        self._batches: dict[int, _LiveBatch] = {}
        self._in_flight: Counter = Counter()
        self._next_rid = 0
        self._dispatches = 0
        self._base_key = jax.random.key(runtime.seed)

    # -- submission ----------------------------------------------------------

    def submit(self, tenant, prompt, *, max_new: int,
               temperature: float = 0.0) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0 or prompt.size > self.max_prompt:
            raise ValueError(
                f"prompt length {prompt.size} outside (0, {self.max_prompt}]"
            )
        if not 0 < max_new <= self.max_new_cap:
            raise ValueError(f"max_new {max_new} outside (0, {self.max_new_cap}]")
        req = Request(
            rid=self._next_rid, tenant=tenant, prompt=prompt, max_new=max_new,
            temperature=float(temperature), submitted_at=time.perf_counter(),
        )
        self._next_rid += 1
        self._pending.append(req)
        self.counters["submitted"] += 1
        return req

    def submit_ingest(self, tenant, tokens, labels) -> IngestRequest:
        req = IngestRequest(
            rid=self._next_rid, tenant=tenant, tokens=tokens, labels=labels
        )
        self._next_rid += 1
        self._ingest_queue.append(req)
        return req

    # -- shard routing -------------------------------------------------------

    def _scope_of(self, shard: int):
        """The shard's ``ShardScope`` (None on 1-D sessions): rides every
        dispatch's compiled-fn key and wraps its trace so model-axis
        sessions bake the right activation constraints."""
        scopes = getattr(self.rt, "_scope", None)
        return None if scopes is None else scopes[shard]

    def predicted_bubble(self) -> Optional[float]:
        """GPipe bubble fraction the pipelined admission is scheduled at
        (None without pipelining) — the serving bench's bar for 'pipeline
        serve within the predicted bubble of the non-pipelined path'."""
        if not self.pipeline:
            return None
        from repro.runtime.pipeline_par import bubble_fraction

        return bubble_fraction(self.n_micro, self.rt.pipeline_stages)

    def quality_metrics(self) -> dict:
        """Control-plane gate events, shaped for the serving metrics
        surface: SLO dashboards read quality events (gate decisions,
        rollbacks, quarantines) next to latency. Empty gate section when
        the runtime has no control plane."""
        out: dict[str, Any] = {
            k.split("/", 1)[1]: int(v)
            for k, v in sorted(self.rt.counters.items())
            if k.startswith("control/")
        }
        cm = getattr(self.rt, "control_metrics", lambda: None)()
        if cm is not None:
            out["gate"] = {
                k: cm[k] for k in (
                    "accepted", "rejected", "quarantined", "rollbacks",
                    "auto_rollbacks",
                )
            }
            out["quarantined_tenants"] = cm["quarantined_tenants"]
        return out

    def _shard_of(self, tenant) -> int:
        """Serve placement: a tenant with a pool slot decodes on its slot's
        shard; base traffic and slot-less tenants ride shard 0's pinned
        zero slot (mirrors ``SessionRuntime.ingest``'s ``pool.has`` check,
        without creating placements for serve-only strangers)."""
        pool = self.rt.pool
        if tenant is not None and pool.has(tenant):
            return pool.shard_of(tenant)
        return 0

    def _batch(self, shard: int) -> _LiveBatch:
        lb = self._batches.get(shard)
        if lb is None:
            lb = self._batches[shard] = _LiveBatch(
                self.rt.cfg, self.max_batch, self.max_seq,
                self.rt._shard_device[shard],
            )
        return lb

    def _refresh_idx(self, shard: int, lb: _LiveBatch) -> None:
        """Re-resolve occupied rows' pool slots when the shard's slot map
        changed (an interleaved ``adapt`` bumps the version)."""
        version = self.rt.pool.shards[shard].version
        if lb.idx_version == version:
            return
        pool = self.rt.pool
        who = [
            r.tenant if r is not None and pool.has(r.tenant) else None
            for r in lb.rows
        ]
        lb.idx = np.asarray(pool.lookup_local(shard, who), np.int32)
        lb.idx_version = version

    # -- the event loop ------------------------------------------------------

    def step(self) -> int:
        """One scheduler tick: admit + dispatch on every shard with work,
        harvest the produced tokens, then run queued ingest work. Returns
        the number of dispatches issued."""
        plans = self._plan()
        issued = []
        for shard, admits in plans:
            issued.append(self._dispatch(shard, admits))
        done0 = self.counters["completed"]
        for shard, admits, out in issued:    # async dispatch, sync here
            self._harvest(shard, admits, out)
        # Row recycle: rows released by THIS step's retirements are
        # admissible immediately. Planning before the harvest meant a full
        # batch rejected admissible requests for one extra step even
        # though the dispatch about to land would free their rows — so
        # when the harvest retired something and work is still queued, run
        # one follow-up wave on shards with fresh admissions (a shard
        # without admissions is not re-dispatched; on one that is, the
        # other live rows simply advance an extra chunk — per-row decode
        # is asynchronous by construction, so that is just an extra tick).
        if self._pending and self.counters["completed"] > done0:
            extra = [
                self._dispatch(shard, admits)
                for shard, admits in self._plan() if admits
            ]
            for shard, admits, out in extra:
                self._harvest(shard, admits, out)
            if extra:
                self.counters["recycle_waves"] += 1
            issued.extend(extra)
        self._run_ingest()
        return len(issued)

    def drain(self) -> list[Request]:
        """Pump the loop until every queued request has completed; returns
        the requests completed during the drain, in completion order."""
        done0 = len(self._completed)
        while self._pending or self._ingest_queue or any(
            lb.n_active() for lb in self._batches.values()
        ):
            if self.step() == 0 and not self._ingest_queue:
                raise RuntimeError("scheduler stalled with queued work")
        return self._completed[done0:]

    def _plan(self) -> list[tuple[int, list[Request]]]:
        """Route the pending queue by shard and pick admissions per shard
        under the fairness policy. Sequential mode admits one request, and
        only into an idle batch."""
        plans: list[tuple[int, list[Request]]] = []
        pending = list(self._pending)
        total_active = sum(lb.n_active() for lb in self._batches.values())
        if self.mode == "sequential":
            # Globally one request at a time: admit the queue head only
            # into a fully idle system; otherwise just keep stepping the
            # shard holding the current request.
            if total_active == 0 and pending:
                plans.append((self._shard_of(pending[0].tenant), pending[:1]))
            else:
                plans.extend(
                    (s, []) for s, lb in sorted(self._batches.items())
                    if lb.n_active()
                )
            return plans
        by_shard: dict[int, list[Request]] = {}
        for r in pending:
            by_shard.setdefault(self._shard_of(r.tenant), []).append(r)
        shards = set(by_shard) | {
            s for s, lb in self._batches.items() if lb.n_active()
        }
        for shard in sorted(shards):
            lb = self._batch(shard)
            queue = by_shard.get(shard, [])
            picks = batch_plan.plan_admissions(
                queue, self._in_flight, len(lb.free_rows()),
                cap=self.inflight_per_tenant, bucket=self.admit_bucket,
            )
            admits = [queue[i] for i in picks]
            if admits or lb.n_active():
                plans.append((shard, admits))
        return plans

    def _prefix_state(self, shard: int):
        """(pool, index) for a shard's paged prefix cache, built lazily on
        the runtime. Disabled — ``(None, None)`` — when no full block can
        ever be matched (a match is capped at ``(len - 1) // block`` so a
        tail token survives; with ``block >= max_prompt`` that cap is
        always zero and the pool would be dead weight)."""
        from repro.core import kv_pool as KV

        blk = self.kv_block or KV.get_default_block()
        if (self.max_prompt - 1) // blk < 1:
            return None, None
        n_blocks = self.kv_pool_blocks or max(
            8, 2 * self.max_batch * (self.max_prompt // blk)
        )
        pool = self.rt.kv_pool(shard, block=blk, n_blocks=n_blocks)
        return pool, self.rt.prefix_index(shard)

    def _dispatch(self, shard: int, admits: list[Request]):
        lb = self._batch(shard)
        matches = None
        pool = pidx = None
        if admits and self.prefix_reuse:
            pool, pidx = self._prefix_state(shard)
            if pidx is not None:
                m = [pidx.match(r.tenant, r.prompt) for r in admits]
                # One dispatch is one geometry: split a mixed wave at the
                # first kind flip and take the longest same-kind FIFO
                # prefix (all-reuse or all-dense); the rest stay pending
                # for the next plan.
                want = bool(m[0])
                take = 1
                while take < len(admits) and bool(m[take]) == want:
                    take += 1
                if take < len(admits):
                    admits = admits[:take]
                    self.counters["prefix/wave_split"] += 1
                if want:
                    matches = m[:take]
        now = time.perf_counter()
        free = lb.free_rows()
        for req, row in zip(admits, free):
            self._pending.remove(req)
            lb.rows[row] = req
            lb.active[row] = True
            lb.temps[row] = req.temperature
            self._in_flight[req.tenant] += 1
            req.started_at = now
        lb.idx_version = None            # occupancy changed: re-resolve slots
        self._refresh_idx(shard, lb)
        params = self.rt._shard_params[shard]
        pools = self.rt.pool.shard_pools(shard)
        key = jax.random.fold_in(
            jax.random.fold_in(self._base_key, self._dispatches), shard
        )
        self._dispatches += 1
        scope = self._scope_of(shard)
        if admits:
            a, p = self.admit_pad, self.max_prompt
            rows = free[: len(admits)]
            if matches is not None:
                return self._dispatch_reuse(
                    shard, lb, admits, rows, matches, pool, pidx, params,
                    pools, key, scope,
                )
            new_tokens = np.zeros((a, p), np.int32)
            new_lens = np.ones((a,), np.int32)
            new_rows = np.full((a,), _DROP_ROW, np.int32)
            for j, (req, row) in enumerate(zip(admits, rows)):
                new_tokens[j, : req.prompt.size] = req.prompt
                new_lens[j] = req.prompt.size
                new_rows[j] = row
            new_idx = lb.idx[np.minimum(new_rows, self.max_batch - 1)]
            if self.pipeline:
                fn = _sched_admit_pipe_fn(
                    self.rt.cfg, self.rt.use_kernel, self.chunk, self.max_seq,
                    a, p, getattr(self.rt, "decode_fuse", False), scope,
                    self.n_micro,
                )
                args = (
                    params, self.rt._stage_blocks[shard],
                    self.rt._stage_valid[shard], pools, jnp.asarray(lb.idx),
                    new_tokens, new_lens, new_idx, new_rows, lb.caches,
                    lb.tok, lb.pos, lb.active, lb.temps, key,
                )
            else:
                fn = _sched_admit_fn(
                    self.rt.cfg, self.rt.use_kernel, self.chunk, self.max_seq,
                    a, p, getattr(self.rt, "decode_fuse", False), scope,
                )
                args = (
                    params, pools, jnp.asarray(lb.idx), new_tokens, new_lens,
                    new_idx, new_rows, lb.caches, lb.tok, lb.pos, lb.active,
                    lb.temps, key,
                )
            try:
                lb.caches, lb.tok, lb.pos, toks, tok0 = fn(*args)
            except Exception as err:
                self._abort_admits(lb, admits, rows, err)
                raise
            self.counters[
                "dispatch/admit_pipe" if self.pipeline else "dispatch/admit"
            ] += 1
            if pidx is not None:
                self._publish_rows(pool, pidx, lb, admits, rows)
                self.counters["prefix/misses"] += len(admits)
            return shard, list(zip(admits, rows)), (toks, tok0)
        fn = _sched_step_fn(
            self.rt.cfg, self.rt.use_kernel, self.chunk, self.max_seq,
            getattr(self.rt, "decode_fuse", False), scope,
        )
        lb.caches, lb.tok, lb.pos, toks = fn(
            params, pools, jnp.asarray(lb.idx), lb.caches, lb.tok, lb.pos,
            lb.active, lb.temps, key,
        )
        self.counters["dispatch/step"] += 1
        return shard, [], (toks, None)

    def _dispatch_reuse(self, shard: int, lb: _LiveBatch, admits, rows,
                        matches, pool, pidx, params, pools, key, scope):
        """Reuse-wave dispatch: every admit matched >= 1 pooled block. Pin
        the matched blocks for the rows' lifetimes, then one fused jit
        gathers them into the admission caches and prefills only the
        tails (``_sched_admit_reuse_fn``)."""
        a, p = self.admit_pad, self.max_prompt
        blk = pool.block
        nbs = [len(ids) for ids in matches]
        max_nb = max(nbs)
        tails = [r.prompt.size - nb * blk for r, nb in zip(admits, nbs)]
        # Tail pad bucket: block-quantised (trace reuse across waves whose
        # max tail rounds the same), never above the prompt bucket.
        pt = min(p, -(-max(tails) // blk) * blk)
        tables = np.zeros((a, max_nb), np.int32)
        tail_tokens = np.zeros((a, pt), np.int32)
        tail_lens = np.ones((a,), np.int32)
        prefix_lens = np.zeros((a,), np.int32)
        new_rows = np.full((a,), _DROP_ROW, np.int32)
        for j, (req, row, ids) in enumerate(zip(admits, rows, matches)):
            nb = len(ids)
            # Rows with nb < max_nb pad their table with block 0 — any
            # valid id: the padded key positions are >= the row's own
            # length, masked in the tail prefill and overwritten by
            # decode before it ever attends there.
            tables[j, :nb] = ids
            plen = nb * blk
            t = req.prompt[plen:]
            tail_tokens[j, : t.size] = t
            tail_lens[j] = t.size
            prefix_lens[j] = plen
            new_rows[j] = row
            lb.blocks[row] = (pidx, pidx.acquire(ids))
            self.counters["prefix/blocks_reused"] += nb
            self.counters["prefix/tokens_reused"] += plen
        self.counters["prefix/hits"] += len(admits)
        new_idx = lb.idx[np.minimum(new_rows, self.max_batch - 1)]
        fn = _sched_admit_reuse_fn(
            self.rt.cfg, self.rt.use_kernel, self.chunk, self.max_seq, a, p,
            pt, max_nb, blk, getattr(self.rt, "decode_fuse", False), scope,
        )
        try:
            lb.caches, lb.tok, lb.pos, toks, tok0 = fn(
                params, pools, jnp.asarray(lb.idx), pool.data, tables,
                tail_tokens, tail_lens, prefix_lens, new_idx, new_rows,
                lb.caches, lb.tok, lb.pos, lb.active, lb.temps, key,
            )
        except Exception as err:
            self._abort_admits(lb, admits, rows, err)
            raise
        self.counters["dispatch/admit_reuse"] += 1
        return shard, list(zip(admits, rows)), (toks, tok0)

    def _publish_rows(self, pool, pidx, lb: _LiveBatch, admits, rows) -> None:
        """After a dense admission lands, index the wave's full prompt
        blocks and publish their freshly-prefilled K/V out of the live
        rows into the pool (``floor(len / block)`` blocks per prompt;
        only newly-created radix nodes copy)."""
        for req, row in zip(admits, rows):
            created = pidx.insert(req.tenant, req.prompt)
            if created:
                pool.publish(
                    lb.caches, row,
                    [bid for bid, _ in created],
                    [slot for _, slot in created],
                )
                self.counters["prefix/published_blocks"] += len(created)

    def _release_blocks(self, lb: _LiveBatch, row: int) -> None:
        handle = lb.blocks[row]
        if handle is not None:
            lb.blocks[row] = None
            pidx, h = handle
            pidx.release(h)

    def prefix_metrics(self) -> dict:
        """Prefix-reuse observability for the serving bench: hit/miss and
        reused-block/token counters plus per-shard pool occupancy. After a
        drain (no rows in flight) every held block belongs to exactly one
        radix node, so ``refs_total == held == nodes`` — the no-leak gate
        (``SessionRuntime.check_prefix_no_leaks``)."""
        out: dict[str, Any] = {
            k.split("/", 1)[1]: int(v)
            for k, v in sorted(self.counters.items())
            if k.startswith("prefix/")
        }
        out["pools"] = {
            str(s): {
                "block": p.block,
                "n_blocks": p.n_blocks,
                "free": p.n_free(),
                "held": int((p.refs > 0).sum()),
                "refs_total": int(p.refs.sum()),
                "nodes": (
                    self.rt._prefix_indexes[s].n_nodes()
                    if s in getattr(self.rt, "_prefix_indexes", {}) else 0
                ),
            }
            for s, p in sorted(getattr(self.rt, "_kv_pools", {}).items())
        }
        return out

    def _abort_admits(self, lb: _LiveBatch, admits, rows, err) -> None:
        """Unwind a failed dispatch's admissions: the rows just claimed go
        back to the free list and each admitted tenant's in-flight count
        comes back down — otherwise one raising dispatch permanently leaks
        batch rows AND pins the tenant at its cap (every later admission of
        that tenant would be skipped forever). The requests are terminally
        failed (``error`` set; ``result()`` re-raises), not re-queued: the
        caller sees the raise and owns the retry policy."""
        now = time.perf_counter()
        for req, row in zip(admits, rows):
            self._release_blocks(lb, row)
            lb.rows[row] = None
            lb.active[row] = False
            self._in_flight[req.tenant] -= 1
            if self._in_flight[req.tenant] <= 0:
                del self._in_flight[req.tenant]
            req.done = True
            req.error = err
            req.finished_at = now
            self.counters["failed"] += 1
        lb.idx_version = None  # occupancy changed again: re-resolve slots

    def _harvest(self, shard: int, admitted, out) -> None:
        lb = self._batch(shard)
        toks, tok0 = out
        toks = np.asarray(toks)                      # (chunk, B) sync point
        if tok0 is not None:
            tok0 = np.asarray(tok0)                  # (bucket, 1)
            for j, (req, row) in enumerate(admitted):
                req.tokens.append(int(tok0[j, 0]))
        for row, req in enumerate(lb.rows):
            if req is None or not lb.active[row]:
                continue
            need = req.max_new - len(req.tokens)
            req.tokens.extend(int(t) for t in toks[: max(need, 0), row])
            if len(req.tokens) >= req.max_new:
                self._finish(lb, row, req)
        self.counters["steps"] += self.chunk

    def _finish(self, lb: _LiveBatch, row: int, req: Request) -> None:
        req.done = True
        req.finished_at = time.perf_counter()
        self._release_blocks(lb, row)
        lb.rows[row] = None
        lb.active[row] = False
        self._in_flight[req.tenant] -= 1
        if self._in_flight[req.tenant] <= 0:
            del self._in_flight[req.tenant]
        self._completed.append(req)
        self.counters["completed"] += 1
        self.counters["tokens"] += req.max_new

    def _run_ingest(self) -> None:
        while self._ingest_queue:
            req = self._ingest_queue.popleft()
            req.logits = self.rt.ingest(req.tenant, req.tokens, req.labels)
            req.done = True
            self.counters["ingested"] += 1
