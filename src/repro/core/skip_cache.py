"""Skip-Cache (Section 4.2): the forward-activation cache.

The paper stores, for each training sample i, the frozen backbone's
intermediate outputs so the forward pass of seen samples can be skipped.
Here the cache is a struct-of-arrays pytree with a leading ``num_samples``
axis plus a validity bitmap — O(1) lookup by sample id (the paper's
"stored exclusively in the i-th element of C_skip"), fully vectorised, and
shardable (the LM-scale variant in ``repro/core/lm_skiplora.py`` adds
int8 compression and mode-dependent layouts on the same structure; the
tiered HBM/host engine in ``repro/core/cache_engine.py`` builds on both).

TPU adaptation (see DESIGN.md §2): instead of a per-row `if` inside the
matmul, the fine-tune loop is phase-split — a *populate* epoch computes the
backbone forward and scatters results; *cached* epochs gather and never touch
the backbone. ``masked_populate`` covers streaming ingestion where a batch
mixes hits and misses.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SkipCache:
    """Activation cache: ``slots`` maps name -> (num_samples, ...) array."""

    slots: dict[str, jax.Array]
    valid: jax.Array  # (num_samples,) bool

    @property
    def num_samples(self) -> int:
        return self.valid.shape[0]

    def hit_count(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))


def init_cache(num_samples: int, slot_shapes: dict[str, tuple], dtype=jnp.float32) -> SkipCache:
    slots = {
        name: jnp.zeros((num_samples,) + tuple(shape), dtype)
        for name, shape in slot_shapes.items()
    }
    return SkipCache(slots=slots, valid=jnp.zeros((num_samples,), jnp.bool_))


def cache_for_mlp(num_samples: int, dims: tuple[int, ...], dtype=jnp.float32) -> SkipCache:
    """Cache layout for the paper's MLP: x^1..x^n inputs + base last output.

    Size check from Section 4.3: Fan dataset, 470 samples, net 256-96-96-3
    -> 470 * (96 + 96 + 3) floats = 358 KiB, matching the paper's figure
    (x^1 is the raw input, already stored as the training set itself, so we
    cache x^2..x^n and y_base; x^1 is read from the dataset).
    """
    n = len(dims) - 1
    slots = {f"x{k}": (dims[k],) for k in range(1, n)}  # inputs of FC2..FCn
    slots["y_base"] = (dims[n],)
    return init_cache(num_samples, slots, dtype)


@jax.jit
def cache_write(cache: SkipCache, idx: jax.Array, values: dict[str, jax.Array]) -> SkipCache:
    """Scatter a batch of computed activations at sample indices ``idx``."""
    slots = dict(cache.slots)
    for name, val in values.items():
        slots[name] = slots[name].at[idx].set(val)
    return SkipCache(slots=slots, valid=cache.valid.at[idx].set(True))


@jax.jit
def cache_write_masked(
    cache: SkipCache, idx: jax.Array, values: dict[str, jax.Array], write_mask: jax.Array
) -> SkipCache:
    """Scatter only rows where ``write_mask`` is True (streaming ingestion).

    Rows with ``write_mask == False`` perform a self-overwrite with the
    existing value (gather + where) so the op stays dense and jittable.
    Validity follows the same rule: a masked-out row keeps its previous
    validity bit (a never-seen row stays invalid).
    """
    slots = dict(cache.slots)
    for name, val in values.items():
        old = slots[name][idx]
        mask = write_mask.reshape((-1,) + (1,) * (val.ndim - 1))
        slots[name] = slots[name].at[idx].set(jnp.where(mask, val, old))
    valid = cache.valid.at[idx].set(cache.valid[idx] | write_mask)
    return SkipCache(slots=slots, valid=valid)


@jax.jit
def cache_read(cache: SkipCache, idx: jax.Array) -> dict[str, jax.Array]:
    """Gather cached activations for a batch of sample indices."""
    return {name: arr[idx] for name, arr in cache.slots.items()}


@jax.jit
def cache_hits(cache: SkipCache, idx: jax.Array) -> jax.Array:
    return cache.valid[idx]


def cache_nbytes(cache: SkipCache) -> int:
    return sum(int(a.size) * a.dtype.itemsize for a in cache.slots.values())
