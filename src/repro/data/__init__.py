"""Data substrate: synthetic drifted datasets + distributed token pipeline."""
