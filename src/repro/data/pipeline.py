"""Deterministic, resumable token data pipeline.

Production shape: an index-based sampler (deterministic in (seed, step)) over
a memory-mappable token store, yielding host-sharded batches. Here the store
is a synthetic corpus generator (offline container), but the contract is the
real one:

  - O(1) random access by sample id (the Skip-Cache needs stable ids!),
  - iterator state = (seed, step) only -> checkpointable / restartable,
  - per-host slicing for multi-host launches (each host feeds its devices).

The Skip2-LoRA fine-tune loop additionally needs *epoch-partitioned*
visitation (populate epoch sees each sample exactly once), provided by
``epoch_permutation``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_samples: int
    seed: int = 0
    host_count: int = 1
    host_index: int = 0


class SyntheticTokenStore:
    """Deterministic synthetic corpus with O(1) access by sample id.

    Samples are Zipf-ish token sequences with a per-sample Markov flavour so
    the LM loss actually decreases during the examples' fine-tuning runs.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def __len__(self) -> int:
        return self.cfg.num_samples

    def get(self, idx: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ idx)
        # Zipf-distributed tokens, clipped to vocab.
        toks = rng.zipf(1.3, size=cfg.seq_len + 1).astype(np.int64)
        toks = (toks - 1) % cfg.vocab_size
        # Inject per-sample periodic structure (learnable signal).
        period = 3 + idx % 5
        anchor = (idx * 2654435761) % cfg.vocab_size
        toks[::period] = (anchor + np.arange(len(toks[::period]))) % cfg.vocab_size
        return toks.astype(np.int32)

    def batch(self, ids: np.ndarray) -> dict[str, np.ndarray]:
        toks = np.stack([self.get(int(i)) for i in ids])
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "sample_ids": ids.astype(np.int32),
        }


@dataclasses.dataclass
class SamplerState:
    """Fully describes the iterator position — checkpoint this."""

    seed: int
    step: int
    epoch: int = 0

    def as_dict(self):
        return dataclasses.asdict(self)


def epoch_permutation(seed: int, epoch: int, n: int) -> np.ndarray:
    return np.random.default_rng((seed << 10) ^ epoch).permutation(n)


class BatchSampler:
    """Deterministic batch-id sampler with host sharding + resume."""

    def __init__(self, cfg: DataConfig, state: Optional[SamplerState] = None):
        self.cfg = cfg
        self.state = state or SamplerState(seed=cfg.seed, step=0)

    @property
    def steps_per_epoch(self) -> int:
        return self.cfg.num_samples // self.cfg.global_batch

    def next_ids(self) -> np.ndarray:
        """Global batch ids for the current step (then advances)."""
        cfg = self.cfg
        spe = max(1, self.steps_per_epoch)
        epoch = self.state.step // spe
        pos = self.state.step % spe
        perm = epoch_permutation(self.state.seed, epoch, cfg.num_samples)
        ids = perm[pos * cfg.global_batch : (pos + 1) * cfg.global_batch]
        self.state = SamplerState(self.state.seed, self.state.step + 1, epoch)
        return ids

    def host_slice(self, ids: np.ndarray) -> np.ndarray:
        """This host's shard of the global batch."""
        per_host = len(ids) // self.cfg.host_count
        lo = self.cfg.host_index * per_host
        return ids[lo : lo + per_host]

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            yield self.next_ids()


def make_pipeline(cfg: DataConfig, state: Optional[SamplerState] = None):
    """(store, sampler) pair — the canonical construction."""
    return SyntheticTokenStore(cfg), BatchSampler(cfg, state)
