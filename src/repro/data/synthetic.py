"""Synthetic drifted classification datasets (Section 5.1 stand-ins).

The paper's Damage1/Damage2 (fan vibration, 256 features, 3 classes) and HAR
(561 features, 6 classes) datasets are not redistributable offline, so we
synthesize *structural twins*: Gaussian-mixture classification with a
controlled distribution drift between the pre-train and fine-tune/test
splits. The drift is composed of
  (1) a random partial rotation of the class-mean geometry,
  (2) a class-conditional mean shift, and
  (3) a covariate noise-scale change,
which mimics "same task, shifted sensing conditions" (silent office vs
ventilation-fan noise; different human subjects). The *claims* we reproduce
on these twins are relational — accuracy collapses before fine-tuning and
recovers after; method ordering and cost ratios — not absolute accuracies.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DriftedDataset:
    name: str
    x_pre: jax.Array
    y_pre: jax.Array
    x_ft: jax.Array
    y_ft: jax.Array
    x_test: jax.Array
    y_test: jax.Array

    @property
    def n_features(self) -> int:
        return self.x_pre.shape[1]

    @property
    def n_classes(self) -> int:
        return int(jnp.max(self.y_pre)) + 1


#: name -> (n_features, n_classes, n_pretrain, n_finetune, n_test)
DATASET_SPECS: dict[str, tuple[int, int, int, int, int]] = {
    "damage1": (256, 3, 470, 470, 470),
    "damage2": (256, 3, 470, 470, 470),
    "har": (561, 6, 5894, 1050, 694),
}


def _sample_mixture(key, means, noise_scale, n, n_classes):
    ky, kx = jax.random.split(key)
    y = jax.random.randint(ky, (n,), 0, n_classes)
    eps = jax.random.normal(kx, (n, means.shape[1])) * noise_scale
    return means[y] + eps, y


def _random_rotation(key, d: int, strength: float) -> jax.Array:
    """Partial random rotation: R = exp(strength * (S - S^T)) approximated by
    orthogonalising I + strength*skew (QR)."""
    s = jax.random.normal(key, (d, d)) / jnp.sqrt(d)
    skew = (s - s.T) / 2.0
    q, _ = jnp.linalg.qr(jnp.eye(d) + strength * skew)
    return q


def make_drifted_dataset(
    key: jax.Array,
    name: str = "damage1",
    *,
    class_sep: float = 2.8,
    noise_pre: float = 0.9,
    noise_post: float = 1.0,
    rotation_strength: float = 0.75,
    shift_strength: float = 1.3,
) -> DriftedDataset:
    """Build a drifted twin of a paper dataset (see DATASET_SPECS)."""
    if name not in DATASET_SPECS:
        raise ValueError(f"unknown dataset {name!r}; options: {sorted(DATASET_SPECS)}")
    d, c, n_pre, n_ft, n_test = DATASET_SPECS[name]
    # Distinct twin per dataset name (damage1 vs damage2 share a spec but
    # must differ in geometry, like the paper's two damage types).
    # zlib.crc32 is stable across processes (str hash is randomised).
    import zlib

    key = jax.random.fold_in(key, zlib.crc32(name.encode()))
    km, kr, ks, k1, k2, k3 = jax.random.split(key, 6)

    means = jax.random.normal(km, (c, d)) * class_sep / jnp.sqrt(d) * jnp.sqrt(d)
    means = means / jnp.linalg.norm(means, axis=1, keepdims=True) * class_sep

    rot = _random_rotation(kr, d, rotation_strength)
    shift = jax.random.normal(ks, (c, d))
    shift = shift / jnp.linalg.norm(shift, axis=1, keepdims=True) * shift_strength
    means_drift = means @ rot + shift

    x_pre, y_pre = _sample_mixture(k1, means, noise_pre, n_pre, c)
    x_ft, y_ft = _sample_mixture(k2, means_drift, noise_post, n_ft, c)
    x_test, y_test = _sample_mixture(k3, means_drift, noise_post, n_test, c)
    return DriftedDataset(name, x_pre, y_pre, x_ft, y_ft, x_test, y_test)
