"""Pallas TPU kernels for the paper's compute hot-spots.

The Skip-LoRA aggregation sum_k x^k A_k B_k (Eq. 17) is the fine-tune loop's
inner loop once the cache removes the backbone; done per-layer it re-reads
x^k from HBM L times and wastes MXU lanes on R<<128. The fused kernels here
stream each x^k tile through VMEM exactly once:

  - ``skip_lora``: fused forward (sum over layers) + fused adapter backward
    (gA_k, gB_k for all k in one pass) + int8 fused-dequant variant.

Validated in interpret mode against ``ref.py`` jnp oracles (CPU container;
TPU is the target).
"""
