"""Profiler-driven autotuning for the grouped Skip-LoRA kernels.

The grouped kernels ran for five PRs on hand-picked parameters (``TM = 128``
rows per tile, rows-outer grid, scan ``unroll=1``) that were never profiled.
This harness makes them *measured*:

  - ``tune_grouped`` sweeps the row tile (``tm``) and layer-grid order
    (``grid_order``) for one kernel variant at one batch shape, timing the
    real dispatch (median of repeats, post-``block_until_ready``) and
    recording the roofline cost-model prediction
    (``launch.roofline.PEAK_FLOPS`` / ``HBM_BW``) next to each measurement —
    the predicted/measured pair is what makes a surprising winner auditable.
  - ``tune_decode_unroll`` sweeps the decode-scan unroll factor over a
    synthetic scan-of-dispatches at decode shape.
  - ``AutotuneCache`` persists winners in a deterministic JSON file keyed on
    ``config_key|device_kind|variant`` — same config + device kind always
    resolves to the same choice, and a warm cache skips timing entirely
    (the CI smoke asserts the second run is all cache hits).
  - ``apply_choice`` installs a winner as the process-wide kernel default
    (``ops.set_default_tile``), which every wrapper resolves at trace time.

Tile candidates respect the TPU sublane minimum for the activation dtype
(f32 8, bf16 16, int8 32 — smaller tiles can't be laid out in VMEM) and
always include the hand-picked default, so the tuned choice is never worse
than untuned *by construction*: the argmin runs over a set containing it.

Usage (CI smoke):
    PYTHONPATH=src python -m repro.kernels.autotune --quick --cache /tmp/at.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.skip_lora import kernel as K
from repro.kernels.skip_lora import ops as O
from repro.kernels.skip_lora import quant as Q
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

#: Minimum row tile per activation dtype: the TPU sublane tiling floor.
_MIN_TILE = {
    jnp.dtype(jnp.float32): 8,
    jnp.dtype(jnp.bfloat16): 16,
    jnp.dtype(jnp.int8): 32,
    jnp.dtype(jnp.uint8): 32,
}

GRID_ORDERS = ("ml", "lm")
UNROLL_CANDIDATES = (1, 2, 4)
#: KV block sizes swept by ``tune_kv_block`` (tokens per paged block).
#: Smaller blocks match longer prefixes (match granularity is the block);
#: larger blocks amortise gather/publish dispatch overhead.
KV_BLOCK_CANDIDATES = (4, 8, 16)


def tile_candidates(
    m: int, dtype=jnp.float32, *, max_tile: int = 512
) -> tuple[int, ...]:
    """Valid row tiles for a batch of ``m`` rows: powers of two from the
    dtype's sublane minimum up to ``max_tile``, the hand-picked default
    always included. Tiles far above the row count only add padding, so the
    sweep stops one doubling past ``m``."""
    lo = _MIN_TILE.get(jnp.dtype(dtype), 8)
    out = []
    t = lo
    while t <= max_tile:
        out.append(t)
        if t >= 2 * m and t >= K.TM:
            break
        t *= 2
    if K.TM not in out:
        out.append(K.TM)
    return tuple(sorted(set(out)))


def config_key(cfg, rank: int) -> str:
    """Stable identity of the model shape the kernels serve: everything the
    grouped dispatch geometry depends on."""
    name = getattr(cfg, "name", "anon")
    return f"{name}-d{cfg.d_model}-L{cfg.n_layers}-r{rank}"


def device_kind() -> str:
    """Hardware identity for the cache key; off-TPU the kernels run in
    interpret mode, which has its own (very different) cost surface."""
    kind = jax.devices()[0].device_kind.replace(" ", "_")
    if jax.default_backend() != "tpu":
        kind = f"{kind}-interpret"
    return kind


@dataclasses.dataclass(frozen=True)
class Choice:
    """One tuned parameter set + the evidence that chose it."""

    tm: int
    grid_order: str
    unroll: int = 1
    time_s: float = 0.0           # measured median for the winner
    default_time_s: float = 0.0   # measured median for (K.TM, "ml")
    predicted_s: float = 0.0      # roofline prediction for the winner
    source: str = "measured"      # "measured" | "cache" | "default"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Choice":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls) if f.name in d})


class AutotuneCache:
    """Deterministic JSON store of tuned choices.

    Entries are keyed ``config_key|device_kind|variant``; the file is written
    with sorted keys so identical tuning runs produce byte-identical caches
    (the round-trip test diffs the serialized form). ``hits``/``misses``
    count lookups since construction — the CI smoke asserts a warm second
    run never re-times."""

    VERSION = 1

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.entries: dict[str, dict] = {}
        self.hits = 0
        self.misses = 0
        if path and os.path.exists(path):
            with open(path) as f:
                blob = json.load(f)
            if blob.get("version") == self.VERSION:
                self.entries = blob.get("entries", {})

    @staticmethod
    def key(config: str, device: str, variant: str) -> str:
        return f"{config}|{device}|{variant}"

    def get(self, config: str, device: str, variant: str) -> Optional[Choice]:
        entry = self.entries.get(self.key(config, device, variant))
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        return Choice.from_dict({**entry, "source": "cache"})

    def put(self, config: str, device: str, variant: str, choice: Choice) -> None:
        self.entries[self.key(config, device, variant)] = choice.as_dict()
        if self.path:
            self.save(self.path)

    def save(self, path: str) -> None:
        blob = {"version": self.VERSION, "entries": self.entries}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)


# ---------------------------------------------------------------------------
# Measurement + prediction
# ---------------------------------------------------------------------------


def median_timer(iters: int = 3, warmup: int = 1) -> Callable:
    """Default timer: median wall-clock of ``iters`` post-warmup calls.
    Tests inject a deterministic fake with the same signature."""

    def timer(fn: Callable[[], jax.Array]) -> float:
        for _ in range(warmup):
            jax.block_until_ready(fn())
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2]

    return timer


def predict_grouped_time(
    m: int, d: int, r: int, lnum: int, n_groups: int, tm: int,
    bytes_per_elt: int = 4,
) -> float:
    """Roofline estimate for one grouped dispatch at tile ``tm``.

    FLOPs: two (tm, d) x (d, r) / (tm, r) x (r, d) dots per (row-tile,
    layer) step over the PADDED row count — padding is real work, which is
    exactly why small tiles win at decode shape. Bytes: per step, the x
    tile in, the out tile read+written (layer accumulation), and one
    (d, r) + (r, d) adapter block gathered. The max of the two terms over
    the peak rates is the modeled step time."""
    m_pad = (m + tm - 1) // tm * tm + min(n_groups, m) * tm
    steps = (m_pad // tm) * lnum
    flops = 4.0 * m_pad * d * r * lnum
    tile_bytes = tm * d * bytes_per_elt
    pool_bytes = 2 * d * r * bytes_per_elt
    bytes_moved = steps * (3 * tile_bytes + pool_bytes)
    return max(flops / PEAK_FLOPS, bytes_moved / HBM_BW)


def _variant_dispatch(variant: str):
    """variant -> (pools builder, dispatch fn). The builder turns a float
    (a_pool, b_pool) pair into the variant's payload; the dispatch runs one
    grouped forward at (tm, grid_order)."""
    if variant == "grouped":
        def build(a_pool, b_pool):
            return (a_pool, b_pool)

        def run(x, pools, idx, tm, order):
            return O._grouped_rows(x, *pools, idx, tm, order)

    elif variant == "grouped_int8":
        from repro.core.lm_skiplora import quantize_int8

        def build(a_pool, b_pool):
            qa, sa = quantize_int8(a_pool)
            qb, sb = quantize_int8(b_pool)
            return (qa, sa, qb, sb)

        def run(x, pools, idx, tm, order):
            return O._grouped_rows_int8(x, *pools, idx, tm, order)

    elif variant in ("grouped_int4", "grouped_nf4"):
        kind = variant.split("_")[1]

        def build(a_pool, b_pool):
            qa, sa = Q.quantize_q4(a_pool, kind)
            qb, sb = Q.quantize_q4(b_pool, kind)
            return (qa, sa, qb, sb, Q.codebook(kind))

        def run(x, pools, idx, tm, order):
            return O._grouped_rows_q4(x, *pools, idx, tm, order)

    else:
        raise ValueError(f"unknown kernel variant {variant!r}")
    return build, run


def tune_grouped(
    x: jax.Array,
    a_pool: jax.Array,
    b_pool: jax.Array,
    idx: jax.Array,
    variant: str = "grouped",
    *,
    config: str,
    cache: Optional[AutotuneCache] = None,
    device: Optional[str] = None,
    tiles: Optional[Sequence[int]] = None,
    orders: Sequence[str] = GRID_ORDERS,
    timer: Optional[Callable] = None,
) -> Choice:
    """Pick (tm, grid_order) for one variant at one batch shape.

    x: (L, M, D) rows; pools (N, L, D, R) / (N, L, R, D) float — the builder
    quantises them for int8/q4 variants. Every candidate is timed on the
    real dispatch; the hand-picked default (K.TM, "ml") is always a
    candidate, so the winner is <= default by construction. A cache hit
    returns without timing anything."""
    device = device or device_kind()
    if cache is not None:
        hit = cache.get(config, device, variant)
        if hit is not None:
            return hit
    timer = timer or median_timer()
    build, run = _variant_dispatch(variant)
    pools = build(a_pool, b_pool)
    lnum, m, d = x.shape
    n, r = a_pool.shape[0], a_pool.shape[-1]
    g = int(min(n, m))
    tiles = tuple(tiles) if tiles is not None else tile_candidates(m, x.dtype)

    results = []  # (time_s, predicted_s, tm, order); tuple order breaks ties
    for tm in tiles:
        for order in orders:
            t = timer(lambda tm=tm, order=order: run(x, pools, idx, tm, order))
            p = predict_grouped_time(m, d, r, lnum, g, tm)
            results.append((t, p, tm, order))
    default_t = min(t for t, _, tm, order in results if tm == K.TM and order == "ml")
    best_t, best_p, best_tm, best_order = min(results)
    choice = Choice(
        tm=best_tm, grid_order=best_order, time_s=best_t,
        default_time_s=default_t, predicted_s=best_p,
    )
    if cache is not None:
        cache.put(config, device, variant, choice)
    return choice


def tune_decode_unroll(
    x: jax.Array,
    a_pool: jax.Array,
    b_pool: jax.Array,
    idx: jax.Array,
    *,
    tm: int,
    grid_order: str,
    steps: int = 16,
    candidates: Sequence[int] = UNROLL_CANDIDATES,
    timer: Optional[Callable] = None,
) -> tuple[int, float]:
    """Pick the decode-scan ``unroll`` by timing a scan-of-dispatches at
    decode shape — the same structure ``lm.decode_scan`` compiles, minus
    the backbone. Returns (unroll, time_s)."""
    timer = timer or median_timer()

    def make(unroll):
        @jax.jit
        def scanned(x, pools, idx):
            def step(carry, _):
                out = O._grouped_rows(carry, *pools, idx, tm, grid_order)
                return carry + out[None].astype(carry.dtype) * 0, out
            _, outs = jax.lax.scan(step, x, None, length=steps, unroll=unroll)
            return outs

        return scanned

    results = []
    for u in candidates:
        fn = make(u)
        t = timer(lambda fn=fn: fn(x, (a_pool, b_pool), idx))
        results.append((t, u))
    best_t, best_u = min(results)
    return best_u, best_t


def apply_choice(choice: Choice) -> None:
    """Install a tuned winner as the process-wide kernel default. Trace-time
    only: call before warmup, not under live traffic."""
    O.set_default_tile(tm=choice.tm, grid_order=choice.grid_order)


def tune_kv_block(
    cfg,
    *,
    config: str,
    seq: int = 64,
    batch: int = 4,
    candidates: Sequence[int] = KV_BLOCK_CANDIDATES,
    cache: Optional[AutotuneCache] = None,
    device: Optional[str] = None,
    timer: Optional[Callable] = None,
) -> Choice:
    """Pick the paged-KV block size by timing the pool round-trip the
    scheduler's prefix reuse actually dispatches: one ``publish`` (live row
    -> pool blocks) plus one ``gather_blocks`` (block tables -> admission
    layout) over a ``seq``-token prompt for ``batch`` rows.

    The winner rides the shared ``Choice``/``AutotuneCache`` machinery with
    the block size in the ``tm`` field (one schema for every tuned knob);
    ``apply_kv_block`` installs it via ``kv_pool.set_default_block``. The
    untuned ``DEFAULT_BLOCK`` is always a candidate, so tuned is never
    worse than untuned by construction."""
    from repro.core import kv_pool as KV

    device = device or device_kind()
    if cache is not None:
        hit = cache.get(config, device, "kv_block")
        if hit is not None:
            return hit
    timer = timer or median_timer()
    from repro.models.lm import init_serve_caches

    blocks = sorted(set(
        b for b in (*candidates, KV.DEFAULT_BLOCK) if seq % b == 0
    ))
    caches = init_serve_caches(cfg, 1, seq)
    results = []  # (time_s, block)
    for blk in blocks:
        per_row = seq // blk
        pool = KV.KVBlockPool(cfg, n_blocks=batch * per_row, block=blk)
        ids = pool.alloc(per_row)
        slots = list(range(per_row))
        tables = jnp.tile(jnp.asarray(ids, jnp.int32)[None], (batch, 1))

        def run(pool=pool, ids=ids, slots=slots, tables=tables, blk=blk):
            pool.publish(caches, 0, ids, slots)
            out = KV.gather_blocks(pool.data, tables, block=blk)
            return jax.tree.leaves(out)[0]

        results.append((timer(run), blk))
    default_t = min(t for t, blk in results if blk == KV.DEFAULT_BLOCK)
    best_t, best_blk = min(results)
    choice = Choice(
        tm=best_blk, grid_order="na", time_s=best_t, default_time_s=default_t,
    )
    if cache is not None:
        cache.put(config, device, "kv_block", choice)
    return choice


def apply_kv_block(choice: Choice) -> None:
    """Install a tuned KV block size as the process-wide pool default
    (``tm`` carries the block; see ``tune_kv_block``). Applies to pools
    built AFTER the call — existing pools keep their geometry."""
    from repro.core import kv_pool as KV

    KV.set_default_block(choice.tm)


# ---------------------------------------------------------------------------
# CLI smoke (CI quick tier): tiny sweep twice, assert the second run is all
# cache hits and both runs agree.
# ---------------------------------------------------------------------------


def _smoke_inputs(m: int = 8, d: int = 32, r: int = 4, lnum: int = 2, n: int = 4):
    key = jax.random.PRNGKey(0)
    kx, ka, kb = jax.random.split(key, 3)
    x = jax.random.normal(kx, (lnum, m, d), jnp.float32)
    a_pool = jax.random.normal(ka, (n, lnum, d, r), jnp.float32) * 0.1
    b_pool = jax.random.normal(kb, (n, lnum, r, d), jnp.float32) * 0.1
    idx = jnp.arange(m, dtype=jnp.int32) % n
    return x, a_pool, b_pool, idx


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--cache", default="/tmp/skiplora_autotune.json")
    ap.add_argument("--quick", action="store_true", help="tiny sweep (CI smoke)")
    ap.add_argument("--variant", default="grouped", help="kernel variant to tune")
    args = ap.parse_args()

    x, a_pool, b_pool, idx = _smoke_inputs()
    tiles = (8, 16, K.TM) if args.quick else None
    timer = median_timer(iters=2, warmup=1) if args.quick else None
    if os.path.exists(args.cache):
        os.unlink(args.cache)

    cache = AutotuneCache(args.cache)
    first = tune_grouped(
        x, a_pool, b_pool, idx, args.variant,
        config="smoke", cache=cache, tiles=tiles, timer=timer,
    )
    assert cache.misses == 1 and cache.hits == 0, (cache.hits, cache.misses)

    cache2 = AutotuneCache(args.cache)  # re-read from disk: warm
    second = tune_grouped(
        x, a_pool, b_pool, idx, args.variant,
        config="smoke", cache=cache2, tiles=tiles, timer=timer,
    )
    assert cache2.hits == 1 and cache2.misses == 0, (cache2.hits, cache2.misses)
    assert (second.tm, second.grid_order) == (first.tm, first.grid_order)
    assert second.source == "cache"
    print(
        f"autotune smoke OK: tm={first.tm} order={first.grid_order} "
        f"t={first.time_s * 1e3:.2f}ms (default {first.default_time_s * 1e3:.2f}ms), "
        f"warm run hit cache"
    )


if __name__ == "__main__":
    main()
