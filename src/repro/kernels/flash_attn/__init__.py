"""Flash attention (sliding-window + GQA + softcap) for populate/prefill."""

from repro.kernels.flash_attn.ops import flash_attention  # noqa: F401
