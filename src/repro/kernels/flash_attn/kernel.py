"""Pallas TPU flash attention: online-softmax over KV blocks in VMEM.

Forward-only fusion for the backbone's populate/prefill pass (the paper's
epoch-0 cost): never materialises the (S, S) score matrix. Supports causal
masking, gemma-style sliding windows (local layers), GQA (kv-head folding),
and gemma2 logit softcaps.

Grid (B*H, S/BQ, S/BK) with the KV axis innermost ("arbitrary"): the fp32
accumulator, running max m and normaliser l live in VMEM scratch and are
carried across KV steps; the output block is written on the last KV step.
Sliding windows make most KV blocks fully masked for large S — those steps
exit early via ``pl.when`` (block-level skipping; with BQ=BK=128 and window
1024, a 32k-prefill local layer touches ~9/256 of the KV blocks).

VMEM per step (BQ=BK=128, hd<=256, bf16): q/k/v blocks 3*64 KB + fp32 acc
128x256x4 = 128 KB + scores 64 KB << 16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams in newer releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

BQ = 128
BK = 128
NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_sc, l_sc, *, scale, window, softcap, s_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    q_start = qi * BQ
    k_start = ki * BK

    # Block-level reachability: causal + window (traced on grid indices).
    # Any query row in [q_start, q_start+BQ) can see key col c iff
    # c <= row and c > row - window.
    reachable = k_start <= q_start + BQ - 1
    if window > 0:  # static hyperparameter
        reachable = jnp.logical_and(
            reachable, k_start + BK - 1 >= q_start - (window - 1)
        )

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0]  # (BQ, hd)
        k = k_ref[0]  # (BK, hd)
        v = v_ref[0]  # (BK, hd)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                           # (BQ, BK)
        if softcap:
            scores = softcap * jnp.tanh(scores / softcap)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
        mask = cols <= rows
        if window > 0:
            mask &= cols > rows - window
        scores = jnp.where(mask, scores, NEG_INF)

        m_prev = m_sc[...]                                  # (BQ,)
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1))
        alpha = jnp.exp(m_prev - m_new)                     # (BQ,)
        p = jnp.exp(scores - m_new[:, None])                # (BQ, BK)
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_sc[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "scale", "interpret")
)
def flash_attention_fwd(
    q: jax.Array,   # (BH, S, hd) — batch*heads folded
    k: jax.Array,   # (BH, S, hd) — kv heads pre-broadcast to BH
    v: jax.Array,
    *,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    bh, s, hd = q.shape
    assert s % BQ == 0 and s % BK == 0, f"seq {s} must be a multiple of {BQ}"
    scale = scale if scale is not None else hd**-0.5
    grid = (bh, s // BQ, s // BK)
    kernel = functools.partial(
        _kernel, scale=scale, window=window, softcap=softcap, s_len=s
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BQ, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, BK, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, BK, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ, hd), jnp.float32),
            pltpu.VMEM((BQ,), jnp.float32),
            pltpu.VMEM((BQ,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
