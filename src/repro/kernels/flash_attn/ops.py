"""Public wrapper: framework-native layouts + GQA folding + interpret fallback.

Forward-only fusion (the populate/prefill pass is forward-only by
construction — the paper's whole point is that the backbone never runs a
backward). For full-train use, wrap with ``jax.checkpoint`` and let XLA
differentiate the reference path, or call the ref directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn import kernel as K


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(
    q: jax.Array,   # (B, H, S, hd)
    k: jax.Array,   # (B, Hkv, S, hd)
    v: jax.Array,   # (B, Hkv, S, hd)
    *,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
) -> jax.Array:
    """Causal (optionally sliding-window) attention, GQA-aware."""
    b, h, s, hd = q.shape
    hkv = k.shape[1]
    group = h // hkv
    # Fold GQA: repeat each KV head across its query group, then flatten
    # (B, H) into the kernel's leading grid axis.
    kf = jnp.repeat(k, group, axis=1).reshape(b * h, s, hd)
    vf = jnp.repeat(v, group, axis=1).reshape(b * h, s, hd)
    qf = q.reshape(b * h, s, hd)
    out = K.flash_attention_fwd(
        qf, kf, vf, window=window, softcap=softcap, scale=scale,
        interpret=_interpret(),
    )
    return out.reshape(b, h, s, hd)
