"""Paged KV-cache block gather: Pallas kernel + jnp oracle.

The paged KV pool (``core.kv_pool``) stores key/value blocks as
``(n_blocks, block, n_kv, head_dim)``; a request addresses its prefix
through an ordered *block table* of pool ids. The gather materialises a
batch of tables into contiguous per-row K/V — the admission path's
"zero prefill FLOPs" move: reused prefix keys are copied, never
recomputed.

Two implementations with one contract (bitwise equal — this is data
movement, not arithmetic, so there is nothing to drift):

  - ``paged_gather_ref``: ``jnp.take`` oracle. Fuses into the
    surrounding admission jit; the CPU/default path.
  - ``paged_gather``: Pallas kernel with the block table scalar-prefetched
    (``PrefetchScalarGridSpec``), so on TPU each grid step DMAs exactly
    one pool block HBM->VMEM with its index known before the body runs —
    the same trick the grouped skip-LoRA kernels use for slot tiling.
    Off-TPU it runs in interpret mode (tests assert kernel == oracle).

``models.attention.attn_decode_paged`` builds the block-table decode
variant on top of these.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def paged_gather_ref(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """Oracle: pool (NB, block, n_kv, hd) + tables (B, T) int32 ->
    (B, T * block, n_kv, hd). Table entries must be valid pool ids; rows
    that own fewer than T blocks pad with any valid id (the caller masks
    the padded positions out of attention)."""
    b, t = tables.shape
    nb, blk, nkv, hd = pool.shape
    out = jnp.take(pool, tables.reshape(-1), axis=0)
    return out.reshape(b, t * blk, nkv, hd)


def _gather_kernel(tbl_ref, pool_ref, out_ref):
    del tbl_ref  # consumed by the index maps; the body sees the gathered block
    out_ref[0, 0] = pool_ref[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_gather(
    pool: jax.Array,          # (NB, block, n_kv, hd)
    tables: jax.Array,        # (B, T) int32 pool block ids
    *,
    interpret: bool = False,
) -> jax.Array:
    """Pallas block-table gather; same contract as ``paged_gather_ref``.

    Grid = (rows, table slots); the table rides in as the scalar-prefetch
    operand so the input BlockSpec's index map selects pool block
    ``tables[b, j]`` for grid step (b, j) — one block copy per step, no
    dynamic indexing inside the body."""
    b, t = tables.shape
    nb, blk, nkv, hd = pool.shape
    d = nkv * hd
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, t),
        in_specs=[
            pl.BlockSpec((1, blk, d), lambda bi, ji, tbl: (tbl[bi, ji], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk, d), lambda bi, ji, tbl: (bi, ji, 0, 0)),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, t, blk, d), pool.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(tables.astype(jnp.int32), pool.reshape(nb, blk, d))
    return out.reshape(b, t * blk, nkv, hd)


def gather(pool: jax.Array, tables: jax.Array, *, use_kernel: bool = False) -> jax.Array:
    """Dispatch helper for the serve path: the Pallas kernel on real TPU,
    the fusing oracle everywhere else. Unlike the grouped skip-LoRA
    wrappers this does NOT fall back to interpret mode off-TPU — an
    interpreted per-block grid walk is orders of magnitude slower than
    the ``jnp.take`` oracle it is bitwise-equal to, and the admission
    dispatch is latency-critical. Interpret-mode kernel parity is covered
    by tests calling ``paged_gather(..., interpret=True)`` directly."""
    if use_kernel and not _interpret():
        return paged_gather(pool, tables)
    return paged_gather_ref(pool, tables)
