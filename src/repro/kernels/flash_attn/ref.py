"""Pure-jnp oracle for flash attention (causal, sliding window, GQA, softcap)."""

from __future__ import annotations

import jax.numpy as jnp
import jax

NEG_INF = -2.0e38


def flash_attention_ref(
    q: jnp.ndarray,    # (B, H, S, hd)
    k: jnp.ndarray,    # (B, Hkv, S, hd)
    v: jnp.ndarray,    # (B, Hkv, S, hd)
    *,
    window: int = 0,   # 0 -> full causal
    softcap: float = 0.0,
    scale: float | None = None,
) -> jnp.ndarray:
    b, h, s, hd = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = scale if scale is not None else hd**-0.5
    qg = q.reshape(b, hkv, group, s, hd)
    logits = jnp.einsum(
        "bngsh,bnth->bngst", (qg * scale), k, preferred_element_type=jnp.float32
    ).astype(jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    mask = kj <= qi
    if window > 0:
        mask &= kj > qi - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngst,bnth->bngsh", probs, v)
    return out.reshape(b, h, s, hd)
