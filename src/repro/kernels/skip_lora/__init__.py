"""Fused Skip-LoRA aggregation kernels (forward, backward, int8, grouped)."""

from repro.kernels.skip_lora.ops import (  # noqa: F401
    skip_lora_fused,
    skip_lora_fused_int8,
    skip_lora_grouped,
    skip_lora_grouped_int8,
    skip_lora_grouped_train,
    skip_lora_grouped_train_int8,
)
