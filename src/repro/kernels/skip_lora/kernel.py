"""Pallas TPU kernels for the fused Skip-LoRA aggregation.

Shapes: x (L, M, D) cached activations (M = batch*seq rows), a (L, D, R),
b (L, R, D), out (M, D). R is the LoRA rank (4..64), far below the 128x128
MXU tile — so the win is not MXU utilisation on the tiny contractions but
HBM traffic: each x tile is read exactly once across all L layers and the
(M, D) output is written once, instead of L round-trips.

Forward grid (m_tiles, L): the layer axis is the *inner, arbitrary* axis so
the fp32 output block stays resident in VMEM while layers accumulate into
it (out index_map ignores l -> block revisited, initialised at l == 0).

Backward grid (L, m_tiles): per-layer gA (D, R) / gB (R, D) blocks stay
resident while row tiles stream (accumulated over m, initialised at m == 0).

Grouped (multi-tenant serving) variants take a stacked adapter *pool*
(N, L, D, R) plus a per-row-tile slot index delivered by scalar prefetch:
rows are pre-grouped by adapter so each tile gathers exactly one (A, B)
layer block from the pool per grid step (BGMV-style). The int8 grouped
variant keeps the pool int8 in HBM and dequantises gathered blocks in VMEM.

VMEM budget per step (bf16, TM=128, D=8192 worst case among assigned archs):
x tile 2 MB + fp32 out tile 4 MB + A/B/z < 1.5 MB << 16 MB/core.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.skip_lora import quant as _Q

# jax renamed TPUCompilerParams -> CompilerParams in newer releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

#: Default row-tile size (MXU-aligned). Every kernel below takes ``tm`` as a
#: static parameter; this constant is only the untuned fallback — the
#: autotune harness (``kernels.autotune``) measures per-config winners and
#: threads them through ``ops`` (``ops.set_default_tile``). Valid tiles are
#: bounded below by the dtype's minimum sublane count on TPU (f32 8, bf16 16,
#: int8/uint8 32 — see ``autotune.tile_candidates``).
TM = 128


def _grouped_grid(grid_order: str, m_tiles: int, lnum: int):
    """Grid + index-map convention for the grouped forwards.

    ``"ml"`` (default): rows outer, layers inner — the fp32 out block stays
    VMEM-resident while layers accumulate (one write-back per row tile).
    ``"lm"``: layers outer, rows inner — each (A, B) layer block is gathered
    once per (slot, layer) instead of once per (tile, layer), at the price
    of revisiting out blocks across the outer axis (flush + re-fetch per
    layer). Which wins is a bandwidth-vs-revisit trade the autotuner
    measures per config. Returns (grid, wrap, l_axis, semantics) where
    ``wrap`` lifts an index map written in (mi, li, g) convention into the
    grid's argument order."""
    if grid_order == "ml":
        return (
            (m_tiles, lnum),
            lambda f: (lambda mi, li, g: f(mi, li, g)),
            1,
            ("parallel", "arbitrary"),
        )
    if grid_order == "lm":
        # Out blocks are revisited across the OUTER axis, so neither axis
        # may be reordered: both arbitrary.
        return (
            (lnum, m_tiles),
            lambda f: (lambda li, mi, g: f(mi, li, g)),
            0,
            ("arbitrary", "arbitrary"),
        )
    raise ValueError(f"unknown grid_order {grid_order!r} (want 'ml' or 'lm')")


# ---------------------------------------------------------------------------
# Forward: out[m, :] = sum_l x[l, m, :] @ a[l] @ b[l]
# ---------------------------------------------------------------------------


def _fwd_kernel(x_ref, a_ref, b_ref, o_ref):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]  # (TM, D)
    a = a_ref[0].astype(x.dtype)  # (D, R)
    b = b_ref[0].astype(x.dtype)  # (R, D)
    z = jnp.dot(x, a, preferred_element_type=jnp.float32).astype(x.dtype)
    o_ref[...] += jnp.dot(z, b, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def skip_lora_fwd(
    x: jax.Array, a: jax.Array, b: jax.Array, *, tm: int = TM, interpret: bool = False
) -> jax.Array:
    lnum, m, d = x.shape
    r = a.shape[-1]
    assert m % tm == 0, f"rows {m} must be padded to a multiple of {tm}"
    grid = (m // tm, lnum)
    out = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, d), lambda mi, li: (li, mi, 0)),
            pl.BlockSpec((1, d, r), lambda mi, li: (li, 0, 0)),
            pl.BlockSpec((1, r, d), lambda mi, li: (li, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, d), lambda mi, li: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, a, b)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Backward: gA[l] = x[l]^T (g b[l]^T);  gB[l] = (x[l] a[l])^T g
# ---------------------------------------------------------------------------


def _bwd_kernel(x_ref, a_ref, b_ref, g_ref, ga_ref, gb_ref):
    mi = pl.program_id(1)

    @pl.when(mi == 0)
    def _init():
        ga_ref[...] = jnp.zeros_like(ga_ref)
        gb_ref[...] = jnp.zeros_like(gb_ref)

    x = x_ref[0]                    # (TM, D)
    g = g_ref[...]                  # (TM, D)
    a = a_ref[0].astype(x.dtype)    # (D, R)
    b = b_ref[0].astype(x.dtype)    # (R, D)
    z = jnp.dot(x, a, preferred_element_type=jnp.float32).astype(x.dtype)   # (TM, R)
    gz = jnp.dot(g, b.T, preferred_element_type=jnp.float32).astype(x.dtype)  # (TM, R)
    ga_ref[0] += jnp.dot(x.T, gz, preferred_element_type=jnp.float32)
    gb_ref[0] += jnp.dot(z.T, g, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def skip_lora_bwd(
    x: jax.Array, a: jax.Array, b: jax.Array, g: jax.Array, *, tm: int = TM,
    interpret: bool = False
) -> tuple[jax.Array, jax.Array]:
    lnum, m, d = x.shape
    r = a.shape[-1]
    assert m % tm == 0
    grid = (lnum, m // tm)
    ga, gb = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, d), lambda li, mi: (li, mi, 0)),
            pl.BlockSpec((1, d, r), lambda li, mi: (li, 0, 0)),
            pl.BlockSpec((1, r, d), lambda li, mi: (li, 0, 0)),
            pl.BlockSpec((tm, d), lambda li, mi: (mi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d, r), lambda li, mi: (li, 0, 0)),
            pl.BlockSpec((1, r, d), lambda li, mi: (li, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lnum, d, r), jnp.float32),
            jax.ShapeDtypeStruct((lnum, r, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, a, b, g)
    return ga, gb


# ---------------------------------------------------------------------------
# int8 forward: x[l] = q[l] * scale[l][:, None], dequant fused into the
# A-projection so the int8 cache never round-trips through HBM as bf16.
# ---------------------------------------------------------------------------


def _grouped_fwd_kernel(l_axis, g_ref, x_ref, a_ref, b_ref, o_ref):
    del g_ref  # consumed by the index_maps; the body sees gathered blocks
    l = pl.program_id(l_axis)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]                        # (TM, D)
    a = a_ref[0, 0].astype(x.dtype)     # (D, R)
    b = b_ref[0, 0].astype(x.dtype)     # (R, D)
    z = jnp.dot(x, a, preferred_element_type=jnp.float32).astype(x.dtype)
    o_ref[...] += jnp.dot(z, b, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm", "grid_order", "interpret"))
def skip_lora_grouped_fwd(
    x: jax.Array,            # (L, M, D) rows pre-grouped by adapter
    a_pool: jax.Array,       # (N, L, D, R) stacked adapter pool
    b_pool: jax.Array,       # (N, L, R, D)
    tile_adapter: jax.Array,  # (M // tm,) int32 adapter slot per row tile
    *,
    tm: int = TM,
    grid_order: str = "ml",
    interpret: bool = False,
) -> jax.Array:
    """BGMV-style grouped forward: out[m] = sum_l x[l,m] @ A[g,l] @ B[g,l]
    where g = tile_adapter[m // tm]. The caller groups rows so every row
    tile maps to exactly ONE adapter slot; the tile->slot map rides in as a
    scalar-prefetch operand so each (A, B) layer block is gathered from the
    pool into VMEM once per tile — HBM traffic is the *active* adapters'
    blocks, never the whole pool (DESIGN.md §6). ``tm``/``grid_order`` are
    the autotuned tile parameters (``kernels.autotune``)."""
    lnum, m, d = x.shape
    n, _, _, r = a_pool.shape
    assert m % tm == 0, f"rows {m} must be padded to a multiple of {tm}"
    grid, wrap, l_axis, semantics = _grouped_grid(grid_order, m // tm, lnum)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, d), wrap(lambda mi, li, g: (li, mi, 0))),
            pl.BlockSpec((1, 1, d, r), wrap(lambda mi, li, g: (g[mi], li, 0, 0))),
            pl.BlockSpec((1, 1, r, d), wrap(lambda mi, li, g: (g[mi], li, 0, 0))),
        ],
        out_specs=pl.BlockSpec((tm, d), wrap(lambda mi, li, g: (mi, 0))),
    )
    out = pl.pallas_call(
        functools.partial(_grouped_fwd_kernel, l_axis),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        compiler_params=_CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(tile_adapter, x, a_pool, b_pool)
    return out.astype(x.dtype)


def _grouped_fwd_int8_kernel(l_axis, g_ref, x_ref, qa_ref, sa_ref, qb_ref, sb_ref, o_ref):
    del g_ref
    l = pl.program_id(l_axis)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]                                             # (TM, D)
    a = (qa_ref[0, 0].astype(jnp.float32) * sa_ref[0, 0][:, None]).astype(x.dtype)
    b = (qb_ref[0, 0].astype(jnp.float32) * sb_ref[0, 0][:, None]).astype(x.dtype)
    z = jnp.dot(x, a, preferred_element_type=jnp.float32).astype(x.dtype)
    o_ref[...] += jnp.dot(z, b, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm", "grid_order", "interpret"))
def skip_lora_grouped_fwd_int8(
    x: jax.Array,             # (L, M, D) rows pre-grouped by adapter
    qa: jax.Array,            # (N, L, D, R) int8 pool payload
    sa: jax.Array,            # (N, L, D) fp32 rowwise scales for A
    qb: jax.Array,            # (N, L, R, D) int8
    sb: jax.Array,            # (N, L, R) fp32 rowwise scales for B
    tile_adapter: jax.Array,  # (M // tm,) int32
    *,
    tm: int = TM,
    grid_order: str = "ml",
    interpret: bool = False,
) -> jax.Array:
    """Grouped forward over an int8-compressed adapter pool. The pool stays
    int8 in HBM (4x the resident tenants of bf16); dequant happens on the
    gathered per-tile blocks in VMEM, so the full-precision adapters are
    never materialised outside the kernel."""
    lnum, m, d = x.shape
    n, _, _, r = qa.shape
    assert m % tm == 0
    grid, wrap, l_axis, semantics = _grouped_grid(grid_order, m // tm, lnum)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, d), wrap(lambda mi, li, g: (li, mi, 0))),
            pl.BlockSpec((1, 1, d, r), wrap(lambda mi, li, g: (g[mi], li, 0, 0))),
            pl.BlockSpec((1, 1, d), wrap(lambda mi, li, g: (g[mi], li, 0))),
            pl.BlockSpec((1, 1, r, d), wrap(lambda mi, li, g: (g[mi], li, 0, 0))),
            pl.BlockSpec((1, 1, r), wrap(lambda mi, li, g: (g[mi], li, 0))),
        ],
        out_specs=pl.BlockSpec((tm, d), wrap(lambda mi, li, g: (mi, 0))),
    )
    out = pl.pallas_call(
        functools.partial(_grouped_fwd_int8_kernel, l_axis),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        compiler_params=_CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(tile_adapter, x, qa, sa, qb, sb)
    return out.astype(x.dtype)


def _grouped_fwd_q4_kernel(
    l_axis, g_ref, x_ref, qa_ref, sa_ref, qb_ref, sb_ref, code_ref, o_ref
):
    del g_ref
    l = pl.program_id(l_axis)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]                                             # (TM, D)
    code = code_ref[0]                                       # (16,) fp32
    # Unpack nibbles + codebook-dequant the gathered blocks in VMEM: the
    # pool payload crosses HBM packed (two 4-bit indices per byte).
    a_nib = _Q.unpack_nibbles(qa_ref[0, 0])                  # (D, R)
    b_nib = _Q.unpack_nibbles(qb_ref[0, 0])                  # (R, D)
    a = (
        jnp.take(code, a_nib.astype(jnp.int32), axis=0)
        * sa_ref[0, 0][:, None]
    ).astype(x.dtype)
    b = (
        jnp.take(code, b_nib.astype(jnp.int32), axis=0)
        * sb_ref[0, 0][:, None]
    ).astype(x.dtype)
    z = jnp.dot(x, a, preferred_element_type=jnp.float32).astype(x.dtype)
    o_ref[...] += jnp.dot(z, b, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm", "grid_order", "interpret"))
def skip_lora_grouped_fwd_q4(
    x: jax.Array,             # (L, M, D) rows pre-grouped by adapter
    qa: jax.Array,            # (N, L, D, R // 2) packed 4-bit pool payload
    sa: jax.Array,            # (N, L, D) fp32 rowwise absmax scales for A
    qb: jax.Array,            # (N, L, R, D // 2) packed 4-bit
    sb: jax.Array,            # (N, L, R) fp32 rowwise absmax scales for B
    code: jax.Array,          # (1, 16) fp32 codebook (int4 or nf4 levels)
    tile_adapter: jax.Array,  # (M // tm,) int32
    *,
    tm: int = TM,
    grid_order: str = "ml",
    interpret: bool = False,
) -> jax.Array:
    """Grouped forward over a packed-4-bit adapter pool (int4 or nf4 — the
    codebook decides, see ``kernels.skip_lora.quant``). The payload stays
    packed in HBM (8x the resident tenants of bf16, 2x int8); nibble unpack
    + codebook dequant happen on the gathered per-tile blocks in VMEM."""
    lnum, m, d = x.shape
    n, _, _, rp = qa.shape
    r = 2 * rp
    assert m % tm == 0
    grid, wrap, l_axis, semantics = _grouped_grid(grid_order, m // tm, lnum)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, d), wrap(lambda mi, li, g: (li, mi, 0))),
            pl.BlockSpec((1, 1, d, rp), wrap(lambda mi, li, g: (g[mi], li, 0, 0))),
            pl.BlockSpec((1, 1, d), wrap(lambda mi, li, g: (g[mi], li, 0))),
            pl.BlockSpec((1, 1, r, d // 2), wrap(lambda mi, li, g: (g[mi], li, 0, 0))),
            pl.BlockSpec((1, 1, r), wrap(lambda mi, li, g: (g[mi], li, 0))),
            pl.BlockSpec((1, 16), wrap(lambda mi, li, g: (0, 0))),
        ],
        out_specs=pl.BlockSpec((tm, d), wrap(lambda mi, li, g: (mi, 0))),
    )
    out = pl.pallas_call(
        functools.partial(_grouped_fwd_q4_kernel, l_axis),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        compiler_params=_CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(tile_adapter, x, qa, sa, qb, sb, code)
    return out.astype(x.dtype)


def _grouped_fwd_actint8_kernel(g_ref, q_ref, s_ref, a_ref, b_ref, o_ref):
    del g_ref
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0].astype(jnp.float32)              # (TM, D)
    s = s_ref[0][:, None]                         # (TM, 1) fp32
    x = (q * s).astype(jnp.bfloat16)
    a = a_ref[0, 0].astype(jnp.bfloat16)          # (D, R) gathered from pool
    b = b_ref[0, 0].astype(jnp.bfloat16)          # (R, D)
    z = jnp.dot(x, a, preferred_element_type=jnp.float32).astype(jnp.bfloat16)
    o_ref[...] += jnp.dot(z, b, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def skip_lora_grouped_fwd_actint8(
    q: jax.Array,             # (L, M, D) int8 rows pre-grouped by adapter
    scale: jax.Array,         # (L, M) fp32 per-row dequant scales
    a_pool: jax.Array,        # (N, L, D, R) float adapter pool
    b_pool: jax.Array,        # (N, L, R, D)
    tile_adapter: jax.Array,  # (M // tm,) int32
    *,
    tm: int = TM,
    interpret: bool = False,
) -> jax.Array:
    """Grouped forward over an int8-compressed *activation* cache (the
    training-side mirror of ``skip_lora_grouped_fwd_int8``, whose int8 side
    is the pool). Rows stay int8 in HBM; dequant is fused into the
    A-projection per gathered tile, so the raw cache payload feeds the fleet
    trainer without ever materialising bf16 activations outside the kernel."""
    lnum, m, d = q.shape
    n, _, _, r = a_pool.shape
    assert m % tm == 0
    grid = (m // tm, lnum)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, d), lambda mi, li, g: (li, mi, 0)),
            pl.BlockSpec((1, tm), lambda mi, li, g: (li, mi)),
            pl.BlockSpec((1, 1, d, r), lambda mi, li, g: (g[mi], li, 0, 0)),
            pl.BlockSpec((1, 1, r, d), lambda mi, li, g: (g[mi], li, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, d), lambda mi, li, g: (mi, 0)),
    )
    out = pl.pallas_call(
        _grouped_fwd_actint8_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(tile_adapter, q, scale, a_pool, b_pool)
    return out.astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# Grouped backward: per-adapter gA[n] / gB[n] via the same sort-by-slot
# segment tiling as the forward. Rows are pre-grouped so ``tile_adapter`` is
# non-decreasing; for a fixed layer each (slot, layer) output block is
# therefore visited in exactly ONE contiguous run of row tiles — it stays
# VMEM-resident across the run (zero-initialised on first visit, detected by
# comparing the tile's slot with its predecessor's) and flushes once when
# the slot changes. Slots with no rows are never visited; the ops wrapper
# masks their (uninitialised) blocks to zero.
# ---------------------------------------------------------------------------


def _grouped_bwd_kernel(g_ref, x_ref, a_ref, b_ref, gy_ref, ga_ref, gb_ref):
    mi = pl.program_id(1)
    cur = g_ref[mi]
    prev = g_ref[jnp.maximum(mi - 1, 0)]
    first_visit = jnp.logical_or(mi == 0, cur != prev)

    @pl.when(first_visit)
    def _init():
        ga_ref[...] = jnp.zeros_like(ga_ref)
        gb_ref[...] = jnp.zeros_like(gb_ref)

    x = x_ref[0]                        # (TM, D)
    gy = gy_ref[...].astype(x.dtype)    # (TM, D)
    a = a_ref[0, 0].astype(x.dtype)     # (D, R)
    b = b_ref[0, 0].astype(x.dtype)     # (R, D)
    z = jnp.dot(x, a, preferred_element_type=jnp.float32).astype(x.dtype)     # (TM, R)
    gz = jnp.dot(gy, b.T, preferred_element_type=jnp.float32).astype(x.dtype)  # (TM, R)
    ga_ref[0, 0] += jnp.dot(x.T, gz, preferred_element_type=jnp.float32)
    gb_ref[0, 0] += jnp.dot(z.T, gy, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def skip_lora_grouped_bwd(
    x: jax.Array,             # (L, M, D) rows pre-grouped by adapter
    a_pool: jax.Array,        # (N, L, D, R)
    b_pool: jax.Array,        # (N, L, R, D)
    g: jax.Array,             # (M, D) output cotangent, grouped row layout
    tile_adapter: jax.Array,  # (M // tm,) int32, non-decreasing
    *,
    tm: int = TM,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fleet backward: gA[n,l] = sum_{m in group n} x[l,m]^T (g[m] B[n,l]^T),
    gB[n,l] = (x[l,m] A[n,l])^T g[m]. Grid (L, m_tiles) with the row axis
    inner so each per-(slot, layer) gradient block accumulates VMEM-resident
    over its contiguous tile run (rows sorted by slot). Empty slots are never
    visited — callers mask them (``ops._grouped_rows_train``)."""
    lnum, m, d = x.shape
    n, _, _, r = a_pool.shape
    assert m % tm == 0
    grid = (lnum, m // tm)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, d), lambda li, mi, g: (li, mi, 0)),
            pl.BlockSpec((1, 1, d, r), lambda li, mi, g: (g[mi], li, 0, 0)),
            pl.BlockSpec((1, 1, r, d), lambda li, mi, g: (g[mi], li, 0, 0)),
            pl.BlockSpec((tm, d), lambda li, mi, g: (mi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, d, r), lambda li, mi, g: (g[mi], li, 0, 0)),
            pl.BlockSpec((1, 1, r, d), lambda li, mi, g: (g[mi], li, 0, 0)),
        ],
    )
    ga, gb = pl.pallas_call(
        _grouped_bwd_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, lnum, d, r), jnp.float32),
            jax.ShapeDtypeStruct((n, lnum, r, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(tile_adapter, x, a_pool, b_pool, g)
    return ga, gb


def _fwd_int8_kernel(q_ref, s_ref, a_ref, b_ref, o_ref):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0].astype(jnp.float32)          # (TM, D)
    s = s_ref[0][:, None]                     # (TM, 1) fp32
    x = (q * s).astype(jnp.bfloat16)
    a = a_ref[0].astype(jnp.bfloat16)
    b = b_ref[0].astype(jnp.bfloat16)
    z = jnp.dot(x, a, preferred_element_type=jnp.float32).astype(jnp.bfloat16)
    o_ref[...] += jnp.dot(z, b, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def skip_lora_fwd_int8(
    q: jax.Array, scale: jax.Array, a: jax.Array, b: jax.Array, *, tm: int = TM,
    interpret: bool = False
) -> jax.Array:
    lnum, m, d = q.shape
    r = a.shape[-1]
    assert m % tm == 0
    grid = (m // tm, lnum)
    out = pl.pallas_call(
        _fwd_int8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, d), lambda mi, li: (li, mi, 0)),
            pl.BlockSpec((1, tm), lambda mi, li: (li, mi)),
            pl.BlockSpec((1, d, r), lambda mi, li: (li, 0, 0)),
            pl.BlockSpec((1, r, d), lambda mi, li: (li, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, d), lambda mi, li: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, scale, a, b)
    return out.astype(jnp.bfloat16)
