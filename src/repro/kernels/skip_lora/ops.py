"""jit'd public wrappers for the fused Skip-LoRA kernels.

``skip_lora_fused`` takes the framework-native layouts — acts (L, B, S, D),
adapters (L, D, R) / (L, R, D) — flattens rows, pads to the kernel's row
tile, dispatches the Pallas kernel (interpret mode off-TPU), and wires the
fused backward through ``jax.custom_vjp``. Cached activations are constants
in the fine-tune loop, so their cotangent is a symbolic zero (dropped by
DCE); only (gA, gB) are ever computed — exactly the paper's Table-1
``LoRA_yw`` compute type.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.skip_lora import kernel as K


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x: jax.Array, tm: int) -> tuple[jax.Array, int]:
    m = x.shape[1]
    pad = (-m) % tm
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x, m


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _skip_lora_rows(x: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """x: (L, M, D) -> (M, D). Differentiable in (a, b); x treated as data."""
    xp, m = _pad_rows(x, K.TM)
    out = K.skip_lora_fwd(xp, a, b, interpret=_interpret())
    return out[:m]


def _fwd(x, a, b):
    return _skip_lora_rows(x, a, b), (x, a, b)


def _bwd(res, g):
    x, a, b = res
    xp, m = _pad_rows(x, K.TM)
    gp = jnp.pad(g, ((0, (-m) % K.TM), (0, 0))).astype(x.dtype)
    ga, gb = K.skip_lora_bwd(xp, a, b, gp, interpret=_interpret())
    # Cached activations are frozen-backbone constants: zero cotangent
    # (symbolic; DCE'd when unused).
    return jnp.zeros_like(x), ga.astype(a.dtype), gb.astype(b.dtype)


_skip_lora_rows.defvjp(_fwd, _bwd)


def skip_lora_fused(acts: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """Fused sum_l acts[l] @ a[l] @ b[l].

    acts: (L, B, S, D); a: (L, D, R); b: (L, R, D) -> (B, S, D).
    """
    l, bsz, s, d = acts.shape
    x = acts.reshape(l, bsz * s, d)
    out = _skip_lora_rows(x, a, b)
    return out.reshape(bsz, s, d)


def skip_lora_fused_int8(
    q: jax.Array, scale: jax.Array, a: jax.Array, b: jax.Array
) -> jax.Array:
    """int8-cache variant (dequant fused). q: (L,B,S,D) int8; scale (L,B,S)."""
    l, bsz, s, d = q.shape
    qr = q.reshape(l, bsz * s, d)
    sr = scale.reshape(l, bsz * s)
    pad = (-qr.shape[1]) % K.TM
    m = qr.shape[1]
    if pad:
        qr = jnp.pad(qr, ((0, 0), (0, pad), (0, 0)))
        sr = jnp.pad(sr, ((0, 0), (0, pad)))
    out = K.skip_lora_fwd_int8(qr, sr, a, b, interpret=_interpret())
    return out[:m].reshape(bsz, s, d)
