"""jit'd public wrappers for the fused Skip-LoRA kernels.

``skip_lora_fused`` takes the framework-native layouts — acts (L, B, S, D),
adapters (L, D, R) / (L, R, D) — flattens rows, pads to the kernel's row
tile, dispatches the Pallas kernel (interpret mode off-TPU), and wires the
fused backward through ``jax.custom_vjp``. Cached activations are constants
in the fine-tune loop, so their cotangent is a symbolic zero (dropped by
DCE); only (gA, gB) are ever computed — exactly the paper's Table-1
``LoRA_yw`` compute type.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.skip_lora import kernel as K


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x: jax.Array, tm: int) -> tuple[jax.Array, int]:
    m = x.shape[1]
    pad = (-m) % tm
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x, m


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _skip_lora_rows(x: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """x: (L, M, D) -> (M, D). Differentiable in (a, b); x treated as data."""
    xp, m = _pad_rows(x, K.TM)
    out = K.skip_lora_fwd(xp, a, b, interpret=_interpret())
    return out[:m]


def _fwd(x, a, b):
    return _skip_lora_rows(x, a, b), (x, a, b)


def _bwd(res, g):
    x, a, b = res
    xp, m = _pad_rows(x, K.TM)
    gp = jnp.pad(g, ((0, (-m) % K.TM), (0, 0))).astype(x.dtype)
    ga, gb = K.skip_lora_bwd(xp, a, b, gp, interpret=_interpret())
    # Cached activations are frozen-backbone constants: zero cotangent
    # (symbolic; DCE'd when unused).
    return jnp.zeros_like(x), ga.astype(a.dtype), gb.astype(b.dtype)


_skip_lora_rows.defvjp(_fwd, _bwd)


def skip_lora_fused(acts: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """Fused sum_l acts[l] @ a[l] @ b[l].

    acts: (L, B, S, D); a: (L, D, R); b: (L, R, D) -> (B, S, D).
    """
    l, bsz, s, d = acts.shape
    x = acts.reshape(l, bsz * s, d)
    out = _skip_lora_rows(x, a, b)
    return out.reshape(bsz, s, d)


def _pad_rows_int8(q: jax.Array, s: jax.Array) -> tuple[jax.Array, jax.Array, int]:
    m = q.shape[1]
    pad = (-m) % K.TM
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0)))
        s = jnp.pad(s, ((0, 0), (0, pad)))
    return q, s, m


@jax.custom_vjp
def _skip_lora_rows_int8(q: jax.Array, s: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """q: (L, M, D) int8, s: (L, M) fp32 -> (M, D) bf16. Dequant stays fused
    in the kernel; differentiable in (a, b) only (the cache is data)."""
    qp, sp, m = _pad_rows_int8(q, s)
    out = K.skip_lora_fwd_int8(qp, sp, a, b, interpret=_interpret())
    return out[:m]


def _int8_fwd(q, s, a, b):
    return _skip_lora_rows_int8(q, s, a, b), (q, s, a, b)


def _int8_bwd(res, g):
    q, s, a, b = res
    # Adapter grads need the dequantised activations once; the forward never
    # materialises them (dequant is fused), so this is the only bf16 copy.
    x = (q.astype(jnp.float32) * s[..., None]).astype(jnp.bfloat16)
    xp, m = _pad_rows(x, K.TM)
    gp = jnp.pad(g, ((0, (-m) % K.TM), (0, 0))).astype(x.dtype)
    ga, gb = K.skip_lora_bwd(xp, a, b, gp, interpret=_interpret())
    # int8 payload / fp32 scales are cache constants: symbolic-zero cotangents.
    zeros_q = np.zeros(q.shape, jax.dtypes.float0)
    return zeros_q, jnp.zeros_like(s), ga.astype(a.dtype), gb.astype(b.dtype)


_skip_lora_rows_int8.defvjp(_int8_fwd, _int8_bwd)


def skip_lora_fused_int8(
    q: jax.Array, scale: jax.Array, a: jax.Array, b: jax.Array
) -> jax.Array:
    """int8-cache variant (dequant fused). q: (L,B,S,D) int8; scale (L,B,S)."""
    l, bsz, s, d = q.shape
    qr = q.reshape(l, bsz * s, d)
    sr = scale.reshape(l, bsz * s)
    out = _skip_lora_rows_int8(qr, sr, a, b)
    return out.reshape(bsz, s, d)
