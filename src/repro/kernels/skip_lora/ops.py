"""jit'd public wrappers for the fused Skip-LoRA kernels.

``skip_lora_fused`` takes the framework-native layouts — acts (L, B, S, D),
adapters (L, D, R) / (L, R, D) — flattens rows, pads to the kernel's row
tile, dispatches the Pallas kernel (interpret mode off-TPU), and wires the
fused backward through ``jax.custom_vjp``. Cached activations are constants
in the fine-tune loop, so their cotangent is a symbolic zero (dropped by
DCE); only (gA, gB) are ever computed — exactly the paper's Table-1
``LoRA_yw`` compute type.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.skip_lora import kernel as K
from repro.kernels.skip_lora import quant as Q


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Autotuned kernel-parameter defaults. ``TM`` stopped being a constant in the
# kernel speed pass: every wrapper takes ``tm`` (row tile) and the grouped
# forwards ``grid_order`` explicitly, and ``None`` resolves against this
# process-wide default — which ``kernels.autotune.apply_choice`` installs
# from a measured per-(config, device, variant) winner. Resolution happens
# at TRACE time: change the default before warmup, not under live traffic
# (already-compiled dispatches keep the tile they were traced with).
# ---------------------------------------------------------------------------

_DEFAULT_TILE: dict = {"tm": None, "grid_order": None}


def set_default_tile(tm: Optional[int] = None, grid_order: Optional[str] = None) -> None:
    """Install autotuned kernel parameters as process-wide defaults
    (``None`` resets a field to the untuned fallback: ``K.TM`` / ``"ml"``)."""
    if tm is not None and (tm <= 0 or tm % 8):
        raise ValueError(f"row tile {tm} must be a positive multiple of 8")
    if grid_order not in (None, "ml", "lm"):
        raise ValueError(f"unknown grid_order {grid_order!r}")
    _DEFAULT_TILE["tm"] = tm
    _DEFAULT_TILE["grid_order"] = grid_order


def get_default_tile() -> tuple[int, str]:
    return (_DEFAULT_TILE["tm"] or K.TM, _DEFAULT_TILE["grid_order"] or "ml")


def _resolve_tm(tm: Optional[int]) -> int:
    return tm if tm is not None else get_default_tile()[0]


def _resolve_order(grid_order: Optional[str]) -> str:
    return grid_order if grid_order is not None else get_default_tile()[1]


# ---------------------------------------------------------------------------
# Shared row-tiling helpers: every wrapper (float / int8, single / grouped,
# forward / backward) pads the row axis to the kernel's TM tile and — for the
# backwards — feeds the padded layout to ``skip_lora_bwd``. These four
# operations used to be copied per variant; they live here once.
# ---------------------------------------------------------------------------


def _pad_axis(x: jax.Array, axis: int, tm: Optional[int] = None) -> jax.Array:
    """Zero-pad ``axis`` up to a multiple of the kernel row tile."""
    tm = _resolve_tm(tm)
    pad = (-x.shape[axis]) % tm
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pad_rows(x: jax.Array, tm: Optional[int] = None) -> tuple[jax.Array, int]:
    """(L, M, D) -> tile-padded rows + the original row count."""
    return _pad_axis(x, 1, tm), x.shape[1]


def _pad_rows_int8(
    q: jax.Array, s: jax.Array, tm: Optional[int] = None
) -> tuple[jax.Array, jax.Array, int]:
    """int8 payload (L, M, D) + scales (L, M), padded together."""
    return _pad_axis(q, 1, tm), _pad_axis(s, 1, tm), q.shape[1]


def _dequant_rows(q: jax.Array, s: jax.Array) -> jax.Array:
    """One-off dequantisation of int8 cache rows for the adapter backward —
    the forwards never materialise this (dequant stays fused in-kernel)."""
    return (q.astype(jnp.float32) * s[..., None]).astype(jnp.bfloat16)


def _adapter_grads(
    x: jax.Array, a: jax.Array, b: jax.Array, g: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Shared backward body: pad rows + cotangent, run the fused backward
    kernel, cast grads to the adapter dtypes. x: (L, M, D); g: (M, D)."""
    xp, m = _pad_rows(x)
    gp = _pad_axis(g.astype(x.dtype), 0)
    ga, gb = K.skip_lora_bwd(xp, a, b, gp, interpret=_interpret())
    return ga.astype(a.dtype), gb.astype(b.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _skip_lora_rows(x: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """x: (L, M, D) -> (M, D). Differentiable in (a, b); x treated as data."""
    xp, m = _pad_rows(x)
    out = K.skip_lora_fwd(xp, a, b, interpret=_interpret())
    return out[:m]


def _fwd(x, a, b):
    return _skip_lora_rows(x, a, b), (x, a, b)


def _bwd(res, g):
    x, a, b = res
    ga, gb = _adapter_grads(x, a, b, g)
    # Cached activations are frozen-backbone constants: zero cotangent
    # (symbolic; DCE'd when unused).
    return jnp.zeros_like(x), ga, gb


_skip_lora_rows.defvjp(_fwd, _bwd)


def skip_lora_fused(acts: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """Fused sum_l acts[l] @ a[l] @ b[l].

    acts: (L, B, S, D); a: (L, D, R); b: (L, R, D) -> (B, S, D).
    """
    l, bsz, s, d = acts.shape
    x = acts.reshape(l, bsz * s, d)
    out = _skip_lora_rows(x, a, b)
    return out.reshape(bsz, s, d)


@jax.custom_vjp
def _skip_lora_rows_int8(q: jax.Array, s: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """q: (L, M, D) int8, s: (L, M) fp32 -> (M, D) bf16. Dequant stays fused
    in the kernel; differentiable in (a, b) only (the cache is data)."""
    qp, sp, m = _pad_rows_int8(q, s)
    out = K.skip_lora_fwd_int8(qp, sp, a, b, interpret=_interpret())
    return out[:m]


def _int8_fwd(q, s, a, b):
    return _skip_lora_rows_int8(q, s, a, b), (q, s, a, b)


def _int8_bwd(res, g):
    q, s, a, b = res
    # Adapter grads need the dequantised activations once; the forward never
    # materialises them (dequant is fused), so this is the only bf16 copy.
    ga, gb = _adapter_grads(_dequant_rows(q, s), a, b, g)
    # int8 payload / fp32 scales are cache constants: symbolic-zero cotangents.
    zeros_q = np.zeros(q.shape, jax.dtypes.float0)
    return zeros_q, jnp.zeros_like(s), ga, gb


_skip_lora_rows_int8.defvjp(_int8_fwd, _int8_bwd)


def skip_lora_fused_int8(
    q: jax.Array, scale: jax.Array, a: jax.Array, b: jax.Array
) -> jax.Array:
    """int8-cache variant (dequant fused). q: (L,B,S,D) int8; scale (L,B,S)."""
    l, bsz, s, d = q.shape
    qr = q.reshape(l, bsz * s, d)
    sr = scale.reshape(l, bsz * s)
    out = _skip_lora_rows_int8(qr, sr, a, b)
    return out.reshape(bsz, s, d)


# ---------------------------------------------------------------------------
# Grouped multi-adapter serving path (BGMV-style)
# ---------------------------------------------------------------------------
#
# The kernel wants rows pre-grouped so every TM-row tile maps to exactly one
# adapter slot. Group sizes are data-dependent (whatever mix of tenants the
# batch carries), so the wrapper sorts rows by slot and pads each group to a
# tile boundary inside a statically-sized buffer. A batch of M rows touches
# at most G = min(N, M) distinct slots, and sum_g ceil(c_g/TM)*TM
# <= M + G*(TM-1), so capacity ceil(M/TM)*TM + G*TM always fits — the
# padded buffer scales with the *batch's* possible group count, never the
# pool size. Padding rows are zero (contribute zero output) and are never
# gathered back.


def _grouping_plan(idx: jax.Array, n_adapters: int, m: int, tm: Optional[int] = None):
    """Row permutation + tile->slot map for grouped dispatch (all jittable).

    Returns (dest_orig (M,) padded-buffer position per original row,
    tile_adapter (m_pad//tm,) int32, m_pad). ``tm`` is the row tile the
    dispatch will use (None -> the process default, see ``set_default_tile``)."""
    tm = _resolve_tm(tm)
    m_pad = (m + tm - 1) // tm * tm + min(n_adapters, m) * tm
    counts = jnp.bincount(idx, length=n_adapters)             # (N,)
    counts_cum_ex = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
    )
    padded = (counts + tm - 1) // tm * tm                     # (N,) tile-aligned
    starts = jnp.concatenate(
        [jnp.zeros((1,), padded.dtype), jnp.cumsum(padded)[:-1]]
    )
    order = jnp.argsort(idx)                                  # stable
    g_sorted = idx[order]
    within = jnp.arange(m) - counts_cum_ex[g_sorted]
    dest_sorted = starts[g_sorted] + within                   # (M,)
    dest_orig = jnp.zeros((m,), dest_sorted.dtype).at[order].set(dest_sorted)
    tile_cum = jnp.cumsum(padded // tm)
    tile_adapter = jnp.searchsorted(
        tile_cum, jnp.arange(m_pad // tm), side="right"
    )
    # Slack tiles past the last group alias slot N-1; their rows are zero.
    tile_adapter = jnp.clip(tile_adapter, 0, n_adapters - 1).astype(jnp.int32)
    return dest_orig, tile_adapter, m_pad


def _grouped_scatter(arr: jax.Array, dest: jax.Array, m_pad: int, axis: int) -> jax.Array:
    """Scatter rows into the grouped padded layout along ``axis`` (padding
    rows stay zero — they contribute zero output and are never gathered
    back). Shared by every grouped forward and backward wrapper."""
    shape = list(arr.shape)
    shape[axis] = m_pad
    zeros = jnp.zeros(tuple(shape), arr.dtype)
    if axis == 0:
        return zeros.at[dest].set(arr)
    return zeros.at[:, dest].set(arr)


def _grouped_rows(
    x: jax.Array, a_pool: jax.Array, b_pool: jax.Array, idx: jax.Array,
    tm: Optional[int] = None, grid_order: Optional[str] = None,
) -> jax.Array:
    tm, grid_order = _resolve_tm(tm), _resolve_order(grid_order)
    dest, tile_adapter, m_pad = _grouping_plan(idx, a_pool.shape[0], x.shape[1], tm)
    xg = _grouped_scatter(x, dest, m_pad, 1)
    out = K.skip_lora_grouped_fwd(
        xg, a_pool, b_pool, tile_adapter,
        tm=tm, grid_order=grid_order, interpret=_interpret(),
    )
    return out[dest]


def _grouped_rows_int8(
    x: jax.Array, qa: jax.Array, sa: jax.Array, qb: jax.Array, sb: jax.Array,
    idx: jax.Array,
    tm: Optional[int] = None, grid_order: Optional[str] = None,
) -> jax.Array:
    tm, grid_order = _resolve_tm(tm), _resolve_order(grid_order)
    dest, tile_adapter, m_pad = _grouping_plan(idx, qa.shape[0], x.shape[1], tm)
    xg = _grouped_scatter(x, dest, m_pad, 1)
    out = K.skip_lora_grouped_fwd_int8(
        xg, qa, sa, qb, sb, tile_adapter,
        tm=tm, grid_order=grid_order, interpret=_interpret(),
    )
    return out[dest]


def _grouped_rows_q4(
    x: jax.Array, qa: jax.Array, sa: jax.Array, qb: jax.Array, sb: jax.Array,
    code: jax.Array, idx: jax.Array,
    tm: Optional[int] = None, grid_order: Optional[str] = None,
) -> jax.Array:
    tm, grid_order = _resolve_tm(tm), _resolve_order(grid_order)
    dest, tile_adapter, m_pad = _grouping_plan(idx, qa.shape[0], x.shape[1], tm)
    xg = _grouped_scatter(x, dest, m_pad, 1)
    out = K.skip_lora_grouped_fwd_q4(
        xg, qa, sa, qb, sb, code.reshape(1, 16), tile_adapter,
        tm=tm, grid_order=grid_order, interpret=_interpret(),
    )
    return out[dest]


def skip_lora_grouped(
    acts: jax.Array, a_pool: jax.Array, b_pool: jax.Array, idx: jax.Array,
    *, use_kernel: bool = True,
    tm: Optional[int] = None, grid_order: Optional[str] = None,
) -> jax.Array:
    """Multi-tenant fused skip-sum: row b gets its own adapter stack.

    acts: (L, B, S, D); a_pool: (N, L, D, R); b_pool: (N, L, R, D);
    idx: (B,) int32 slot per batch row -> (B, S, D).
    ``use_kernel=False`` routes to the per-row jnp oracle (same layout and
    stop_gradient contract — this is the only wrapper for both paths).

    Serve-only: the pool is a registry of *already fine-tuned* tenants, so
    every input is wrapped in ``stop_gradient`` — adapter-pool gathers are
    non-differentiable constants at serve time (tested).
    """
    from repro.kernels.skip_lora import ref as R

    acts = jax.lax.stop_gradient(acts)
    a_pool = jax.lax.stop_gradient(a_pool)
    b_pool = jax.lax.stop_gradient(b_pool)
    l, bsz, s, d = acts.shape
    x = acts.reshape(l, bsz * s, d)
    row_idx = jnp.repeat(idx, s)
    if use_kernel:
        out = _grouped_rows(x, a_pool, b_pool, row_idx, tm, grid_order)
    else:
        out = R.skip_lora_grouped_ref(x, a_pool, b_pool, row_idx)
    return out.reshape(bsz, s, d)


# ---------------------------------------------------------------------------
# Trainable grouped path (fleet fine-tuning)
# ---------------------------------------------------------------------------
#
# The serve wrappers above pin every input with stop_gradient — correct for a
# registry of already-trained tenants, fatal for training them. The train
# wrappers wire a jax.custom_vjp whose backward reuses the forward's
# sort-by-slot/segment tiling: cotangent rows are scattered into the same
# padded layout and the grouped backward kernel accumulates per-(slot, layer)
# gA/gB blocks over each slot's contiguous tile run. Activations stay data
# (symbolic-zero cotangent, the paper's frozen-backbone contract); slots with
# no rows in the batch get exact-zero grads (their kernel output blocks are
# never visited, so the wrapper masks them by group count).


def _live_slot_mask(idx: jax.Array, n: int) -> jax.Array:
    """(N,) bool: slots that own at least one row of the batch."""
    return jnp.bincount(idx, length=n) > 0


def _mask_slots(grad: jax.Array, live: jax.Array) -> jax.Array:
    return jnp.where(live[:, None, None, None], grad, jnp.zeros_like(grad))


def _grouped_pool_grads(x, a_pool, b_pool, idx, g, tm):
    """Shared backward body for every trainable grouped variant: scatter rows
    + cotangent into the forward's padded layout, run the grouped backward
    kernel, mask slots with no rows to exact zero. x: (L, M, D); g: (M, D)."""
    dest, tile_adapter, m_pad = _grouping_plan(idx, a_pool.shape[0], x.shape[1], tm)
    xg = _grouped_scatter(x, dest, m_pad, 1)
    gg = _grouped_scatter(g.astype(x.dtype), dest, m_pad, 0)
    ga, gb = K.skip_lora_grouped_bwd(
        xg, a_pool, b_pool, gg, tile_adapter, tm=tm, interpret=_interpret()
    )
    live = _live_slot_mask(idx, a_pool.shape[0])
    ga = _mask_slots(ga, live).astype(a_pool.dtype)
    gb = _mask_slots(gb, live).astype(b_pool.dtype)
    return ga, gb


# custom_vjp functions can't carry static kwargs, so each (tm, grid_order)
# pair gets its own cached VJP'd callable — the public wrappers resolve the
# process default and fetch from here. The cache is tiny (one entry per
# distinct tuned parameter set seen in-process).


@functools.lru_cache(maxsize=None)
def _grouped_train_fn(tm: int, grid_order: str):
    @jax.custom_vjp
    def rows_train(x, a_pool, b_pool, idx):
        """x: (L, M, D), pools (N, L, D, R)/(N, L, R, D), idx: (M,) -> (M, D).
        Differentiable in the pools; x and idx are data."""
        return _grouped_rows(x, a_pool, b_pool, idx, tm, grid_order)

    def fwd(x, a_pool, b_pool, idx):
        return rows_train(x, a_pool, b_pool, idx), (x, a_pool, b_pool, idx)

    def bwd(res, g):
        x, a_pool, b_pool, idx = res
        ga, gb = _grouped_pool_grads(x, a_pool, b_pool, idx, g, tm)
        return (
            jnp.zeros_like(x),                      # cached activations are data
            ga,
            gb,
            np.zeros(idx.shape, jax.dtypes.float0),  # int row->slot map
        )

    rows_train.defvjp(fwd, bwd)
    return rows_train


@functools.lru_cache(maxsize=None)
def _grouped_train_int8_fn(tm: int, grid_order: str):
    @jax.custom_vjp
    def rows_train_int8(q, s, a_pool, b_pool, idx):
        """Raw-int8-activation rows -> (M, D) bf16; differentiable in the pools."""
        dest, tile_adapter, m_pad = _grouping_plan(idx, a_pool.shape[0], q.shape[1], tm)
        qg = _grouped_scatter(q, dest, m_pad, 1)
        sg = _grouped_scatter(s, dest, m_pad, 1)
        out = K.skip_lora_grouped_fwd_actint8(
            qg, sg, a_pool, b_pool, tile_adapter, tm=tm, interpret=_interpret()
        )
        return out[dest]

    def fwd(q, s, a_pool, b_pool, idx):
        return rows_train_int8(q, s, a_pool, b_pool, idx), (q, s, a_pool, b_pool, idx)

    def bwd(res, g):
        q, s, a_pool, b_pool, idx = res
        # The forward never materialises the dequantised rows (dequant is
        # fused); the adapter grads need them once — this is the only bf16 copy.
        ga, gb = _grouped_pool_grads(_dequant_rows(q, s), a_pool, b_pool, idx, g, tm)
        return (
            np.zeros(q.shape, jax.dtypes.float0),
            jnp.zeros_like(s),
            ga,
            gb,
            np.zeros(idx.shape, jax.dtypes.float0),
        )

    rows_train_int8.defvjp(fwd, bwd)
    return rows_train_int8


@functools.lru_cache(maxsize=None)
def _grouped_train_q4_fn(tm: int, grid_order: str):
    @jax.custom_vjp
    def rows_train_q4(x, qa, sa, qb, sb, code, idx):
        """Packed-4-bit pools -> (M, D). Differentiable in the SCALES
        (sa, sb) only — quantisation-aware scale refinement; the packed
        nibble payload and codebook are data."""
        return _grouped_rows_q4(x, qa, sa, qb, sb, code, idx, tm, grid_order)

    def fwd(x, qa, sa, qb, sb, code, idx):
        return rows_train_q4(x, qa, sa, qb, sb, code, idx), (x, qa, sa, qb, sb, code, idx)

    def bwd(res, g):
        x, qa, sa, qb, sb, code, idx = res
        # pool[n,l,i,j] = code[nib[n,l,i,j]] * scale[n,l,i] — linear in the
        # scale with coefficient "unit pool" u = code[nib]. Run the float
        # grouped backward on the dequantised pools, then chain-rule onto the
        # scales: g_scale[n,l,i] = sum_j g_pool[n,l,i,j] * u[n,l,i,j].
        ua = jnp.take(code, Q.unpack_nibbles(qa).astype(jnp.int32), axis=0)
        ub = jnp.take(code, Q.unpack_nibbles(qb).astype(jnp.int32), axis=0)
        a_pool = (ua * sa[..., None]).astype(x.dtype)
        b_pool = (ub * sb[..., None]).astype(x.dtype)
        ga, gb = _grouped_pool_grads(x, a_pool, b_pool, idx, g, tm)
        gsa = jnp.sum(ga.astype(jnp.float32) * ua, axis=-1).astype(sa.dtype)
        gsb = jnp.sum(gb.astype(jnp.float32) * ub, axis=-1).astype(sb.dtype)
        return (
            jnp.zeros_like(x),
            np.zeros(qa.shape, jax.dtypes.float0),   # packed payload is data
            gsa,
            np.zeros(qb.shape, jax.dtypes.float0),
            gsb,
            jnp.zeros_like(code),                    # codebook is a constant
            np.zeros(idx.shape, jax.dtypes.float0),
        )

    rows_train_q4.defvjp(fwd, bwd)
    return rows_train_q4


def _grouped_rows_train(x, a_pool, b_pool, idx, tm=None, grid_order=None):
    return _grouped_train_fn(_resolve_tm(tm), _resolve_order(grid_order))(
        x, a_pool, b_pool, idx
    )


def _grouped_rows_train_int8(q, s, a_pool, b_pool, idx, tm=None, grid_order=None):
    return _grouped_train_int8_fn(_resolve_tm(tm), _resolve_order(grid_order))(
        q, s, a_pool, b_pool, idx
    )


def freeze_pool_slots(pool: jax.Array, freeze_mask: jax.Array) -> jax.Array:
    """Detach the given slots from autodiff (forward value unchanged).

    freeze_mask: (N,) bool — True slots get exact-zero grads through ANY
    downstream use (kernel or oracle path). This is how the pinned zero
    slot stays zero when base-model rows ride a fleet-training batch."""
    mask = freeze_mask.reshape((-1,) + (1,) * (pool.ndim - 1))
    return jnp.where(mask, jax.lax.stop_gradient(pool), pool)


def skip_lora_grouped_train(
    acts: jax.Array,
    a_pool: jax.Array,
    b_pool: jax.Array,
    idx: jax.Array,
    *,
    use_kernel: bool = True,
    freeze_mask: Optional[jax.Array] = None,
    tm: Optional[int] = None,
    grid_order: Optional[str] = None,
) -> jax.Array:
    """Trainable multi-tenant skip-sum: same contract as
    ``skip_lora_grouped`` but differentiable in the pools — the fleet
    fine-tuning primitive (one batch, N tenants' adapters, per-slot grads).

    acts: (L, B, S, D) cached activations (data: zero cotangent);
    a_pool: (N, L, D, R); b_pool: (N, L, R, D); idx: (B,) int32 slot per
    batch row; freeze_mask: optional (N,) bool of slots whose grads must be
    exactly zero (e.g. ``AdapterPool``'s pinned zero slot). Slots with no
    rows in the batch always get exact-zero grads. ``use_kernel=False``
    routes to the per-row jnp oracle, differentiable by plain autodiff —
    the gradient-equivalence baseline for the kernel VJP."""
    from repro.kernels.skip_lora import ref as R

    acts = jax.lax.stop_gradient(acts)
    if freeze_mask is not None:
        a_pool = freeze_pool_slots(a_pool, freeze_mask)
        b_pool = freeze_pool_slots(b_pool, freeze_mask)
    l, bsz, s, d = acts.shape
    x = acts.reshape(l, bsz * s, d)
    row_idx = jnp.repeat(idx, s)
    if use_kernel:
        out = _grouped_rows_train(x, a_pool, b_pool, row_idx, tm, grid_order)
    else:
        out = R.skip_lora_grouped_ref(x, a_pool, b_pool, row_idx)
    return out.reshape(bsz, s, d)


def skip_lora_grouped_train_int8(
    acts_q: jax.Array,
    acts_scale: jax.Array,
    a_pool: jax.Array,
    b_pool: jax.Array,
    idx: jax.Array,
    *,
    use_kernel: bool = True,
    freeze_mask: Optional[jax.Array] = None,
    tm: Optional[int] = None,
    grid_order: Optional[str] = None,
) -> jax.Array:
    """Trainable grouped skip-sum over a raw int8 activation cache.

    acts_q: (L, B, S, D) int8 payload; acts_scale: (L, B, S) fp32 — the
    ``SkipLoRAConfig(mode="int8")`` cache layout, handed over raw (dequant
    fused into the kernel's A-projection). Pools are float (live weights).
    Backward dequantises rows once, then shares the float grouped tiling."""
    from repro.kernels.skip_lora import ref as R

    if freeze_mask is not None:
        a_pool = freeze_pool_slots(a_pool, freeze_mask)
        b_pool = freeze_pool_slots(b_pool, freeze_mask)
    l, bsz, s, d = acts_q.shape
    q = acts_q.reshape(l, bsz * s, d)
    sc = jax.lax.stop_gradient(acts_scale).reshape(l, bsz * s)
    row_idx = jnp.repeat(idx, s)
    if use_kernel:
        out = _grouped_rows_train_int8(q, sc, a_pool, b_pool, row_idx, tm, grid_order)
    else:
        out = R.skip_lora_grouped_actint8_ref(q, sc, a_pool, b_pool, row_idx)
    return out.reshape(bsz, s, d)


def skip_lora_grouped_int8(
    acts: jax.Array,
    qa: jax.Array,
    sa: jax.Array,
    qb: jax.Array,
    sb: jax.Array,
    idx: jax.Array,
    *,
    use_kernel: bool = True,
    tm: Optional[int] = None,
    grid_order: Optional[str] = None,
) -> jax.Array:
    """Multi-tenant skip-sum over an int8-compressed adapter pool.

    acts: (L, B, S, D) live activations (float); qa/sa, qb/sb: rowwise-
    quantised pool payloads + scales (see ``AdapterPool``); idx: (B,) int32.
    Dequant happens on gathered blocks inside the kernel
    (``use_kernel=False``: the dequantise-then-oracle jnp path). Serve-only.
    """
    from repro.kernels.skip_lora import ref as R

    acts = jax.lax.stop_gradient(acts)
    sa = jax.lax.stop_gradient(sa)
    sb = jax.lax.stop_gradient(sb)
    l, bsz, s, d = acts.shape
    x = acts.reshape(l, bsz * s, d)
    row_idx = jnp.repeat(idx, s)
    if use_kernel:
        out = _grouped_rows_int8(x, qa, sa, qb, sb, row_idx, tm, grid_order)
    else:
        out = R.skip_lora_grouped_int8_ref(x, qa, sa, qb, sb, row_idx)
    return out.reshape(bsz, s, d)


def skip_lora_grouped_q4(
    acts: jax.Array,
    qa: jax.Array,
    sa: jax.Array,
    qb: jax.Array,
    sb: jax.Array,
    code: jax.Array,
    idx: jax.Array,
    *,
    use_kernel: bool = True,
    tm: Optional[int] = None,
    grid_order: Optional[str] = None,
) -> jax.Array:
    """Multi-tenant skip-sum over a packed-4-bit adapter pool (int4 or nf4).

    acts: (L, B, S, D) live activations (float); qa: (N, L, D, R//2) packed
    nibble payload, sa: (N, L, D) fp32 scales; qb: (N, L, R, D//2), sb:
    (N, L, R); code: (16,) fp32 codebook; idx: (B,) int32. Nibble unpack +
    codebook dequant happen on the gathered blocks inside the kernel
    (``use_kernel=False``: dequantise-then-oracle jnp path). Serve-only."""
    from repro.kernels.skip_lora import ref as R

    acts = jax.lax.stop_gradient(acts)
    sa = jax.lax.stop_gradient(sa)
    sb = jax.lax.stop_gradient(sb)
    l, bsz, s, d = acts.shape
    x = acts.reshape(l, bsz * s, d)
    row_idx = jnp.repeat(idx, s)
    if use_kernel:
        out = _grouped_rows_q4(x, qa, sa, qb, sb, code, row_idx, tm, grid_order)
    else:
        out = R.skip_lora_grouped_q4_ref(x, qa, sa, qb, sb, code, row_idx)
    return out.reshape(bsz, s, d)


def skip_lora_grouped_train_q4(
    acts: jax.Array,
    qa: jax.Array,
    sa: jax.Array,
    qb: jax.Array,
    sb: jax.Array,
    code: jax.Array,
    idx: jax.Array,
    *,
    use_kernel: bool = True,
    freeze_mask: Optional[jax.Array] = None,
    tm: Optional[int] = None,
    grid_order: Optional[str] = None,
) -> jax.Array:
    """Trainable grouped skip-sum over packed-4-bit pools.

    4-bit slots train by QUANTISATION-AWARE SCALE REFINEMENT: the packed
    nibble payload is frozen data and gradients flow into (sa, sb) only —
    pool[i, j] = code[nib] * scale[i] is linear in the scale, so the VJP
    runs the float grouped backward on the dequantised pools and contracts
    the result against the unit (scale-1) pools. Slots with no rows in the
    batch and ``freeze_mask`` slots get exact-zero scale grads, same
    contract as the float/int8 trainable paths."""
    from repro.kernels.skip_lora import ref as R

    acts = jax.lax.stop_gradient(acts)
    if freeze_mask is not None:
        sa = freeze_pool_slots(sa, freeze_mask)
        sb = freeze_pool_slots(sb, freeze_mask)
    l, bsz, s, d = acts.shape
    x = acts.reshape(l, bsz * s, d)
    row_idx = jnp.repeat(idx, s)
    if use_kernel:
        out = _grouped_train_q4_fn(_resolve_tm(tm), _resolve_order(grid_order))(
            x, qa, sa, qb, sb, code, row_idx
        )
    else:
        out = R.skip_lora_grouped_q4_ref(x, qa, sa, qb, sb, code, row_idx)
    return out.reshape(bsz, s, d)
