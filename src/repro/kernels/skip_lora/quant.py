"""Sub-int8 adapter quantisation: packed 4-bit codebook formats (int4, nf4).

Both formats share ONE storage layout so the grouped kernel, the oracle,
and the pool machinery need a single dequant path:

  - payload: two 4-bit codebook indices packed per byte along the LAST
    axis (even positions in the low nibble, odd in the high nibble) —
    ``(..., K)`` float rows become ``(..., K // 2)`` uint8;
  - scale:   fp32 rowwise absmax over the last axis, ``(...,)``;
  - code:    a 16-entry fp32 codebook of levels in ``[-8/7, 1]``.

Dequant is ``code[nibble] * scale[..., None]`` for either format — the only
difference between int4 and nf4 is WHICH codebook the indices address:

  - ``int4``: uniform symmetric levels ``(i - 8) / 7`` for i in 0..15
    (quantise clips to [-7, 7], so index 0 is never produced);
  - ``nf4``: the QLoRA NormalFloat4 levels — the 16 quantiles of a standard
    normal, information-optimal for the normally-distributed weights LoRA
    factors actually have (PAPERS.md: TrainDeeploy's sub-int8 arithmetic).

A zero row quantises to the codebook's exact-zero level (int4 index 8,
nf4 index 7) with scale 0, so the pool's pinned zero slot dequantises to
EXACT zeros — base-model rows stay bitwise base-model through a q4 pool.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: Uniform symmetric int4 levels: dequant (nib - 8) / 7 * absmax.
INT4_CODE = ((jnp.arange(16) - 8) / 7.0).astype(jnp.float32)

#: QLoRA NormalFloat4 levels (Dettmers et al., 2023), exact-zero at index 7.
NF4_CODE = jnp.asarray(
    [
        -1.0,
        -0.6961928009986877,
        -0.5250730514526367,
        -0.39491748809814453,
        -0.28444138169288635,
        -0.18477343022823334,
        -0.09105003625154495,
        0.0,
        0.07958029955625534,
        0.16093020141124725,
        0.24611230194568634,
        0.33791524171829224,
        0.44070982933044434,
        0.5626170039176941,
        0.7229568362236023,
        1.0,
    ],
    jnp.float32,
)

Q4_KINDS = ("int4", "nf4")


def codebook(kind: str) -> jax.Array:
    if kind == "int4":
        return INT4_CODE
    if kind == "nf4":
        return NF4_CODE
    raise ValueError(f"unknown 4-bit kind {kind!r} (want one of {Q4_KINDS})")


def pack_nibbles(nib: jax.Array) -> jax.Array:
    """(..., K) uint8 values in [0, 15] -> (..., K // 2) packed bytes.

    Even last-axis positions land in the low nibble, odd in the high one
    (``unpack_nibbles`` is the exact inverse). K must be even."""
    if nib.shape[-1] % 2:
        raise ValueError(f"last axis {nib.shape[-1]} must be even to pack")
    lo = nib[..., 0::2].astype(jnp.uint8)
    hi = nib[..., 1::2].astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(packed: jax.Array) -> jax.Array:
    """(..., P) packed bytes -> (..., 2P) uint8 nibble indices in [0, 15]."""
    lo = packed & jnp.uint8(0x0F)
    hi = (packed >> 4) & jnp.uint8(0x0F)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(packed.shape[:-1] + (2 * packed.shape[-1],))


def quantize_q4(x: jax.Array, kind: str) -> tuple[jax.Array, jax.Array]:
    """Rowwise (last-axis) 4-bit quantisation into the shared layout.

    x: (..., K) float, K even -> (packed (..., K // 2) uint8,
    scale (...,) fp32 rowwise absmax). Dequant: ``code[nib] * scale``."""
    x = jnp.asarray(x, jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=-1)
    safe = jnp.where(scale > 0, scale, 1.0)[..., None]
    if kind == "int4":
        q = jnp.clip(jnp.round(x / safe * 7.0), -7, 7)
        nib = (q + 8).astype(jnp.uint8)
    elif kind == "nf4":
        xn = x / safe
        nib = jnp.argmin(
            jnp.abs(xn[..., None] - NF4_CODE), axis=-1
        ).astype(jnp.uint8)
    else:
        raise ValueError(f"unknown 4-bit kind {kind!r} (want one of {Q4_KINDS})")
    return pack_nibbles(nib), scale


def dequantize_q4(
    packed: jax.Array, scale: jax.Array, code: jax.Array
) -> jax.Array:
    """Inverse of ``quantize_q4``: (..., P) bytes + (...,) scales -> (..., 2P)
    fp32. ``code`` is the 16-entry codebook the indices address."""
    nib = unpack_nibbles(packed)
    return jnp.take(code, nib.astype(jnp.int32), axis=0) * scale[..., None]
