"""Pure-jnp oracles for the fused Skip-LoRA kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def skip_lora_fwd_ref(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """sum_l x[l] @ a[l] @ b[l].

    x: (L, M, D); a: (L, D, R); b: (L, R, D) -> (M, D) in x.dtype.
    Contractions accumulate in fp32 (matches kernel numerics).
    """
    z = jnp.einsum(
        "lmd,ldr->lmr", x, a.astype(x.dtype), preferred_element_type=jnp.float32
    )
    out = jnp.einsum(
        "lmr,lrd->md", z.astype(x.dtype), b.astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    return out.astype(x.dtype)


def skip_lora_bwd_ref(
    x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, g: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Adapter grads for all layers. Returns (gA (L,D,R), gB (L,R,D)) fp32.

    gB[l] = (x[l] a[l])^T g ;  gA[l] = x[l]^T (g b[l]^T).
    No gx: cached activations are constants (the paper's frozen backbone).
    """
    z = jnp.einsum(
        "lmd,ldr->lmr", x, a.astype(x.dtype), preferred_element_type=jnp.float32
    ).astype(x.dtype)
    gb = jnp.einsum("lmr,md->lrd", z, g, preferred_element_type=jnp.float32)
    gz = jnp.einsum(
        "md,lrd->lmr", g, b.astype(g.dtype), preferred_element_type=jnp.float32
    ).astype(x.dtype)
    ga = jnp.einsum("lmd,lmr->ldr", x, gz, preferred_element_type=jnp.float32)
    return ga.astype(jnp.float32), gb.astype(jnp.float32)


def skip_lora_grouped_ref(
    x: jnp.ndarray, a_pool: jnp.ndarray, b_pool: jnp.ndarray, idx: jnp.ndarray
) -> jnp.ndarray:
    """Per-row multi-adapter oracle: out[m] = sum_l x[l,m] @ A[idx[m],l] @ B[idx[m],l].

    x: (L, M, D); a_pool: (N, L, D, R); b_pool: (N, L, R, D); idx: (M,) int32
    -> (M, D) in x.dtype. Materialises the per-row adapter gather (fine for
    tests; the kernel gathers per tile instead)."""
    a_r = a_pool[idx].astype(x.dtype)   # (M, L, D, R)
    b_r = b_pool[idx].astype(x.dtype)   # (M, L, R, D)
    z = jnp.einsum("lmd,mldr->mlr", x, a_r, preferred_element_type=jnp.float32)
    out = jnp.einsum(
        "mlr,mlrd->md", z.astype(x.dtype), b_r, preferred_element_type=jnp.float32
    )
    return out.astype(x.dtype)


def skip_lora_grouped_int8_ref(
    x: jnp.ndarray,
    qa: jnp.ndarray,
    sa: jnp.ndarray,
    qb: jnp.ndarray,
    sb: jnp.ndarray,
    idx: jnp.ndarray,
) -> jnp.ndarray:
    """int8-pool oracle: dequantise the whole pool, then the float oracle.

    qa: (N, L, D, R) int8 with sa (N, L, D) scales; qb: (N, L, R, D) int8
    with sb (N, L, R) scales (rowwise over the last axis, matching
    ``core.lm_skiplora.quantize_int8``)."""
    a_pool = qa.astype(jnp.float32) * sa[..., None]
    b_pool = qb.astype(jnp.float32) * sb[..., None]
    return skip_lora_grouped_ref(x, a_pool, b_pool, idx)


def skip_lora_grouped_q4_ref(
    x: jnp.ndarray,
    qa: jnp.ndarray,
    sa: jnp.ndarray,
    qb: jnp.ndarray,
    sb: jnp.ndarray,
    code: jnp.ndarray,
    idx: jnp.ndarray,
) -> jnp.ndarray:
    """Packed-4-bit-pool oracle: unpack + codebook-dequantise the whole pool,
    then the float oracle. Differentiable in (sa, sb) by plain autodiff —
    the gradient baseline for the q4 scale-training VJP.

    qa: (N, L, D, R//2) uint8 packed nibbles with sa (N, L, D) fp32 scales;
    qb: (N, L, R, D//2) with sb (N, L, R); code: 16-entry fp32 codebook
    (int4 or nf4 levels, see ``kernels.skip_lora.quant``)."""
    from repro.kernels.skip_lora import quant as Q

    code = code.reshape(16)
    a_pool = Q.dequantize_q4(qa, sa, code)
    b_pool = Q.dequantize_q4(qb, sb, code)
    return skip_lora_grouped_ref(x, a_pool, b_pool, idx)


def skip_lora_grouped_bwd_ref(
    x: jnp.ndarray,
    a_pool: jnp.ndarray,
    b_pool: jnp.ndarray,
    g: jnp.ndarray,
    idx: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-adapter grads for the grouped skip-sum. Returns
    (gA (N,L,D,R), gB (N,L,R,D)) fp32; slots with no rows get exact zeros.

    gB[n,l] = sum_{m: idx[m]=n} (x[l,m] A[n,l])^T g[m];
    gA[n,l] = sum_{m: idx[m]=n} x[l,m]^T (g[m] B[n,l]^T).
    x: (L, M, D); pools (N, L, D, R)/(N, L, R, D); g: (M, D); idx: (M,).
    No gx: cached activations are frozen-backbone constants."""
    n = a_pool.shape[0]
    a_r = a_pool[idx].astype(x.dtype)        # (M, L, D, R)
    b_r = b_pool[idx].astype(x.dtype)        # (M, L, R, D)
    z = jnp.einsum(
        "lmd,mldr->mlr", x, a_r, preferred_element_type=jnp.float32
    ).astype(x.dtype)
    gz = jnp.einsum(
        "md,mlrd->mlr", g.astype(x.dtype), b_r, preferred_element_type=jnp.float32
    ).astype(x.dtype)
    # Per-row outer products, then segment-sum rows into their slots.
    ga_rows = jnp.einsum(
        "lmd,mlr->mldr", x, gz, preferred_element_type=jnp.float32
    )
    gb_rows = jnp.einsum(
        "mlr,md->mlrd", z, g.astype(x.dtype), preferred_element_type=jnp.float32
    )
    onehot = jax.nn.one_hot(idx, n, dtype=jnp.float32)       # (M, N)
    ga = jnp.einsum("mn,mldr->nldr", onehot, ga_rows)
    gb = jnp.einsum("mn,mlrd->nlrd", onehot, gb_rows)
    return ga.astype(jnp.float32), gb.astype(jnp.float32)


def skip_lora_grouped_actint8_ref(
    q: jnp.ndarray,
    scale: jnp.ndarray,
    a_pool: jnp.ndarray,
    b_pool: jnp.ndarray,
    idx: jnp.ndarray,
    dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """int8-activation grouped oracle: dequantise rows, then the float
    grouped oracle (pool stays float — the training-side layout, where the
    adapters are live weights and the *cache* is compressed).

    q: (L, M, D) int8; scale: (L, M) fp32."""
    x = (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
    return skip_lora_grouped_ref(x, a_pool.astype(dtype), b_pool.astype(dtype), idx)


def skip_lora_int8_fwd_ref(
    q: jnp.ndarray, scale: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, dtype=jnp.bfloat16
) -> jnp.ndarray:
    """int8 variant: x[l] = q[l] * scale[l][:, None] dequantised on the fly.

    q: (L, M, D) int8; scale: (L, M) fp32.
    """
    x = (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
    return skip_lora_fwd_ref(x, a.astype(dtype), b.astype(dtype))
