"""Launchers: mesh construction, multi-pod dry-run, train/finetune/serve."""
