import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent without hardware: the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh must lower AND compile every
supported cell; ``memory_analysis`` proves the working set fits,
``cost_analysis`` + HLO collective parsing feed §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-1.6b \
      --shape train_4k --step train --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results.json
"""

import argparse
import json
import time
import traceback

import jax

from repro.launch.hlo_analysis import analyze_collectives, analyze_dot_flops


def run_cell(
    arch: str,
    shape_name: str,
    step_kind: str,
    *,
    multi_pod: bool = False,
    unroll: bool = False,
    skiplora_mode: str = "full",
    strategy: str = "tp",
) -> dict:
    """Lower + compile one cell; return the §Dry-run / §Roofline record."""
    from repro.configs.registry import get_config
    from repro.core.lm_skiplora import SkipLoRAConfig
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell
    from repro.models import blocks

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    sl = SkipLoRAConfig(rank=16, mode=skiplora_mode)
    fn, args, in_sh, out_sh = build_cell(
        arch, shape_name, mesh, step_kind, skiplora=sl, strategy=strategy
    )

    with mesh:
        with blocks.scan_unroll_scope(unroll):
            jitted = (
                jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
                if out_sh is not None
                else jax.jit(fn, in_shardings=in_sh)
            )
            lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = analyze_collectives(hlo)
    dot_flops = analyze_dot_flops(hlo)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "step": step_kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "strategy": strategy,
        "chips": int(mesh.devices.size),
        "unrolled": unroll,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # Per-device numbers (XLA SPMD module == one device's program).
        "flops": float(cost.get("flops", 0.0)),
        "dot_flops": dot_flops,
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll.total_bytes,
        "collective_count": coll.count,
        "collectives_per_op": coll.per_op_bytes,
    }
    for attr in (
        "temp_size_in_bytes",
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        try:
            rec[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    rec["memory_analysis"] = str(mem)
    return rec


def default_step_for(shape_name: str) -> str:
    return {
        "train_4k": "train",
        "prefill_32k": "prefill",
        "decode_32k": "decode",
        "long_500k": "decode",
    }[shape_name]


def main() -> None:
    from repro.configs.registry import list_archs
    from repro.launch.shapes import SHAPES, cell_supported

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--step", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="run every supported cell")
    ap.add_argument("--skiplora-mode", default="full")
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp", "ep"])
    ap.add_argument("--unroll", action="store_true", help="unroll period scans (slower compile; same analysis numbers)")
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args()

    cells: list[tuple[str, str, str]] = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                ok, why = cell_supported(a, s)
                if not ok:
                    print(f"SKIP {a} x {s}: {why}")
                    continue
                cells.append((a, s, default_step_for(s)))
    else:
        assert args.arch and args.shape
        step = args.step or default_step_for(args.shape)
        cells.append((args.arch, args.shape, step))

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    records = []
    for arch, shape, step in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} x {step} x {'multi' if mp else 'single'}"
            try:
                rec = run_cell(
                    arch,
                    shape,
                    step,
                    multi_pod=mp,
                    unroll=args.unroll,
                    skiplora_mode=args.skiplora_mode,
                    strategy=args.strategy,
                )
                records.append(rec)
                print(
                    f"OK   {tag}: flops={rec['flops']:.3e} "
                    f"coll={rec['collective_bytes']:.3e}B "
                    f"compile={rec['compile_s']}s"
                )
                print("  memory:", rec["memory_analysis"].replace("\n", " | ")[:300])
            except Exception as e:
                print(f"FAIL {tag}: {type(e).__name__}: {e}")
                traceback.print_exc()
                records.append(
                    {"arch": arch, "shape": shape, "step": step,
                     "mesh": "2x16x16" if mp else "16x16", "error": str(e)}
                )
    if args.out:
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        with open(args.out, "w") as f:
            json.dump(existing + records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")


if __name__ == "__main__":
    main()
