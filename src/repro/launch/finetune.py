"""Skip2-LoRA fine-tuning launcher — the paper's Algorithm 1 at LM scale.

Epoch 0 populates the activation cache (backbone forward once per sample);
epochs >= 1 run cached steps with ZERO backbone compute. Each epoch phase is
a single ``jax.lax.scan`` dispatch (DESIGN.md §2) — no per-batch Python.
Compare wall-clock per epoch to see the paper's claim live
(examples/finetune_lm.py drives this for a ~100M model):

  PYTHONPATH=src python -m repro.launch.finetune --arch stablelm-1.6b \
      --reduced --epochs 4 --samples 64 --batch 8 --seq 128 --mode full

With ``--hbm-mb`` the activation cache is placed by a ``TieredCacheEngine``
under that HBM budget: rows beyond the budget spill to the host tier and
cached epochs run the streaming path (per-batch engine reads, next batch
prefetched on a background thread while the adapter step runs). Tier hit
counts are reported at the end.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.cache_engine import TieredCacheEngine
from repro.core.skip_cache import cache_read
from repro.data.pipeline import DataConfig, epoch_permutation, make_pipeline
from repro.models.lm import init_lm
from repro.optim.optimizers import adamw


def _index_matrix(samples: int, batch: int, epoch: int = 0) -> np.ndarray:
    perm = epoch_permutation(0, epoch, samples)  # same visitation order
    steps = samples // batch
    return perm[: steps * batch].reshape(steps, batch)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--samples", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mode", default="full", choices=["full", "int8", "freeze_a"])
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--hbm-mb", type=float, default=0.0,
                    help="cache HBM budget in MiB; 0 = fully device-resident")
    ap.add_argument("--cache-dir", default=None,
                    help="host-tier directory (disk spill); default in-memory")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    sl = SL.SkipLoRAConfig(
        rank=args.rank, mode=args.mode, cache_dtype="float32",
        use_fused_kernel=args.use_kernel,
    )
    print(
        f"arch={cfg.name} mode={sl.mode} rank={sl.rank} "
        f"cache/sample={SL.cache_nbytes_per_sample(cfg, sl, args.seq)/2**20:.2f} MiB"
    )

    key = jax.random.key(0)
    params = init_lm(key, cfg)
    adapters = SL.init_adapters(jax.random.key(1), cfg, sl)
    trainable, static = SL.split_trainable(adapters, sl)
    opt = adamw(args.lr)
    opt_state = opt.init(trainable)

    dcfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, num_samples=args.samples,
    )
    store, _ = make_pipeline(dcfg)
    cache = SL.init_lm_cache(args.samples, cfg, sl, args.seq)

    # Stage the fine-tune set once; the populate epoch is then one dispatch.
    all_ids = np.arange(args.samples)
    staged = store.batch(all_ids)
    tokens = jnp.asarray(staged["tokens"])
    labels = jnp.asarray(staged["labels"])

    populate_epoch = SL.make_populate_epoch(cfg, sl, opt)
    cached_epoch = SL.make_cached_epoch(cfg, sl, opt)
    step_from_vals = jax.jit(SL.make_cached_step_from_vals(cfg, sl, opt))

    engine = None
    if args.hbm_mb > 0:
        layout = SL.lm_cache_layout(cfg, sl, args.seq)
        engine = TieredCacheEngine(
            args.samples, layout,
            hbm_budget_bytes=int(args.hbm_mb * 2**20),
            directory=args.cache_dir,
        )
        print(f"tiered engine: HBM budget {args.hbm_mb:g} MiB -> "
              f"{engine.capacity}/{args.samples} rows resident")

    epoch_times, losses = [], []
    for epoch in range(args.epochs):
        idx_mat = _index_matrix(args.samples, args.batch)
        t0 = time.perf_counter()
        if epoch == 0:
            trainable, opt_state, cache, ls = populate_epoch(
                params, trainable, static, opt_state, cache,
                tokens, labels, jnp.asarray(idx_mat),
            )
            loss = ls[-1]
        elif engine is None:
            trainable, opt_state, ls = cached_epoch(
                params, trainable, static, opt_state, cache, jnp.asarray(idx_mat)
            )
            loss = ls[-1]
        else:
            for _, vals in engine.stream_batches(idx_mat):
                trainable, opt_state, loss = step_from_vals(
                    params, trainable, static, opt_state, vals
                )
        jax.block_until_ready(loss)
        dt = time.perf_counter() - t0
        epoch_times.append(dt)
        losses.append(float(loss))
        kind = "populate" if epoch == 0 else "cached  "
        print(f"epoch {epoch} [{kind}] loss {float(loss):.4f} time {dt:.2f}s")
        if epoch == 0 and engine is not None:
            # Hand the populated rows to the placement engine (outside the
            # timed region — staging is a one-off, not epoch cost); rows
            # past the HBM budget spill to the host tier.
            for row in idx_mat:
                idx = jnp.asarray(row)
                engine.write(idx, cache_read(cache, idx))
            cache = None  # engine owns placement now

    if len(epoch_times) > 1:
        speedup = epoch_times[0] / (sum(epoch_times[1:]) / len(epoch_times[1:]))
        print(f"cached-epoch speedup vs populate epoch: {speedup:.1f}x")
    out = {"epoch_times": epoch_times, "losses": losses}
    if engine is not None:
        st = engine.stats
        print(f"cache tiers: hbm_hits={st.hbm_hits} host_hits={st.host_hits} "
              f"staged_hits={st.staged_hits} spills={st.spills} "
              f"hbm_hit_rate={st.hbm_hit_rate():.2f}")
        out["cache_stats"] = st
    return out


if __name__ == "__main__":
    main()
