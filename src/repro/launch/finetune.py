"""Skip2-LoRA fine-tuning launcher — a thin CLI over the session runtime.

The paper's Algorithm 1 as a one-tenant continual session (DESIGN.md §9):
epoch 0 *ingests* the fine-tune set (populate forwards that write the
activation cache — and would serve logits back in a live deployment);
every later epoch is a cached ``adapt`` with ZERO backbone compute.
Compare wall-clock per epoch to see the paper's claim live:

  PYTHONPATH=src python -m repro.launch.finetune --arch stablelm-1.6b \
      --reduced --epochs 4 --samples 64 --batch 8 --seq 128 --mode full

With ``--hbm-mb`` the runtime's ``TieredCacheEngine`` places the cache
under that budget: rows beyond it spill to the host tier and ``adapt``
takes the streaming prefetch path instead of the fused scan (the §9 path
table). Tier hit counts are reported at the end.

``--mode freeze_a`` (R-wide compressed cache; not a fleet-trainable mode)
keeps the single-tenant scan loop from ``core.lm_skiplora`` directly.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.data.pipeline import DataConfig, make_pipeline
from repro.models.lm import init_lm
from repro.optim.optimizers import adamw


def _parse(argv):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--samples", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mode", default="full", choices=["full", "int8", "freeze_a"])
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--hbm-mb", type=float, default=0.0,
                    help="cache HBM budget in MiB; 0 = fully device-resident")
    ap.add_argument("--cache-dir", default=None,
                    help="host-tier directory (disk spill); default in-memory")
    return ap.parse_args(argv)


def main(argv=None) -> dict:
    args = _parse(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    sl = SL.SkipLoRAConfig(
        rank=args.rank, mode=args.mode, cache_dtype="float32",
        use_fused_kernel=args.use_kernel,
    )
    print(
        f"arch={cfg.name} mode={sl.mode} rank={sl.rank} "
        f"cache/sample={SL.cache_nbytes_per_sample(cfg, sl, args.seq)/2**20:.2f} MiB"
    )

    params = init_lm(jax.random.key(0), cfg)
    dcfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, num_samples=args.samples,
    )
    store, _ = make_pipeline(dcfg)
    staged = store.batch(np.arange(args.samples))
    tokens = jnp.asarray(staged["tokens"])
    labels = jnp.asarray(staged["labels"])

    if args.mode == "freeze_a":
        return _legacy_freeze_a(args, cfg, sl, params, tokens, labels)

    from repro.core.runtime import SessionRuntime

    rt = SessionRuntime(
        cfg, sl, params, max_tenants=1, samples_per_tenant=args.samples,
        seq=args.seq, lr=args.lr, use_kernel=args.use_kernel,
        hbm_budget_bytes=(int(args.hbm_mb * 2**20) if args.hbm_mb > 0 else None),
        cache_dir=args.cache_dir,
    )
    if args.hbm_mb > 0:
        print(f"tiered engine: HBM budget {args.hbm_mb:g} MiB -> "
              f"{rt.engine.capacity}/{args.samples} rows resident")

    epoch_times, losses = [], []
    key = jax.random.key(1)
    for epoch in range(args.epochs):
        t0 = time.perf_counter()
        if epoch == 0:
            # Populate phase: ingest the whole set (backbone forward once
            # per sample; in a live session these logits serve the caller).
            for lo in range(0, args.samples, args.batch):
                rt.ingest("device-0", tokens[lo:lo + args.batch],
                          labels[lo:lo + args.batch])
        out = rt.adapt(epochs=1, batch_per_tenant=args.batch, key=key)
        ls = out["losses"]["device-0"]
        jax.block_until_ready(ls)
        dt = time.perf_counter() - t0
        epoch_times.append(dt)
        losses.append(float(ls.mean()))  # mean epoch loss (order-robust)
        kind = "populate" if epoch == 0 else "cached  "
        print(f"epoch {epoch} [{kind}] loss {losses[-1]:.4f} time {dt:.2f}s "
              f"({out['path']} path)")

    if len(epoch_times) > 1:
        speedup = epoch_times[0] / (sum(epoch_times[1:]) / len(epoch_times[1:]))
        print(f"cached-epoch speedup vs populate epoch: {speedup:.1f}x")
    out = {"epoch_times": epoch_times, "losses": losses}
    if args.hbm_mb > 0:
        st = rt.engine.stats
        print(f"cache tiers: hbm_hits={st.hbm_hits} host_hits={st.host_hits} "
              f"staged_hits={st.staged_hits} spills={st.spills} "
              f"hbm_hit_rate={st.hbm_hit_rate():.2f}")
        out["cache_stats"] = st
    return out


def _legacy_freeze_a(args, cfg, sl, params, tokens, labels) -> dict:
    """freeze_a trains only B against an R-wide cache — outside the fleet
    trainer's modes, so it keeps the PR 1 single-tenant scan loop."""
    from repro.core.finetune import epoch_index_matrix

    adapters = SL.init_adapters(jax.random.key(1), cfg, sl)
    trainable, static = SL.split_trainable(adapters, sl)
    opt = adamw(args.lr)
    opt_state = opt.init(trainable)
    cache = SL.init_lm_cache(args.samples, cfg, sl, args.seq)
    populate_epoch = SL.make_populate_epoch(cfg, sl, opt)
    cached_epoch = SL.make_cached_epoch(cfg, sl, opt)
    epoch_times, losses = [], []
    rng = jax.random.key(2)
    for epoch in range(args.epochs):
        rng, sk = jax.random.split(rng)
        idx_mat = epoch_index_matrix(sk, args.samples, args.batch)
        t0 = time.perf_counter()
        if epoch == 0:
            trainable, opt_state, cache, ls = populate_epoch(
                params, trainable, static, opt_state, cache,
                tokens, labels, idx_mat,
            )
        else:
            trainable, opt_state, ls = cached_epoch(
                params, trainable, static, opt_state, cache, idx_mat
            )
        jax.block_until_ready(ls)
        dt = time.perf_counter() - t0
        epoch_times.append(dt)
        losses.append(float(ls[-1]))
        kind = "populate" if epoch == 0 else "cached  "
        print(f"epoch {epoch} [{kind}] loss {losses[-1]:.4f} time {dt:.2f}s")
    if len(epoch_times) > 1:
        speedup = epoch_times[0] / (sum(epoch_times[1:]) / len(epoch_times[1:]))
        print(f"cached-epoch speedup vs populate epoch: {speedup:.1f}x")
    return {"epoch_times": epoch_times, "losses": losses}


if __name__ == "__main__":
    main()
