"""Skip2-LoRA fine-tuning launcher — the paper's Algorithm 1 at LM scale.

Epoch 0 populates the activation cache (backbone forward once per sample);
epochs >= 1 run cached steps with ZERO backbone compute. Compare wall-clock
per epoch to see the paper's claim live (examples/finetune_lm.py drives
this for a ~100M model):

  PYTHONPATH=src python -m repro.launch.finetune --arch stablelm-1.6b \
      --reduced --epochs 4 --samples 64 --batch 8 --seq 128 --mode full
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.data.pipeline import DataConfig, epoch_permutation, make_pipeline
from repro.models.lm import init_lm
from repro.optim.optimizers import adamw


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--samples", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mode", default="full", choices=["full", "int8", "freeze_a"])
    ap.add_argument("--use-kernel", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    sl = SL.SkipLoRAConfig(
        rank=args.rank, mode=args.mode, cache_dtype="float32",
        use_fused_kernel=args.use_kernel,
    )
    print(
        f"arch={cfg.name} mode={sl.mode} rank={sl.rank} "
        f"cache/sample={SL.cache_nbytes_per_sample(cfg, sl, args.seq)/2**20:.2f} MiB"
    )

    key = jax.random.key(0)
    params = init_lm(key, cfg)
    adapters = SL.init_adapters(jax.random.key(1), cfg, sl)
    trainable, static = SL.split_trainable(adapters, sl)
    opt = adamw(args.lr)
    opt_state = opt.init(trainable)

    dcfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, num_samples=args.samples,
    )
    store, _ = make_pipeline(dcfg)
    cache = SL.init_lm_cache(args.samples, cfg, sl, args.seq)

    populate = jax.jit(SL.make_populate_step(cfg, sl, opt))
    cached = jax.jit(SL.make_cached_step(cfg, sl, opt))

    epoch_times, losses = [], []
    for epoch in range(args.epochs):
        perm = epoch_permutation(0, 0, args.samples)  # same visitation order
        t0 = time.perf_counter()
        for s in range(args.samples // args.batch):
            ids = perm[s * args.batch : (s + 1) * args.batch]
            idx = jnp.asarray(ids)
            if epoch == 0:
                b = store.batch(ids)
                batch = {
                    "tokens": jnp.asarray(b["tokens"]),
                    "labels": jnp.asarray(b["labels"]),
                }
                trainable, opt_state, cache, loss = populate(
                    params, trainable, static, opt_state, cache, batch, idx
                )
            else:
                trainable, opt_state, loss = cached(
                    params, trainable, static, opt_state, cache, idx
                )
        jax.block_until_ready(loss)
        dt = time.perf_counter() - t0
        epoch_times.append(dt)
        losses.append(float(loss))
        kind = "populate" if epoch == 0 else "cached  "
        print(f"epoch {epoch} [{kind}] loss {float(loss):.4f} time {dt:.2f}s")

    if len(epoch_times) > 1:
        speedup = epoch_times[0] / (sum(epoch_times[1:]) / len(epoch_times[1:]))
        print(f"cached-epoch speedup vs populate epoch: {speedup:.1f}x")
    return {"epoch_times": epoch_times, "losses": losses}


if __name__ == "__main__":
    main()
