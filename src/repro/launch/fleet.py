"""Sharded fleet fine-tuning launcher — a thin CLI over the mesh-native
``SessionRuntime``.

Single- and multi-device fleets now run through ONE engine: the session
runtime ingests every tenant's samples (the populate forwards), then runs
per-epoch grouped ``adapt`` calls with pool write-back. On a multi-device
mesh the runtime places each tenant's adapters, optimizer moments, and
cache partition on its logical shard's device and dispatches every
(trajectory, shard) group's fused epochs shard-locally (DESIGN.md §10) —
the bespoke ``shard_map`` data-parallel path this launcher used to carry
collapsed into the runtime, which is now the one way to run multi-device
fine-tuning.

CPU verification (no hardware needed): the device count is forced *before*
jax import, exactly like ``launch/dryrun.py``:

  PYTHONPATH=src python -m repro.launch.fleet --arch stablelm-1.6b \
      --reduced --tenants 4 --devices 2 --samples 8 --batch-per-tenant 4 \
      --seq 16 --epochs 3 --check-parity

``--check-parity`` compares against the offline single-dispatch
``fleet_finetune`` trainer: at ``--devices 1`` the session reproduces it
BITWISE on the kernel path (the §9 bar, zero tolerance); at ``--devices N``
the per-shard groups train fewer tenants per dispatch than the offline
joint fleet, and under a forced host-device count XLA compiles
shape-dependent reductions, so parity is held to 1e-5 (the same tolerance
the legacy shard_map path needed, for the same reason — see DESIGN.md §10;
the *zero*-tolerance multi-device bar is ``launch/run.py --check-parity``,
which pins the group layout and varies only device placement).
"""

from __future__ import annotations

import argparse
import os
import time


def _parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--devices", type=int, default=1,
                    help="tenant-parallel devices (forced on CPU via XLA_FLAGS)")
    ap.add_argument("--samples", type=int, default=8, help="samples per tenant")
    ap.add_argument("--batch-per-tenant", type=int, default=4)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--mode", default="full", choices=["full", "int8"])
    ap.add_argument("--use-kernel", action="store_true",
                    help="grouped Pallas kernel (interpret mode off-TPU)")
    ap.add_argument("--check-parity", action="store_true",
                    help="compare session losses/adapters against the "
                         "offline fleet trainer")
    return ap.parse_args(argv)


def main(argv=None) -> dict:
    args = _parse_args(argv)
    if args.devices > 1 and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        # Must land before the first jax import (same trick as dryrun.py).
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax

    from repro.configs import get_config, reduce_config
    from repro.core import lm_skiplora as SL

    if args.tenants % args.devices:
        raise SystemExit(
            f"--tenants {args.tenants} must divide over --devices {args.devices}"
        )
    if len(jax.devices()) < args.devices:
        raise SystemExit(
            f"need {args.devices} devices, have {len(jax.devices())} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count before jax "
            "imports, or let this CLI do it by running it first)"
        )

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    sl = SL.SkipLoRAConfig(rank=args.rank, mode=args.mode, cache_dtype="float32",
                           use_fused_kernel=args.use_kernel)

    n_t, n_per = args.tenants, args.samples
    bpt = min(args.batch_per_tenant, n_per)  # fleet_index_matrix clamp

    from repro.models.lm import init_lm

    params = init_lm(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (n_t, n_per, args.seq), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.key(2), (n_t, n_per, args.seq), 0, cfg.vocab_size)

    return _runtime_main(args, cfg, sl, params, tokens, labels, bpt)


def _runtime_main(args, cfg, sl, params, tokens, labels, bpt) -> dict:
    """Fleet epochs as one interleaved runtime session over the mesh:
    ingest every tenant's samples (the populate forwards, one per tenant —
    identical shapes on any device count), then per-epoch grouped ``adapt``
    calls with pool write-back, each (trajectory, shard) group dispatched
    on its own device. At ``--devices 1`` this is bitwise-identical to
    ``fleet_finetune`` on the kernel path (DESIGN.md §9), which
    ``--check-parity`` asserts at zero tolerance."""
    import time

    import jax
    import numpy as np

    from repro.core import fleet_finetune as FF
    from repro.core.runtime import SessionRuntime
    from repro.optim.optimizers import adamw
    from repro.runtime.sharding import make_mesh

    if args.check_parity and args.mode != "full":
        raise SystemExit(
            "--check-parity on the runtime path requires --mode full: int8 "
            "cached epochs intentionally train on the quantised cache, "
            "while the offline populate epoch steps on full-precision "
            "activations (DESIGN.md §9)"
        )
    n_t, n_per = args.tenants, args.samples
    mesh = make_mesh(
        (args.devices,), ("data",), devices=jax.devices()[: args.devices]
    )
    rt = SessionRuntime(
        cfg, sl, params, max_tenants=n_t, samples_per_tenant=n_per,
        seq=args.seq, lr=args.lr, use_kernel=args.use_kernel, mesh=mesh,
    )
    t0 = time.perf_counter()
    for t in range(n_t):
        for lo in range(0, n_per, bpt):
            rt.ingest(t, tokens[t, lo:lo + bpt], labels[t, lo:lo + bpt])
    ingest_s = time.perf_counter() - t0

    losses, times = [], []
    for e in range(args.epochs):
        t0 = time.perf_counter()
        out = rt.adapt(epochs=1, batch_per_tenant=bpt, key=jax.random.key(3))
        ls = np.stack([out["losses"][t][0] for t in range(n_t)], axis=-1)
        dt = time.perf_counter() - t0
        times.append(dt)
        losses.append(ls)
        kind = "populate" if e == 0 else "cached  "
        extra = f" (+{ingest_s:.2f}s ingest)" if e == 0 else ""
        print(f"epoch {e} [{kind}] mean loss {float(np.mean(ls)):.4f} "
              f"time {dt:.2f}s{extra} ({n_t / dt:.1f} tenants/s/epoch, "
              f"{len(out['groups'])} shard group(s))")

    losses = np.stack(losses)  # (epochs, steps, n_tenants)
    out = {"losses": losses, "epoch_times": times, "devices": args.devices}

    if args.check_parity:
        ref = FF.fleet_finetune(
            jax.random.key(3), cfg, sl, params, tokens, labels,
            epochs=args.epochs, batch_per_tenant=bpt, optimizer=adamw(args.lr),
            use_kernel=args.use_kernel,
        )
        diff = float(np.max(np.abs(ref.losses - losses)))
        adiff = max(
            float(np.max(np.abs(
                np.asarray(rt.tenant(t).adapters[k]) - np.asarray(ref.adapters[k][t])
            )))
            for t in range(n_t) for k in ("A", "B")
        )
        print(f"parity_max_abs_diff={diff:.3e}")
        print(f"parity_adapter_diff={adiff:.3e}")
        out["parity_max_abs_diff"] = diff
        out["parity_adapter_diff"] = adiff
        # The single-device session reproduces the offline trainer BITWISE
        # (the §9 bar); sharded groups differ from the offline joint fleet
        # only by shape-dependent XLA reduction compilation — 1e-5 bounds
        # it with orders of magnitude to spare (measured ~1e-6).
        tol = 0.0 if args.devices == 1 else 1e-5
        if diff > tol or adiff > tol:
            # The CI verification step must FAIL on divergence, not just
            # print it.
            raise SystemExit(
                f"session/offline parity broken: losses {diff:.3e} "
                f"adapters {adiff:.3e} (tol {tol:.0e})"
            )
    return out


if __name__ == "__main__":
    main()
