"""Sharded fleet fine-tuning launcher: tenant-axis data parallelism.

Trains N tenants' Skip2-LoRA adapters in one dispatch per epoch
(``core.fleet_finetune``), with the tenant axis split across devices via
``shard_map`` (DESIGN.md §8): the frozen backbone is *replicated* (it is
tenant-independent), while the stacked adapters, their optimizer moments,
each tenant's cache partition, and the fleet batch columns are sharded on
the mesh's ``data`` axis. Tenants never exchange data — the only cross-
device value is the replicated backbone — so the sharded epoch reproduces
the single-device epoch per shard (to XLA-fusion float tolerance),
verified by ``--check-parity``.

CPU verification (no hardware needed): the device count is forced *before*
jax import, exactly like ``launch/dryrun.py``:

  PYTHONPATH=src python -m repro.launch.fleet --arch stablelm-1.6b \
      --reduced --tenants 4 --devices 2 --samples 8 --batch-per-tenant 4 \
      --seq 16 --epochs 3 --check-parity
"""

from __future__ import annotations

import argparse
import os
import time


def _parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--devices", type=int, default=1,
                    help="tenant-parallel devices (forced on CPU via XLA_FLAGS)")
    ap.add_argument("--samples", type=int, default=8, help="samples per tenant")
    ap.add_argument("--batch-per-tenant", type=int, default=4)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--mode", default="full", choices=["full", "int8"])
    ap.add_argument("--use-kernel", action="store_true",
                    help="grouped Pallas kernel (interpret mode off-TPU)")
    ap.add_argument("--check-parity", action="store_true",
                    help="compare sharded losses against the single-device "
                         "fleet trainer")
    return ap.parse_args(argv)


def main(argv=None) -> dict:
    args = _parse_args(argv)
    if args.devices > 1 and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        # Must land before the first jax import (same trick as dryrun.py).
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config, reduce_config
    from repro.core import fleet_finetune as FF
    from repro.core import lm_skiplora as SL
    from repro.optim.optimizers import adamw

    if args.tenants % args.devices:
        raise SystemExit(
            f"--tenants {args.tenants} must divide over --devices {args.devices}"
        )
    if len(jax.devices()) < args.devices:
        raise SystemExit(
            f"need {args.devices} devices, have {len(jax.devices())} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count before jax "
            "imports, or let this CLI do it by running it first)"
        )

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    sl = SL.SkipLoRAConfig(rank=args.rank, mode=args.mode, cache_dtype="float32",
                           use_fused_kernel=args.use_kernel)

    n_t, n_per, seq = args.tenants, args.samples, args.seq
    bpt = min(args.batch_per_tenant, n_per)  # fleet_index_matrix clamp
    n_local = n_t // args.devices
    samples_per_device = n_local * n_per

    from repro.models.lm import init_lm

    params = init_lm(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (n_t, n_per, seq), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.key(2), (n_t, n_per, seq), 0, cfg.vocab_size)

    if args.devices == 1:
        # Single-device fleets run through the session runtime (one pool,
        # one cache engine, the shared compiled-fn cache) — the shard_map
        # below is the multi-device escape hatch for the same epochs.
        return _runtime_main(args, cfg, sl, params, tokens, labels, bpt)

    opt = adamw(args.lr)
    stacked = FF.init_fleet_adapters(jax.random.key(3), cfg, sl, n_t)
    opt_state = opt.init(stacked)
    row_tenant = FF.fleet_row_tenant(n_t, bpt)
    tokens_flat = tokens.reshape(n_t * n_per, seq)
    labels_flat = labels.reshape(n_t * n_per, seq)
    cache = SL.init_lm_cache(n_t * n_per, cfg, sl, seq)

    # ---- sharded epoch builders (per-shard bodies are the unjitted fleet
    # epochs over n_local tenants; jit wraps the sharded call) -------------
    mesh = jax.make_mesh((args.devices,), ("data",))
    populate_raw = FF.make_fleet_populate_epoch(
        cfg, sl, opt, n_local, use_kernel=args.use_kernel, jit=False
    )
    cached_raw = FF.make_fleet_cached_epoch(
        cfg, sl, opt, n_local, use_kernel=args.use_kernel, jit=False
    )

    def _localize(idx, row_t):
        dev = jax.lax.axis_index("data")
        return idx - dev * samples_per_device, row_t - dev * n_local

    def populate_body(params, stacked, opt_state, cache, tokens, labels, idx_mat, row_t):
        idx_local, rt_local = _localize(idx_mat, row_t)
        return populate_raw(
            params, stacked, opt_state, cache, tokens, labels, idx_local, rt_local
        )

    def cached_body(params, stacked, opt_state, cache, idx_mat, row_t):
        idx_local, rt_local = _localize(idx_mat, row_t)
        return cached_raw(params, stacked, opt_state, cache, idx_local, rt_local)

    # Spec prefixes: replicated backbone, tenant-axis sharding everywhere a
    # leading tenant/sample axis exists, replicated scalar step counter.
    s_params = P()
    s_stack = P("data")
    s_opt = type(opt_state)(step=P(), mu=P("data"), nu=P("data"))
    s_cache = P("data")
    s_idx = P(None, "data")
    s_rt = P("data")
    s_losses = P(None, "data")

    # Donation matches the single-device epoch builders: adapters/opt-state
    # always; the cache only where it is carried out (populate). Off-CPU
    # this keeps one copy of the fleet activation cache live, not two.
    from repro.core import donate_argnums

    populate_sharded = jax.jit(shard_map(
        populate_body, mesh=mesh,
        in_specs=(s_params, s_stack, s_opt, s_cache, P("data"), P("data"), s_idx, s_rt),
        out_specs=(s_stack, s_opt, s_cache, s_losses),
        check_rep=False,
    ), donate_argnums=donate_argnums(1, 2, 3))
    cached_sharded = jax.jit(shard_map(
        cached_body, mesh=mesh,
        in_specs=(s_params, s_stack, s_opt, s_cache, s_idx, s_rt),
        out_specs=(s_stack, s_opt, s_losses),
        check_rep=False,
    ), donate_argnums=donate_argnums(1, 2))

    losses, times = [], []
    for e in range(args.epochs):
        idx_mat = jnp.asarray(FF.fleet_index_matrix(e, n_t, n_per, bpt))
        t0 = time.perf_counter()
        if e == 0:
            stacked, opt_state, cache, ls = populate_sharded(
                params, stacked, opt_state, cache,
                tokens_flat, labels_flat, idx_mat, row_tenant,
            )
        else:
            stacked, opt_state, ls = cached_sharded(
                params, stacked, opt_state, cache, idx_mat, row_tenant
            )
        jax.block_until_ready(ls)
        dt = time.perf_counter() - t0
        times.append(dt)
        losses.append(np.asarray(ls))
        kind = "populate" if e == 0 else "cached  "
        print(f"epoch {e} [{kind}] mean loss {float(np.mean(ls)):.4f} "
              f"time {dt:.2f}s ({n_t / dt:.1f} tenants/s/epoch)")

    losses = np.stack(losses)  # (epochs, steps, n_tenants)
    out = {"losses": losses, "epoch_times": times, "devices": args.devices}

    if args.check_parity:
        ref = FF.fleet_finetune(
            jax.random.key(3), cfg, sl, params, tokens, labels,
            epochs=args.epochs, batch_per_tenant=bpt, optimizer=adamw(args.lr),
            use_kernel=args.use_kernel,
        )
        diff = float(np.max(np.abs(ref.losses - losses)))
        print(f"parity_max_abs_diff={diff:.3e}")
        out["parity_max_abs_diff"] = diff
        if diff > 1e-5:
            # The CI verification step must FAIL on divergence, not just
            # print it (XLA fusion differences stay well below this).
            raise SystemExit(f"sharded/single-device parity broken: {diff:.3e}")
    return out


def _runtime_main(args, cfg, sl, params, tokens, labels, bpt) -> dict:
    """Single-device fleet epochs as one interleaved runtime session:
    ingest every tenant's samples (the populate forwards), then per-epoch
    grouped ``adapt`` calls with pool write-back. Bitwise-identical to
    ``fleet_finetune`` on the kernel path (DESIGN.md §9), which
    ``--check-parity`` asserts at zero tolerance here."""
    import time

    import jax
    import numpy as np

    from repro.core import fleet_finetune as FF
    from repro.core.runtime import SessionRuntime
    from repro.optim.optimizers import adamw

    if args.check_parity and args.mode != "full":
        raise SystemExit(
            "--check-parity on the single-device runtime path requires "
            "--mode full: int8 cached epochs intentionally train on the "
            "quantised cache, while the offline populate epoch steps on "
            "full-precision activations (DESIGN.md §9)"
        )
    n_t, n_per = args.tenants, args.samples
    rt = SessionRuntime(
        cfg, sl, params, max_tenants=n_t, samples_per_tenant=n_per,
        seq=args.seq, lr=args.lr, use_kernel=args.use_kernel,
    )
    t0 = time.perf_counter()
    for t in range(n_t):
        for lo in range(0, n_per, bpt):
            rt.ingest(t, tokens[t, lo:lo + bpt], labels[t, lo:lo + bpt])
    ingest_s = time.perf_counter() - t0

    losses, times = [], []
    for e in range(args.epochs):
        t0 = time.perf_counter()
        out = rt.adapt(epochs=1, batch_per_tenant=bpt, key=jax.random.key(3))
        ls = np.stack([out["losses"][t][0] for t in range(n_t)], axis=-1)
        dt = time.perf_counter() - t0
        times.append(dt)
        losses.append(ls)
        kind = "populate" if e == 0 else "cached  "
        extra = f" (+{ingest_s:.2f}s ingest)" if e == 0 else ""
        print(f"epoch {e} [{kind}] mean loss {float(np.mean(ls)):.4f} "
              f"time {dt:.2f}s{extra} ({n_t / dt:.1f} tenants/s/epoch)")

    losses = np.stack(losses)  # (epochs, steps, n_tenants)
    out = {"losses": losses, "epoch_times": times, "devices": 1}

    if args.check_parity:
        ref = FF.fleet_finetune(
            jax.random.key(3), cfg, sl, params, tokens, labels,
            epochs=args.epochs, batch_per_tenant=bpt, optimizer=adamw(args.lr),
            use_kernel=args.use_kernel,
        )
        diff = float(np.max(np.abs(ref.losses - losses)))
        print(f"parity_max_abs_diff={diff:.3e}")
        out["parity_max_abs_diff"] = diff
        if diff > 0.0:
            # The interleaved session reproduces the offline trainer
            # BITWISE on this path — hold it to exactly that.
            raise SystemExit(f"runtime/offline parity broken: {diff:.3e}")
    return out


if __name__ == "__main__":
    main()
