"""Analytic MODEL_FLOPS per (arch x shape x step).

MODEL_FLOPS = the textbook useful compute: 6*N*D for dense training
(2 fwd + 4 bwd per matmul param per token), 6*N_active*D for MoE, plus
attention score/value terms; decode counts 2*N_active per token plus the
KV-cache dot products. Comparing against the compiled HLO dot-FLOPs
surfaces remat recompute and sharding-padding waste (§Roofline ratio).
"""

from __future__ import annotations

from repro.launch.shapes import SHAPES
from repro.models.config import ModelConfig


def _embed_params(cfg: ModelConfig) -> int:
    return cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)


def _matmul_params(cfg: ModelConfig) -> int:
    """Active params that participate in matmuls per token (excl. the
    embedding gather; the tied readout matmul is added separately)."""
    return cfg.active_param_count() - _embed_params(cfg)


def _attention_flops_per_seq(cfg: ModelConfig, s: int, causal: bool = True) -> float:
    """QK^T + PV flops for one sequence of length s across all layers."""
    hd = cfg.resolved_head_dim
    total = 0.0
    for kind in cfg.layer_kinds():
        if not kind.startswith("attn"):
            continue
        window = cfg.sliding_window if kind == "attn_local" else 0
        if window and window < s:
            pairs = s * window  # each query sees <= window keys
        else:
            pairs = s * (s + 1) / 2 if causal else s * s
        total += 2 * 2 * cfg.n_heads * hd * pairs  # QK + PV, 2 flops/MAC
    return total


def reuse_saved_flops(cfg: ModelConfig, prefix_tokens: int) -> float:
    """Prefill FLOPs one prefix-reuse admission skips: the matmul stack
    over ``prefix_tokens`` positions plus their causal attention pairs
    (the gathered KV blocks replace both). The readout is NOT saved — the
    tail prefill still produces the next-token logits."""
    if prefix_tokens <= 0:
        return 0.0
    return (2.0 * _matmul_params(cfg) * prefix_tokens
            + _attention_flops_per_seq(cfg, prefix_tokens))


def _decode_attn_flops(cfg: ModelConfig, ctx: int, batch: int) -> float:
    hd = cfg.resolved_head_dim
    total = 0.0
    for kind in cfg.layer_kinds():
        if not kind.startswith("attn"):
            continue
        window = cfg.sliding_window if kind == "attn_local" else 0
        keys = min(ctx, window) if window else ctx
        total += 2 * 2 * cfg.n_heads * hd * keys * batch
    return total


def _recurrence_flops_per_token(cfg: ModelConfig) -> float:
    """Elementwise state-update flops per token (mamba/mLSTM dominate; these
    sit inside the time scan that HLO cost analysis counts once)."""
    total = 0.0
    for kind in cfg.layer_kinds():
        if kind == "mamba":
            mc = cfg.mamba
            di = mc.d_inner(cfg.d_model)
            total += 6.0 * di * mc.d_state  # exp, mul-add state, C dot
        elif kind == "mlstm":
            xc = cfg.xlstm
            di = int(cfg.d_model * xc.mlstm_proj_factor)
            hd = di // cfg.n_heads
            total += 8.0 * cfg.n_heads * hd * hd  # C update + Cq read
        elif kind == "slstm":
            total += 16.0 * cfg.d_model
    return total


def readout_flops(cfg: ModelConfig, tokens: float) -> float:
    return 2.0 * tokens * cfg.d_model * cfg.vocab_size


def model_flops(cfg: ModelConfig, shape_name, step: str) -> float:
    """Global (all-chips) useful FLOPs for one step.

    ``shape_name`` is either a registered ``SHAPES`` key or an explicit
    ``(batch, seq)`` pair — benchmark code measures at shapes that are not
    registry cells."""
    if isinstance(shape_name, str):
        shape = SHAPES[shape_name]
        b, s = shape.batch, shape.seq
    else:
        b, s = shape_name
    tokens = float(b * s)
    n = _matmul_params(cfg)

    if step in ("train", "finetune_populate"):
        mm = 6.0 * n * tokens
        attn = 3.0 * b * _attention_flops_per_seq(cfg, s)  # fwd + 2x bwd
        head = 3.0 * readout_flops(cfg, tokens)
        rec = 3.0 * tokens * _recurrence_flops_per_token(cfg)
        if step == "finetune_populate":
            # Frozen backbone: forward only (1/3 of the train cost) + adapter
            # terms (negligible) + full readout fwd/bwd.
            return (mm + attn + rec) / 3.0 + head
        return mm + attn + head + rec

    if step == "finetune_cached":
        # Zero backbone compute: adapter sum fwd+bwd + readout fwd+bwd.
        r = 16  # default rank used in the dry-run cells
        adapters = 6.0 * tokens * cfg.n_layers * (2.0 * cfg.d_model * r)
        return adapters + 3.0 * readout_flops(cfg, tokens)

    if step == "prefill":
        mm = 2.0 * n * tokens
        attn = b * _attention_flops_per_seq(cfg, s)
        rec = tokens * _recurrence_flops_per_token(cfg)
        return mm + attn + rec + readout_flops(cfg, float(b))

    if step == "decode":
        mm = 2.0 * n * b
        attn = _decode_attn_flops(cfg, s, b)
        rec = b * _recurrence_flops_per_token(cfg)
        return mm + attn + rec + readout_flops(cfg, float(b))

    raise ValueError(step)
