"""Post-compile HLO analysis: collective-traffic extraction.

``compiled.cost_analysis()`` has no collective-bytes property, so we parse
the optimized HLO text: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction contributes its estimated
*per-device traffic*, and instructions inside ``while`` bodies (lax.scan!)
are multiplied by the loop trip count (recovered from the loop condition's
``compare(iv, constant(N)), direction=LT`` pattern) — XLA's own cost
analysis counts loop bodies only once, which would undercount a scanned
layer stack by n_periods.

Traffic conventions (ring algorithms, per device):
  all-gather         : result_bytes * (n-1)/n            ~ result bytes
  reduce-scatter     : input ~ result*n -> result_bytes * (n-1)
  all-reduce         : 2 * operand_bytes * (n-1)/n       ~ 2 * result bytes
  all-to-all         : result_bytes * (n-1)/n
  collective-permute : result bytes
We approximate (n-1)/n ~ 1 (n = 16..512 here) and do not know n per op
(subgroups), so the reported number is a slight over-estimate.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+) \(")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)"
)
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_CALL_RE = re.compile(r" call\(.*?\), to_apply=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"%?([\w\.\-]+) = s32\[\] constant\((\d+)\)")
_COMPARE_RE = re.compile(
    r"compare\(\s*[^)]*?%?([\w\.\-]+),\s*[^)]*?%?([\w\.\-]+)\s*\), direction=LT"
)

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


_RESULT_TYPE_RE = re.compile(r"^\s*(?:ROOT )?%?[\w\.\-]+ = (.+?) [\w\-]+\(")


def _first_shape_bytes(text: str) -> float:
    """Bytes of the instruction's result type (tuple results: sum members).

    The type sits between '=' and the op name; tuple types contain parens,
    so match up to the op-name-then-paren rather than the first '('."""
    m = _RESULT_TYPE_RE.match(text)
    head = m.group(1) if m else text.split("(", 1)[0]
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(head):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    per_op_bytes: dict[str, float]
    total_bytes: float
    count: int

    def as_dict(self):
        return {
            "per_op_bytes": dict(self.per_op_bytes),
            "total_bytes": self.total_bytes,
            "count": self.count,
        }


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """Computation name -> body lines. Headers sit at column 0 and open a
    brace; bodies are indented (robust to tuple-typed params with nested
    parens, which defeat naive paren matching)."""
    comps: dict[str, list[str]] = {}
    current = None
    for line in hlo.splitlines():
        if line and not line[0].isspace():
            m = _COMP_RE.match(line)
            if m and line.rstrip().endswith("{"):
                current = m.group(1)
                comps[current] = []
                continue
        if current is not None:
            comps[current].append(line)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Recover scan trip count from the condition computation (default 1)."""
    consts = {}
    for ln in cond_lines:
        for name, val in _CONST_RE.findall(ln):
            consts[name] = int(val)
    for ln in cond_lines:
        m = _COMPARE_RE.search(ln)
        if m:
            for op in m.groups():
                if op in consts:
                    return max(1, consts[op])
    # fallback: any s32 constant in the condition
    if consts:
        return max(consts.values())
    return 1


def _loop_multipliers(comps: dict[str, list[str]]) -> dict[str, float]:
    """Per-computation execution multiplier from enclosing while loops.

    Trip counts come from XLA's ``backend_config known_trip_count`` on the
    while instruction (authoritative for lax.scan), falling back to the
    condition computation's ``compare(iv, constant), direction=LT``."""
    mult: dict[str, float] = defaultdict(lambda: 1.0)
    edges: list[tuple[str, str, float]] = []  # (parent, child, factor)
    for name, lines in comps.items():
        for ln in lines:
            m = _WHILE_RE.search(ln)
            if m:
                cond, body = m.group(1), m.group(2)
                mt = _TRIP_RE.search(ln)
                tc = int(mt.group(1)) if mt else _trip_count(comps.get(cond, []))
                edges.append((name, body, float(tc)))
                edges.append((name, cond, float(tc)))
            mc = _CALL_RE.search(ln)
            if mc:
                edges.append((name, mc.group(1), 1.0))

    for _ in range(8):  # nesting depth bound
        changed = False
        for parent, child, factor in edges:
            want = mult[parent] * factor
            if mult[child] < want:
                mult[child] = want
                changed = True
        if not changed:
            break
    return mult


def analyze_collectives(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)
    mult = _loop_multipliers(comps)
    per_op: dict[str, float] = defaultdict(float)
    count = 0
    for name, lines in comps.items():
        m = mult[name]
        for ln in lines:
            for op in COLLECTIVE_OPS:
                if f" {op}(" in ln or f" {op}-start(" in ln:
                    nbytes = _first_shape_bytes(ln)
                    if op == "all-reduce":
                        nbytes *= 2.0
                    count += 1
                    per_op[op] += nbytes * m
                    break
    total = sum(per_op.values())
    return CollectiveStats(per_op_bytes=dict(per_op), total_bytes=total, count=count)


# ---------------------------------------------------------------------------
# Dot-FLOP extraction.
#
# ``cost_analysis()['flops']`` on the CPU backend is polluted by float-
# normalisation (bf16 ops rewritten to f32 with full-tensor converts/copies
# counted as flops) and misses while-loop trip counts. MXU-relevant compute
# is the dots; we count them from the optimized HLO with loop multipliers:
# flops(dot) = 2 * prod(result_dims) * prod(contracting dims of lhs).
# ---------------------------------------------------------------------------

_INSTR_RE = re.compile(r"^\s*(?:ROOT )?%?([\w\.\-]+) = (\S+) ")
_DOT_RE = re.compile(
    r"^\s*(?:ROOT )?%?([\w\.\-]+) = (\S+) dot\(([^)]*)\).*?"
    r"lhs_contracting_dims=\{([0-9,]*)\}"
)


def _shape_dims(shape_str: str) -> tuple[str, list[int]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def analyze_dot_flops(hlo: str) -> float:
    """Per-device dot FLOPs (2*M*N*K), loop-multiplied."""
    comps = _split_computations(hlo)
    mult = _loop_multipliers(comps)
    total = 0.0
    for cname, lines in comps.items():
        shapes: dict[str, str] = {}
        for ln in lines:
            mi = _INSTR_RE.match(ln)
            if mi:
                shapes[mi.group(1)] = mi.group(2)
        for ln in lines:
            md = _DOT_RE.match(ln)
            if not md:
                continue
            out_name, out_shape, operands, lhs_cdims = md.groups()
            _, out_dims = _shape_dims(out_shape)
            # Operands are either typed ("f32[128,256]{1,0} %ar, ...") or
            # bare ("%ar, %w"). Shape literals contain commas, so prefer a
            # direct shape scan over comma-splitting.
            op_shapes = _SHAPE_RE.findall(operands)
            if op_shapes:
                lhs_dims = [int(d) for d in op_shapes[0][1].split(",") if d]
            else:
                lhs_name = operands.split(",")[0].strip().lstrip("%")
                _, lhs_dims = _shape_dims(shapes.get(lhs_name, ""))
            k = 1
            for ci in (int(c) for c in lhs_cdims.split(",") if c):
                if ci < len(lhs_dims):
                    k *= lhs_dims[ci]
            n_out = 1
            for d in out_dims:
                n_out *= d
            total += 2.0 * n_out * k * mult[cname]
    return total
