"""Production mesh construction.

A function (never a module-level constant) so importing this module never
touches jax device state. The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; smoke tests and benches see the single real CPU device.
"""

from __future__ import annotations

import jax

from repro.runtime.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2) -> jax.sharding.Mesh:
    """Small mesh for CPU tests (requires forced host device count >= n*m)."""
    return make_mesh((n_data, n_model), ("data", "model"))


def mesh_batch_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Axes that shard the global batch."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def mesh_chip_count(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size
