"""Roofline analysis over the dry-run records (§Roofline of EXPERIMENTS.md).

Per (arch x shape x step x mesh):

    compute_s    = dot_flops_per_device / PEAK_FLOPS        (197 TF/s bf16)
    memory_s     = bytes_accessed_per_device / HBM_BW       (819 GB/s)
    collective_s = collective_bytes_per_device / ICI_BW     (~50 GB/s/link)

All three numerators are per-device (the XLA SPMD module is one device's
program). dot_flops comes from our HLO dot parser (loop-aware; the CPU
backend's cost_analysis 'flops' is polluted by f32 normalisation).
bytes_accessed is cost_analysis's number: an over-estimate on this CPU
backend (bf16->f32 materialisation roughly doubles traffic; treat the
memory term as an upper bound — noted in the report).

MFU_model = MODEL_FLOPS / (chips * PEAK * max(terms)): useful-model-flops
utilisation at the modeled bottleneck — the §Perf score.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline \
        --in benchmarks/dryrun_baseline.json --md
"""

from __future__ import annotations

import argparse
import json

PEAK_FLOPS = 197e12     # TPU v5e bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link


def analyze_record(rec: dict) -> dict:
    from repro.configs.registry import get_config
    from repro.launch.flops import model_flops

    if "error" in rec:
        return dict(rec)
    chips = rec["chips"]
    compute_s = rec["dot_flops"] / PEAK_FLOPS
    memory_s = rec["bytes_accessed"] / HBM_BW
    collective_s = rec["collective_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())

    cfg = get_config(rec["arch"])
    mf = model_flops(cfg, rec["shape"], rec["step"])
    hlo_global = rec["dot_flops"] * chips
    ratio = mf / hlo_global if hlo_global else 0.0
    mfu = mf / (chips * PEAK_FLOPS * step_time) if step_time else 0.0

    out = dict(rec)
    out.update(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        step_time_s=step_time,
        model_flops=mf,
        model_to_hlo_ratio=ratio,
        mfu_model=mfu,
    )
    return out


MOVE_HINTS = {
    "compute": "cut recompute (remat policy) / pad-free sharding; compute is the wall",
    "memory": "fuse elementwise chains, keep bf16 end-to-end, larger per-step tiles",
    "collective": "reshard to cut all-gathers (seq-parallel residuals), overlap collectives with compute, compress DP grads",
}


def to_markdown(rows: list[dict]) -> str:
    hdr = (
        "| arch | shape | step | mesh | compute_s | memory_s | collective_s | "
        "dominant | MODEL_FLOPS | model/HLO | MFU_model |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    body = []
    for r in rows:
        if "error" in r:
            body.append(
                f"| {r['arch']} | {r['shape']} | {r['step']} | {r['mesh']} | "
                f"ERROR: {r['error'][:60]} | | | | | | |"
            )
            continue
        body.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} | {r['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| **{r['dominant']}** | {r['model_flops']:.2e} "
            f"| {r['model_to_hlo_ratio']:.2f} | {r['mfu_model']:.3f} |"
        )
    return hdr + "\n".join(body) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="benchmarks/dryrun_baseline.json")
    ap.add_argument("--out", default=None, help="write analyzed JSON here")
    ap.add_argument("--md", action="store_true", help="print markdown table")
    ap.add_argument("--mesh", default=None, help="filter: 16x16 or 2x16x16")
    args = ap.parse_args()

    with open(args.inp) as f:
        records = json.load(f)
    rows = [analyze_record(r) for r in records]
    if args.mesh:
        rows = [r for r in rows if r.get("mesh") == args.mesh]
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    if args.md:
        print(to_markdown(rows))
    else:
        for r in rows:
            if "error" in r:
                print(f"{r['arch']} {r['shape']} {r['mesh']}: ERROR")
                continue
            print(
                f"{r['arch']:24s} {r['shape']:12s} {r['step']:16s} {r['mesh']:7s} "
                f"C={r['compute_s']:.3f}s M={r['memory_s']:.3f}s "
                f"X={r['collective_s']:.3f}s dom={r['dominant']:10s} "
                f"MFU={r['mfu_model']:.3f} hint: {MOVE_HINTS[r['dominant']]}"
            )


if __name__ == "__main__":
    main()
