"""Interleaved continual-learning session CLI — the runtime's event loop,
now mesh-native and supervised.

The paper's deployment story end to end (DESIGN.md §9/§10): one
``SessionRuntime``, constructed over an explicit device mesh, processes an
interleaved stream of serve, ingest, and adapt events over a sharded
adapter pool and per-shard skip-cache engines. Each round, every tenant
(1) serves a mixed batch next to base-model traffic, (2) ingests freshly
"collected" samples — the populate forward that writes its cache partition
and returns logits, so ingestion is also a serving hit — and (3) runs a
grouped cached ``adapt`` whose write-back immediately changes what the
next serve returns.

  PYTHONPATH=src python -m repro.launch.run --arch stablelm-1.6b \
      --reduced --tenants 3 --rounds 2 --samples-per-round 4 --seq 16 \
      --gen 8 --adapt-epochs 2

Mesh + fault-tolerance controls:

  --devices N        run over an N-way data mesh (forced host devices on
                     CPU, set before the first jax import like dryrun.py)
  --mesh DxM         2-D session mesh (DESIGN.md §14): D data groups, each
                     serving/adapting from ONE backbone replica TP-sharded
                     over M model devices; overrides --devices with D*M
  --pipeline-stages N  with --scheduler and --mesh DxM (N == M): admission
                     prefill runs as a microbatched N-stage pipeline over
                     the model-axis ring; decode stays on the TP path
  --check-parity     run the SAME event stream twice — on the N-device
                     mesh and on a 1-device mesh with the identical
                     logical shard layout — and require ZERO tolerance on
                     adapters, adapt losses, pool slot tables, and serve
                     tokens. Device placement is numerically free
                     (DESIGN.md §10); this check enforces it.
  --checkpoint-dir D run the event stream under a ``SessionSupervisor``:
                     checkpoint at every event boundary, restart after
                     failure with zero event replay.
  --inject-failure K raise inside event K on its first execution (crash
                     drill; requires --checkpoint-dir).
  --elastic-devices M after the injected failure, restart the session on
                     only M devices (elastic re-mesh: same logical shards,
                     fewer physical devices — the continuation is bitwise).

Prints per-event wall times and the runtime's path/tier counters; --json
dumps the same metrics machine-readably.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def _parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--samples-per-round", type=int, default=4)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--adapt-epochs", type=int, default=1)
    ap.add_argument("--batch-per-tenant", type=int, default=4)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--mode", default="full", choices=["full", "int8"])
    ap.add_argument("--pool-compress", choices=["int8", "int4", "nf4"],
                    default=None)
    ap.add_argument("--control", action="store_true",
                    help="enable the adapter control plane (DESIGN.md §13): "
                         "per-tenant shadow eval inside adapt, regression "
                         "gate on write-back, versioned slots with rollback")
    ap.add_argument("--control-threshold", type=float, default=0.0,
                    help="max tolerated held-out regression (post - pre) "
                         "before the gate fires")
    ap.add_argument("--control-mode", default="reject",
                    choices=["reject", "quarantine"],
                    help="what a gated write-back does to training state")
    ap.add_argument("--holdout-every", type=int, default=4,
                    help="every N-th ingested row per tenant is held out "
                         "for shadow eval")
    ap.add_argument("--history-depth", type=int, default=2,
                    help="previous adapter versions kept per tenant for "
                         "rollback")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--hbm-mb", type=float, default=0.0,
                    help="cache HBM budget in MiB; 0 = fully device-resident")
    ap.add_argument("--unroll", type=int, default=1)
    ap.add_argument("--devices", type=int, default=1,
                    help="data-mesh devices (forced on CPU via XLA_FLAGS)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="2-D session mesh, e.g. 2x2: D data groups, each "
                         "serving from ONE backbone replica TP-sharded over "
                         "M model devices (DESIGN.md §14). Overrides "
                         "--devices with D*M; M=1 is the data-only mesh.")
    ap.add_argument("--pipeline-stages", type=int, default=0, metavar="N",
                    help="pipeline the scheduler's admission prefill over N "
                         "stages (requires --mesh DxM with N == M and "
                         "--scheduler; decode stays on the TP path)")
    ap.add_argument("--shards", type=int, default=None,
                    help="logical shard count (default: --devices, or D "
                         "with --mesh DxM)")
    ap.add_argument("--check-parity", action="store_true",
                    help="sharded session vs 1-device same-layout twin at "
                         "zero tolerance (requires --devices >= 2)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="supervise the event stream with per-event "
                         "session checkpoints")
    ap.add_argument("--inject-failure", type=int, default=None, metavar="K",
                    help="crash inside event K once (requires "
                         "--checkpoint-dir)")
    ap.add_argument("--elastic-devices", type=int, default=None, metavar="M",
                    help="restart on only M devices after the injected "
                         "failure")
    ap.add_argument("--scheduler", action="store_true",
                    help="serve through the continuous-batching request "
                         "scheduler (per-request admission + drain) instead "
                         "of pre-formed serve batches; ingest/adapt events "
                         "are unchanged")
    ap.add_argument("--sched-chunk", type=int, default=4,
                    help="decode steps per scheduler dispatch")
    ap.add_argument("--json", default=None, help="write metrics to this path")
    return ap.parse_args(argv)


def main(argv=None) -> dict:
    args = _parse_args(argv)
    mesh_dm = None
    if args.mesh:
        d, _, m = args.mesh.lower().partition("x")
        try:
            mesh_dm = (int(d), int(m or 1))
        except ValueError:
            raise SystemExit(f"--mesh wants DxM (e.g. 2x2), got {args.mesh!r}")
        if mesh_dm[0] < 1 or mesh_dm[1] < 1:
            raise SystemExit(f"--mesh axes must be >= 1, got {args.mesh!r}")
        args.devices = mesh_dm[0] * mesh_dm[1]
    n_model = mesh_dm[1] if mesh_dm else 1
    if args.pipeline_stages:
        if args.pipeline_stages != n_model or n_model < 2:
            raise SystemExit(
                "--pipeline-stages N repurposes the model axis as the "
                f"pipeline ring, so N must equal M of --mesh DxM (got "
                f"N={args.pipeline_stages}, M={n_model})"
            )
        if not args.scheduler:
            raise SystemExit(
                "--pipeline-stages pipelines the scheduler's admission "
                "prefill; add --scheduler"
            )
    if n_model > 1 and args.use_kernel:
        raise SystemExit(
            "grouped Pallas kernels do not partition over the model axis; "
            "drop --use-kernel for --mesh with M > 1"
        )
    if n_model > 1 and args.checkpoint_dir:
        raise SystemExit(
            "supervised restart re-meshes along the data axis only; "
            "--checkpoint-dir is not supported with --mesh M > 1 yet"
        )
    if args.devices > 1 and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        # Must land before the first jax import (same trick as dryrun.py).
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )
    if args.check_parity and args.devices < 2:
        raise SystemExit(
            "--check-parity compares an N-device mesh against its 1-device "
            "twin; for the single-device session's bitwise bar against the "
            "offline trainer use launch/fleet.py --devices 1 --check-parity"
        )
    if args.inject_failure is not None and not args.checkpoint_dir:
        raise SystemExit("--inject-failure requires --checkpoint-dir")

    import jax
    import numpy as np

    from repro.configs import get_config, reduce_config
    from repro.core import lm_skiplora as SL
    from repro.core.control_plane import ControlConfig
    from repro.core.runtime import SessionRuntime
    from repro.models.lm import init_lm
    from repro.runtime.fault import SessionSupervisor, elastic_session_mesh
    from repro.runtime.sharding import make_mesh

    if len(jax.devices()) < args.devices:
        raise SystemExit(
            f"need {args.devices} devices, have {len(jax.devices())} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count before "
            "jax imports, or let this CLI do it by running it first)"
        )
    n_shards = (
        args.shards if args.shards is not None
        else (mesh_dm[0] if mesh_dm else args.devices)
    )
    if args.tenants % n_shards:
        raise SystemExit(
            f"--tenants {args.tenants} must divide over {n_shards} shards"
        )

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    sl = SL.SkipLoRAConfig(rank=args.rank, mode=args.mode,
                           cache_dtype="float32",
                           use_fused_kernel=args.use_kernel)
    params = init_lm(jax.random.key(0), cfg)
    control_cfg = (
        ControlConfig(
            holdout_every=args.holdout_every,
            threshold=args.control_threshold,
            mode=args.control_mode,
            history_depth=args.history_depth,
        )
        if args.control else None
    )
    names = [f"tenant-{t}" for t in range(args.tenants)]
    prompts = jax.random.randint(
        jax.random.key(1), (args.tenants + 1, args.prompt_len), 0, cfg.vocab_size
    )

    def make_runtime(n_devices: int) -> SessionRuntime:
        # The 1-device parity twin always runs the data-only layout: device
        # placement (including the TP split) must be numerically free.
        if n_model > 1 and n_devices == args.devices:
            mesh = make_mesh(
                mesh_dm, ("data", "model"), devices=jax.devices()[:n_devices]
            )
            stages = args.pipeline_stages
        else:
            mesh = make_mesh(
                (n_devices,), ("data",), devices=jax.devices()[:n_devices]
            )
            stages = 0
        return SessionRuntime(
            cfg, sl, params,
            max_tenants=args.tenants,
            samples_per_tenant=args.rounds * args.samples_per_round,
            seq=args.seq, lr=args.lr, use_kernel=args.use_kernel,
            pool_compress=args.pool_compress,
            hbm_budget_bytes=(int(args.hbm_mb * 2**20) if args.hbm_mb > 0 else None),
            mesh=mesh, placement_shards=n_shards, control=control_cfg,
            pipeline_stages=stages,
        )

    # ---- the event stream: one closure per serve / ingest / adapt ---------
    # Per-tenant sample streams are derived from (round, tenant), NOT from a
    # carried RNG, so a restarted session regenerates identical batches.
    def tenant_batch(rnd: int, t: int):
        k1, k2 = jax.random.split(jax.random.fold_in(jax.random.key(2), rnd * args.tenants + t))
        toks = jax.random.randint(
            k1, (args.samples_per_round, args.seq), 0, cfg.vocab_size
        )
        labs = jax.random.randint(
            k2, (args.samples_per_round, args.seq), 0, cfg.vocab_size
        )
        return toks, labs

    def sched_serve(rt: SessionRuntime, who):
        """One serve event through the request scheduler: enqueue each row
        as its own request (staggered admission, recycled rows), drain, and
        stack the per-request token streams back into the (B, gen) layout
        the batch path returns — so --check-parity compares unchanged."""
        if rt._scheduler is None:
            rt.attach_scheduler(
                max_batch=args.tenants + 1, max_prompt=args.prompt_len,
                max_new_cap=args.gen, chunk=args.sched_chunk,
                admit_bucket=min(2, args.tenants + 1),
            )
        reqs = [
            rt.enqueue_serve(t, np.asarray(prompts[j]), max_new=args.gen)
            for j, t in enumerate(who)
        ]
        rt.drain()
        return jax.numpy.stack([jax.numpy.asarray(r.result()) for r in reqs])

    def serve_event(rt, who):
        if args.scheduler:
            return sched_serve(rt, who)
        return rt.serve(who, prompts, max_new=args.gen, unroll=args.unroll)

    events, labels = [], []

    def ev(label, fn):
        events.append(fn)
        labels.append(label)

    ev("serve/base", lambda rt, i: serve_event(
        rt, [None] * (args.tenants + 1)
    ))
    for rnd in range(args.rounds):
        for t, name in enumerate(names):
            ev(f"ingest/{name}/r{rnd}", lambda rt, i, rnd=rnd, t=t, name=name:
               rt.ingest(name, *tenant_batch(rnd, t)))
        ev(f"adapt/r{rnd}", lambda rt, i: rt.adapt(
            names, epochs=args.adapt_epochs,
            batch_per_tenant=args.batch_per_tenant, key=jax.random.key(3),
        ))
        ev(f"serve/mixed/r{rnd}", lambda rt, i: serve_event(
            rt, [None] + names
        ))

    timings: dict[str, float] = {}

    def run_stream(rt: SessionRuntime) -> dict[int, object]:
        results = {}
        for i, (fn, label) in enumerate(zip(events, labels)):
            t0 = time.perf_counter()
            out = fn(rt, i)
            for leaf in jax.tree.leaves(out):
                if isinstance(leaf, jax.Array):
                    leaf.block_until_ready()
            dt = time.perf_counter() - t0
            kind = label.split("/")[0]
            timings[kind] = timings.get(kind, 0.0) + dt
            print(f"{label:<24s} {dt:6.2f}s")
            results[i] = out
        return results

    t_session0 = time.perf_counter()
    if args.checkpoint_dir:
        # ---- supervised session: checkpoint/restart at event boundaries --
        healthy = {"n": args.devices}
        fail_at = {"k": args.inject_failure}

        def boot_runtime():
            # Elastic re-mesh over whatever survived: the session's logical
            # shard layout is a checkpoint property; only placement changes.
            mesh = elastic_session_mesh(jax.devices()[: healthy["n"]])
            return SessionRuntime(
                cfg, sl, params,
                max_tenants=args.tenants,
                samples_per_tenant=args.rounds * args.samples_per_round,
                seq=args.seq, lr=args.lr, use_kernel=args.use_kernel,
                pool_compress=args.pool_compress,
                hbm_budget_bytes=(
                    int(args.hbm_mb * 2**20) if args.hbm_mb > 0 else None
                ),
                mesh=mesh, placement_shards=n_shards, control=control_cfg,
            )

        raw_events = list(events)

        def wrap(i, fn):
            def run_event(rt, idx):
                if fail_at["k"] == idx:
                    fail_at["k"] = None  # crash once
                    if args.elastic_devices is not None:
                        healthy["n"] = args.elastic_devices  # hosts died
                    raise RuntimeError(f"injected failure in event {idx}")
                return fn(rt, idx)
            return run_event

        sup = SessionSupervisor(args.checkpoint_dir, save_every=1)
        rt, info = sup.run(
            boot_runtime, [wrap(i, fn) for i, fn in enumerate(raw_events)]
        )
        print(f"supervised: {len(events)} events, {info['restarts']} restarts, "
              f"resumed at event {info['resumed_at']}, "
              f"{len(info['results'])} executed this incarnation "
              f"(zero replay of completed events)")
        results = info["results"]
    else:
        rt = make_runtime(args.devices)
        results = run_stream(rt)
    session_s = time.perf_counter() - t_session0

    stats = rt.stats()
    # Backbone memory accounting: total param bytes vs the peak any single
    # device actually holds of shard 0's replica — 1.0x when replicated,
    # ~Mx smaller per device on a --mesh DxM TP split.
    bytes_total = sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree.leaves(params)
    )
    bytes_peak = max(
        sum(
            s.data.nbytes
            for x in jax.tree.leaves(rt._shard_params[0])
            for s in x.addressable_shards
            if s.device == d
        )
        for d in rt.mesh.devices.ravel()
    )
    metrics = {
        **{f"time/{k}_s": v for k, v in timings.items()},
        "session/tenants_per_s": args.tenants * args.rounds / session_s,
        "session/wall_s": session_s,
        "session/devices": float(args.devices),
        "session/shards": float(n_shards),
        "session/model_parallel": float(rt.model_parallel),
        "session/pipeline_stages": float(rt.pipeline_stages),
        "session/backbone_bytes_total": float(bytes_total),
        "session/backbone_bytes_per_device_peak": float(bytes_peak),
        **stats,
    }
    cm = rt.control_metrics()
    if cm is not None:
        # Scalar gate counters flatten next to the runtime counters; the
        # full per-tenant ledger (eval deltas, decisions) nests under
        # "control" in the JSON dump.
        for k in ("accepted", "rejected", "quarantined", "rollbacks"):
            metrics[f"control/{k}"] = float(cm[k])
        metrics["control"] = cm
    print(f"\nsession: {args.tenants} tenants x {args.rounds} rounds on "
          f"{args.devices} device(s) / {n_shards} shard(s) in "
          f"{session_s:.2f}s ({metrics['session/tenants_per_s']:.2f} "
          f"tenant-rounds/s)")
    for k in sorted(stats):
        print(f"  {k} = {stats[k]:.3f}")

    if args.check_parity:
        # The 1-device twin: same logical layout, same events. Placement
        # along the DATA axis is numerically free, so values are bitwise;
        # the model axis reorders float partial sums (TP contractions), so
        # adapters/losses there get a tight tolerance instead — while serve
        # TOKENS (temp-0 argmax) must match exactly on every mesh.
        print("\n--check-parity: replaying on the 1-device same-layout twin")
        twin = make_runtime(1)
        twin_results = run_stream(twin)
        diffs = []

        def values_match(x, y) -> bool:
            x, y = np.asarray(x), np.asarray(y)
            if n_model > 1:
                return bool(np.allclose(x, y, rtol=1e-3, atol=1e-5))
            return bool(np.array_equal(x, y))

        for name in names:
            a, b = rt.tenant(name).adapters, twin.tenant(name).adapters
            for leaf in ("A", "B"):
                if not values_match(a[leaf], b[leaf]):
                    diffs.append(f"adapters[{name}][{leaf}]")
        for i, label in enumerate(labels):
            if label.startswith("adapt/") and i in results:
                la = results[i]["losses"] if isinstance(results[i], dict) else None
                lb = twin_results[i]["losses"]
                for name in names:
                    if la is not None and not values_match(la[name], lb[name]):
                        diffs.append(f"losses[{label}][{name}]")
            if label.startswith("serve/") and i in results:
                if not np.array_equal(np.asarray(results[i]),
                                      np.asarray(twin_results[i])):
                    diffs.append(f"tokens[{label}]")
        if rt.pool.slot_table() != twin.pool.slot_table():
            diffs.append("pool slot tables")
        metrics["parity/diffs"] = float(len(diffs))
        if diffs:
            raise SystemExit(f"sharded/twin parity broken: {diffs}")
        bar = ("tokens exact; adapters/losses within TP float tolerance"
               if n_model > 1 else "bitwise (adapters, losses, tokens, "
               "slot tables)")
        print(f"parity OK: {args.devices}-device session == 1-device twin "
              f"— {bar}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return metrics


if __name__ == "__main__":
    main()
