"""Interleaved continual-learning session CLI — the runtime's event loop.

The paper's deployment story end to end (DESIGN.md §9): one
``SessionRuntime`` processes an interleaved stream of serve, ingest, and
adapt events over a shared adapter pool and skip-cache engine. Each round,
every tenant (1) serves a mixed batch next to base-model traffic, (2)
ingests freshly "collected" samples — the populate forward that writes its
cache partition and returns logits, so ingestion is also a serving hit —
and (3) runs a grouped cached ``adapt`` whose write-back immediately
changes what the next serve returns.

  PYTHONPATH=src python -m repro.launch.run --arch stablelm-1.6b \
      --reduced --tenants 3 --rounds 2 --samples-per-round 4 --seq 16 \
      --gen 8 --adapt-epochs 2

Prints per-event wall times and the runtime's path/tier counters; --json
dumps the same metrics machine-readably.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.runtime import SessionRuntime
from repro.models.lm import init_lm


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--samples-per-round", type=int, default=4)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--adapt-epochs", type=int, default=1)
    ap.add_argument("--batch-per-tenant", type=int, default=4)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--mode", default="full", choices=["full", "int8"])
    ap.add_argument("--pool-compress", choices=["int8"], default=None)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--hbm-mb", type=float, default=0.0,
                    help="cache HBM budget in MiB; 0 = fully device-resident")
    ap.add_argument("--unroll", type=int, default=1)
    ap.add_argument("--json", default=None, help="write metrics to this path")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    sl = SL.SkipLoRAConfig(rank=args.rank, mode=args.mode,
                           cache_dtype="float32",
                           use_fused_kernel=args.use_kernel)
    params = init_lm(jax.random.key(0), cfg)
    rt = SessionRuntime(
        cfg, sl, params,
        max_tenants=args.tenants,
        samples_per_tenant=args.rounds * args.samples_per_round,
        seq=args.seq, lr=args.lr, use_kernel=args.use_kernel,
        pool_compress=args.pool_compress,
        hbm_budget_bytes=(int(args.hbm_mb * 2**20) if args.hbm_mb > 0 else None),
    )
    names = [f"tenant-{t}" for t in range(args.tenants)]
    prompts = jax.random.randint(
        jax.random.key(1), (args.tenants + 1, args.prompt_len), 0, cfg.vocab_size
    )
    timings: dict[str, float] = {}

    def timed(name, fn):
        t0 = time.perf_counter()
        out = fn()
        for leaf in jax.tree.leaves(out):
            if isinstance(leaf, jax.Array):
                leaf.block_until_ready()
        dt = time.perf_counter() - t0
        timings[name] = timings.get(name, 0.0) + dt
        return out, dt

    # Round 0 serves base traffic for everyone (nothing registered yet).
    _, dt = timed("serve", lambda: rt.serve(
        [None] * (args.tenants + 1), prompts, max_new=args.gen,
        unroll=args.unroll,
    ))
    print(f"serve  [base x{args.tenants + 1}]      {dt:6.2f}s")

    rng = jax.random.key(2)
    t_session0 = time.perf_counter()
    for rnd in range(args.rounds):
        for t, name in enumerate(names):
            rng, k1, k2 = jax.random.split(rng, 3)
            toks = jax.random.randint(
                k1, (args.samples_per_round, args.seq), 0, cfg.vocab_size
            )
            labs = jax.random.randint(
                k2, (args.samples_per_round, args.seq), 0, cfg.vocab_size
            )
            _, dt = timed("ingest", lambda: rt.ingest(name, toks, labs))
            print(f"ingest [{name} round {rnd}]  {dt:6.2f}s "
                  f"({args.samples_per_round} rows + logits back)")
        out, dt = timed("adapt", lambda: rt.adapt(
            names, epochs=args.adapt_epochs,
            batch_per_tenant=args.batch_per_tenant, key=jax.random.key(3),
        ))
        mean_loss = float(jnp.mean(jnp.stack(
            [jnp.asarray(out["losses"][n]) for n in names]
        )))
        print(f"adapt  [round {rnd}, {args.adapt_epochs} ep, {out['path']}] "
              f"{dt:6.2f}s  mean loss {mean_loss:.4f}")
        # Mixed post-adapt batch: base row + every tenant's fresh slot.
        _, dt = timed("serve", lambda: rt.serve(
            [None] + names, prompts, max_new=args.gen, unroll=args.unroll,
        ))
        print(f"serve  [mixed x{args.tenants + 1}]     {dt:6.2f}s")
    session_s = time.perf_counter() - t_session0

    stats = rt.stats()
    metrics = {
        **{f"time/{k}_s": v for k, v in timings.items()},
        "session/tenants_per_s": args.tenants * args.rounds / session_s,
        "session/wall_s": session_s,
        **stats,
    }
    print(f"\nsession: {args.tenants} tenants x {args.rounds} rounds in "
          f"{session_s:.2f}s ({metrics['session/tenants_per_s']:.2f} "
          f"tenant-rounds/s)")
    for k in sorted(stats):
        print(f"  {k} = {stats[k]:.3f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return metrics


if __name__ == "__main__":
    main()
