"""Multi-tenant serving launcher — a thin CLI over ``core.runtime``.

The serving engine itself (compiled-function cache, scan-fused decode,
grouped adapter routing) lives in ``repro.core.runtime`` since the session
runtime unified serve and fleet fine-tune over one adapter pool (DESIGN.md
§9); this module re-exports the generation entry points for existing
callers (benchmarks, examples, tests) and keeps the CLI:

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --reduced --batch 4 --prompt-len 32 --gen 16 --tenants 3

The multi-tenant path routes through a ``SessionRuntime`` (pool lookup +
path selection per batch); the single-stack path calls ``generate``
directly. Both hit the same shared compiled-fn cache, so the runtime adds
no retrace or rebuild over the PR 2 engine.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.adapter_pool import AdapterPool  # noqa: F401 (re-export)
from repro.core.runtime import (  # noqa: F401 (public re-exports)
    _FN_CACHE,
    _cached_fn,
    _decode_scan_fn,
    _decode_step_fn,
    _prefill_fn,
    _prefill_grouped_fn,
    SessionRuntime,
    generate,
    generate_grouped,
    generate_loop,
)
from repro.models.lm import init_lm


def _demo_runtime(cfg, n_tenants: int, rank: int, compress, params) -> SessionRuntime:
    """Session with ``n_tenants`` pretend on-device fine-tunes (B != 0)."""
    sl = SL.SkipLoRAConfig(rank=rank)
    rt = SessionRuntime(
        cfg, sl, params, max_tenants=n_tenants, samples_per_tenant=1, seq=8,
        pool_compress=compress,
    )
    for t in range(n_tenants):
        ad = SL.init_adapters(jax.random.key(100 + t), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(200 + t), ad["B"].shape) * 0.02
        rt.pool.register(f"tenant-{t}", ad)
    return rt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--with-adapters", action="store_true")
    ap.add_argument("--tenants", type=int, default=0,
                    help="serve a multi-tenant batch over this many adapters")
    ap.add_argument("--pool-compress", choices=["int8"], default=None)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--unroll", type=int, default=1,
                    help="decode steps fused per scan iteration")
    ap.add_argument("--loop", action="store_true",
                    help="use the per-token loop instead of the fused scan")
    ap.add_argument("--scheduler", action="store_true",
                    help="serve through the continuous-batching request "
                         "scheduler (one request per batch row, staggered "
                         "admission) instead of one pre-formed batch")
    ap.add_argument("--chunk", type=int, default=4,
                    help="decode steps per scheduler dispatch")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    params = init_lm(jax.random.key(0), cfg)
    prompts = jax.random.randint(
        jax.random.key(3), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )

    if args.scheduler:
        if args.loop:
            ap.error("--loop and --scheduler are mutually exclusive")
        rt = _demo_runtime(cfg, max(args.tenants, 1), args.rank,
                           args.pool_compress, params)
        rt.attach_scheduler(
            max_batch=args.batch, max_prompt=args.prompt_len,
            max_new_cap=args.gen, chunk=args.chunk,
            admit_bucket=min(2, args.batch),
        )
        tenants = [None] + [
            f"tenant-{i % max(args.tenants, 1)}" for i in range(1, args.batch)
        ]
        t0 = time.perf_counter()
        reqs = [
            rt.enqueue_serve(t, prompts[i], max_new=args.gen,
                             temperature=args.temperature)
            for i, t in enumerate(tenants)
        ]
        rt.drain()
        dt = time.perf_counter() - t0
        toks = jax.numpy.stack([jax.numpy.asarray(r.result()) for r in reqs])
        c = rt.scheduler.counters
        print(f"[scheduler: {c['dispatch/admit']} admit + "
              f"{c['dispatch/step']} step dispatches, chunk {args.chunk}]")
        print(f"generated {toks.shape} in {dt:.2f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s incl. compile)")
        print("first sequences:", toks[:2, :8].tolist())
        return

    if args.tenants > 0:
        if args.loop:
            ap.error("--loop applies to single-stack serving; the grouped "
                     "multi-tenant path always uses the fused scan")
        rt = _demo_runtime(cfg, args.tenants, args.rank, args.pool_compress,
                           params)
        # Mixed batch: rows cycle through tenants; row 0 serves the base
        # model via the pinned zero slot.
        tenants = [None] + [
            f"tenant-{i % args.tenants}" for i in range(1, args.batch)
        ]
        t0 = time.perf_counter()
        toks = rt.serve(
            tenants, prompts, max_new=args.gen,
            temperature=args.temperature, unroll=args.unroll,
        )
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        print(f"[grouped x{args.tenants} tenants, pool "
              f"{rt.pool.nbytes() / 2**20:.1f} MiB, "
              f"compress={args.pool_compress}]")
    else:
        adapters_stack = None
        if args.with_adapters:
            sl = SL.SkipLoRAConfig(rank=args.rank)
            ad = SL.init_adapters(jax.random.key(1), cfg, sl)
            ad["B"] = jax.random.normal(jax.random.key(2), ad["B"].shape) * 0.01
            adapters_stack = SL.adapters_to_stack(ad, cfg)
        gen_fn = generate_loop if args.loop else functools.partial(
            generate, unroll=args.unroll
        )
        t0 = time.perf_counter()
        toks = gen_fn(
            params, cfg, prompts, max_new=args.gen,
            adapters_stack=adapters_stack, temperature=args.temperature,
        )
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0

    n_disp = args.gen if args.loop else 1
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s incl. compile; "
          f"{n_disp} decode dispatch{'es' if n_disp > 1 else ''})")
    print("first sequences:", toks[:2, :8].tolist())


if __name__ == "__main__":
    main()
