"""Batched serving launcher: prefill + decode loop with optional adapters.

Demonstrates serving a (reduced) model with batched requests and Skip-LoRA
adapters applied at decode time — the deployment path after an on-device
fine-tune (adapters are NOT mergeable into the backbone because the skip
topology bypasses it; the running skip-sum costs 2*L*R*(D_in+D_out) FLOPs
per token, <0.1% of a block forward).

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.models.lm import (
    init_lm,
    init_serve_caches,
    serve_decode,
    serve_prefill,
)


def generate(
    params, cfg, tokens, *, max_new: int, adapters_stack=None, temperature: float = 0.0
):
    """Greedy/temperature batched generation. Returns (B, max_new) tokens."""
    b, s = tokens.shape
    caches = init_serve_caches(cfg, b, s + max_new)
    prefill = jax.jit(
        lambda p, t, c: serve_prefill(p, cfg, t, c, adapters=adapters_stack)
    )
    decode = jax.jit(
        lambda p, t, pos, c: serve_decode(p, cfg, t, pos, c, adapters=adapters_stack)
    )
    logits, caches = prefill(params, tokens, caches)
    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    key = jax.random.key(0)
    for i in range(max_new):
        out.append(tok)
        logits, caches = decode(params, tok, jnp.asarray(s + i, jnp.int32), caches)
        if temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(sk, logits[:, 0] / temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--with-adapters", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    params = init_lm(jax.random.key(0), cfg)

    adapters_stack = None
    if args.with_adapters:
        sl = SL.SkipLoRAConfig(rank=8)
        ad = SL.init_adapters(jax.random.key(1), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(2), ad["B"].shape) * 0.01
        adapters_stack = SL.adapters_to_stack(ad, cfg)

    prompts = jax.random.randint(
        jax.random.key(3), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.perf_counter()
    toks = generate(
        params, cfg, prompts, max_new=args.gen,
        adapters_stack=adapters_stack, temperature=args.temperature,
    )
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s incl. compile)")
    print("first sequences:", toks[:2, :8].tolist())


if __name__ == "__main__":
    main()
