"""Multi-tenant serving launcher: scan-fused decode + grouped adapters.

The deployment path after an on-device fine-tune (DESIGN.md §7): adapters
are NOT mergeable into the backbone because the skip topology bypasses it,
so serving applies a running skip-sum — and at fleet scale every batch row
belongs to a *different* tenant, so the skip-sum becomes a grouped gather
from a stacked adapter pool (``core.adapter_pool.AdapterPool`` + the
grouped Pallas kernel).

Two structural fixes over the old per-token loop:

  - **Compiled-function cache**: prefill/decode jits are built once per
    (config, path) and keyed here; jax.jit then keys traces by shape. The
    old ``generate`` rebuilt ``jax.jit(lambda ...)`` closures per call —
    a fresh trace + compile every invocation.
  - **Scan-fused decode**: the whole ``max_new``-token generation is ONE
    XLA dispatch (``models.lm.decode_scan``) with sampling folded into the
    carry and KV caches donated, instead of ``max_new`` Python round-trips.
    ``generate_loop`` keeps the per-token path alive for benchmarks.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --reduced --batch 4 --prompt-len 32 --gen 16 --tenants 3
"""

from __future__ import annotations

import argparse
import functools
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import donate_argnums
from repro.core import lm_skiplora as SL
from repro.core.adapter_pool import AdapterPool
from repro.models.lm import (
    decode_scan,
    init_lm,
    init_serve_caches,
    sample_token,
    serve_decode,
    serve_prefill,
    serve_prefill_grouped,
)

Params = Any

#: (name, cfg, extras) -> jitted callable. cfg is a frozen dataclass and
#: hashes by value; jax.jit keys compiled traces by argument shape below
#: this cache, so repeated calls at a new (batch, seq) retrace but never
#: rebuild the jit wrapper itself.
_FN_CACHE: dict[tuple, Any] = {}


def _cached_fn(name: str, cfg, make, extras: tuple = ()):
    key = (name, cfg, *extras)
    fn = _FN_CACHE.get(key)
    if fn is None:
        fn = _FN_CACHE[key] = make()
    return fn


def _prefill_fn(cfg):
    def make():
        def f(params, tokens, caches, adapters):
            return serve_prefill(params, cfg, tokens, caches, adapters=adapters)

        return jax.jit(f)

    return _cached_fn("prefill", cfg, make)


def _prefill_grouped_fn(cfg, use_kernel: bool):
    def make():
        def f(params, tokens, caches, pools, idx):
            return serve_prefill_grouped(
                params, cfg, tokens, caches, pools, idx, use_kernel=use_kernel
            )

        return jax.jit(f)

    return _cached_fn("prefill_grouped", cfg, make, (use_kernel,))


def _decode_scan_fn(cfg, use_kernel: bool = True):
    def make():
        def f(params, tok0, pos0, caches, key, adapters, pools, idx,
              max_new, temperature, unroll):
            return decode_scan(
                params, cfg, tok0, pos0, caches, key,
                max_new=max_new, temperature=temperature, adapters=adapters,
                pools=pools, idx=idx, use_kernel=use_kernel, unroll=unroll,
            )

        # Donate the KV caches: the scan's carry updates them in place
        # (off-CPU; the CPU backend has no donation and would only warn).
        return jax.jit(
            f,
            static_argnums=(8, 9, 10),
            donate_argnums=donate_argnums(3),
        )

    return _cached_fn("decode_scan", cfg, make, (use_kernel,))


def _decode_step_fn(cfg):
    def make():
        def f(params, tok, pos, caches, adapters):
            return serve_decode(params, cfg, tok, pos, caches, adapters=adapters)

        return jax.jit(f)

    return _cached_fn("decode_step", cfg, make)


# ---------------------------------------------------------------------------
# Generation entry points
# ---------------------------------------------------------------------------


def generate(
    params,
    cfg,
    tokens,
    *,
    max_new: int,
    adapters_stack=None,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
    unroll: int = 1,
):
    """Batched generation, scan-fused: 1 prefill dispatch + 1 decode-scan
    dispatch for all ``max_new`` tokens. Returns (B, max_new) int32."""
    b, s = tokens.shape
    caches = init_serve_caches(cfg, b, s + max_new)
    logits, caches = _prefill_fn(cfg)(params, tokens, caches, adapters_stack)
    tok0, key = sample_token(
        logits, rng if rng is not None else jax.random.key(0), temperature
    )
    toks, _ = _decode_scan_fn(cfg)(
        params, tok0, jnp.asarray(s, jnp.int32), caches, key,
        adapters_stack, None, None, max_new, float(temperature), unroll,
    )
    return toks


def generate_grouped(
    params,
    cfg,
    tokens,
    pools: dict[str, jax.Array],
    idx: jax.Array,
    *,
    max_new: int,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
    use_kernel: bool = True,
    unroll: int = 1,
):
    """Multi-tenant generation: batch row b decodes under adapter slot
    idx[b] gathered from the stacked pool (float or raw-int8 layout, see
    ``AdapterPool.pools()``). Same two-dispatch structure as ``generate``."""
    b, s = tokens.shape
    caches = init_serve_caches(cfg, b, s + max_new)
    logits, caches = _prefill_grouped_fn(cfg, use_kernel)(
        params, tokens, caches, pools, idx
    )
    tok0, key = sample_token(
        logits, rng if rng is not None else jax.random.key(0), temperature
    )
    toks, _ = _decode_scan_fn(cfg, use_kernel)(
        params, tok0, jnp.asarray(s, jnp.int32), caches, key,
        None, pools, idx, max_new, float(temperature), unroll,
    )
    return toks


def generate_loop(
    params,
    cfg,
    tokens,
    *,
    max_new: int,
    adapters_stack=None,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
):
    """Per-token Python decode loop (the pre-scan path, kept for the
    loop-vs-scan benchmark): ``max_new`` dispatches, cached step jits."""
    b, s = tokens.shape
    caches = init_serve_caches(cfg, b, s + max_new)
    prefill = _prefill_fn(cfg)
    decode = _decode_step_fn(cfg)
    logits, caches = prefill(params, tokens, caches, adapters_stack)
    key = rng if rng is not None else jax.random.key(0)
    tok, key = sample_token(logits, key, temperature)
    out = []
    for i in range(max_new):
        out.append(tok)
        logits, caches = decode(
            params, tok, jnp.asarray(s + i, jnp.int32), caches, adapters_stack
        )
        tok, key = sample_token(logits, key, temperature)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _demo_pool(cfg, n_tenants: int, rank: int, compress) -> AdapterPool:
    """Register ``n_tenants`` pretend on-device fine-tunes (B != 0)."""
    pool = AdapterPool(n_tenants + 1, cfg, rank, compress=compress)
    sl = SL.SkipLoRAConfig(rank=rank)
    for t in range(n_tenants):
        ad = SL.init_adapters(jax.random.key(100 + t), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(200 + t), ad["B"].shape) * 0.02
        pool.register(f"tenant-{t}", ad)
    return pool


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--with-adapters", action="store_true")
    ap.add_argument("--tenants", type=int, default=0,
                    help="serve a multi-tenant batch over this many adapters")
    ap.add_argument("--pool-compress", choices=["int8"], default=None)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--unroll", type=int, default=1,
                    help="decode steps fused per scan iteration")
    ap.add_argument("--loop", action="store_true",
                    help="use the per-token loop instead of the fused scan")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    params = init_lm(jax.random.key(0), cfg)
    prompts = jax.random.randint(
        jax.random.key(3), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )

    if args.tenants > 0:
        if args.loop:
            ap.error("--loop applies to single-stack serving; the grouped "
                     "multi-tenant path always uses the fused scan")
        pool = _demo_pool(cfg, args.tenants, args.rank, args.pool_compress)
        # Mixed batch: rows cycle through tenants; row 0 serves the base
        # model via the pinned zero slot.
        tenants = [None] + [
            f"tenant-{i % args.tenants}" for i in range(1, args.batch)
        ]
        idx = pool.lookup(tenants)
        t0 = time.perf_counter()
        toks = generate_grouped(
            params, cfg, prompts, pool.pools(), idx,
            max_new=args.gen, temperature=args.temperature, unroll=args.unroll,
        )
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        print(f"[grouped x{args.tenants} tenants, pool "
              f"{pool.nbytes() / 2**20:.1f} MiB, compress={args.pool_compress}]")
    else:
        adapters_stack = None
        if args.with_adapters:
            sl = SL.SkipLoRAConfig(rank=args.rank)
            ad = SL.init_adapters(jax.random.key(1), cfg, sl)
            ad["B"] = jax.random.normal(jax.random.key(2), ad["B"].shape) * 0.01
            adapters_stack = SL.adapters_to_stack(ad, cfg)
        gen_fn = generate_loop if args.loop else functools.partial(
            generate, unroll=args.unroll
        )
        t0 = time.perf_counter()
        toks = gen_fn(
            params, cfg, prompts, max_new=args.gen,
            adapters_stack=adapters_stack, temperature=args.temperature,
        )
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0

    n_disp = args.gen if args.loop else 1
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s incl. compile; "
          f"{n_disp} decode dispatch{'es' if n_disp > 1 else ''})")
    print("first sequences:", toks[:2, :8].tolist())


if __name__ == "__main__":
    main()
