"""Assigned input shapes and per-cell support rules.

LM transformer shapes are seq_len x global_batch. ``decode_*`` / ``long_*``
lower ``serve_decode`` (one new token against a KV/state cache of seq_len),
``prefill_32k`` lowers ``serve_prefill``, ``train_4k`` lowers ``train_step``.
``long_500k`` requires sub-quadratic attention: only the SSM/hybrid archs
run it (DESIGN.md §5 records the 8 documented skips).
"""

from __future__ import annotations

import dataclasses

from repro.configs.registry import SUBQUADRATIC_ARCHS, get_config


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    """(supported, reason-if-not)."""
    if shape == "long_500k" and arch not in SUBQUADRATIC_ARCHS:
        cfg = get_config(arch)
        return False, (
            f"{arch} has full global attention layers (family={cfg.family}); "
            "long_500k needs sub-quadratic attention — documented skip"
        )
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    from repro.configs.registry import list_archs

    return [(a, s) for a in list_archs() for s in SHAPES]


def live_cells() -> list[tuple[str, str]]:
    return [(a, s) for a, s in all_cells() if cell_supported(a, s)[0]]
