"""Step factories + input/sharding spec builders for every dry-run cell.

``build_cell(arch, shape, mesh, step_kind)`` returns (fn, arg_specs,
in_shardings, out_shardings) ready for ``jax.jit(fn, ...).lower(*specs)``:

  step kinds:
    train            : full-backprop AdamW train step (baseline)
    finetune_populate: Skip2-LoRA populate step (backbone fwd + cache write)
    finetune_cached  : Skip2-LoRA cached step (the paper's fast path;
                       consumes a batch of cached activations — the cache
                       itself streams from host/store, DESIGN.md §4)
    prefill          : serve_prefill over the full prompt
    decode           : one-token serve_decode against a seq-long cache

All inputs are ShapeDtypeStructs (no allocation).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config
from repro.core import lm_skiplora as SL
from repro.launch.shapes import SHAPES, ShapeCell
from repro.models.config import ModelConfig
from repro.models.lm import (
    init_lm,
    init_serve_caches,
    model_dtype,
    serve_decode,
    serve_prefill,
    train_loss_fn,
)
from repro.optim.optimizers import adamw, apply_updates, clip_by_global_norm
from repro.runtime import sharding as SH

Params = Any


# ---------------------------------------------------------------------------
# Spec helpers
# ---------------------------------------------------------------------------


def _shape_tree(f, *args) -> Params:
    return jax.eval_shape(f, *args)


def _guarded_spec(shape: tuple, logical: tuple, mesh, rules: SH.AxisRules) -> P:
    parts = []
    for dim, a in zip(shape, logical):
        r = rules.resolve(mesh.axis_names, a)
        if r is not None and dim % SH._axis_size(mesh, r) != 0:
            r = None  # argument shardings must divide evenly
        parts.append(r)
    parts += [None] * (len(shape) - len(logical))
    return P(*parts)


def cache_specs(cache_shape: Params, mesh, rules: SH.AxisRules) -> Params:
    """Sharding specs for serve caches (KV + recurrent states)."""

    def leaf(path, x):
        pstr = SH._path_str(path)
        shape = tuple(x.shape)
        stacked = "periods" in pstr
        inner = shape[1:] if stacked else shape
        name = pstr.rsplit("/", 1)[-1]
        if name in ("k", "v"):             # (B, S, nk, hd)
            # Prefer head-sharded KV; if kv-head count doesn't divide the
            # model axis, shard the *sequence* dim over it instead (context
            # parallelism) — composing with 'data' when long-decode rules
            # already put seq there.
            nk = inner[2]
            heads_ok = nk % SH._axis_size(mesh, "model") == 0
            seq_axes = []
            if rules.resolve(mesh.axis_names, "seq") is not None:
                seq_axes.append("data")
            if not heads_ok:
                seq_axes.append("model")
            seq_part = tuple(seq_axes) if seq_axes else None
            if seq_part is not None and inner[1] % SH._axis_size(mesh, seq_part) != 0:
                seq_part = None
            sp = _guarded_spec(
                inner,
                ("batch", seq_part, "heads" if heads_ok else None, None),
                mesh,
                rules,
            )
        elif name == "ssm":                # (B, Di, N)
            sp = _guarded_spec(inner, ("batch", "d_inner", None), mesh, rules)
        elif name == "conv":               # (B, K-1, Di)
            sp = _guarded_spec(inner, ("batch", None, "d_inner"), mesh, rules)
        elif name == "c":                  # (B, H, hd, hd) mLSTM
            sp = _guarded_spec(inner, ("batch", "heads", None, None), mesh, rules)
        elif name in ("n",):               # (B, H, hd) or (B, D)
            sp = _guarded_spec(inner, ("batch",) + (None,) * (len(inner) - 1), mesh, rules)
        else:                              # m, h, ...
            sp = _guarded_spec(inner, ("batch",) + (None,) * (len(inner) - 1), mesh, rules)
        return P(None, *sp) if stacked else sp

    return jax.tree_util.tree_map_with_path(leaf, cache_shape)


def batch_specs(batch_shape: Params, mesh, rules: SH.AxisRules) -> Params:
    def leaf(x):
        return _guarded_spec(tuple(x.shape), ("batch",), mesh, rules)

    return jax.tree.map(leaf, batch_shape)


def opt_state_specs(opt_shape, p_specs, mesh) -> Params:
    """OptState specs: scalar step replicated; moments ZeRO-1-upgraded
    (mu/nu share the params' tree structure)."""
    from repro.optim.optimizers import OptState

    mu_specs = (
        SH.zero1_specs(opt_shape.mu, p_specs, mesh) if opt_shape.mu is not None else None
    )
    nu_specs = (
        SH.zero1_specs(opt_shape.nu, p_specs, mesh) if opt_shape.nu is not None else None
    )
    return OptState(step=P(), mu=mu_specs, nu=nu_specs)


# ---------------------------------------------------------------------------
# Cell builder
# ---------------------------------------------------------------------------


STEP_KINDS = (
    "train", "finetune_populate", "finetune_cached", "prefill", "decode",
    "decode_adapted",
)


def _grid_batch_rules(kw: dict, shape: ShapeCell, mesh, vocab_size: int,
                      batch_cands) -> SH.AxisRules:
    for cand in batch_cands:
        axes = tuple(a for a in cand if a in mesh.axis_names)
        if axes and shape.batch % SH._axis_size(mesh, axes) == 0:
            kw["batch"] = axes
            break
    # Loss sharding: whole-grid vocab when it divides (logits batch stays
    # replicated, d_table fully local); otherwise batch@data x vocab@model.
    grid = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    if vocab_size % SH._axis_size(mesh, grid) == 0:
        kw["vocab"] = grid
        kw["logits_batch"] = None
    else:
        kw["vocab"] = "model"
        kw["logits_batch"] = ("data",)
    return SH.AxisRules(**kw)


def rules_for(shape: ShapeCell, mesh, strategy: str = "tp", vocab_size: int = 0) -> SH.AxisRules:
    if strategy == "ep":
        return _grid_batch_rules(
            dict(SH.EP_RULES_KW), shape, mesh, vocab_size,
            (("data", "model"), ("pod", "data"), ("data",)),
        )
    if strategy == "fsdp":
        return _grid_batch_rules(
            dict(SH.FSDP_RULES_KW), shape, mesh, vocab_size,
            (("pod", "data", "model"), ("data", "model"), ("pod", "data"), ("data",)),
        )
    if shape.kind == "decode" and shape.batch < SH._axis_size(mesh, "data"):
        # Long-context decode (batch=1): sequence parallelism over the cache.
        return SH.AxisRules(seq="data")
    return SH.AxisRules()


def default_skiplora(cfg: ModelConfig) -> SL.SkipLoRAConfig:
    return SL.SkipLoRAConfig(rank=16, mode="full", cache_dtype="bfloat16")


def build_cell(
    arch: str,
    shape_name: str,
    mesh,
    step_kind: str,
    *,
    skiplora: Optional[SL.SkipLoRAConfig] = None,
    strategy: str = "tp",
):
    """Returns (fn, example_args (ShapeDtypeStructs), in_shardings, out_shardings).

    strategy:
      tp   — Megatron TP on 'model' + DP on ('pod','data') (baseline);
             auto-upgrades to mixed FSDP when weights don't fit.
      fsdp — batch over the whole (data x model) grid, weights fully
             sharded, per-layer weight all-gather (§Perf hillclimb).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules = rules_for(shape, mesh, strategy, get_config(arch).vocab_size)
    dt = model_dtype(cfg)
    key = jax.random.key(0)

    params_shape = _shape_tree(lambda k: init_lm(k, cfg), key)
    if strategy == "fsdp":
        p_specs = SH.fsdp_param_specs(params_shape, mesh)
    elif strategy == "ep":
        p_specs = SH.ep_param_specs(params_shape, mesh)
    else:
        p_specs = SH.param_specs(params_shape, mesh)
        # FSDP upgrade when TP alone can't fit the weights (jamba-398B).
        p_specs, _ = SH.maybe_fsdp_specs(params_shape, p_specs, mesh)
    p_shard = SH.named(mesh, p_specs)

    def mk_batch_shape(b, s):
        bs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if cfg.frontend:
            bs["prefix_embeds"] = jax.ShapeDtypeStruct((b, cfg.frontend_seq, cfg.d_model), dt)
        return bs

    if step_kind == "train":
        opt = adamw(3e-4, weight_decay=0.1)
        opt_shape = _shape_tree(opt.init, params_shape)
        o_specs = opt_state_specs(opt_shape, p_specs, mesh)
        o_shard = SH.named(mesh, o_specs)
        batch_shape = mk_batch_shape(shape.batch, shape.seq)
        b_specs = batch_specs(batch_shape, mesh, rules)
        b_shard = SH.named(mesh, b_specs)

        def train_step(params, opt_state, batch):
            with SH.sharding_scope(mesh, rules):
                loss, grads = jax.value_and_grad(
                    lambda p: train_loss_fn(p, cfg, batch)
                )(params)
                grads = clip_by_global_norm(grads, 1.0)
                updates, opt_state = opt.update(grads, opt_state, params)
                params = apply_updates(params, updates)
            return params, opt_state, loss

        args = (params_shape, opt_shape, batch_shape)
        in_sh = (p_shard, o_shard, b_shard)
        out_sh = (p_shard, o_shard, SH.replicated(mesh))
        return train_step, args, in_sh, out_sh

    if step_kind in ("finetune_populate", "finetune_cached"):
        sl = skiplora or default_skiplora(cfg)
        opt = adamw(1e-3)
        ad_shape = _shape_tree(lambda k: SL.init_adapters(k, cfg, sl), key)
        trainable_shape, static_shape = SL.split_trainable(ad_shape, sl)
        opt_shape = _shape_tree(opt.init, trainable_shape)
        # A (L, D, R): tiny, replicate. B (L, R, D): shard output dim.
        ad_spec = {
            "A": P(None, None, None),
            "B": P(None, None, "model"),
        }
        t_specs, s_specs = SL.split_trainable(ad_spec, sl)
        t_shard = SH.named(mesh, t_specs)
        s_shard = SH.named(mesh, s_specs)
        o_shard = SH.named(mesh, jax.tree.map(lambda _: P(), opt_shape))

        if step_kind == "finetune_populate":
            batch_shape = mk_batch_shape(shape.batch, shape.seq)
            b_specs = batch_specs(batch_shape, mesh, rules)
            b_shard = SH.named(mesh, b_specs)
            # Cache values are *outputs* here (stream to host/store).
            def populate_step(params, trainable, static, batch):
                with SH.sharding_scope(mesh, rules):
                    def loss_fn(t):
                        return SL.populate_loss_fn(
                            params, cfg, SL.merge_adapters(t, static), batch
                        )

                    (loss, (acts, y_base, labels)), grads = jax.value_and_grad(
                        loss_fn, has_aux=True
                    )(trainable)
                    values = SL._encode_acts(
                        acts, SL.merge_adapters(trainable, static), sl
                    )
                    values["y_base"] = y_base
                    trainable = apply_updates(
                        trainable, jax.tree.map(lambda g: -1e-3 * g, grads)
                    )
                return trainable, values, loss

            args = (params_shape, trainable_shape, static_shape, batch_shape)
            in_sh = (p_shard, t_shard, s_shard, b_shard)
            out_sh = None
            return populate_step, args, in_sh, out_sh

        # finetune_cached: consumes a batch of cached activations.
        b, s = shape.batch, shape.seq
        l, d, r = cfg.n_layers, cfg.d_model, sl.rank
        cdt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[sl.cache_dtype]
        if sl.mode == "freeze_a":
            vals_shape = {"z": jax.ShapeDtypeStruct((b, l, s, r), cdt)}
        elif sl.mode == "int8":
            vals_shape = {
                "acts_q": jax.ShapeDtypeStruct((b, l, s, d), jnp.int8),
                "acts_scale": jax.ShapeDtypeStruct((b, l, s), jnp.float32),
            }
        else:
            vals_shape = {"acts": jax.ShapeDtypeStruct((b, l, s, d), cdt)}
        vals_shape["y_base"] = jax.ShapeDtypeStruct((b, s, d), cdt)
        vals_shape["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        v_specs = batch_specs(vals_shape, mesh, rules)
        v_shard = SH.named(mesh, v_specs)

        def cached_step(params, trainable, static, opt_state, vals):
            with SH.sharding_scope(mesh, rules):
                def loss_fn(t):
                    return SL.cached_loss_fn(
                        params, cfg, sl, SL.merge_adapters(t, static), vals, dt
                    )

                loss, grads = jax.value_and_grad(loss_fn)(trainable)
                updates, opt_state = opt.update(grads, opt_state, trainable)
                trainable = apply_updates(trainable, updates)
            return trainable, opt_state, loss

        args = (params_shape, trainable_shape, static_shape, opt_shape, vals_shape)
        in_sh = (p_shard, t_shard, s_shard, o_shard, v_shard)
        out_sh = (t_shard, o_shard, SH.replicated(mesh))
        return cached_step, args, in_sh, out_sh

    if step_kind == "prefill":
        b, s = shape.batch, shape.seq
        # The frontend prefix occupies the first positions of the context
        # window: text/code tokens fill the remainder (total == shape.seq).
        s_tok = s - (cfg.frontend_seq if cfg.frontend else 0)
        tokens_shape = jax.ShapeDtypeStruct((b, s_tok), jnp.int32)
        cache_shape = _shape_tree(lambda: init_serve_caches(cfg, b, s))
        c_specs = cache_specs(cache_shape, mesh, rules)
        c_shard = SH.named(mesh, c_specs)
        tok_shard = SH.named(mesh, batch_specs(tokens_shape, mesh, rules))
        prefix_shape = (
            jax.ShapeDtypeStruct((b, cfg.frontend_seq, cfg.d_model), dt)
            if cfg.frontend
            else None
        )

        def prefill_step(params, tokens, caches, prefix_embeds):
            with SH.sharding_scope(mesh, rules):
                return serve_prefill(
                    params, cfg, tokens, caches, prefix_embeds=prefix_embeds
                )

        args = (params_shape, tokens_shape, cache_shape, prefix_shape)
        pre_shard = (
            SH.named(mesh, batch_specs(prefix_shape, mesh, rules))
            if prefix_shape is not None
            else None
        )
        in_sh = (p_shard, tok_shard, c_shard, pre_shard)
        out_sh = (SH.replicated(mesh), c_shard)
        return prefill_step, args, in_sh, out_sh

    if step_kind in ("decode", "decode_adapted"):
        b, s = shape.batch, shape.seq
        token_shape = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
        cache_shape = _shape_tree(lambda: init_serve_caches(cfg, b, s))
        c_specs = cache_specs(cache_shape, mesh, rules)
        c_shard = SH.named(mesh, c_specs)
        tok_shard = SH.named(mesh, batch_specs(token_shape, mesh, rules))

        if step_kind == "decode_adapted":
            # Post-fine-tune deployment: Skip-LoRA adapters applied at
            # decode time (the skip topology is not mergeable; the running
            # skip-sum rides along through the stack).
            sl = skiplora or default_skiplora(cfg)
            ad_shape = _shape_tree(lambda k: SL.init_adapters(k, cfg, sl), key)
            ad_spec = {"A": P(None, None, None), "B": P(None, None, "model")}
            ad_shard = SH.named(mesh, ad_spec)

            def decode_adapted_step(params, adapters, token, pos, caches):
                with SH.sharding_scope(mesh, rules):
                    stack = SL.adapters_to_stack(adapters, cfg)
                    return serve_decode(
                        params, cfg, token, pos, caches, adapters=stack
                    )

            args = (params_shape, ad_shape, token_shape, pos_shape, cache_shape)
            in_sh = (p_shard, ad_shard, tok_shard, SH.replicated(mesh), c_shard)
            out_sh = (SH.replicated(mesh), c_shard)
            return decode_adapted_step, args, in_sh, out_sh

        def decode_step(params, token, pos, caches):
            with SH.sharding_scope(mesh, rules):
                return serve_decode(params, cfg, token, pos, caches)

        args = (params_shape, token_shape, pos_shape, cache_shape)
        in_sh = (p_shard, tok_shard, SH.replicated(mesh), c_shard)
        out_sh = (SH.replicated(mesh), c_shard)
        return decode_step, args, in_sh, out_sh

    raise ValueError(step_kind)
