"""End-to-end training launcher (runs on whatever devices exist).

Full-backprop baseline training of any ``--arch`` (reduced or full config)
with AdamW, gradient clipping, deterministic resumable data, checkpointing
and the fault supervisor. On the CPU container this drives reduced configs
(examples/ use it to train a ~100M model); on a pod the same entry point
runs the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --reduced --steps 200 --batch 8 --seq 128

``--scan-chunk N`` fuses N steps into one ``jax.lax.scan`` dispatch over
pre-sampled batch ids (the whole synthetic fine-tune set is staged on
device). This is the same dispatch-amortisation strategy the Skip2-LoRA
epoch loops use (DESIGN.md §2); the supervisor/straggler path stays on the
default per-step loop.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduce_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.launch.mesh import make_debug_mesh
from repro.models.lm import init_lm, train_loss_fn
from repro.optim.optimizers import adamw, apply_updates, clip_by_global_norm
from repro.runtime.fault import Supervisor


def make_step(cfg, opt):
    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: train_loss_fn(p, cfg, batch))(params)
        grads = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return step


def make_scan_chunk(cfg, opt):
    """A chunk of train steps as one compiled dispatch: scan over an
    (n_steps, batch) id matrix gathering from device-staged tokens/labels."""

    def run_chunk(params, opt_state, tokens, labels, idx_mat):
        def body(carry, idx):
            p, o = carry
            batch = {"tokens": tokens[idx], "labels": labels[idx]}
            loss, grads = jax.value_and_grad(
                lambda q: train_loss_fn(q, cfg, batch)
            )(p)
            grads = clip_by_global_norm(grads, 1.0)
            updates, o = opt.update(grads, o, p)
            p = apply_updates(p, updates)
            return (p, o), loss

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), idx_mat
        )
        return params, opt_state, losses

    from repro.core import donate_argnums

    return jax.jit(run_chunk, donate_argnums=donate_argnums(0, 1))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--scan-chunk", type=int, default=0,
                    help="fuse N steps per dispatch via lax.scan (0 = off)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} params={cfg.param_count():,}")

    key = jax.random.key(0)
    params = init_lm(key, cfg)
    opt = adamw(args.lr, weight_decay=0.1)
    opt_state = opt.init(params)
    step_fn = make_step(cfg, opt)

    dcfg = DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=args.seq,
        global_batch=args.batch,
        num_samples=max(args.batch * 8, 256),
    )
    store, sampler = make_pipeline(dcfg)

    ckpt = CheckpointManager(args.ckpt_dir, save_every=args.ckpt_every)
    sup = Supervisor(ckpt)

    state = {"params": params, "opt": opt_state}
    t_start = time.time()
    losses = []

    if args.scan_chunk > 0:
        # Fused path: chunks of steps in one dispatch; checkpoint per chunk.
        run_chunk = make_scan_chunk(cfg, opt)
        staged = store.batch(np.arange(dcfg.num_samples))
        tokens = jnp.asarray(staged["tokens"])
        labels = jnp.asarray(staged["labels"])
        params, opt_state = state["params"], state["opt"]
        step = 0
        while step < args.steps:
            n = min(args.scan_chunk, args.steps - step)
            idx_mat = jnp.asarray(
                np.stack([sampler.next_ids() for _ in range(n)])
            )
            params, opt_state, ls = run_chunk(
                params, opt_state, tokens, labels, idx_mat
            )
            jax.block_until_ready(ls)
            losses.extend(np.asarray(ls, np.float32).tolist())
            prev = step
            step += n
            dt = time.time() - t_start
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"({dt:.1f}s, {n} steps/dispatch)")
            # Save whenever the chunk crossed a save boundary (chunk size
            # need not divide --ckpt-every).
            if prev // args.ckpt_every != step // args.ckpt_every:
                ckpt.save(step, {"params": params, "opt": opt_state})
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
        return

    def run_one(state, step):
        ids = sampler.next_ids()
        batch_np = store.batch(ids)
        batch = {
            "tokens": jnp.asarray(batch_np["tokens"]),
            "labels": jnp.asarray(batch_np["labels"]),
        }
        params, opt_state, loss = step_fn(state["params"], state["opt"], batch)
        losses.append(float(loss))
        if step % args.log_every == 0:
            dt = time.time() - t_start
            print(f"step {step:5d} loss {float(loss):.4f} ({dt:.1f}s)")
        return {"params": params, "opt": opt_state}

    state = sup.run(state, run_one, num_steps=args.steps)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
