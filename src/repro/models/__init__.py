"""Model zoo: paper-scale MLPs and the assigned LM architectures."""
