"""Grouped-query attention with sliding windows, softcaps, and KV caches.

Covers every attention variant among the assigned architectures:
  - MHA / GQA / MQA via ``n_kv_heads`` (paligemma: kv=1).
  - gemma2/3 interleaved local (sliding-window) and global layers: the window
    is a *static per-layer* parameter; ``window >= seq`` means global.
  - gemma2 attention-logit softcap.
  - partial rotary (stablelm), configurable rope theta, head_dim != d/heads
    (gemma-7b head_dim=256).

Three entry points share one core:
  - ``attn_train``: full-sequence causal attention (training / scoring).
  - ``attn_prefill``: same, but also returns the populated KV cache.
  - ``attn_decode``: single-token step against a pre-allocated ring cache.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope
from repro.runtime.sharding import constrain

Params = Any

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Static per-layer attention hyperparameters."""

    n_heads: int
    n_kv_heads: int
    head_dim: int
    window: int = 0          # 0 -> global causal; >0 -> sliding window
    softcap: float = 0.0
    query_scale: float = 0.0  # 0 -> rsqrt(head_dim)
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0

    @classmethod
    def from_config(cls, cfg: ModelConfig, *, local: bool) -> "AttnSpec":
        return cls(
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim,
            window=cfg.sliding_window if local else 0,
            softcap=cfg.attn_softcap,
            query_scale=cfg.query_scale,
            rope_theta=cfg.rope_theta,
            rope_pct=cfg.rope_pct,
        )


def init_attn(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(d)
    return {
        "wq": jax.random.normal(kq, (d, cfg.n_heads, hd), dtype) * s,
        "wk": jax.random.normal(kk, (d, cfg.n_kv_heads, hd), dtype) * s,
        "wv": jax.random.normal(kv, (d, cfg.n_kv_heads, hd), dtype) * s,
        "wo": jax.random.normal(ko, (cfg.n_heads, hd, d), dtype) * s,
    }


def _qkv(params: Params, x: jax.Array, positions: jax.Array, spec: AttnSpec):
    dtype = x.dtype
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"].astype(dtype))
    q = apply_rope(q, positions, theta=spec.rope_theta, rope_pct=spec.rope_pct)
    k = apply_rope(k, positions, theta=spec.rope_theta, rope_pct=spec.rope_pct)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "heads", None)
    v = constrain(v, "batch", "seq", "heads", None)
    return q, k, v


def _scale(spec: AttnSpec) -> float:
    return spec.query_scale if spec.query_scale else spec.head_dim**-0.5


def _sdpa(
    q: jax.Array,          # (b, sq, n, h)
    k: jax.Array,          # (b, sk, nk, h)
    v: jax.Array,          # (b, sk, nk, h)
    mask: jax.Array,       # (b or 1, sq, sk) boolean, True = attend
    spec: AttnSpec,
) -> jax.Array:
    b, sq, n, h = q.shape
    group = spec.n_heads // spec.n_kv_heads
    qg = q.reshape(b, sq, spec.n_kv_heads, group, h)
    logits = jnp.einsum("bsngh,btnh->bngst", qg * _scale(spec), k).astype(jnp.float32)
    if spec.softcap:
        logits = spec.softcap * jnp.tanh(logits / spec.softcap)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngst,btnh->bsngh", probs, v)
    return out.reshape(b, sq, n, h)


def causal_mask(sq: int, sk: int, q_offset, window: int) -> jax.Array:
    """(1, sq, sk) mask: key t attends iff t <= q_pos and q_pos - t < window."""
    q_pos = jnp.arange(sq) + q_offset  # may be traced (decode)
    k_pos = jnp.arange(sk)
    m = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m[None]


def attn_train(
    params: Params,
    x: jax.Array,
    spec: AttnSpec,
    positions: Optional[jax.Array] = None,
    *,
    use_flash: bool = False,
) -> jax.Array:
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q, k, v = _qkv(params, x, positions, spec)
    if use_flash:
        # Fused Pallas path (TPU target; interpret-mode on CPU): the
        # populate/prefill hot spot never materialises the (S, S) scores.
        from repro.kernels.flash_attn.ops import flash_attention

        out = flash_attention(
            jnp.swapaxes(q, 1, 2),
            jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2),
            window=spec.window,
            softcap=spec.softcap,
            scale=_scale(spec),
        )
        out = jnp.swapaxes(out, 1, 2)
    else:
        mask = causal_mask(s, s, 0, spec.window)
        out = _sdpa(q, k, v, mask, spec)
    return jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# KV cache (serving)
# ---------------------------------------------------------------------------


def init_kv_cache(
    batch: int, max_seq: int, spec: AttnSpec, dtype=jnp.bfloat16
) -> dict[str, jax.Array]:
    shape = (batch, max_seq, spec.n_kv_heads, spec.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def attn_prefill(
    params: Params, x: jax.Array, spec: AttnSpec, cache: dict[str, jax.Array]
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Full-sequence forward that also writes positions [0, s) of the cache."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q, k, v = _qkv(params, x, positions, spec)
    mask = causal_mask(s, s, 0, spec.window)
    out = _sdpa(q, k, v, mask, spec)
    new_cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
    }
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(x.dtype))
    return y, new_cache


def attn_prefill_ext(
    params: Params,
    x: jax.Array,                 # (b, s, d) tail tokens (right-padded)
    offs: jax.Array,              # (b,) int32 per-row start position
    spec: AttnSpec,
    cache: dict[str, jax.Array],
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Per-row *offset* prefill: row b's ``s`` tokens occupy positions
    ``[offs[b], offs[b] + s)``; K/V scatter into the cache at those
    positions and attention runs over the CACHE — including whatever the
    caller pre-wrote below ``offs`` (the prefix-reuse admission path:
    gathered pool blocks sit at ``[0, offs)``, so the tail attends reused
    keys without recomputing them; the cache and compute dtypes coincide,
    so a cached key is bitwise the key a dense prefill would recompute).

    Padding doctrine matches ``sched_prefill``: pad tail positions write
    garbage K/V at indices >= the row's true end (``mode="drop"`` for
    writes past the cache) and rows with a shorter reused prefix see
    garbage between their prefix and the wave's padded prefix — all at
    positions >= their own length, which the causal mask hides and decode
    overwrites before ever attending."""
    b, s, _ = x.shape
    positions = (
        offs[:, None].astype(jnp.int32)
        + jnp.arange(s, dtype=jnp.int32)[None]
    )                                                        # (b, s)
    q, k, v = _qkv(params, x, positions, spec)
    rows = jnp.arange(b)[:, None]
    ck = cache["k"].at[rows, positions].set(
        k.astype(cache["k"].dtype), mode="drop"
    )
    cv = cache["v"].at[rows, positions].set(
        v.astype(cache["v"].dtype), mode="drop"
    )
    sk = ck.shape[1]
    k_pos = jnp.arange(sk)
    mask = k_pos[None, None, :] <= positions[:, :, None]     # (b, s, sk)
    if spec.window > 0:
        mask &= k_pos[None, None, :] > (positions[:, :, None] - spec.window)
    out = _sdpa(q, ck.astype(x.dtype), cv.astype(x.dtype), mask, spec)
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(x.dtype))
    return y, {"k": ck, "v": cv}


def attn_decode(
    params: Params,
    x: jax.Array,                 # (b, 1, d)
    pos: jax.Array,               # scalar int32 OR (b,) int32 per-row index
    spec: AttnSpec,
    cache: dict[str, jax.Array],
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """One decode step: write K/V at ``pos``, attend over cache[0:pos+1].

    ``pos`` is a scalar on the classic whole-batch path (every row at the
    same sequence position; this branch is kept byte-identical so fused
    ``decode_scan`` traces are unchanged). A (b,) vector selects the
    continuous-batching path: each row writes its K/V at its own position
    and masks keys per row — what the request scheduler needs once rows
    admitted at different times share one live batch."""
    b = x.shape[0]
    if getattr(pos, "ndim", 0) > 0:
        positions = pos[:, None].astype(jnp.int32)               # (b, 1)
        q, k, v = _qkv(params, x, positions, spec)
        rows = jnp.arange(b)
        ck = cache["k"].at[rows, pos].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[rows, pos].set(v[:, 0].astype(cache["v"].dtype))
        sk = ck.shape[1]
        k_pos = jnp.arange(sk)
        mask = k_pos[None, None, :] <= pos[:, None, None]        # (b, 1, sk)
        if spec.window > 0:
            mask &= k_pos[None, None, :] > (pos[:, None, None] - spec.window)
        out = _sdpa(q, ck.astype(x.dtype), cv.astype(x.dtype), mask, spec)
        y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(x.dtype))
        return y, {"k": ck, "v": cv}
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    q, k, v = _qkv(params, x, positions, spec)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    sk = ck.shape[1]
    mask = causal_mask(1, sk, pos, spec.window)
    out = _sdpa(q, ck.astype(x.dtype), cv.astype(x.dtype), mask, spec)
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(x.dtype))
    return y, {"k": ck, "v": cv}


def attn_decode_paged(
    params: Params,
    x: jax.Array,                 # (b, 1, d)
    pos: jax.Array,               # (b,) int32 per-row position
    spec: AttnSpec,
    pool: dict[str, jax.Array],   # {"k","v"}: (n_blocks, block, n_kv, hd)
    table: dict[str, Any] | jax.Array,  # (b, T) int32 pool block ids
    *,
    use_kernel: bool = False,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Block-table variant of ``attn_decode``'s vector branch: row ``b``'s
    KV for token position ``t`` lives in pool block ``table[b, t //
    block]`` at offset ``t % block`` instead of a contiguous cache row.

    The step writes the new K/V at ``pos`` into the owning pool block —
    the caller must hold that block EXCLUSIVELY (the copy-on-write rule:
    ``KVBlockPool.copy_block`` first if shared) — then attends over the
    gathered per-row keys with the same per-row causal mask as the dense
    branch, so given equal KV bytes the output is bitwise the dense
    ``attn_decode``'s (tested). ``use_kernel`` routes the gather through
    the Pallas scalar-prefetch kernel (interpret off-TPU); either way the
    gather is pure data movement. Returns (y, updated pool)."""
    from repro.kernels.flash_attn import paged

    b = x.shape[0]
    blk = pool["k"].shape[1]
    positions = pos[:, None].astype(jnp.int32)               # (b, 1)
    q, k, v = _qkv(params, x, positions, spec)
    owner = jnp.take_along_axis(
        table, (pos // blk)[:, None].astype(table.dtype), axis=1
    )[:, 0]                                                  # (b,)
    off = pos % blk
    new_pool = {
        "k": pool["k"].at[owner, off].set(k[:, 0].astype(pool["k"].dtype)),
        "v": pool["v"].at[owner, off].set(v[:, 0].astype(pool["v"].dtype)),
    }
    ck = paged.gather(new_pool["k"], table, use_kernel=use_kernel)
    cv = paged.gather(new_pool["v"], table, use_kernel=use_kernel)
    sk = ck.shape[1]
    k_pos = jnp.arange(sk)
    mask = k_pos[None, None, :] <= pos[:, None, None]        # (b, 1, sk)
    if spec.window > 0:
        mask &= k_pos[None, None, :] > (pos[:, None, None] - spec.window)
    out = _sdpa(q, ck.astype(x.dtype), cv.astype(x.dtype), mask, spec)
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(x.dtype))
    return y, new_pool
