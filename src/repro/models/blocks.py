"""Per-layer blocks and the periodic LayerStack.

A *block* is one residual layer: pre-norm -> mixer (attention / mamba /
mLSTM / sLSTM) -> residual add, then (for attention/mamba layers) pre-norm ->
FFN-or-MoE -> residual add. gemma2/3 sandwich post-norms are supported.

The *LayerStack* tiles ``cfg.pattern`` ``n_periods`` times via ``lax.scan``
(params stacked on a leading periods axis, one compiled body per period) plus
an unrolled remainder. The stack also implements the Skip-LoRA tap: when
adapter params are passed, every block's *input* hidden state is projected
through its (A_k, B_k) pair and accumulated into a running skip term that the
LM adds to the final hidden state (Eq. 17 of the paper, at LM scale).
"""

from __future__ import annotations

import contextlib
import contextvars
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.models.ffn import ffn, init_ffn
from repro.models.layers import apply_norm, make_norm
from repro.models.moe import init_moe, moe_ffn
from repro.runtime.sharding import constrain

Params = Any

ATTN_KINDS = ("attn", "attn_local")

# Dry-run control: unroll the period scan so HLO cost analysis sees every
# layer (lax.scan lowers to a while loop whose body XLA counts only once).
_SCAN_UNROLL: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_scan_unroll", default=False
)


@contextlib.contextmanager
def scan_unroll_scope(enabled: bool = True):
    tok = _SCAN_UNROLL.set(enabled)
    try:
        yield
    finally:
        _SCAN_UNROLL.reset(tok)


def _norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    return apply_norm(
        cfg.norm_type, p, x, eps=cfg.norm_eps, unit_offset=cfg.rmsnorm_unit_offset
    )


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------


def init_block(key: jax.Array, kind: str, layer_idx: int, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    p: dict[str, Params] = {"norm1": make_norm(cfg.norm_type, d)}
    if kind in ATTN_KINDS:
        p["attn"] = A.init_attn(k1, cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = S.init_mamba(k1, cfg, dtype)
    elif kind == "mlstm":
        p["mlstm"] = S.init_mlstm(k1, cfg, dtype)
    elif kind == "slstm":
        p["slstm"] = S.init_slstm(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    if cfg.use_post_norm:
        p["post_norm1"] = make_norm(cfg.norm_type, d)
    # External FFN sublayer (attention and mamba blocks; xLSTM cells have
    # their own internal projections).
    if kind in ATTN_KINDS or kind == "mamba":
        has_moe = cfg.layer_has_moe(layer_idx)
        if has_moe:
            p["norm2"] = make_norm(cfg.norm_type, d)
            p["moe"] = init_moe(k2, d, cfg.moe, dtype)
        elif cfg.d_ff:
            p["norm2"] = make_norm(cfg.norm_type, d)
            p["ffn"] = init_ffn(k2, d, cfg.d_ff, gated=cfg.ffn_gated, dtype=dtype)
        if cfg.use_post_norm and ("moe" in p or "ffn" in p):
            p["post_norm2"] = make_norm(cfg.norm_type, d)
    return p


def init_block_cache(
    kind: str, batch: int, max_seq: int, cfg: ModelConfig, dtype
) -> Optional[Params]:
    if kind in ATTN_KINDS:
        spec = A.AttnSpec.from_config(cfg, local=(kind == "attn_local"))
        return A.init_kv_cache(batch, max_seq, spec, dtype)
    if kind == "mamba":
        return S.init_mamba_state(batch, cfg, dtype)
    if kind == "mlstm":
        return S.init_mlstm_state(batch, cfg, dtype)
    if kind == "slstm":
        return S.init_slstm_state(batch, cfg, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------


def block_forward(
    kind: str,
    params: Params,
    h: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str,                     # "train" | "prefill" | "decode"
    cache: Optional[Params] = None,
    pos: Optional[jax.Array] = None,
) -> tuple[jax.Array, Optional[Params], jax.Array]:
    """Apply one block. Returns (h_out, new_cache, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    x = _norm(cfg, params["norm1"], h)
    new_cache = None

    if kind in ATTN_KINDS:
        spec = A.AttnSpec.from_config(cfg, local=(kind == "attn_local"))
        if mode == "train":
            y = A.attn_train(params["attn"], x, spec)
        elif mode == "prefill":
            if pos is not None:
                # Offset prefill (prefix-reuse admission): ``pos`` is the
                # (b,) per-row start position; the cache below it already
                # holds the reused prefix K/V.
                y, new_cache = A.attn_prefill_ext(
                    params["attn"], x, pos, spec, cache
                )
            else:
                y, new_cache = A.attn_prefill(params["attn"], x, spec, cache)
        else:
            y, new_cache = A.attn_decode(params["attn"], x, pos, spec, cache)
    elif kind == "mamba":
        if mode == "train":
            y, _ = S.mamba_seq(params["mamba"], x, cfg, None)
        elif mode == "prefill":
            y, new_cache = S.mamba_seq(params["mamba"], x, cfg, cache)
        else:
            y, new_cache = S.mamba_step(params["mamba"], x, cfg, cache)
    elif kind == "mlstm":
        if mode == "train":
            y, _ = S.mlstm_seq(params["mlstm"], x, cfg, None)
        elif mode == "prefill":
            y, new_cache = S.mlstm_seq(params["mlstm"], x, cfg, cache)
        else:
            y, new_cache = S.mlstm_step(params["mlstm"], x, cfg, cache)
    elif kind == "slstm":
        if mode == "train":
            y, _ = S.slstm_seq(params["slstm"], x, cfg, None)
        elif mode == "prefill":
            y, new_cache = S.slstm_seq(params["slstm"], x, cfg, cache)
        else:
            y, new_cache = S.slstm_step(params["slstm"], x, cfg, cache)
    else:
        raise ValueError(kind)

    if cfg.use_post_norm and "post_norm1" in params:
        y = _norm(cfg, params["post_norm1"], y)
    h = h + y

    if "moe" in params:
        z = _norm(cfg, params["norm2"], h)
        y2, aux = moe_ffn(params["moe"], z, cfg.moe, act=cfg.ffn_activation)
        if cfg.use_post_norm and "post_norm2" in params:
            y2 = _norm(cfg, params["post_norm2"], y2)
        h = h + y2
    elif "ffn" in params:
        z = _norm(cfg, params["norm2"], h)
        y2 = ffn(params["ffn"], z, act=cfg.ffn_activation, gated=cfg.ffn_gated)
        if cfg.use_post_norm and "post_norm2" in params:
            y2 = _norm(cfg, params["post_norm2"], y2)
        h = h + y2

    return h, new_cache, aux


# ---------------------------------------------------------------------------
# LayerStack: periodic scan + remainder
# ---------------------------------------------------------------------------


def init_stack(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    """Params: {"periods": [per-position stacked pytrees], "remainder": [...]}"""
    kinds = cfg.layer_kinds()
    keys = jax.random.split(key, len(kinds))
    per_layer = [
        init_block(keys[i], kinds[i], i, cfg, dtype) for i in range(len(kinds))
    ]
    n_per, period = cfg.n_periods, cfg.period
    periods = []
    for pos in range(period):
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=0),
            *[per_layer[p * period + pos] for p in range(n_per)],
        )
        periods.append(stacked)
    remainder = per_layer[n_per * period :]
    return {"periods": periods, "remainder": remainder}


def init_stack_caches(
    batch: int, max_seq: int, cfg: ModelConfig, dtype
) -> Params:
    """Caches in the same periods/remainder layout as the params."""
    kinds = cfg.layer_kinds()
    per_layer = [
        init_block_cache(kinds[i], batch, max_seq, cfg, dtype)
        for i in range(len(kinds))
    ]
    n_per, period = cfg.n_periods, cfg.period
    periods = []
    for pos in range(period):
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=0),
            *[per_layer[p * period + pos] for p in range(n_per)],
        )
        periods.append(stacked)
    return {"periods": periods, "remainder": per_layer[n_per * period :]}


def _apply_adapter(adapter: Params, h: jax.Array) -> jax.Array:
    """Skip-LoRA tap: (h @ A) @ B in model dtype."""
    return (h @ adapter["A"].astype(h.dtype)) @ adapter["B"].astype(h.dtype)


def stack_forward(
    stack: Params,
    h: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str,
    caches: Optional[Params] = None,
    pos: Optional[jax.Array] = None,
    adapters: Optional[Params] = None,   # {"periods": [...], "remainder": [...]}
    collect_acts: bool = False,
) -> dict[str, Any]:
    """Run all layers. Returns dict with:
    h            : final hidden state
    skip         : accumulated Skip-LoRA term (zeros if no adapters)
    caches       : updated caches (prefill/decode) or None
    acts         : per-layer block inputs (n_layers, B, S, D) if collect_acts
    aux          : summed MoE aux loss
    """
    period = cfg.period
    skip0 = jnp.zeros_like(h)
    aux0 = jnp.zeros((), jnp.float32)

    def period_body(carry, xs):
        hh, skip, aux = carry
        p_params, p_caches, p_adapters = xs
        new_caches = []
        acts = []
        for i, kind in enumerate(cfg.pattern):
            if collect_acts:
                acts.append(hh)
            if p_adapters is not None:
                skip = skip + _apply_adapter(p_adapters[i], hh)
            hh, c_new, a = block_forward(
                kind,
                p_params[i],
                hh,
                cfg,
                mode=mode,
                cache=None if p_caches is None else p_caches[i],
                pos=pos,
            )
            hh = constrain(hh, "batch", "seq", None)
            new_caches.append(c_new)
            aux = aux + a
        ys = (
            new_caches if mode != "train" else None,
            jnp.stack(acts, axis=0) if collect_acts else None,
        )
        return (hh, skip, aux), ys

    xs = (
        stack["periods"],
        None if caches is None else caches["periods"],
        None if adapters is None else adapters["periods"],
    )
    body = period_body
    if mode == "train":
        # Rematerialise each period in the backward pass: the scan otherwise
        # saves every block's internals (incl. attention probs) per period.
        body = jax.checkpoint(period_body)
    (h, skip, aux), (period_caches, period_acts) = jax.lax.scan(
        body,
        (h, skip0, aux0),
        xs,
        unroll=cfg.n_periods if _SCAN_UNROLL.get() else 1,
    )

    # Remainder layers (unrolled).
    rem_caches = []
    rem_acts = []
    kinds = cfg.layer_kinds()
    for j, kind in enumerate(cfg.remainder_pattern):
        if collect_acts:
            rem_acts.append(h)
        if adapters is not None:
            skip = skip + _apply_adapter(adapters["remainder"][j], h)
        h, c_new, a = block_forward(
            kind,
            stack["remainder"][j],
            h,
            cfg,
            mode=mode,
            cache=None if caches is None else caches["remainder"][j],
            pos=pos,
        )
        rem_caches.append(c_new)
        aux = aux + a

    out_caches = None
    if mode != "train":
        out_caches = {"periods": period_caches, "remainder": rem_caches}

    acts = None
    if collect_acts:
        # period_acts: (n_periods, period, B, S, D) -> (L_periodic, B, S, D)
        acts = period_acts.reshape((-1,) + period_acts.shape[2:])
        if rem_acts:
            acts = jnp.concatenate([acts, jnp.stack(rem_acts, axis=0)], axis=0)

    return {"h": h, "skip": skip, "caches": out_caches, "acts": acts, "aux": aux}
