"""Unified model configuration for all assigned architectures.

One ``ModelConfig`` describes a decoder-only LM backbone built from a
periodic pattern of blocks (attention / mamba / mLSTM / sLSTM), with
optional MoE FFNs, modality frontends (stubbed), and per-arch attention
details (GQA, sliding windows, logit softcaps, partial RoPE).

The layer stack is ``pattern`` tiled ``n_layers // len(pattern)`` times plus
an unrolled remainder — this is what lets ``lax.scan`` compile one body per
period position instead of one per layer (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # always-on shared experts (qwen2-moe)
    shared_d_ff: int = 0         # total ff width of the shared path
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    every_k_layers: int = 1      # jamba: MoE on every 2nd layer


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    mlstm_proj_factor: float = 2.0   # up-projection factor of mLSTM blocks
    slstm_ff_factor: float = 4.0 / 3.0
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # Layer pattern: block kind per position within one period.
    # Kinds: "attn", "attn_local", "mamba", "mlstm", "slstm".
    pattern: tuple[str, ...] = ("attn",)

    # Attention details.
    sliding_window: int = 0          # window for "attn_local" layers
    attn_softcap: float = 0.0        # gemma2-style attention logit softcap
    final_softcap: float = 0.0       # gemma2-style final logit softcap
    query_scale: float = 0.0         # 0 -> 1/sqrt(head_dim)
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0            # stablelm: 25% partial rotary
    attn_bias: bool = False          # stablelm2 uses qkv bias? (no) keep generic

    # FFN details.
    ffn_activation: str = "silu"     # silu | gelu
    ffn_gated: bool = True           # SwiGLU/GeGLU vs plain MLP
    moe: Optional[MoEConfig] = None

    # Norm / embedding.
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-6
    rmsnorm_unit_offset: bool = False  # gemma: weight = 1 + w
    use_post_norm: bool = False        # gemma2/3 pre+post sandwich norms
    tie_embeddings: bool = True
    scale_embed_by_sqrt_dim: bool = False  # gemma family

    # Non-attention block families.
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # Modality frontend stub: None | "vision" | "audio".
    frontend: Optional[str] = None
    frontend_seq: int = 0            # prefix length supplied by the frontend

    # Numerics.
    dtype: str = "bfloat16"          # activation/weight compute dtype

    def __post_init__(self):
        if self.n_layers % len(self.pattern) and self.n_layers < len(self.pattern):
            raise ValueError("pattern longer than n_layers")
        if self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be divisible by n_kv_heads")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def remainder_pattern(self) -> tuple[str, ...]:
        rem = self.n_layers - self.n_periods * self.period
        return self.pattern[:rem]

    def layer_kinds(self) -> list[str]:
        """Block kind for every layer, in order."""
        return list(self.pattern) * self.n_periods + list(self.remainder_pattern)

    def layer_has_moe(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        kind = self.layer_kinds()[layer_idx]
        if kind in ("mlstm", "slstm"):
            return False  # xLSTM blocks have no external FFN
        return layer_idx % self.moe.every_k_layers == (self.moe.every_k_layers - 1)

    def param_count(self) -> int:
        """Approximate total parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i, kind in enumerate(self.layer_kinds()):
            if kind.startswith("attn"):
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads)  # q,k,v
                total += self.n_heads * hd * d                          # o
                if not self.layer_has_moe(i) and self.d_ff:
                    total += d * self.d_ff * (3 if self.ffn_gated else 2)
            elif kind == "mamba":
                mc = self.mamba or MambaConfig()
                di = mc.d_inner(d)
                total += d * 2 * di + di * d + di * (mc.d_conv + 2 * mc.d_state + 2)
            elif kind == "mlstm":
                xc = self.xlstm or XLSTMConfig()
                di = int(d * xc.mlstm_proj_factor)
                total += d * 2 * di + di * d + 3 * di * di // max(1, self.n_heads)
            elif kind == "slstm":
                xc = self.xlstm or XLSTMConfig()
                total += 4 * d * d + 4 * d * (d // max(1, self.n_heads))
                total += int(d * xc.slstm_ff_factor) * d * 2
            if self.layer_has_moe(i):
                m = self.moe
                total += d * m.n_experts * m.d_ff_expert * 3
                total += d * m.n_experts  # router
                if m.n_shared:
                    total += d * m.shared_d_ff * 3
            total += 2 * d  # norms
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        m = self.moe
        n_moe_layers = sum(
            1 for i in range(self.n_layers) if self.layer_has_moe(i)
        )
        inactive = (
            n_moe_layers * self.d_model * (m.n_experts - m.top_k) * m.d_ff_expert * 3
        )
        return int(full - inactive)
