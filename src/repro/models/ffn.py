"""Feed-forward blocks: plain MLP, SwiGLU/GeGLU gated variants."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import activation

Params = Any


def init_ffn(key: jax.Array, d: int, d_ff: int, *, gated: bool, dtype=jnp.float32) -> Params:
    s_in = 1.0 / jnp.sqrt(d)
    s_out = 1.0 / jnp.sqrt(d_ff)
    if gated:
        kg, ku, kd = jax.random.split(key, 3)
        return {
            "w_gate": jax.random.normal(kg, (d, d_ff), dtype) * s_in,
            "w_up": jax.random.normal(ku, (d, d_ff), dtype) * s_in,
            "w_down": jax.random.normal(kd, (d_ff, d), dtype) * s_out,
        }
    ku, kd = jax.random.split(key)
    return {
        "w_up": jax.random.normal(ku, (d, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(kd, (d_ff, d), dtype) * s_out,
    }


def ffn(params: Params, x: jax.Array, *, act: str, gated: bool) -> jax.Array:
    dtype = x.dtype
    if gated:
        g = activation(act, x @ params["w_gate"].astype(dtype))
        u = x @ params["w_up"].astype(dtype)
        return (g * u) @ params["w_down"].astype(dtype)
    h = activation(act, x @ params["w_up"].astype(dtype))
    return h @ params["w_down"].astype(dtype)
