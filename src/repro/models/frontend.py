"""Modality frontend stubs (assignment: frontends are NOT implemented).

``[vlm]`` / ``[audio]`` architectures specify the transformer *backbone*
only. Per the assignment, ``input_specs()`` provides precomputed patch/frame
embeddings; these helpers define their shapes and fold them into the token
stream (prefix embeddings ahead of the embedded text/code tokens, with the
loss masked over the prefix).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def frontend_prefix_len(cfg: ModelConfig) -> int:
    """Number of prefix embedding positions supplied by the (stub) frontend."""
    if cfg.frontend is None:
        return 0
    return cfg.frontend_seq


def prefix_spec(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct for the precomputed frontend embeddings."""
    p = frontend_prefix_len(cfg)
    if p == 0:
        return None
    return jax.ShapeDtypeStruct((batch, p, cfg.d_model), dtype)


def splice_prefix(
    token_embeds: jax.Array, prefix_embeds: jax.Array | None
) -> jax.Array:
    """Concatenate frontend prefix embeddings ahead of token embeddings."""
    if prefix_embeds is None:
        return token_embeds
    return jnp.concatenate([prefix_embeds.astype(token_embeds.dtype), token_embeds], axis=1)
