"""Elementary layers: norms, embeddings, rotary embeddings, activations."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, *, eps: float = 1e-6, unit_offset: bool = True) -> jax.Array:
    """RMSNorm. ``unit_offset`` follows gemma: effective scale = 1 + w, with
    w zero-initialised (so init_rmsnorm starts as identity either way)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    scale = params["scale"].astype(jnp.float32)
    scale = 1.0 + scale if unit_offset else scale
    return (xf * scale).astype(dtype)


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mean) * jax.lax.rsqrt(var + eps)
    out = xf * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dtype)


def make_norm(norm_type: str, d: int, dtype=jnp.float32) -> Params:
    if norm_type == "rmsnorm":
        return init_rmsnorm(d, dtype)
    if norm_type == "layernorm":
        return init_layernorm(d, dtype)
    raise ValueError(norm_type)


def apply_norm(norm_type: str, params: Params, x: jax.Array, *, eps: float, unit_offset: bool = False) -> jax.Array:
    if norm_type == "rmsnorm":
        return rmsnorm(params, x, eps=eps, unit_offset=unit_offset)
    if norm_type == "layernorm":
        return layernorm(params, x, eps=eps)
    raise ValueError(norm_type)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def init_embedding(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(params: Params, ids: jax.Array, *, scale_by_sqrt_dim: bool, dtype) -> jax.Array:
    x = jnp.take(params["table"], ids, axis=0).astype(dtype)
    if scale_by_sqrt_dim:
        x = x * jnp.asarray(jnp.sqrt(params["table"].shape[1]), dtype)
    return x


def unembed(params: Params, h: jax.Array) -> jax.Array:
    """Tied readout: logits = h @ E^T (computed in fp32 for stability)."""
    return jnp.einsum(
        "...d,vd->...v", h.astype(jnp.float32), params["table"].astype(jnp.float32)
    )


# ---------------------------------------------------------------------------
# Rotary position embeddings (with partial-rotary support)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float, rope_pct: float) -> jax.Array:
    rot_dim = int(head_dim * rope_pct) // 2 * 2
    exponent = jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / max(rot_dim, 1)
    return 1.0 / (theta**exponent)  # (rot_dim/2,)


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    *,
    theta: float = 10_000.0,
    rope_pct: float = 1.0,
) -> jax.Array:
    """Apply rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    rot_dim = int(head_dim * rope_pct) // 2 * 2
    if rot_dim == 0:
        return x
    freqs = rope_freqs(head_dim, theta, rope_pct)  # (rot/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, rot/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., seq, 1, rot/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = x_rot[..., : rot_dim // 2], x_rot[..., rot_dim // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out1 = xf1 * cos - xf2 * sin
    out2 = xf2 * cos + xf1 * sin
    out = jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if rot_dim < head_dim else out


# ---------------------------------------------------------------------------
# Dense / activations
# ---------------------------------------------------------------------------


def init_dense(key: jax.Array, n: int, m: int, dtype=jnp.float32, *, scale: float | None = None) -> Params:
    s = scale if scale is not None else (1.0 / jnp.sqrt(n))
    return {"W": jax.random.normal(key, (n, m), dtype) * s}


def dense(params: Params, x: jax.Array) -> jax.Array:
    return x @ params["W"].astype(x.dtype)


def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma2-style logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)
