"""TransformerLM: embedding -> LayerStack -> final norm -> (tied) readout.

Entry points:
  - ``init_lm`` / ``lm_forward``: parameter init and the three-mode forward
    (train / prefill / decode), with optional Skip-LoRA adapters and
    activation collection (for Skip-Cache population).
  - ``lm_loss``: next-token cross entropy with *chunked* readout — the
    (B, S, vocab) logits tensor is never materialised; the unembedding and
    log-softmax run per sequence chunk inside a rematerialised scan (critical
    for vocab 256k at seq 4k+).
  - ``init_serve_caches``: per-layer KV/state caches for serving.
  - ``serve_prefill`` / ``serve_decode`` (+ ``_grouped`` multi-tenant
    variants) and ``decode_scan``: the whole generation as one ``lax.scan``
    dispatch with sampling folded into the carry (DESIGN.md §7).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.config import ModelConfig
from repro.models.frontend import splice_prefix
from repro.models.layers import embed, init_embedding, make_norm, softcap, unembed
from repro.models.blocks import stack_forward
from repro.runtime.sharding import constrain

Params = Any


def model_dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def init_lm(key: jax.Array, cfg: ModelConfig) -> Params:
    ke, ks, kh = jax.random.split(key, 3)
    dtype = model_dtype(cfg)
    params = {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
        "stack": B.init_stack(ks, cfg, dtype),
        "final_norm": make_norm(cfg.norm_type, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = {
            "table": jax.random.normal(kh, (cfg.vocab_size, cfg.d_model), dtype) * 0.02
        }
    return params


def lm_forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,                       # (B, S) int32
    *,
    mode: str = "train",
    caches: Optional[Params] = None,
    pos: Optional[jax.Array] = None,
    adapters: Optional[Params] = None,
    collect_acts: bool = False,
    prefix_embeds: Optional[jax.Array] = None,
) -> dict[str, Any]:
    """Returns {"h": final hidden (pre-norm, incl. skip term), "caches",
    "acts", "aux", "y_base": final hidden *without* the skip term}."""
    dtype = model_dtype(cfg)
    h = embed(params["embed"], tokens, scale_by_sqrt_dim=cfg.scale_embed_by_sqrt_dim, dtype=dtype)
    h = splice_prefix(h, prefix_embeds)
    h = constrain(h, "batch", "seq", None)
    out = stack_forward(
        params["stack"],
        h,
        cfg,
        mode=mode,
        caches=caches,
        pos=pos,
        adapters=adapters,
        collect_acts=collect_acts,
    )
    y_base = out["h"]
    y = y_base + out["skip"].astype(y_base.dtype) if adapters is not None else y_base
    return {
        "h": y,
        "y_base": y_base,
        "caches": out["caches"],
        "acts": out["acts"],
        "aux": out["aux"],
    }


def readout(params: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    """Final norm + unembed (+ gemma2 final softcap). h: (..., D) -> logits."""
    from repro.models.layers import apply_norm

    hn = apply_norm(
        cfg.norm_type, params["final_norm"], h, eps=cfg.norm_eps,
        unit_offset=cfg.rmsnorm_unit_offset,
    )
    table = params["head"] if not cfg.tie_embeddings else params["embed"]
    logits = unembed(table, hn)
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    if logits.ndim == 3:
        logits = constrain(logits, "logits_batch", None, "vocab")
    return logits


def lm_loss_rows(
    params: Params,
    cfg: ModelConfig,
    h: jax.Array,                       # (B, S, D) final hidden (pre-norm)
    labels: jax.Array,                  # (B, S) int32; -1 = masked
    *,
    chunk: int = 512,
) -> tuple[jax.Array, jax.Array]:
    """Per-row next-token log-likelihood sums with chunked readout.

    Returns (ll (B,) fp32 summed log-likelihood per row, count (B,) fp32
    unmasked-token count per row) — the pre-reduction form ``lm_loss``
    averages over, exposed so multi-tenant callers can reduce per *tenant*
    (contiguous row groups) instead of per batch (``core.fleet_finetune``).
    The (B, S, vocab) logits tensor is never materialised."""
    from repro.models.layers import apply_norm

    b, s, d = h.shape
    hn = apply_norm(
        cfg.norm_type, params["final_norm"], h, eps=cfg.norm_eps,
        unit_offset=cfg.rmsnorm_unit_offset,
    )
    table = (params["head"] if not cfg.tie_embeddings else params["embed"])["table"]
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)  # ceil: a ragged tail must still count
    padded = n_chunks * chunk
    if padded > s:
        # Pad the tail chunk with masked positions (label -1 contributes
        # zero log-likelihood and zero count) instead of dropping it.
        hn = jnp.pad(hn, ((0, 0), (0, padded - s), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, padded - s)), constant_values=-1)
    hn = hn.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    lab = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(hc, lc):
        logits = jnp.einsum(
            "bsd,vd->bsv", hc.astype(jnp.float32), table.astype(jnp.float32)
        )
        logits = constrain(logits, "logits_batch", None, "vocab")
        if cfg.final_softcap:
            logits = softcap(logits, cfg.final_softcap)
        logp = jax.nn.log_softmax(logits, axis=-1)
        mask = (lc >= 0).astype(jnp.float32)
        ll = jnp.take_along_axis(logp, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        return jnp.sum(ll * mask, axis=-1), jnp.sum(mask, axis=-1)

    def body(carry, xs):
        tot, cnt = carry
        ll, m = chunk_loss(*xs)
        return (tot + ll, cnt + m), None

    from repro.models.blocks import _SCAN_UNROLL

    (total, count), _ = jax.lax.scan(
        body,
        (jnp.zeros((b,), jnp.float32), jnp.zeros((b,), jnp.float32)),
        (hn, lab),
        unroll=n_chunks if _SCAN_UNROLL.get() else 1,
    )
    return total, count


def lm_loss(
    params: Params,
    cfg: ModelConfig,
    h: jax.Array,                       # (B, S, D) final hidden (pre-norm)
    labels: jax.Array,                  # (B, S) int32; -1 = masked
    *,
    chunk: int = 512,
) -> jax.Array:
    """Mean next-token CE with chunked readout (never materialises B,S,V)."""
    total, count = lm_loss_rows(params, cfg, h, labels, chunk=chunk)
    return -jnp.sum(total) / jnp.maximum(jnp.sum(count), 1.0)


# ---------------------------------------------------------------------------
# Steps (train / serve)
# ---------------------------------------------------------------------------


def train_loss_fn(
    params: Params,
    cfg: ModelConfig,
    batch: dict[str, jax.Array],
    *,
    adapters: Optional[Params] = None,
) -> jax.Array:
    out = lm_forward(
        params,
        cfg,
        batch["tokens"],
        mode="train",
        adapters=adapters,
        prefix_embeds=batch.get("prefix_embeds"),
    )
    h = out["h"]
    labels = batch["labels"]
    if batch.get("prefix_embeds") is not None:
        # Prefix positions carry no next-token loss.
        p = batch["prefix_embeds"].shape[1]
        pad = -jnp.ones((labels.shape[0], p), labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    return lm_loss(params, cfg, h, labels) + out["aux"]


def init_serve_caches(cfg: ModelConfig, batch: int, max_seq: int) -> Params:
    return B.init_stack_caches(batch, max_seq, cfg, jnp.bfloat16)


def serve_prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    caches: Params,
    *,
    adapters: Optional[Params] = None,
    prefix_embeds: Optional[jax.Array] = None,
) -> tuple[jax.Array, Params]:
    """Prefill: process the prompt, return (last-position logits, caches)."""
    out = lm_forward(
        params, cfg, tokens, mode="prefill", caches=caches,
        adapters=adapters, prefix_embeds=prefix_embeds,
    )
    logits = readout(params, cfg, out["h"][:, -1:])
    return logits, out["caches"]


def serve_decode(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,        # (B, 1) int32
    pos: jax.Array,          # scalar int32
    caches: Params,
    *,
    adapters: Optional[Params] = None,
) -> tuple[jax.Array, Params]:
    """One decode step: returns (logits (B,1,V), updated caches)."""
    out = lm_forward(
        params, cfg, token, mode="decode", caches=caches, pos=pos, adapters=adapters
    )
    logits = readout(params, cfg, out["h"])
    return logits, out["caches"]


# ---------------------------------------------------------------------------
# Multi-tenant (grouped) serving: per-row adapter slots from a stacked pool
# ---------------------------------------------------------------------------


def serve_prefill_grouped(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    caches: Params,
    pools: dict[str, jax.Array],   # AdapterPool.pools() layout (float or int8)
    idx: jax.Array,                # (B,) int32 slot per batch row
    *,
    use_kernel: bool = True,
) -> tuple[jax.Array, Params]:
    """Prefill with per-row adapters. The backbone runs adapter-free (the
    skip term never feeds back into the blocks — DESIGN.md §2), activations
    are collected, and one grouped skip-sum over the *last* position yields
    the per-tenant logits. Returns (last-position logits, caches)."""
    from repro.core.adapter_pool import grouped_skip_sum

    out = lm_forward(
        params, cfg, tokens, mode="prefill", caches=caches, collect_acts=True
    )
    y_last = out["y_base"][:, -1:]
    skip = grouped_skip_sum(
        out["acts"][:, :, -1:], pools, idx, use_kernel=use_kernel
    )
    logits = readout(params, cfg, y_last + skip.astype(y_last.dtype))
    return logits, out["caches"]


def serve_decode_grouped(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,              # (B, 1) int32
    pos: jax.Array,                # scalar int32
    caches: Params,
    pools: dict[str, jax.Array],
    idx: jax.Array,                # (B,) int32
    *,
    use_kernel: bool = True,
    fuse_skip: bool = False,
) -> tuple[jax.Array, Params]:
    """One grouped decode step: per-row adapters via one fused gather-and-
    sum over the (L, B, 1, D) collected block inputs.

    ``fuse_skip=True`` inlines the skip term as dense per-row math instead
    of a grouped kernel dispatch, so the whole step compiles to ONE fused
    XLA program (backbone + skip) — see ``grouped_skip_sum``. Token output
    at temperature 0 is identical either way (tested)."""
    from repro.core.adapter_pool import grouped_skip_sum

    out = lm_forward(
        params, cfg, token, mode="decode", caches=caches, pos=pos, collect_acts=True
    )
    skip = grouped_skip_sum(
        out["acts"], pools, idx, use_kernel=use_kernel, fused=fuse_skip
    )
    y = out["y_base"] + skip.astype(out["y_base"].dtype)
    logits = readout(params, cfg, y)
    return logits, out["caches"]


def ingest_prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,             # (B, S) int32
    pools: Optional[dict[str, jax.Array]] = None,
    idx: Optional[jax.Array] = None,   # (B,) int32 slot per row
    *,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Populate-phase forward that doubles as serving (DESIGN.md §9).

    One train-mode backbone pass with activation collection yields both the
    Skip-Cache payload (``acts``, ``y_base`` — bitwise what the offline
    populate epoch writes, since the backbone is frozen) *and*, via one
    grouped skip-sum over the last position, the per-row adapted logits a
    serving request would return. ``pools``/``idx`` select each row's
    adapter slot (``None`` pools -> base model). Returns
    (last-position logits (B, 1, V), acts (L, B, S, D), y_base (B, S, D)).
    """
    out = lm_forward(params, cfg, tokens, mode="train", collect_acts=True)
    acts = jax.lax.stop_gradient(out["acts"])
    y_base = jax.lax.stop_gradient(out["y_base"])
    y_last = y_base[:, -1:]
    if pools is not None:
        from repro.core.adapter_pool import grouped_skip_sum

        skip = grouped_skip_sum(
            acts[:, :, -1:], pools, idx, use_kernel=use_kernel
        )
        y_last = y_last + skip.astype(y_last.dtype)
    logits = readout(params, cfg, y_last)
    return logits, acts, y_base


# ---------------------------------------------------------------------------
# Scan-fused decode: the whole generation as ONE lax.scan dispatch
# ---------------------------------------------------------------------------


def sample_token(
    logits: jax.Array,             # (B, 1, V)
    key: jax.Array,
    temperature,                   # python float (static) or traced scalar/(B,)
) -> tuple[jax.Array, jax.Array]:
    """Greedy / temperature sampling. Returns (tok (B, 1) int32, next key).

    The (B, 1) shape is invariant across both branches (scan carries depend
    on it), and the PRNG key is split-and-carried so every step of a scanned
    generation draws from a fresh subkey.

    ``temperature`` may be a static python float (the historical path:
    greedy skips the categorical and leaves the key untouched) or a traced
    scalar / per-row (B,) vector. The serve path passes it traced so ONE
    compiled decode serves every sampling temperature — and, under the
    request scheduler, heterogeneous per-row temperatures — without
    recompiling; the greedy/temperature select then happens inside the
    computation and the key splits unconditionally (a greedy row still
    ignores the drawn sample, so greedy tokens are unchanged)."""
    if isinstance(temperature, (int, float)):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, 0] / temperature)[:, None]
        else:
            tok = jnp.argmax(logits, axis=-1)
        return tok.astype(jnp.int32), key
    t = jnp.broadcast_to(
        jnp.asarray(temperature, jnp.float32), (logits.shape[0],)
    )
    key, sub = jax.random.split(key)
    # Divide in the logits dtype (a python-float temperature is a weak
    # scalar and would not promote either) so temp>0 draws stay bitwise
    # identical to the static-temperature path.
    safe_t = jnp.where(t > 0, t, 1.0).astype(logits.dtype)
    drawn = jax.random.categorical(sub, logits[:, 0] / safe_t[:, None])[:, None]
    greedy = jnp.argmax(logits, axis=-1)
    tok = jnp.where((t > 0)[:, None], drawn, greedy)
    return tok.astype(jnp.int32), key


def decode_step(
    params: Params,
    cfg: ModelConfig,
    carry,                         # (tok (B,1), pos, caches, key)
    *,
    temperature=0.0,
    adapters: Optional[Params] = None,
    pools: Optional[dict[str, jax.Array]] = None,
    idx: Optional[jax.Array] = None,
    use_kernel: bool = True,
    fuse_skip: bool = False,
) -> tuple[tuple, jax.Array]:
    """One explicitly resumable decode step (the Lingvo ``Step.FProp``
    idiom: per-step state in, per-step state out — SNIPPETS.md §3).

    ``carry`` is exactly the ``decode_scan`` carry — (tok, pos, caches,
    key) — so a scan of this function IS the fused decode, and anything
    holding a carry can stop at a step boundary, let the scheduler admit
    new rows into it (scattering prefilled cache rows + per-row positions),
    and resume. ``pos`` may be a scalar (whole batch at one position, the
    classic path) or a per-row (B,) vector (continuous batching: every row
    at its own sequence position — see ``attention.attn_decode``).

    Returns ``(next_carry, next_token)`` where ``next_token`` is the token
    sampled THIS step (it is also ``next_carry[0]``)."""
    tok, pos, caches, key = carry
    if pools is not None:
        logits, caches = serve_decode_grouped(
            params, cfg, tok, pos, caches, pools, idx,
            use_kernel=use_kernel, fuse_skip=fuse_skip,
        )
    else:
        logits, caches = serve_decode(
            params, cfg, tok, pos, caches, adapters=adapters
        )
    nxt, key = sample_token(logits, key, temperature)
    return (nxt, pos + 1, caches, key), nxt


def decode_scan(
    params: Params,
    cfg: ModelConfig,
    tok0: jax.Array,               # (B, 1) int32 first generated token
    start_pos: jax.Array,          # scalar int32 position of tok0
    caches: Params,
    key: jax.Array,                # PRNG key (carried even for greedy)
    *,
    max_new: int,
    temperature=0.0,               # python float (static) or traced scalar/(B,)
    adapters: Optional[Params] = None,
    pools: Optional[dict[str, jax.Array]] = None,
    idx: Optional[jax.Array] = None,
    use_kernel: bool = True,
    fuse_skip: bool = False,
    unroll: int = 1,
) -> tuple[jax.Array, Params]:
    """Generate ``max_new`` tokens as one ``lax.scan`` dispatch.

    Sampling is folded into the carry (tok, pos, caches, key), so the whole
    generation is a single XLA computation: 1 dispatch instead of ``max_new``
    Python round-trips, and the KV caches can be donated by the caller's jit
    instead of round-tripping per token. ``pools``/``idx`` select the
    multi-tenant grouped path; ``adapters`` the single-stack path.
    ``unroll`` fuses that many decode steps per while-loop iteration — XLA
    then optimises across step boundaries, which on dispatch-bound backends
    cuts the residual per-step loop overhead severalfold (compile time
    grows with it; ``max_new`` need not be a multiple).
    Returns (tokens (B, max_new) — tok0 first, matching the loop path —
    and the final caches)."""

    def body(carry, _):
        tok = carry[0]
        new_carry, _ = decode_step(
            params, cfg, carry, temperature=temperature, adapters=adapters,
            pools=pools, idx=idx, use_kernel=use_kernel, fuse_skip=fuse_skip,
        )
        return new_carry, tok

    (_, _, caches, _), toks = jax.lax.scan(
        body, (tok0, start_pos, caches, key), None, length=max_new,
        unroll=min(unroll, max_new),
    )
    return jnp.swapaxes(toks[..., 0], 0, 1), caches


def sched_prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,             # (A, P) int32, right-padded per row
    lens: jax.Array,               # (A,) int32 true prompt length per row
    pools: Optional[dict[str, jax.Array]] = None,
    idx: Optional[jax.Array] = None,   # (A,) int32 slot per row
    *,
    use_kernel: bool = True,
) -> tuple[jax.Array, Params]:
    """Admission prefill for the request scheduler: ragged prompts in one
    padded (A, P) batch (the Lingvo ``Step.PrepareExternalInputs`` moment).

    Unlike ``serve_prefill_grouped`` this reads each row's logits at its own
    last *real* position (``lens[a] - 1``) instead of column -1, so rows
    shorter than the pad bucket still produce their correct next-token
    distribution. Pad positions do write garbage K/V at indices >= len, but
    decode resumes at ``pos = len`` and overwrites index ``len`` before the
    causal mask ever exposes it — each later pad index likewise — so padding
    never leaks into attention. Caches are allocated at (A, P) here; the
    scheduler scatters rows into its live (B, max_seq) caches on admission.
    When ``lens == P`` (uniform bucket) this is bitwise
    ``serve_prefill_grouped``: the per-row gather picks the same elements
    column -1 slicing does. Returns (logits (A, 1, V), caches)."""
    a, p = tokens.shape
    caches = init_serve_caches(cfg, a, p)
    out = lm_forward(
        params, cfg, tokens, mode="prefill", caches=caches, collect_acts=True
    )
    last = (jnp.maximum(lens, 1) - 1).astype(jnp.int32)          # (A,)
    y_last = jnp.take_along_axis(
        out["y_base"], last[:, None, None], axis=1
    )                                                            # (A, 1, D)
    if pools is not None:
        from repro.core.adapter_pool import grouped_skip_sum

        acts_last = jnp.take_along_axis(
            out["acts"], last[None, :, None, None], axis=2
        )                                                        # (L, A, 1, D)
        skip = grouped_skip_sum(acts_last, pools, idx, use_kernel=use_kernel)
        y_last = y_last + skip.astype(y_last.dtype)
    logits = readout(params, cfg, y_last)
    return logits, out["caches"]


def sched_prefill_reuse(
    params: Params,
    cfg: ModelConfig,
    tail_tokens: jax.Array,        # (A, PT) int32, right-padded tail per row
    tail_lens: jax.Array,          # (A,) int32 true tail length (>= 1)
    prefix_lens: jax.Array,        # (A,) int32 reused-prefix length per row
    caches: Params,                # (A, P) caches, prefix K/V pre-written
    pools: Optional[dict[str, jax.Array]] = None,
    idx: Optional[jax.Array] = None,   # (A,) int32 slot per row
    *,
    use_kernel: bool = True,
) -> tuple[jax.Array, Params]:
    """Admission prefill over only the UNSEEN tail of each prompt — the
    serve-path Skip2-LoRA move: the prefix's K/V was cached (paged pool
    blocks gathered into ``caches[:, 0:prefix_lens)``) so its forward is
    skipped entirely; the backbone runs at (A, PT << P).

    The tail attends the cache (``attn_prefill_ext``), and because cache
    dtype == compute dtype a pooled key is bitwise the key ``sched_prefill``
    would recompute, so temp-0 tokens match the dense path exactly (tested
    + gated). The skip-LoRA readout needs only the LAST real position's
    block inputs, which live in the tail (tail_lens >= 1 by construction:
    the radix match never swallows a whole prompt) — so cached-prefix
    activations are never needed, mirroring the paper's last-position
    adapter tap. Returns (logits (A, 1, V), caches at (A, P))."""
    out = lm_forward(
        params, cfg, tail_tokens, mode="prefill", caches=caches,
        pos=prefix_lens.astype(jnp.int32), collect_acts=True,
    )
    last = (jnp.maximum(tail_lens, 1) - 1).astype(jnp.int32)     # (A,)
    y_last = jnp.take_along_axis(
        out["y_base"], last[:, None, None], axis=1
    )                                                            # (A, 1, D)
    if pools is not None:
        from repro.core.adapter_pool import grouped_skip_sum

        acts_last = jnp.take_along_axis(
            out["acts"], last[None, :, None, None], axis=2
        )                                                        # (L, A, 1, D)
        skip = grouped_skip_sum(acts_last, pools, idx, use_kernel=use_kernel)
        y_last = y_last + skip.astype(y_last.dtype)
    logits = readout(params, cfg, y_last)
    return logits, out["caches"]


# ---------------------------------------------------------------------------
# Pipelined admission prefill (pipeline_stages=N on SessionRuntime)
# ---------------------------------------------------------------------------


def _flat_layers(stack: Params, cfg: ModelConfig) -> list[Params]:
    """Unstack the periods/remainder layout into a flat per-layer list in
    execution order (layer l = p * period + pos; remainder at the tail)."""
    layers = []
    for p in range(cfg.n_periods):
        for i in range(len(cfg.pattern)):
            layers.append(
                jax.tree.map(lambda x, p=p: x[p], stack["periods"][i])
            )
    layers.extend(stack["remainder"])
    return layers


def _caches_from_flat(flat: Params, cfg: ModelConfig) -> Params:
    """Invert ``_flat_layers`` for caches: (L, B, S, ...) leaves back into
    the periods/remainder layout ``init_serve_caches`` produces."""
    n_per, period = cfg.n_periods, cfg.period
    periods = [
        jax.tree.map(lambda x, i=i: x[i : n_per * period : period], flat)
        for i in range(period)
    ]
    remainder = [
        jax.tree.map(lambda x, j=j: x[n_per * period + j], flat)
        for j in range(len(cfg.remainder_pattern))
    ]
    return {"periods": periods, "remainder": remainder}


def pipeline_stage_params(
    params: Params, cfg: ModelConfig, n_stages: int
) -> tuple[Params, jax.Array]:
    """Split the backbone stack into pipeline stages for
    ``pipeline_sched_prefill``. Returns ``(stage_blocks, valid)`` from
    ``runtime.pipeline_par.split_stages`` (leaves (n_stages, Lp, ...));
    the caller commits them P("model") over the shard's device group."""
    from repro.runtime.pipeline_par import split_stages

    kinds = set(cfg.layer_kinds())
    if len(kinds) != 1 or not kinds <= set(B.ATTN_KINDS):
        raise NotImplementedError(
            f"pipeline serve needs a uniform attention-only stack; "
            f"config has {sorted(kinds)}"
        )
    return split_stages(_flat_layers(params["stack"], cfg), n_stages)


def pipeline_sched_prefill(
    params: Params,
    cfg: ModelConfig,
    stage_blocks: Params,          # from pipeline_stage_params, P("model")
    valid: jax.Array,              # (n_stages, Lp) bool
    tokens: jax.Array,             # (A, P) int32, right-padded per row
    lens: jax.Array,               # (A,) int32
    pools: dict[str, jax.Array],   # float AdapterPool layout {"A","B"}
    idx: jax.Array,                # (A,) int32 slot per row
    *,
    mesh,
    axis: str = "model",
    n_micro: int,
) -> tuple[jax.Array, Params]:
    """``sched_prefill`` over GPipe stages: the model-axis device group runs
    the backbone as ``n_stages`` pipeline stages over ``n_micro``
    microbatches, each stage accumulating its resident layers' skip-LoRA
    terms from block inputs (``runtime.pipeline_par.pipeline_prefill``).
    Temp-0 tokens match ``sched_prefill`` (same ``max(len,1)-1`` padding
    semantics); caches come back in the standard periods layout at (A, P)
    so the scheduler's admission scatter is path-agnostic."""
    from repro.runtime.pipeline_par import pipeline_prefill

    a, p_len = tokens.shape
    if a % n_micro:
        raise ValueError(f"admission width {a} not divisible into {n_micro} microbatches")
    mb = a // n_micro
    n_stages = mesh.shape[axis]
    dtype = model_dtype(cfg)
    h = embed(
        params["embed"], tokens,
        scale_by_sqrt_dim=cfg.scale_embed_by_sqrt_dim, dtype=dtype,
    )
    x_micro = h.reshape(n_micro, mb, p_len, h.shape[-1])
    lens_m = lens.reshape(n_micro, mb)
    idx_m = idx.reshape(n_micro, mb)
    lp = jax.tree.leaves(stage_blocks)[0].shape[1]
    l_pad = n_stages * lp
    if not (isinstance(pools.get("A"), jax.Array) or hasattr(pools.get("A"), "shape")):
        raise NotImplementedError("pipeline serve needs a float adapter pool")

    def stage_pool(w):
        # (n_slots, L, ...) -> (n_stages, Lp, n_slots, ...); zero pad rows.
        w = jnp.swapaxes(w, 0, 1)
        w = jnp.pad(w, ((0, l_pad - w.shape[0]),) + ((0, 0),) * (w.ndim - 1))
        return w.reshape((n_stages, lp) + w.shape[1:])

    kind = cfg.layer_kinds()[0]

    def block_fn(p_l, hh):
        cache = B.init_block_cache(kind, mb, p_len, cfg, jnp.bfloat16)
        h2, c_new, _ = B.block_forward(
            kind, p_l, hh, cfg, mode="prefill", cache=cache
        )
        return h2, c_new

    y, skip, stage_caches = pipeline_prefill(
        stage_blocks, stage_pool(pools["A"]), stage_pool(pools["B"]), valid,
        x_micro, lens_m, idx_m, block_fn, mesh=mesh, axis=axis,
    )
    y = y.reshape(a, p_len, -1)
    skip = skip.reshape(a, -1)
    last = (jnp.maximum(lens, 1) - 1).astype(jnp.int32)
    y_last = jnp.take_along_axis(y, last[:, None, None], axis=1)  # (A, 1, D)
    logits = readout(params, cfg, y_last + skip[:, None, :].astype(y_last.dtype))

    n_layers = len(cfg.layer_kinds())

    def unstage(c):
        # (n_stages, Lp, n_micro, mb, ...) -> (L, A, ...): drop stage pads,
        # merge the microbatch grid back into admission-row order.
        c = c.reshape((l_pad,) + c.shape[2:])[:n_layers]
        return c.reshape((n_layers, a) + c.shape[3:])

    caches = _caches_from_flat(jax.tree.map(unstage, stage_caches), cfg)
    return logits, caches
