"""Paper-scale backbone: the 3-layer DNN of Section 5.1.

Structure per Table 2: FC1 -> (LoRA1) -> BN1 -> ReLU -> FC2 -> (LoRA2) ->
BN2 -> ReLU -> FC3 -> (LoRA3) -> cross-entropy loss. Hidden width 96,
LoRA rank 4, input/output 256/3 (Fan) or 561/6 (HAR).

Everything is pure-functional: parameters are plain dict pytrees, forward
functions return the intermediate feature maps x^k (inputs of each FC layer)
that Skip-LoRA adapters tap and Skip-Cache stores.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any  # pytree of arrays


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    in_dim: int
    hidden_dim: int
    out_dim: int
    n_layers: int = 3
    lora_rank: int = 4
    batchnorm: bool = True
    dtype: Any = jnp.float32

    @property
    def dims(self) -> tuple[int, ...]:
        """(d0, d1, ..., dn): layer k maps dims[k-1] -> dims[k]."""
        return (self.in_dim,) + (self.hidden_dim,) * (self.n_layers - 1) + (self.out_dim,)


def init_mlp(key: jax.Array, cfg: MLPConfig) -> Params:
    """He-init FC stack + identity-init inference-mode batchnorm."""
    dims = cfg.dims
    keys = jax.random.split(key, cfg.n_layers)
    fc = []
    for k in range(cfg.n_layers):
        n, m = dims[k], dims[k + 1]
        w = jax.random.normal(keys[k], (n, m), cfg.dtype) * jnp.sqrt(2.0 / n)
        fc.append({"W": w, "b": jnp.zeros((m,), cfg.dtype)})
    bn = []
    for k in range(cfg.n_layers - 1):
        m = dims[k + 1]
        bn.append(
            {
                "gamma": jnp.ones((m,), cfg.dtype),
                "beta": jnp.zeros((m,), cfg.dtype),
                "mean": jnp.zeros((m,), cfg.dtype),
                "var": jnp.ones((m,), cfg.dtype),
            }
        )
    return {"fc": fc, "bn": bn}


def bn_apply(bn: Params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    """Inference-mode batch normalization (frozen running statistics)."""
    inv = jax.lax.rsqrt(bn["var"] + eps)
    return (x - bn["mean"]) * inv * bn["gamma"] + bn["beta"]


def bn_update_stats(bn: Params, x: jax.Array, *, momentum: float = 0.9) -> Params:
    """Update running statistics from a batch (used only during pre-training)."""
    mean = jnp.mean(x, axis=0)
    var = jnp.var(x, axis=0)
    return {
        "gamma": bn["gamma"],
        "beta": bn["beta"],
        "mean": momentum * bn["mean"] + (1 - momentum) * mean,
        "var": momentum * bn["var"] + (1 - momentum) * var,
    }


def bn_apply_batch(bn: Params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    """Training-mode BN using batch statistics (pre-training only)."""
    mean = jnp.mean(x, axis=0)
    var = jnp.var(x, axis=0)
    inv = jax.lax.rsqrt(var + eps)
    return (x - mean) * inv * bn["gamma"] + bn["beta"]


def mlp_forward(
    params: Params,
    x: jax.Array,
    cfg: MLPConfig,
    *,
    train_bn: bool = False,
) -> tuple[jax.Array, list[jax.Array]]:
    """Forward pass. Returns (logits, xs) where xs[k] is the input feature
    map of FC layer k — exactly what Skip-LoRA taps and Skip-Cache stores.
    ``xs`` has n_layers entries; the *base* last-layer output (pre-adapter,
    the paper's c_i^n) is the returned logits themselves.
    """
    xs = []
    h = x
    n = cfg.n_layers
    for k in range(n):
        xs.append(h)
        h = h @ params["fc"][k]["W"] + params["fc"][k]["b"]
        if k < n - 1:
            if cfg.batchnorm:
                bn = params["bn"][k]
                h = bn_apply_batch(bn, h) if train_bn else bn_apply(bn, h)
            h = jax.nn.relu(h)
    return h, xs


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def pretrain(
    key: jax.Array,
    cfg: MLPConfig,
    x_train: jax.Array,
    y_train: jax.Array,
    *,
    epochs: int,
    batch_size: int = 20,
    lr: float = 0.05,
) -> Params:
    """Plain SGD pre-training of the full backbone (paper step 1)."""
    params = init_mlp(key, cfg)
    n = x_train.shape[0]
    steps_per_epoch = max(1, n // batch_size)

    def loss_fn(p, xb, yb):
        logits, _ = mlp_forward(p, xb, cfg, train_bn=False)
        return cross_entropy(logits, yb)

    @jax.jit
    def step(p, xb, yb):
        g = jax.grad(loss_fn)(p, xb, yb)
        p = jax.tree.map(lambda a, b: a - lr * b, p, g)
        # Refresh BN running stats from the batch (cheap full re-forward of
        # the prefix would be exact; momentum update is the standard choice).
        h = xb
        for k in range(cfg.n_layers - 1):
            h = h @ p["fc"][k]["W"] + p["fc"][k]["b"]
            if cfg.batchnorm:
                p["bn"][k] = bn_update_stats(p["bn"][k], h)
                h = bn_apply(p["bn"][k], h)
            h = jax.nn.relu(h)
        return p

    rng = key
    for _ in range(epochs):
        rng, sk = jax.random.split(rng)
        perm = jax.random.permutation(sk, n)
        for s in range(steps_per_epoch):
            idx = perm[s * batch_size : (s + 1) * batch_size]
            params = step(params, x_train[idx], y_train[idx])
    return params
