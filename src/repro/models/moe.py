"""Mixture-of-Experts FFN: top-k routing with grouped, capacity-bounded
dispatch (GShard-style).

Tokens are routed *within groups* (the batch rows), so every gather/scatter
indexes inside a group and the whole dispatch shards cleanly over the data
axis — no global-index scatter that would force full-activation all-gathers
(the first, flat-index implementation cost TBs/step of all-reduce on the
jamba/phi cells; see EXPERIMENTS.md §Perf iteration log).

Layout: x (G, S, D) -> per group: route -> position-in-expert via cumsum
over the S*K assignments -> dispatch to (G, E, C, D) buffers (C = per-group
capacity) -> batched expert einsum (compute scales with top_k * capacity
factor, not n_experts) -> gate-weighted scatter-add back. Overflow beyond C
drops (standard capacity trade-off). Supports shared (always-on) experts
(qwen2-moe) and MoE on every k-th layer (jamba); Switch-style aux loss.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import MoEConfig
from repro.models.ffn import ffn, init_ffn
from repro.models.layers import activation
from repro.runtime.sharding import constrain

Params = Any


def init_moe(key: jax.Array, d: int, mcfg: MoEConfig, dtype=jnp.float32) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    e, f = mcfg.n_experts, mcfg.d_ff_expert
    s_in, s_out = 1.0 / jnp.sqrt(d), 1.0 / jnp.sqrt(f)
    kg, ku, kd = jax.random.split(ke, 3)
    params = {
        "router": jax.random.normal(kr, (d, e), jnp.float32) * s_in,
        "w_gate": jax.random.normal(kg, (e, d, f), dtype) * s_in,
        "w_up": jax.random.normal(ku, (e, d, f), dtype) * s_in,
        "w_down": jax.random.normal(kd, (e, f, d), dtype) * s_out,
    }
    if mcfg.n_shared:
        params["shared"] = init_ffn(ks, d, mcfg.shared_d_ff, gated=True, dtype=dtype)
    return params


def _capacity(tokens_per_group: int, mcfg: MoEConfig) -> int:
    cap = int(mcfg.top_k * tokens_per_group * mcfg.capacity_factor / mcfg.n_experts)
    return max(cap, mcfg.top_k)


def route(
    router_w: jax.Array, x: jax.Array, mcfg: MoEConfig
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (G, S, D). Returns (gates (G,S,K), expert_idx (G,S,K), probs (G,S,E))."""
    logits = x.astype(jnp.float32) @ router_w  # fp32 routing
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, mcfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, expert_idx, probs


def moe_ffn(
    params: Params, x: jax.Array, mcfg: MoEConfig, *, act: str = "silu"
) -> tuple[jax.Array, jax.Array]:
    """Apply the MoE FFN. x: (..., S, D) with leading group dims. Returns
    (y, aux_loss)."""
    orig_shape = x.shape
    d = x.shape[-1]
    s = x.shape[-2]
    xg = x.reshape(-1, s, d)                       # (G, S, D)
    g_dim = xg.shape[0]
    e, k = mcfg.n_experts, mcfg.top_k
    c = _capacity(s, mcfg)

    gates, expert_idx, probs = route(params["router"], xg, mcfg)

    # Position of each (token, k) assignment within its expert, per group.
    flat_e = expert_idx.reshape(g_dim, s * k)                  # (G, S*K)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # (G, S*K, E)
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=1) - onehot, flat_e[..., None], axis=2
    )[..., 0]                                                  # (G, S*K)
    keep = pos < c

    # Scatter (token, gate) into (E, C) slots per group. Dropped -> index E*C
    # (out of range, mode="drop").
    token_ids = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s), k)[None], (g_dim, s * k)
    )
    slot = jnp.where(keep, flat_e * c + pos, e * c)            # (G, S*K)
    slot_token = jnp.zeros((g_dim, e * c), jnp.int32)
    slot_token = jax.vmap(lambda st, sl, ti: st.at[sl].set(ti, mode="drop"))(
        slot_token, slot, token_ids
    )
    slot_gate = jax.vmap(lambda sg, sl, gv: sg.at[sl].set(gv, mode="drop"))(
        jnp.zeros((g_dim, e * c), gates.dtype), slot, gates.reshape(g_dim, s * k)
    )
    slot_valid = jax.vmap(lambda sv, sl: sv.at[sl].set(True, mode="drop"))(
        jnp.zeros((g_dim, e * c), jnp.bool_), slot
    )

    # Gather tokens into per-group expert buffers: all indexing is within
    # the group -> shards over the batch axes with zero cross-shard traffic.
    xe = jnp.take_along_axis(xg, slot_token[..., None], axis=1)  # (G, E*C, D)
    xe = xe * slot_valid[..., None].astype(xe.dtype)
    xe = xe.reshape(g_dim, e, c, d)
    xe = constrain(xe, "expert_group", "expert", None, None)

    g_act = activation(
        act, jnp.einsum("gecd,edf->gecf", xe, params["w_gate"].astype(xe.dtype))
    )
    u = jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(xe.dtype))
    ye = jnp.einsum("gecf,efd->gecd", g_act * u, params["w_down"].astype(xe.dtype))
    ye = constrain(ye, "expert_group", "expert", None, None)

    # Combine: scatter-add each slot back to its token, gate-weighted.
    w = (slot_gate * slot_valid.astype(slot_gate.dtype))[..., None]  # (G,E*C,1)
    contrib = ye.reshape(g_dim, e * c, d) * w.astype(ye.dtype)
    y = jax.vmap(lambda acc, st, cb: acc.at[st].add(cb))(
        jnp.zeros_like(xg), slot_token, contrib
    )

    if mcfg.n_shared:
        y = y + ffn(params["shared"], xg, act=act, gated=True)

    # Switch-style load-balance auxiliary loss.
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx, e, dtype=jnp.float32).sum(axis=2),
        axis=(0, 1),
    )
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = mcfg.router_aux_weight * e * jnp.sum(frac_tokens * frac_probs)

    return y.reshape(orig_shape), aux
