"""Recurrent sequence blocks: Mamba (selective SSM), mLSTM and sLSTM (xLSTM).

Each block exposes two forms sharing the same parameters:
  - ``*_seq``:   full-sequence forward via ``lax.scan`` over time (training /
                 prefill); also returns the final recurrent state so serving
                 can continue from it.
  - ``*_step``:  single-token update against an explicit state (decode).

States are plain pytrees so they stack across layers inside the LayerStack
scan and shard like any other array. These are the sub-quadratic paths that
make the ``long_500k`` shape runnable for xlstm-350m and jamba (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import MambaConfig, ModelConfig, XLSTMConfig

Params = Any


def _causal_conv1d(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C). Returns (y, new_state)
    where state holds the last K-1 inputs for streaming decode."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype) for i in range(k))
    new_state = xp[:, xp.shape[1] - (k - 1) :, :]
    if state is not None:
        new_state = new_state.astype(state.dtype)  # keep streaming-cache dtype stable
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba (selective state-space model, arXiv:2312.00752)
# ---------------------------------------------------------------------------


def init_mamba(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    mc = cfg.mamba or MambaConfig()
    d, di, n = cfg.d_model, (cfg.mamba or MambaConfig()).d_inner(cfg.d_model), mc.d_state
    dt_rank = max(1, d // 16)
    ks = jax.random.split(key, 6)
    s = 1.0 / jnp.sqrt(d)
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (mc.d_conv, di), dtype) * 0.2,
        "x_proj": jax.random.normal(ks[2], (di, dt_rank + 2 * n), dtype) / jnp.sqrt(di),
        "dt_proj": jax.random.normal(ks[3], (dt_rank, di), dtype) / jnp.sqrt(dt_rank),
        "dt_bias": jnp.zeros((di,), dtype),
        "a_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
        ),
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(ks[4], (di, d), dtype) / jnp.sqrt(di),
    }


def init_mamba_state(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    mc = cfg.mamba or MambaConfig()
    di = mc.d_inner(cfg.d_model)
    return {
        "ssm": jnp.zeros((batch, di, mc.d_state), jnp.float32),
        "conv": jnp.zeros((batch, mc.d_conv - 1, di), dtype),
    }


def _mamba_scan_inputs(params: Params, x: jax.Array, cfg: ModelConfig, conv_state):
    """Shared projections for both seq and step forms. x: (B,S,D)."""
    mc = cfg.mamba or MambaConfig()
    dtype = x.dtype
    di = mc.d_inner(cfg.d_model)
    xz = x @ params["in_proj"].astype(dtype)
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, new_conv = _causal_conv1d(xin, params["conv_w"], conv_state)
    xc = jax.nn.silu(xc)
    proj = xc @ params["x_proj"].astype(dtype)
    dt_rank = params["dt_proj"].shape[0]
    dt_r, b, c = jnp.split(proj, [dt_rank, dt_rank + mc.d_state], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_proj"].astype(dtype) + params["dt_bias"].astype(dtype))
    return xc, z, dt, b, c, new_conv, di


def mamba_seq(
    params: Params, x: jax.Array, cfg: ModelConfig, state: Params | None = None
) -> tuple[jax.Array, Params]:
    """Full-sequence selective scan. x: (B,S,D) -> (y, final_state)."""
    mc = cfg.mamba or MambaConfig()
    conv_state = state["conv"] if state is not None else None
    xc, z, dt, b, c, new_conv, di = _mamba_scan_inputs(params, x, cfg, conv_state)
    a = -jnp.exp(params["a_log"])  # (Di, N) fp32

    h0 = (
        state["ssm"]
        if state is not None
        else jnp.zeros((x.shape[0], di, mc.d_state), jnp.float32)
    )

    def step(h, inp):
        xc_t, dt_t, b_t, c_t = inp  # (B,Di),(B,Di),(B,N),(B,N)
        dt_f = dt_t.astype(jnp.float32)
        da = jnp.exp(dt_f[..., None] * a[None])                    # (B,Di,N)
        dbx = dt_f[..., None] * b_t.astype(jnp.float32)[:, None, :] * xc_t.astype(jnp.float32)[..., None]
        h = da * h + dbx
        y_t = jnp.einsum("bdn,bn->bd", h, c_t.astype(jnp.float32))
        return h, y_t.astype(x.dtype)

    xs = (
        jnp.moveaxis(xc, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(b, 1, 0),
        jnp.moveaxis(c, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + xc * params["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(x.dtype)
    return out, {"ssm": h_final, "conv": new_conv}


def mamba_step(
    params: Params, x: jax.Array, cfg: ModelConfig, state: Params
) -> tuple[jax.Array, Params]:
    """Single-token decode. x: (B,1,D)."""
    y, new_state = mamba_seq(params, x, cfg, state)
    return y, new_state


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell, arXiv:2405.04517)
# ---------------------------------------------------------------------------


def init_mlstm(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    xc = cfg.xlstm or XLSTMConfig()
    d = cfg.d_model
    di = int(d * xc.mlstm_proj_factor)
    h = cfg.n_heads
    hd = di // h
    ks = jax.random.split(key, 8)
    s = 1.0 / jnp.sqrt(d)
    si = 1.0 / jnp.sqrt(di)
    return {
        "up_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (xc.conv_kernel, di), dtype) * 0.2,
        "wq": jax.random.normal(ks[2], (di, h, hd), dtype) * si,
        "wk": jax.random.normal(ks[3], (di, h, hd), dtype) * si,
        "wv": jax.random.normal(ks[4], (di, h, hd), dtype) * si,
        "w_i": jax.random.normal(ks[5], (di, h), dtype) * si,
        "w_f": jax.random.normal(ks[6], (di, h), dtype) * si,
        "f_bias": 3.0 * jnp.ones((h,), dtype),
        "down_proj": jax.random.normal(ks[7], (di, d), dtype) * si,
    }


def init_mlstm_state(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    xc = cfg.xlstm or XLSTMConfig()
    di = int(cfg.d_model * xc.mlstm_proj_factor)
    h = cfg.n_heads
    hd = di // h
    return {
        "c": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
        "conv": jnp.zeros((batch, xc.conv_kernel - 1, di), dtype),
    }


def mlstm_seq(
    params: Params, x: jax.Array, cfg: ModelConfig, state: Params | None = None
) -> tuple[jax.Array, Params]:
    """Full-sequence mLSTM with stabilised exponential gating."""
    xc_cfg = cfg.xlstm or XLSTMConfig()
    dtype = x.dtype
    b, s, d = x.shape
    di = int(d * xc_cfg.mlstm_proj_factor)
    nh = cfg.n_heads
    hd = di // nh

    up = x @ params["up_proj"].astype(dtype)
    a_in, z = jnp.split(up, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    a_c, new_conv = _causal_conv1d(a_in, params["conv_w"], conv_state)
    a_c = jax.nn.silu(a_c)

    q = jnp.einsum("bsd,dnh->bsnh", a_c, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dnh->bsnh", a_c, params["wk"].astype(dtype)) / jnp.sqrt(
        jnp.asarray(hd, dtype)
    )
    v = jnp.einsum("bsd,dnh->bsnh", a_in, params["wv"].astype(dtype))
    ig = (a_c @ params["w_i"].astype(dtype)).astype(jnp.float32)             # (B,S,H)
    fg = (a_c @ params["w_f"].astype(dtype)).astype(jnp.float32) + params["f_bias"].astype(jnp.float32)

    if state is None:
        c0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, nh, hd), jnp.float32)
        m0 = jnp.zeros((b, nh), jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]

    def step(carry, inp):
        c, n, m = carry
        q_t, k_t, v_t, i_t, f_t = inp
        logf = jax.nn.log_sigmoid(f_t)                      # (B,H)
        m_new = jnp.maximum(logf + m, i_t)
        i_p = jnp.exp(i_t - m_new)                          # (B,H)
        f_p = jnp.exp(logf + m - m_new)
        kf = k_t.astype(jnp.float32)
        vf = v_t.astype(jnp.float32)
        c = f_p[..., None, None] * c + i_p[..., None, None] * jnp.einsum(
            "bnh,bng->bnhg", kf, vf
        )
        n = f_p[..., None] * n + i_p[..., None] * kf
        qf = q_t.astype(jnp.float32)
        num = jnp.einsum("bnhg,bnh->bng", c, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bnh,bnh->bn", n, qf)), 1.0)
        h_t = (num / den[..., None]).astype(dtype)          # (B,H,hd)
        return (c, n, m_new), h_t

    xs = tuple(
        jnp.moveaxis(t, 1, 0) for t in (q, k, v, ig, fg)
    )
    (cF, nF, mF), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, di)
    h = h * jax.nn.silu(z)
    out = h @ params["down_proj"].astype(dtype)
    return out, {"c": cF, "n": nF, "m": mF, "conv": new_conv}


def mlstm_step(params, x, cfg, state):
    return mlstm_seq(params, x, cfg, state)


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory cell with recurrent block-diagonal weights)
# ---------------------------------------------------------------------------


def init_slstm(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    xc = cfg.xlstm or XLSTMConfig()
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    ks = jax.random.split(key, 7)
    s = 1.0 / jnp.sqrt(d)
    sh = 1.0 / jnp.sqrt(hd)
    dff = int(d * xc.slstm_ff_factor)
    return {
        # Input weights for i, f, z, o gates.
        "w_in": jax.random.normal(ks[0], (d, 4, d), dtype) * s,
        # Recurrent block-diagonal weights per head for the 4 gates.
        "r": jax.random.normal(ks[1], (4, nh, hd, hd), dtype) * sh,
        "bias": jnp.concatenate(
            [jnp.zeros((1, d)), 3.0 * jnp.ones((1, d)), jnp.zeros((2, d))], axis=0
        ).astype(dtype),  # f-gate bias +3 for stability
        # Post-cell gated FF (factor 4/3 per xLSTM paper).
        "ff_up": jax.random.normal(ks[2], (d, 2 * dff), dtype) * s,
        "ff_down": jax.random.normal(ks[3], (dff, d), dtype) / jnp.sqrt(dff),
    }


def init_slstm_state(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), dtype),
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }


def slstm_seq(
    params: Params, x: jax.Array, cfg: ModelConfig, state: Params | None = None
) -> tuple[jax.Array, Params]:
    dtype = x.dtype
    b, s, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    xc = cfg.xlstm or XLSTMConfig()

    gates_in = jnp.einsum("bsd,dge->bsge", x, params["w_in"].astype(dtype))  # (B,S,4,D)

    st = state if state is not None else init_slstm_state(b, cfg, dtype)

    def step(carry, g_in):
        h, c, n, m = carry
        hh = h.reshape(b, nh, hd)
        rec = jnp.einsum("bnh,gnhk->bgnk", hh.astype(dtype), params["r"].astype(dtype))
        g = (g_in + rec.reshape(b, 4, d) + params["bias"].astype(dtype)[None]).astype(
            jnp.float32
        )
        i_raw, f_raw, z_raw, o_raw = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        logf = jax.nn.log_sigmoid(f_raw)
        m_new = jnp.maximum(logf + m, i_raw)
        i_p = jnp.exp(i_raw - m_new)
        f_p = jnp.exp(logf + m - m_new)
        c = f_p * c + i_p * jnp.tanh(z_raw)
        n = f_p * n + i_p
        h_new = (jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1e-6)).astype(dtype)
        return (h_new, c, n, m_new), h_new

    carry0 = (
        st["h"].astype(dtype),
        st["c"].astype(jnp.float32),
        st["n"].astype(jnp.float32),
        st["m"].astype(jnp.float32),
    )
    (hF, cF, nF, mF), hs = jax.lax.scan(step, carry0, jnp.moveaxis(gates_in, 1, 0))
    hF = hF.astype(st["h"].dtype)  # keep streaming-cache dtype stable
    h_seq = jnp.moveaxis(hs, 0, 1)  # (B,S,D)

    # Gated FF (factor 4/3).
    upg = h_seq @ params["ff_up"].astype(dtype)
    ug, uu = jnp.split(upg, 2, axis=-1)
    out = (jax.nn.silu(ug) * uu) @ params["ff_down"].astype(dtype)
    return out, {"h": hF, "c": cF, "n": nF, "m": mF}


def slstm_step(params, x, cfg, state):
    return slstm_seq(params, x, cfg, state)
