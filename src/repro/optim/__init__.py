"""Optimizers: SGD/Adam/AdamW with trainable masks, int8 state, compression."""

from repro.optim.optimizers import (  # noqa: F401
    OptState,
    adamw,
    apply_updates,
    make_optimizer,
    sgd,
)
