"""Minimal functional optimizers (no external deps).

API mirrors optax: ``opt = make_optimizer(...)``; ``state = opt.init(params)``;
``updates, state = opt.update(grads, state, params)``;
``params = apply_updates(params, updates)``.

Features needed at framework scale:
  - trainable masks (adapter-only fine-tuning never allocates backbone
    moments — the paper's tiny-optimizer-state property),
  - fp32 master moments regardless of param dtype,
  - optional blockwise-int8 moment quantisation (``repro.optim.quantized``)
    for 100B+ full-training fits.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Params = Any


class Optimizer(NamedTuple):
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Optional[Params]], tuple[Params, Any]]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    step: jax.Array
    mu: Params | None = None
    nu: Params | None = None


def _zeros_like_f32(p):
    return jnp.zeros(p.shape, jnp.float32)


def sgd(lr: float, *, momentum: float = 0.0) -> Optimizer:
    def init(params):
        mu = jax.tree.map(_zeros_like_f32, params) if momentum else None
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu)

    def update(grads, state, params=None):
        del params
        if momentum:
            mu = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state.mu, grads
            )
            updates = jax.tree.map(lambda m: -lr * m, mu)
            return updates, OptState(step=state.step + 1, mu=mu)
        updates = jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads)
        return updates, OptState(step=state.step + 1)

    return Optimizer(init, update)


def adamw(
    lr: float,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(_zeros_like_f32, params),
            nu=jax.tree.map(_zeros_like_f32, params),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        bc1 = 1 - b1**step.astype(jnp.float32)
        bc2 = 1 - b2**step.astype(jnp.float32)

        def upd(m, v, p):
            u = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u

        if params is not None:
            updates = jax.tree.map(upd, mu, nu, params)
        else:
            updates = jax.tree.map(lambda m, v: upd(m, v, None), mu, nu)
        return updates, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init, update)


def make_optimizer(name: str, lr: float, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adam":
        return adamw(lr, weight_decay=0.0, **kw)
    raise ValueError(name)


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates)


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: Params, max_norm: float) -> Params:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
