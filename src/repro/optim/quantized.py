"""Blockwise-int8 optimizer state + gradient compression.

Distributed-optimization tricks for 100B+ full training on fixed HBM:

  - ``int8_adamw``: AdamW whose moments are stored as int8 with per-block
    (128-element) fp32 scales — 3.6x smaller than fp32 moments (the jamba
    398B full-train fit on 256 chips depends on this; EXPERIMENTS.md
    §Dry-run). Dequant -> update -> requant is fused into the step by XLA.
  - gradient compression for the DP all-reduce: int8 rowwise quantisation
    (``compress_grads`` / ``decompress_grads``) and top-k sparsification
    (``topk_sparsify``) with error feedback — classic bandwidth savers when
    the collective term dominates the roofline.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, OptState

Params = Any

BLOCK = 128


def _pad_to_block(x: jax.Array) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), x.size


def quantize_blockwise(x: jax.Array) -> dict[str, jax.Array]:
    """fp -> {q int8 (nblocks, BLOCK), scale fp32 (nblocks,), meta}."""
    blocks, _ = _pad_to_block(x.astype(jnp.float32))
    amax = jnp.max(jnp.abs(blocks), axis=1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale}


def dequantize_blockwise(qs: dict[str, jax.Array], shape, dtype=jnp.float32) -> jax.Array:
    flat = (qs["q"].astype(jnp.float32) * qs["scale"][:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def int8_adamw(
    lr: float,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    """AdamW with int8 blockwise moments (bitsandbytes-style, TPU-friendly)."""

    def init(params):
        mu = jax.tree.map(lambda p: quantize_blockwise(jnp.zeros(p.shape)), params)
        nu = jax.tree.map(lambda p: quantize_blockwise(jnp.zeros(p.shape)), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)

    def update(grads, state, params=None):
        step = state.step + 1
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd_one(g, mq, vq, p):
            m = dequantize_blockwise(mq, g.shape)
            # v is stored in sqrt-domain: linear int8 on v itself destroys
            # small-v entries (update = m/sqrt(v) is 1/sqrt-sensitive);
            # sqrt-domain compresses the dynamic range enough for 8 bits.
            v = jnp.square(dequantize_blockwise(vq, g.shape))
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * jnp.square(gf)
            u = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u, quantize_blockwise(m), quantize_blockwise(jnp.sqrt(v))

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params) if params is not None else [None] * len(flat_g)
        outs = [upd_one(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in outs])
        mu = treedef.unflatten([o[1] for o in outs])
        nu = treedef.unflatten([o[2] for o in outs])
        return updates, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Gradient compression (for bandwidth-bound DP all-reduce)
# ---------------------------------------------------------------------------


class CompressedGrads(NamedTuple):
    q: Params      # int8 tree
    scale: Params  # fp32 rowwise scales


def compress_grads(grads: Params) -> CompressedGrads:
    """Rowwise int8: 4x (fp32) / 2x (bf16) smaller all-reduce payloads."""

    def one(g):
        gf = g.astype(jnp.float32)
        amax = jnp.max(jnp.abs(gf), axis=-1, keepdims=True)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        return jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8), scale

    qs = jax.tree.map(one, grads)
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple))
    return CompressedGrads(q, s)


def decompress_grads(c: CompressedGrads, like: Params) -> Params:
    return jax.tree.map(
        lambda q, s, g: (q.astype(jnp.float32) * s).astype(g.dtype), c.q, c.scale, like
    )


def topk_sparsify(g: jax.Array, k_fraction: float = 0.01) -> tuple[jax.Array, jax.Array]:
    """Keep the top-k |values| (flat); returns (values, indices). Use with
    error feedback: residual = g - scatter(values)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.size * k_fraction))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def error_feedback_residual(g: jax.Array, vals: jax.Array, idx: jax.Array) -> jax.Array:
    flat = g.reshape(-1)
    kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return (flat - kept).reshape(g.shape)
