"""Distributed runtime: sharding rules, pipeline parallelism, fault
tolerance, and the mesh-native session entry points.

``from repro.runtime import ...`` is the one import path for the
distribution layer:

  - sharding: ``AxisRules``, ``make_mesh``, ``session_devices``,
    ``session_param_specs``, ``replicate_backbone``, ``param_specs``,
    ``sharding_scope``, ``constrain``, and the 2-D session surface
    (``session_mesh_layout``, ``shard_submesh``, ``shard_backbone``,
    ``ShardScope``, ``scope_ctx``, ``per_device_bytes``)
  - pipeline parallelism: ``split_stages``, ``pipeline_apply``,
    ``pipeline_prefill``, ``bubble_fraction``
  - fault tolerance: ``Supervisor``, ``SessionSupervisor``,
    ``StragglerMonitor``, ``elastic_remesh``, ``elastic_session_mesh``,
    ``healthy_mesh_shape``
  - the mesh-native session engine: ``SessionRuntime`` (re-exported from
    ``repro.core.runtime``, which this package's sharding/fault modules
    underpin)

Exports resolve lazily (module ``__getattr__``) so that
``repro.core.runtime`` can import ``repro.runtime.sharding`` without a
package cycle, and importing this package never touches jax device state.
"""

from __future__ import annotations

import importlib

_EXPORTS = {
    # sharding
    "AxisRules": "repro.runtime.sharding",
    "make_mesh": "repro.runtime.sharding",
    "session_devices": "repro.runtime.sharding",
    "session_param_specs": "repro.runtime.sharding",
    "replicate_backbone": "repro.runtime.sharding",
    "param_specs": "repro.runtime.sharding",
    "zero1_specs": "repro.runtime.sharding",
    "sharding_scope": "repro.runtime.sharding",
    "constrain": "repro.runtime.sharding",
    "named": "repro.runtime.sharding",
    "session_mesh_layout": "repro.runtime.sharding",
    "shard_submesh": "repro.runtime.sharding",
    "shard_backbone": "repro.runtime.sharding",
    "ShardScope": "repro.runtime.sharding",
    "scope_ctx": "repro.runtime.sharding",
    "SESSION_TP_RULES": "repro.runtime.sharding",
    "per_device_bytes": "repro.runtime.sharding",
    # pipeline parallelism
    "split_stages": "repro.runtime.pipeline_par",
    "pipeline_apply": "repro.runtime.pipeline_par",
    "pipeline_prefill": "repro.runtime.pipeline_par",
    "bubble_fraction": "repro.runtime.pipeline_par",
    # fault tolerance
    "Supervisor": "repro.runtime.fault",
    "SessionSupervisor": "repro.runtime.fault",
    "StragglerMonitor": "repro.runtime.fault",
    "elastic_remesh": "repro.runtime.fault",
    "elastic_session_mesh": "repro.runtime.fault",
    "healthy_mesh_shape": "repro.runtime.fault",
    # session engine (lives in core; the mesh-native half of this package)
    "SessionRuntime": "repro.core.runtime",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.runtime' has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return __all__
