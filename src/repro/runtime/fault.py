"""Fault tolerance: supervised training with checkpoint/restart, elastic
re-meshing, and straggler mitigation hooks.

Single-controller pattern (this process is the controller; on a real
multi-host pod the same logic runs under jax.distributed with a coordinator):

  - ``Supervisor.run`` wraps the step loop; any exception triggers rollback
    to the latest checkpoint and resume, up to ``max_restarts``. Data
    iterator state and RNG live inside the checkpoint, so a restart replays
    nothing and skips nothing.
  - ``elastic_remesh``: on restart with a different healthy-device count,
    rebuild the mesh from the surviving devices and re-shard the restored
    checkpoint onto it (restore_checkpoint already reshards; this helper
    picks the new mesh shape).
  - Straggler mitigation: on real pods, per-step duration is monitored; a
    step exceeding ``straggler_factor`` x the trailing median flags the slow
    host for replacement at the next checkpoint boundary (synchronous SPMD
    can't drop a worker mid-step). The detection logic is implemented and
    unit-tested here; the replacement hook is a callback.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager

Params = Any


@dataclasses.dataclass
class StragglerMonitor:
    """Flags steps (hosts, on a pod) that run far slower than the median."""

    window: int = 32
    factor: float = 2.0
    _durations: list = dataclasses.field(default_factory=list)

    def record(self, seconds: float) -> bool:
        """Returns True if this step is a straggler vs the trailing median."""
        history = self._durations[-self.window :]
        self._durations.append(seconds)
        if len(history) < 8:
            return False
        return seconds > self.factor * float(np.median(history))

    @property
    def median(self) -> float:
        return float(np.median(self._durations[-self.window :])) if self._durations else 0.0


def healthy_mesh_shape(n_devices: int, model_parallel: int) -> tuple[int, int]:
    """Largest (data, model) grid on the surviving devices (elastic restart).
    Keeps the model axis fixed (weights must still fit) and shrinks data."""
    data = n_devices // model_parallel
    if data < 1:
        raise RuntimeError(
            f"cannot keep model_parallel={model_parallel} on {n_devices} devices"
        )
    return (data, model_parallel)


def elastic_remesh(model_parallel: int, devices=None) -> jax.sharding.Mesh:
    devices = devices if devices is not None else jax.devices()
    data, model = healthy_mesh_shape(len(devices), model_parallel)
    arr = np.asarray(devices[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(arr, ("data", "model"))


@dataclasses.dataclass
class Supervisor:
    """Checkpointed, restartable step-loop driver."""

    ckpt: CheckpointManager
    max_restarts: int = 3
    on_straggler: Optional[Callable[[int, float], None]] = None
    monitor: StragglerMonitor = dataclasses.field(default_factory=StragglerMonitor)

    def run(
        self,
        state: Params,
        step_fn: Callable[[Params, int], Params],
        *,
        num_steps: int,
        start_step: int = 0,
        state_shardings: Optional[Params] = None,
    ) -> Params:
        """Run ``num_steps`` of ``step_fn`` with checkpoint/restart.

        ``step_fn(state, step) -> state`` must be pure w.r.t. ``state`` (the
        jit'd train step + host-side bookkeeping).
        """
        restarts = 0
        step = start_step
        # Resume if a checkpoint exists.
        restored = self.ckpt.restore_latest(state, shardings=state_shardings)
        if restored is not None:
            state, manifest = restored
            step = int(manifest["step"])

        while step < num_steps:
            try:
                t0 = time.perf_counter()
                state = step_fn(state, step)
                dt = time.perf_counter() - t0
                if self.monitor.record(dt) and self.on_straggler:
                    self.on_straggler(step, dt)
                step += 1
                if self.ckpt.should_save(step):
                    self.ckpt.save(step, state)
            except KeyboardInterrupt:
                raise
            except Exception:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                restored = self.ckpt.restore_latest(state, shardings=state_shardings)
                if restored is None:
                    # No checkpoint yet: restart from the initial state.
                    step = start_step
                    continue
                state, manifest = restored
                step = int(manifest["step"])
        return state
