"""Fault tolerance: supervised training with checkpoint/restart, elastic
re-meshing, and straggler mitigation hooks.

Single-controller pattern (this process is the controller; on a real
multi-host pod the same logic runs under jax.distributed with a coordinator):

  - ``Supervisor.run`` wraps the step loop; any exception triggers rollback
    to the latest checkpoint and resume, up to ``max_restarts``. Data
    iterator state and RNG live inside the checkpoint, so a restart replays
    nothing and skips nothing.
  - ``elastic_remesh``: on restart with a different healthy-device count,
    rebuild the mesh from the surviving devices and re-shard the restored
    checkpoint onto it (restore_checkpoint already reshards; this helper
    picks the new mesh shape).
  - Straggler mitigation: on real pods, per-step duration is monitored; a
    step exceeding ``straggler_factor`` x the trailing median flags the slow
    host for replacement at the next checkpoint boundary (synchronous SPMD
    can't drop a worker mid-step). The detection logic is implemented and
    unit-tested here; the replacement hook is a callback.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.runtime.sharding import make_mesh

Params = Any


@dataclasses.dataclass
class StragglerMonitor:
    """Flags steps (hosts, on a pod) that run far slower than the median."""

    window: int = 32
    factor: float = 2.0
    _durations: list = dataclasses.field(default_factory=list)

    def record(self, seconds: float) -> bool:
        """Returns True if this step is a straggler vs the trailing median."""
        history = self._durations[-self.window :]
        self._durations.append(seconds)
        if len(history) < 8:
            return False
        return seconds > self.factor * float(np.median(history))

    @property
    def median(self) -> float:
        return float(np.median(self._durations[-self.window :])) if self._durations else 0.0


def healthy_mesh_shape(n_devices: int, model_parallel: int) -> tuple[int, int]:
    """Largest (data, model) grid on the surviving devices (elastic restart).
    Keeps the model axis fixed (weights must still fit) and shrinks data."""
    data = n_devices // model_parallel
    if data < 1:
        raise RuntimeError(
            f"cannot keep model_parallel={model_parallel} on {n_devices} devices"
        )
    return (data, model_parallel)


def elastic_remesh(model_parallel: int, devices=None) -> jax.sharding.Mesh:
    devices = list(devices) if devices is not None else jax.devices()
    data, model = healthy_mesh_shape(len(devices), model_parallel)
    return make_mesh((data, model), ("data", "model"), devices=devices)


def elastic_session_mesh(devices=None) -> jax.sharding.Mesh:
    """Data-only session mesh over the surviving devices (the elastic
    restart path for mesh-native ``SessionRuntime``): the session's
    *logical* shard layout is a checkpoint property and does not change —
    restored shard ``s`` simply lands on ``devices[s % len(devices)]``,
    which keeps every group trace identical and the continuation bitwise
    (DESIGN.md §10)."""
    devices = list(devices) if devices is not None else jax.devices()
    return make_mesh((len(devices),), ("data",), devices=devices)


@dataclasses.dataclass
class SessionSupervisor:
    """The Supervisor folded into the continual-learning session loop.

    Drives a ``SessionRuntime`` through an event stream (serve / ingest /
    adapt closures) with checkpoint/restart at *event boundaries*: after
    every ``save_every`` completed events the whole session — stacked
    adapters, optimizer moments, pool slot tables, cache rows — is captured
    via ``checkpoint.save_runtime_session``. A failure mid-event rolls back
    to the latest boundary and resumes at the first event past it: at the
    default ``save_every=1`` every boundary is an event boundary, so
    completed events are never replayed (their effects live in the
    checkpoint) and only the failed event re-executes — against exactly
    the state it first saw. With ``save_every=k`` up to ``k-1`` completed
    events past the last boundary re-run after a crash (the classic
    checkpoint-interval trade; their ``results`` entries are overwritten).

    Elastic restarts ride the same loop: ``make_runtime`` is consulted on
    every (re)start and may build its mesh from whatever devices currently
    look healthy (``elastic_session_mesh``) — the session's logical shard
    layout travels in the checkpoint, so the restored run's group traces
    (and therefore its adapters) are bitwise those of the uninterrupted one.
    """

    directory: str
    keep: int = 3
    max_restarts: int = 3
    save_every: int = 1
    on_straggler: Optional[Callable[[int, float], None]] = None
    monitor: StragglerMonitor = dataclasses.field(default_factory=StragglerMonitor)

    def run(self, make_runtime: Callable[[], Any], events) -> tuple[Any, dict]:
        """Run ``events`` (callables ``event(runtime, index) -> result``)
        to completion with checkpoint/restart. Returns the live runtime and
        ``{"results": {index: result}, "restarts": n, "resumed_at": i}`` —
        results cover the events executed by this process (a resume skips,
        never re-runs, the events a previous incarnation completed)."""
        from repro.checkpoint.checkpoint import (
            latest_checkpoint,
            restore_runtime_session,
            save_runtime_session,
        )

        events = list(events)
        ckpt = CheckpointManager(
            self.directory, keep=self.keep, save_every=self.save_every
        )

        def boot() -> tuple[Any, int]:
            rt = make_runtime()
            path = latest_checkpoint(self.directory)
            if path is None:
                return rt, 0
            manifest = restore_runtime_session(path, rt)
            return rt, int(manifest["step"])

        restarts = 0
        results: dict[int, Any] = {}
        rt, step = boot()
        resumed_at = step
        while step < len(events):
            try:
                t0 = time.perf_counter()
                results[step] = events[step](rt, step)
                dt = time.perf_counter() - t0
                if self.monitor.record(dt) and self.on_straggler:
                    self.on_straggler(step, dt)
                step += 1
                if step % self.save_every == 0 or step == len(events):
                    save_runtime_session(self.directory, step, rt)
                    ckpt._gc()
            except KeyboardInterrupt:
                raise
            except Exception:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                rt, step = boot()
                resumed_at = step
        return rt, {"results": results, "restarts": restarts,
                    "resumed_at": resumed_at}


@dataclasses.dataclass
class Supervisor:
    """Checkpointed, restartable step-loop driver."""

    ckpt: CheckpointManager
    max_restarts: int = 3
    on_straggler: Optional[Callable[[int, float], None]] = None
    monitor: StragglerMonitor = dataclasses.field(default_factory=StragglerMonitor)

    def run(
        self,
        state: Params,
        step_fn: Callable[[Params, int], Params],
        *,
        num_steps: int,
        start_step: int = 0,
        state_shardings: Optional[Params] = None,
    ) -> Params:
        """Run ``num_steps`` of ``step_fn`` with checkpoint/restart.

        ``step_fn(state, step) -> state`` must be pure w.r.t. ``state`` (the
        jit'd train step + host-side bookkeeping).
        """
        restarts = 0
        step = start_step
        # Resume if a checkpoint exists.
        restored = self.ckpt.restore_latest(state, shardings=state_shardings)
        if restored is not None:
            state, manifest = restored
            step = int(manifest["step"])

        while step < num_steps:
            try:
                t0 = time.perf_counter()
                state = step_fn(state, step)
                dt = time.perf_counter() - t0
                if self.monitor.record(dt) and self.on_straggler:
                    self.on_straggler(step, dt)
                step += 1
                if self.ckpt.should_save(step):
                    self.ckpt.save(step, state)
            except KeyboardInterrupt:
                raise
            except Exception:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                restored = self.ckpt.restore_latest(state, shardings=state_shardings)
                if restored is None:
                    # No checkpoint yet: restart from the initial state.
                    step = start_step
                    continue
                state, manifest = restored
                step = int(manifest["step"])
        return state
