"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

The multi-pod mesh (2, 16, 16) can treat the pod axis as either extra data
parallelism (default) or as *pipeline stages* — the right choice when the
model no longer fits one pod's HBM or when cross-pod DCN bandwidth makes
pure DP gradient all-reduce the bottleneck (only activations cross pods in
a pipeline, once per microbatch-stage boundary, not 2x params per step).

Implementation: ``shard_map`` over the pipeline axis; each device group
holds one contiguous *stage* of layers (params stacked on a leading stage
axis, sharded over the pipeline axis). The classic GPipe schedule runs
``n_micro + n_stages - 1`` ticks; at each tick a stage processes one
microbatch and hands its activation to the next stage via
``lax.ppermute``. Bubble fraction = (P-1)/(M+P-1). Fully differentiable
(ppermute transposes to the reverse permutation), so ``jax.grad`` through
``pipeline_apply`` yields pipelined backward for free.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax promoted shard_map out of experimental (and renamed check_rep ->
# check_vma) in newer releases; support both.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"

Params = Any


def split_stages(layer_params: list[Params], n_stages: int) -> Params:
    """Group per-layer params into n_stages stacked stage pytrees.

    layer_params: list of identically-structured per-layer pytrees, length L
    (L % n_stages == 0). Returns a pytree with leading dims
    (n_stages, L // n_stages, ...) ready to shard over the pipeline axis.
    """
    l = len(layer_params)
    if l % n_stages:
        raise ValueError(f"{l} layers not divisible into {n_stages} stages")
    per = l // n_stages
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *layer_params)
    return jax.tree.map(
        lambda x: x.reshape((n_stages, per) + x.shape[1:]), stacked
    )


def pipeline_apply(
    stage_params: Params,
    x_micro: jax.Array,
    layer_fn: Callable[[Params, jax.Array], jax.Array],
    *,
    mesh: Mesh,
    axis: str = "pod",
) -> jax.Array:
    """Run the pipelined stack over microbatches.

    stage_params: (n_stages, layers_per_stage, ...) pytree, sharded on the
        leading axis over ``axis``.
    x_micro: (n_micro, micro_batch, ...) activations (replicated).
    Returns (n_micro, micro_batch, ...) outputs (replicated).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def stage_block(params_block, x):
        # params_block: (1, layers_per_stage, ...) — this device's stage.
        def body(h, layer_p):
            return layer_fn(layer_p, h), None

        h, _ = jax.lax.scan(body, x, jax.tree.map(lambda a: a[0], params_block))
        return h

    def per_stage(params_block, x_all):
        stage_id = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(x_all[0])          # incoming activation
        outs = jnp.zeros_like(x_all)            # collected at the last stage

        def tick(carry, t):
            buf, outs = carry
            # Stage 0 ingests microbatch t (if still in range).
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            x_in = jax.lax.dynamic_index_in_dim(x_all, mb_idx, keepdims=False)
            h_in = jnp.where(stage_id == 0, x_in, buf)
            h_out = stage_block(params_block, h_in)
            # Pass to the next stage (ring; last stage's send wraps to 0 and
            # is ignored there).
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            nxt = jax.lax.ppermute(h_out, axis, perm)
            # Last stage: microbatch t' = t - (n_stages - 1) finished at tick t.
            done_idx = t - (n_stages - 1)
            valid = jnp.logical_and(done_idx >= 0, stage_id == n_stages - 1)
            safe_idx = jnp.clip(done_idx, 0, n_micro - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, safe_idx, keepdims=False)
            upd = jnp.where(valid, h_out, cur)
            outs = jax.lax.dynamic_update_index_in_dim(outs, upd, safe_idx, 0)
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # Broadcast the last stage's collected outputs to every stage.
        outs = jax.lax.ppermute(
            outs, axis, [( (n_stages - 1 + i) % n_stages, i) for i in range(n_stages)]
        ) if n_stages > 1 else outs
        # After the permute above, stage 0 holds the result; share it around.
        outs = jax.lax.all_gather(outs, axis)[0] if n_stages > 1 else outs
        return outs

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = _shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(spec_params, P()),
        out_specs=P(),
        **{_CHECK_KW: False},
    )
    return fn(stage_params, x_micro)


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
