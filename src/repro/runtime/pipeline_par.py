"""GPipe-style pipeline parallelism for the session serve path.

Two consumers:

  - ``pipeline_apply``: generic pipelined layer stack (used by the schedule
    tests and as the reference for the math below).
  - ``pipeline_prefill``: the session serve-prefill body — each stage holds a
    contiguous block of backbone layers *and* the adapter-pool rows for those
    layers, computes its blocks' skip-LoRA terms from locally-available block
    inputs (the paper's skip connections read block inputs only, so the
    adapter reduction composes across stages), and forwards ``(h, skip)`` to
    the next stage over ``lax.ppermute``. ``SessionRuntime(pipeline_stages=N)``
    wires this in as the alternative partitioning of the 2-D session mesh:
    the same ``model``-axis device group that otherwise TP-shards the
    backbone is repurposed as N pipeline stages.

Implementation: ``shard_map`` over the pipeline axis; each device holds one
stage of layers (params stacked on a leading stage axis, sharded over the
axis). The classic GPipe schedule runs ``n_micro + n_stages - 1`` ticks; at
each tick a stage processes one microbatch and hands its activation to the
next stage via ``lax.ppermute``. Bubble fraction = (P-1)/(M+P-1) — the
request scheduler sizes microbatches from its ``_LiveBatch`` admissions so
continuous batching keeps the realized bubble near this prediction.
``pipeline_apply`` is fully differentiable (ppermute transposes to the
reverse permutation), so ``jax.grad`` through it yields pipelined backward
for free.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime.sharding import suspend_scope

# jax promoted shard_map out of experimental (and renamed check_rep ->
# check_vma) in newer releases; support both.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"

Params = Any


def split_stages(
    layer_params: list[Params], n_stages: int
) -> tuple[Params, jax.Array]:
    """Group per-layer params into ``n_stages`` stacked stage pytrees.

    ``layer_params`` is a list of identically-structured per-layer pytrees,
    length L. Returns ``(stages, valid)``: ``stages`` has leading dims
    ``(n_stages, ceil(L / n_stages), ...)`` ready to shard over the pipeline
    axis; when ``L % n_stages != 0`` the last stage is padded with copies of
    the final layer and ``valid`` (bool, ``(n_stages, ceil(L/n_stages))``)
    marks the pads False so pipeline runners pass activations through them
    unchanged.
    """
    l = len(layer_params)
    if l == 0 or n_stages <= 0:
        raise ValueError(f"need >=1 layer and >=1 stage, got {l}/{n_stages}")
    if n_stages > l:
        raise ValueError(f"{n_stages} stages for {l} layers leaves empty stages")
    per = -(-l // n_stages)
    padded = list(layer_params) + [layer_params[-1]] * (n_stages * per - l)
    if len({jax.tree.structure(p) for p in padded}) != 1:
        raise ValueError(
            "split_stages needs identically-structured layers "
            "(uniform block stacks only)"
        )
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *padded)
    stages = jax.tree.map(
        lambda x: x.reshape((n_stages, per) + x.shape[1:]), stacked
    )
    valid = jnp.asarray(np.arange(n_stages * per).reshape(n_stages, per) < l)
    return stages, valid


def pipeline_apply(
    stage_params: Params,
    x_micro: jax.Array,
    layer_fn: Callable[[Params, jax.Array], jax.Array],
    *,
    mesh: Mesh,
    axis: str = "pod",
    valid: jax.Array = None,
) -> jax.Array:
    """Run the pipelined stack over microbatches.

    stage_params: (n_stages, layers_per_stage, ...) pytree, sharded on the
        leading axis over ``axis``.
    x_micro: (n_micro, micro_batch, ...) activations (replicated).
    valid: optional (n_stages, layers_per_stage) bool from ``split_stages``;
        False layers pass activations through unchanged.
    Returns (n_micro, micro_batch, ...) outputs (replicated).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1
    lead = jax.tree.leaves(stage_params)[0].shape
    if lead[0] != n_stages:
        raise ValueError(
            f"stage_params leading dim {lead[0]} != mesh axis {axis}={n_stages}"
        )
    if valid is None:
        valid = jnp.ones((n_stages, lead[1]), bool)

    def stage_block(params_block, valid_block, x):
        # params_block: (1, layers_per_stage, ...) — this device's stage.
        def body(h, xs):
            layer_p, v = xs
            return jnp.where(v, layer_fn(layer_p, h), h), None

        h, _ = jax.lax.scan(
            body, x, (jax.tree.map(lambda a: a[0], params_block), valid_block[0])
        )
        return h

    def per_stage(params_block, valid_block, x_all):
        stage_id = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(x_all[0])          # incoming activation
        outs = jnp.zeros_like(x_all)            # collected at the last stage

        def tick(carry, t):
            buf, outs = carry
            # Stage 0 ingests microbatch t (if still in range).
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            x_in = jax.lax.dynamic_index_in_dim(x_all, mb_idx, keepdims=False)
            h_in = jnp.where(stage_id == 0, x_in, buf)
            h_out = stage_block(params_block, valid_block, h_in)
            # Pass to the next stage (ring; last stage's send wraps to 0 and
            # is ignored there).
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            nxt = jax.lax.ppermute(h_out, axis, perm)
            # Last stage: microbatch t' = t - (n_stages - 1) finished at tick t.
            done_idx = t - (n_stages - 1)
            ok = jnp.logical_and(done_idx >= 0, stage_id == n_stages - 1)
            safe_idx = jnp.clip(done_idx, 0, n_micro - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, safe_idx, keepdims=False)
            upd = jnp.where(ok, h_out, cur)
            outs = jax.lax.dynamic_update_index_in_dim(outs, upd, safe_idx, 0)
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # Broadcast the last stage's collected outputs to every stage.
        outs = jax.lax.ppermute(
            outs, axis, [( (n_stages - 1 + i) % n_stages, i) for i in range(n_stages)]
        ) if n_stages > 1 else outs
        # After the permute above, stage 0 holds the result; share it around.
        outs = jax.lax.all_gather(outs, axis)[0] if n_stages > 1 else outs
        return outs

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = _shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(spec_params, P(axis), P()),
        out_specs=P(),
        **{_CHECK_KW: False},
    )
    # The stage body is manual SPMD over ``axis``: any ambient ShardScope's
    # auto-constraints would name an axis shard_map has claimed as manual.
    with suspend_scope():
        return fn(stage_params, valid, x_micro)


def pipeline_prefill(
    stage_blocks: Params,
    stage_a: jax.Array,
    stage_b: jax.Array,
    valid: jax.Array,
    x_micro: jax.Array,
    lens: jax.Array,
    slots: jax.Array,
    block_fn: Callable[[Params, jax.Array], tuple[jax.Array, Params]],
    *,
    mesh: Mesh,
    axis: str = "model",
):
    """Pipelined serve prefill with per-stage skip-LoRA accumulation.

    stage_blocks: block params, leaves (n_stages, Lp, ...), sharded P(axis).
    stage_a / stage_b: adapter pools restacked per stage layer —
        (n_stages, Lp, n_slots, D, R) / (n_stages, Lp, n_slots, R, D),
        sharded P(axis) so each stage holds only its resident layers' rows.
    valid: (n_stages, Lp) bool from ``split_stages`` (pads contribute no
        block transform and no skip term).
    x_micro: (n_micro, mb, T, D) embedded prompt activations (replicated).
    lens: (n_micro, mb) int32 per-row prompt lengths (replicated).
    slots: (n_micro, mb) int32 per-row adapter slot (replicated).
    block_fn: (layer_params, h) -> (h_out, kv_cache) one block, prefill mode.

    The traveling carry is ``(h, skip)``: each stage reads its blocks'
    *inputs* at every row's last real position (``max(len,1)-1`` — the same
    padding semantics as ``lm.sched_prefill``), adds
    ``(h_l @ A[slot, l]) @ B[slot, l]`` for its resident layers, and the
    last stage emits the completed sum — the single-stitch reduction the
    skip-architecture admits because no term reads another layer's output.

    Returns ``(y, skip, caches)``: final hiddens (n_micro, mb, T, D) and
    skip sums (n_micro, mb, D), both replicated; kv caches with leaves
    (n_stages, Lp, n_micro, mb, ...) sharded P(axis) in stage-major flat
    layer order (pads at the tail).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1
    if jax.tree.leaves(stage_blocks)[0].shape[0] != n_stages:
        raise ValueError(
            f"stage_blocks leading dim != mesh axis {axis}={n_stages}"
        )

    def per_stage(blocks, a_pool, b_pool, vld, x_all, lens_all, slot_all):
        stage_id = jax.lax.axis_index(axis)
        blocks0 = jax.tree.map(lambda v: v[0], blocks)
        a0, b0, v0 = a_pool[0], b_pool[0], vld[0]
        buf_h = jnp.zeros_like(x_all[0])
        buf_skip = jnp.zeros(x_all.shape[1:2] + x_all.shape[3:], x_all.dtype)

        def tick(carry, t):
            buf_h, buf_skip = carry
            m_in = jnp.clip(t, 0, n_micro - 1)
            # Microbatch m reaches stage s at tick m + s.
            m_my = jnp.clip(t - stage_id, 0, n_micro - 1)
            h = jnp.where(
                stage_id == 0,
                jax.lax.dynamic_index_in_dim(x_all, m_in, keepdims=False),
                buf_h,
            )
            skip = jnp.where(stage_id == 0, jnp.zeros_like(buf_skip), buf_skip)
            row_len = jnp.take(lens_all, m_my, axis=0)
            row_slot = jnp.take(slot_all, m_my, axis=0)
            last = (jnp.maximum(row_len, 1) - 1).astype(jnp.int32)

            def layer(carry, xs):
                h, skip = carry
                p_l, a_l, b_l, v_l = xs
                # Skip term from the block INPUT at the last real position.
                hl = jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0]
                a_rows = jnp.take(a_l, row_slot, axis=0).astype(h.dtype)
                b_rows = jnp.take(b_l, row_slot, axis=0).astype(h.dtype)
                term = jnp.einsum("md,mdr->mr", hl, a_rows)
                term = jnp.einsum("mr,mrd->md", term, b_rows)
                h2, cache = block_fn(p_l, h)
                return (
                    jnp.where(v_l, h2, h),
                    jnp.where(v_l, skip + term, skip),
                ), cache

            (h, skip), caches_t = jax.lax.scan(
                layer, (h, skip), (blocks0, a0, b0, v0)
            )
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            nxt_h = jax.lax.ppermute(h, axis, perm)
            nxt_skip = jax.lax.ppermute(skip, axis, perm)
            return (nxt_h, nxt_skip), (h, skip, caches_t)

        _, (ys_h, ys_skip, ys_caches) = jax.lax.scan(
            tick, (buf_h, buf_skip), jnp.arange(ticks)
        )
        # This stage processed microbatch m at tick m + stage_id: gather the
        # per-tick cache stack back into microbatch order, (Lp, n_micro, ...).
        my_ticks = jnp.arange(n_micro) + stage_id
        caches = jax.tree.map(
            lambda c: jnp.swapaxes(jnp.take(c, my_ticks, axis=0), 0, 1)[None],
            ys_caches,
        )
        # The last stage finished microbatch m at tick m + n_stages - 1.
        done = jnp.arange(n_micro) + (n_stages - 1)
        y = jnp.take(ys_h, done, axis=0)
        sk = jnp.take(ys_skip, done, axis=0)
        if n_stages > 1:
            shift = [((n_stages - 1 + i) % n_stages, i) for i in range(n_stages)]
            y = jax.lax.all_gather(jax.lax.ppermute(y, axis, shift), axis)[0]
            sk = jax.lax.all_gather(jax.lax.ppermute(sk, axis, shift), axis)[0]
        return y, sk, caches

    spec_blocks = jax.tree.map(lambda _: P(axis), stage_blocks)
    fn = _shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(spec_blocks, P(axis), P(axis), P(axis), P(), P(), P()),
        out_specs=(P(), P(), P(axis)),   # specs broadcast over output pytrees
        **{_CHECK_KW: False},
    )
    # Manual SPMD region: suspend any ambient ShardScope so the blocks'
    # auto-constraints (which name this same axis) don't trace inside it.
    with suspend_scope():
        return fn(stage_blocks, stage_a, stage_b, valid, x_micro, lens, slots)


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
