"""Sharding rules: logical axes -> mesh axes, param/opt/cache spec trees.

Megatron-style tensor parallelism on the ``model`` axis (attention heads,
FFN hidden, experts, vocab), data parallelism on ``("pod", "data")``, and a
simplified ZeRO-1: optimizer moments additionally shard a free weight axis
over ``data``. Long-context decode (batch=1) switches the *sequence* logical
axis onto ``data`` (sequence parallelism over the KV/state caches).

Param specs are derived from tree paths + leaf ranks, so any pytree shaped
like the model zoo's params gets a complete spec tree; unknown leaves fall
back to replication (safe, never wrong, only suboptimal).
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import re
from typing import Any, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any


# ---------------------------------------------------------------------------
# Mesh construction: the one helper every mesh in the repo goes through
# ---------------------------------------------------------------------------


def make_mesh(
    shape: Sequence[int], axes: Sequence[str], *, devices=None
) -> Mesh:
    """Build a mesh of ``shape`` over ``axes``.

    ``devices=None`` takes the process's device list in order (the common
    case); an explicit list pins the grid to those devices — the elastic
    path, where a restart rebuilds the mesh from whatever survived. This is
    the single mesh constructor behind ``launch.mesh``, the fleet/session
    launchers, and ``fault.elastic_remesh``.
    """
    shape, axes = tuple(int(s) for s in shape), tuple(axes)
    if len(shape) != len(axes):
        raise ValueError(f"mesh shape {shape} != axes {axes}")
    if devices is None:
        return jax.make_mesh(shape, axes)
    n = int(np.prod(shape))
    devices = list(devices)
    if len(devices) < n:
        raise ValueError(f"mesh {shape} needs {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def session_mesh_layout(mesh: Mesh) -> tuple[int, int, list[list]]:
    """``(n_data, n_model, groups)`` of a session mesh.

    Sessions shard *tenants* over ``("pod", "data")`` and may additionally
    shard the frozen *backbone* over a ``model`` axis (DESIGN.md §14): each
    data shard then owns a model-axis device *group* that holds one
    tensor-parallel backbone replica. ``groups[s]`` is shard ``s``'s device
    list (length ``n_model``); on a data-only mesh every group is a single
    device — the PR 5 committed-replica layout, unchanged.
    """
    data_axes, model_size = [], 1
    for i, (ax, size) in enumerate(zip(mesh.axis_names, mesh.devices.shape)):
        if ax in ("data", "pod"):
            data_axes.append(i)
        elif ax == "model":
            model_size = size
        elif size > 1:
            raise ValueError(
                f"session meshes shard tenants on ('pod', 'data') and the "
                f"backbone on 'model' only; axis {ax!r} has size {size}"
            )
    order = data_axes + [i for i in range(mesh.devices.ndim) if i not in data_axes]
    grid = np.transpose(mesh.devices, order).reshape(-1, model_size)
    return grid.shape[0], model_size, [list(row) for row in grid]


def session_devices(mesh: Mesh) -> list:
    """The data-axis device list of a session mesh, in shard order.

    Mesh-native sessions parallelise the tenant axis over ``("pod",
    "data")``; with a >1 ``model`` axis each data shard is a device *group*
    (one TP backbone replica) and this returns the group anchors — the
    device per shard that host-side bookkeeping (cache tiers, pool stats)
    keys on. ``session_mesh_layout`` exposes the full groups.
    """
    _, _, groups = session_mesh_layout(mesh)
    return [g[0] for g in groups]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Logical axis name -> mesh axis (or tuple, or None=replicated)."""

    batch: Any = ("pod", "data")
    seq: Any = None                # "data" for long-context decode
    heads: Any = "model"
    vocab: Any = "model"
    ffn: Any = "model"
    expert: Any = "model"
    capacity: Any = None
    d_inner: Any = "model"
    # Batch axis of the (B, chunk, V) logits blocks. Distinct from `batch`:
    # under FSDP the batch axes are re-used for vocab sharding in the loss
    # (keeps d_table local-shard; no full-table all-reduce).
    logits_batch: Any = ("pod", "data")
    # Group dim of the (G, E, C, D) expert buffers. Under 'ep' the batch is
    # grid-sharded for dense layers but must release the 'model' axis to the
    # experts inside MoE blocks (a cheap h-reshard at the block boundary).
    expert_group: Any = ("pod", "data")
    # Layer axis of stacked (L, ...) activation tensors — the skip-cache and
    # the collected block inputs. "model" on session TP meshes: each model
    # shard holds (and skip-sums) its resident blocks' inputs locally and
    # one psum stitches the adapter logits (DESIGN.md §14).
    layers: Any = None

    def resolve(self, mesh_axes: tuple[str, ...], logical: Any) -> Any:
        """Drop mesh axes not present (e.g. 'pod' on the single-pod mesh)."""
        v = getattr(self, logical) if isinstance(logical, str) and hasattr(self, logical) else logical
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in mesh_axes else None
        vs = tuple(a for a in v if a in mesh_axes)
        return vs if vs else None


# ---------------------------------------------------------------------------
# Activation-sharding constraints (contextvar scope; no-op outside)
# ---------------------------------------------------------------------------

_ACTIVE: contextvars.ContextVar[Optional[tuple[Mesh, AxisRules]]] = (
    contextvars.ContextVar("repro_sharding_scope", default=None)
)


@contextlib.contextmanager
def sharding_scope(mesh: Mesh, rules: AxisRules):
    tok = _ACTIVE.set((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.reset(tok)


@contextlib.contextmanager
def suspend_scope():
    """Clear any active sharding scope for the dynamic extent — for manual
    SPMD regions (``shard_map``) traced under a scoped jit, where the scope's
    auto-constraints would name an axis the region claims as manual."""
    tok = _ACTIVE.set(None)
    try:
        yield
    finally:
        _ACTIVE.reset(tok)


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    """with_sharding_constraint against the active scope (no-op if none).

    Axes whose dimension is smaller than the mesh-axis size are left
    replicated (e.g. 8 KV heads on a 16-way model axis).
    """
    active = _ACTIVE.get()
    if active is None:
        return x
    mesh, rules = active
    parts = []
    for dim, a in zip(x.shape, logical_axes):
        r = rules.resolve(mesh.axis_names, a)
        if r is not None and dim % _axis_size(mesh, r) != 0:
            r = None
        parts.append(r)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*parts)))


# Session TP rules: within one data shard's model group the *rows* are not
# sharded (the data axis lives across groups, not inside the jit), so every
# batch-like logical axis replicates and the tensor axes follow Megatron.
SESSION_TP_RULES = AxisRules(
    batch=None, seq=None, heads="model", vocab="model", ffn="model",
    expert="model", capacity=None, d_inner="model", logits_batch=None,
    expert_group=None, layers="model",
)


@dataclasses.dataclass(frozen=True)
class ShardScope:
    """Hashable (mesh, rules) pair a compiled-fn factory can close over.

    The ``sharding_scope`` contextvar is read at TRACE time, so any cached
    jit whose body should emit ``constrain`` ops must key its cache entry on
    the scope — this dataclass is that key (``Mesh`` and ``AxisRules`` are
    both hashable) and ``ctx()`` is the trace-time activation.
    """

    mesh: Mesh
    rules: AxisRules = SESSION_TP_RULES

    def ctx(self):
        return sharding_scope(self.mesh, self.rules)


def scope_ctx(scope: Optional[ShardScope]):
    """``scope.ctx()`` or a no-op context — for fns compiled both ways."""
    return scope.ctx() if scope is not None else contextlib.nullcontext()


def shard_submesh(mesh: Mesh, shard: int) -> Mesh:
    """Shard ``shard``'s model-axis group as its own 1-D ``("model",)``
    mesh — the device set every dispatch of that data shard runs on."""
    _, _, groups = session_mesh_layout(mesh)
    return Mesh(np.asarray(groups[shard]), ("model",))


def shard_backbone(params: Params, submesh: Mesh) -> Params:
    """One TP-sharded backbone replica committed to a shard's model group
    (the >1-model-axis counterpart of ``replicate_backbone``): params whose
    rule resolves shard over ``model``, the rest replicate over the group.
    Committed inputs pin every downstream jit to the group's device set,
    exactly like the single-device committed replicas do today."""
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
    )
    return jax.device_put(params, named(submesh, param_specs(shapes, submesh)))


# ---------------------------------------------------------------------------
# Param spec derivation
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axis_size(mesh: Mesh, axis: Any) -> int:
    axes = (axis,) if isinstance(axis, str) else axis
    size = 1
    for a in axes:
        size *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    return size


def _divisible(dim: int, mesh: Mesh, axis: Any) -> bool:
    """jit argument shardings must divide evenly (unlike intermediate
    constraints, which GSPMD pads); non-divisible dims fall back to the
    next rule or replication."""
    if axis is None:
        return True
    return dim % _axis_size(mesh, axis) == 0


def _param_spec_for(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Spec for one leaf. ``shape`` excludes any leading periods-stack axis."""
    nd = len(shape)

    def m(ax_idx: int, axis="model") -> Any:
        return axis if _divisible(shape[ax_idx], mesh, axis) else None

    # Embedding / untied head: vocab-sharded (keeps logits vocab-sharded).
    if re.search(r"(embed|head)/table$", path):
        return P(m(0), None)
    # Attention: shard heads; if the head count doesn't divide the model
    # axis (musicgen 24H, paligemma MQA), fall back to sharding d_model
    # (contraction dim -> partial sums + all-reduce, Megatron row-parallel).
    if re.search(r"attn/w[qkv]$", path):
        if m(1):
            return P(None, "model", None)
        return P(m(0), None, None)
    if re.search(r"attn/wo$", path):
        if m(0):
            return P("model", None, None)
        return P(None, None, m(2))
    # MoE experts (rank 3: E, D, F / E, F, D): shard experts (EP); if the
    # expert count doesn't divide (qwen 60e on 16), shard the expert FFN
    # hidden dim instead (TP inside each expert).
    if "/moe/" in path:
        if path.endswith("router"):
            return P(None, None)
        if nd == 3:
            if m(0):
                return P("model", None, None)
            if path.endswith("w_down"):
                return P(None, m(1), None)
            return P(None, None, m(2))
        # shared expert (rank-2 FFN weights)
        if re.search(r"w_(gate|up)$", path):
            return P(None, m(1))
        if path.endswith("w_down"):
            return P(m(0), None)
        return P(*([None] * nd))
    # Dense FFN.
    if re.search(r"ffn/w_(gate|up)$", path):
        return P(None, m(1))
    if re.search(r"ffn/w_down$", path):
        return P(m(0), None)
    # Mamba.
    if "/mamba/" in path:
        if path.endswith(("in_proj",)):
            return P(None, m(1))
        if path.endswith(("x_proj", "out_proj", "a_log")):
            return P(m(0), None)
        if path.endswith("dt_proj"):
            return P(None, m(1))
        if path.endswith("conv_w"):
            return P(None, m(1))
        if path.endswith(("dt_bias", "d_skip")):
            return P(m(0))
        return P(*([None] * nd))
    # mLSTM.
    if "/mlstm/" in path:
        if path.endswith(("up_proj", "conv_w")):
            return P(None, m(1))
        if path.endswith(("wq", "wk", "wv")):
            return P(m(0), None, None)
        if path.endswith(("w_i", "w_f")):
            return P(m(0), None)
        if path.endswith("down_proj"):
            return P(m(0), None)
        return P(*([None] * nd))
    # sLSTM: small dense recurrence -> replicate.
    # Norms, biases, everything else: replicate.
    return P(*([None] * nd))


def param_specs(params_shape: Params, mesh: Mesh) -> Params:
    """Spec tree matching a params (or ShapeDtypeStruct) tree."""

    def leaf_spec(path, leaf):
        pstr = _path_str(path)
        shape = tuple(leaf.shape)
        if "periods" in pstr and shape:
            # Leading n_periods stack axis is never sharded.
            inner = _param_spec_for(pstr, shape[1:], mesh)
            return P(None, *inner)
        return _param_spec_for(pstr, shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def session_param_specs(params_shape: Params, mesh: Mesh) -> Params:
    """Backbone placement for a session mesh, derived from the same rule
    table as the pretraining path: a mesh carrying a >1 ``model`` axis gets
    the Megatron ``param_specs``; on a data-only session mesh every rule
    resolves to replication — the *adapters, moments and cache partitions*
    carry the data axis (by tenant), never the frozen backbone."""
    if "model" in mesh.axis_names and _axis_size(mesh, "model") > 1:
        return param_specs(params_shape, mesh)
    return jax.tree.map(lambda x: P(*([None] * len(x.shape))), params_shape)


def specs_all_replicated(specs: Params) -> bool:
    return all(
        all(part is None for part in spec)
        for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    )


def replicate_backbone(params: Params, devices) -> list[Params]:
    """Per-device committed replicas of the frozen backbone — the physical
    realisation of all-replicated ``session_param_specs`` that keeps every
    per-shard dispatch device-local (a committed-input jit runs entirely on
    its shard; a GSPMD-replicated array would force one SPMD program)."""
    return [jax.device_put(params, d) for d in devices]


def zero1_specs(params_shape: Params, specs: Params, mesh: Mesh) -> Params:
    """Optimizer-moment specs: like param specs but additionally shard the
    first still-replicated axis over 'data' when divisible (ZeRO-1)."""

    def upgrade(leaf, spec):
        shape = tuple(leaf.shape)
        parts = list(spec) + [None] * (len(shape) - len(spec))

        def uses_data(p):
            return p == "data" or (isinstance(p, tuple) and "data" in p)

        if any(uses_data(p) for p in parts):
            return P(*parts)  # already data-sharded (idempotent)
        data_size = _axis_size(mesh, "data")
        for i, (dim, pspec) in enumerate(zip(shape, parts)):
            if pspec is None and dim % data_size == 0 and dim >= 128:
                parts[i] = "data"
                break
        return P(*parts)

    return jax.tree.map(upgrade, params_shape, specs, is_leaf=lambda x: isinstance(x, P))


def spec_shards(shape: tuple[int, ...], spec: P, mesh: Mesh) -> int:
    total = 1
    for part in spec:
        if part is not None:
            total *= _axis_size(mesh, part)
    return total


def per_device_bytes(params_shape: Params, specs: Params, mesh: Mesh) -> float:
    flat = jax.tree.leaves(params_shape)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    total = 0.0
    for leaf, spec in zip(flat, flat_s):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n * jnp.dtype(leaf.dtype).itemsize / spec_shards(leaf.shape, spec, mesh)
    return total


def fsdp_param_specs(params_shape: Params, mesh: Mesh) -> Params:
    """Fully-sharded weights: every large leaf shards its first axis that
    divides the full (data x model) device grid; falls back to 'data'-only,
    then replication. Batch shards over the same grid (per-device batch ~1),
    so layers see *local* activations and weights all-gather per use —
    traffic ~ 3 x param bytes per step instead of ~ L x activation bytes."""
    grid = tuple(a for a in ("data", "model") if a in mesh.axis_names)

    def leaf_spec(path, leaf):
        pstr = _path_str(path)
        shape = tuple(leaf.shape)
        start = 1 if ("periods" in pstr and shape) else 0
        if re.search(r"(embed|head)/table$", pstr):
            # (V, D): prefer vocab sharded over the whole grid (matches the
            # grid-vocab loss sharding when V divides); else V@model, D@data.
            if shape[0] % _axis_size(mesh, grid) == 0:
                return P(grid, None)
            v_ok = shape[0] % _axis_size(mesh, "model") == 0
            d_ok = shape[1] % _axis_size(mesh, "data") == 0
            return P("model" if v_ok else None, "data" if d_ok else None)
        n = 1
        for d in shape:
            n *= d
        parts = [None] * len(shape)
        if n >= (1 << 16):
            for i in range(start, len(shape)):
                if shape[i] % _axis_size(mesh, grid) == 0:
                    parts[i] = grid
                    break
                if shape[i] % _axis_size(mesh, "data") == 0:
                    parts[i] = "data"
                    break
        return P(*parts)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


FSDP_RULES_KW = dict(
    batch=("data", "model"),  # per-device batch ~1; pod stays pure DP
    heads=None,
    vocab="model",            # loss logits: batch@data x vocab@model —
    ffn=None,                 # d_table never materialises unsharded
    expert=None,
    capacity=None,            # group dim already carries the batch shard
    d_inner=None,
    logits_batch=("data",),
    expert_group=("data", "model"),
)

# 'ep': FSDP for the dense path (grid-sharded batch, no per-layer h
# all-reduce) + expert parallelism for MoE blocks (experts on 'model',
# expert buffers grouped on 'data') — the batch reshards cheaply at MoE
# boundaries instead of paying 2 all-reduces per layer.
EP_RULES_KW = dict(
    batch=("data", "model"),
    heads=None,
    vocab="model",
    ffn=None,
    expert="model",
    capacity=None,
    d_inner=None,
    logits_batch=("data",),
    expert_group=("pod", "data"),
)


def ep_param_specs(params_shape: Params, mesh: Mesh) -> Params:
    """'ep' strategy weights: MoE expert tensors (rank 3 under /moe/) shard
    E over 'model' and their widest remaining axis over 'data'; everything
    else is FSDP-sharded over the grid."""
    base = fsdp_param_specs(params_shape, mesh)

    def leaf_spec(path, leaf, spec):
        pstr = _path_str(path)
        shape = tuple(leaf.shape)
        start = 1 if ("periods" in pstr and shape) else 0
        if "/moe/" in pstr and len(shape) - start == 3:
            e_ok = shape[start] % _axis_size(mesh, "model") == 0
            parts = [None] * len(shape)
            if e_ok:
                parts[start] = "model"
            for i in range(start + 1, len(shape)):
                if shape[i] % _axis_size(mesh, "data") == 0:
                    parts[i] = "data"
                    break
            return P(*parts)
        return spec

    return jax.tree_util.tree_map_with_path(
        leaf_spec, params_shape, base
    )


def maybe_fsdp_specs(
    params_shape: Params, specs: Params, mesh: Mesh, *, threshold_bytes: float = 8e9
) -> tuple[Params, bool]:
    """If the TP-sharded weights still exceed ``threshold_bytes`` per device
    (jamba-398B on a 16-way model axis), additionally shard every large leaf
    over 'data' (FSDP: weights all-gather per layer). Returns (specs, applied).
    """
    if per_device_bytes(params_shape, specs, mesh) <= threshold_bytes:
        return specs, False
    return zero1_specs(params_shape, specs, mesh), True


def named(mesh: Mesh, spec_tree: Params) -> Params:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_spec(mesh: Mesh, rules: AxisRules, *trailing) -> P:
    return P(rules.resolve(mesh.axis_names, "batch"), *trailing)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
