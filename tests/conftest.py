import os

# Tests run on the single real CPU device; the 512-way placeholder mesh is
# *only* for launch/dryrun.py (which sets XLA_FLAGS itself before jax import).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
