"""Adapter pool registry: slots, LRU eviction, int8 layout, grouped sum."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.adapter_pool import ZERO_SLOT, AdapterPool, grouped_skip_sum


@pytest.fixture(scope="module")
def cfg():
    return reduce_config(get_config("stablelm-1.6b"))


def make_adapters(cfg, rank, seed):
    sl = SL.SkipLoRAConfig(rank=rank)
    ad = SL.init_adapters(jax.random.key(seed), cfg, sl)
    ad["B"] = jax.random.normal(jax.random.key(seed + 100), ad["B"].shape) * 0.05
    return ad


class TestRegistry:
    def test_register_lookup_roundtrip(self, cfg):
        pool = AdapterPool(4, cfg, rank=4)
        ad = make_adapters(cfg, 4, seed=0)
        slot = pool.register("u0", ad)
        assert slot != ZERO_SLOT
        assert pool.has("u0") and len(pool) == 1
        idx = pool.lookup([None, "u0"])
        assert idx.tolist() == [ZERO_SLOT, slot]
        np.testing.assert_allclose(
            np.asarray(pool.pools()["A"][slot]), np.asarray(ad["A"]), atol=1e-6
        )

    def test_zero_slot_is_pinned_zeros(self, cfg):
        pool = AdapterPool(3, cfg, rank=4)
        for t in range(5):  # overflow capacity repeatedly
            pool.register(f"u{t}", make_adapters(cfg, 4, seed=t))
        p = pool.pools()
        assert float(jnp.max(jnp.abs(p["A"][ZERO_SLOT]))) == 0.0
        assert float(jnp.max(jnp.abs(p["B"][ZERO_SLOT]))) == 0.0

    def test_lru_eviction_order(self, cfg):
        pool = AdapterPool(3, cfg, rank=4)  # 2 usable slots
        pool.register("a", make_adapters(cfg, 4, seed=1))
        pool.register("b", make_adapters(cfg, 4, seed=2))
        pool.lookup(["a"])  # touch a -> b is now LRU
        pool.register("c", make_adapters(cfg, 4, seed=3))
        assert pool.has("a") and pool.has("c") and not pool.has("b")
        assert pool.stats.evictions == 1

    def test_reregister_overwrites_in_place(self, cfg):
        pool = AdapterPool(3, cfg, rank=4)
        s1 = pool.register("u", make_adapters(cfg, 4, seed=4))
        ad2 = make_adapters(cfg, 4, seed=5)
        s2 = pool.register("u", ad2)
        assert s1 == s2 and len(pool) == 1
        np.testing.assert_allclose(
            np.asarray(pool.pools()["A"][s1]), np.asarray(ad2["A"]), atol=1e-6
        )

    def test_unknown_tenant_raises(self, cfg):
        pool = AdapterPool(2, cfg, rank=4)
        with pytest.raises(KeyError):
            pool.lookup(["ghost"])
        assert pool.stats.misses == 1

    def test_shape_mismatch_raises(self, cfg):
        pool = AdapterPool(2, cfg, rank=4)
        bad = make_adapters(cfg, 8, seed=6)  # wrong rank
        with pytest.raises(ValueError):
            pool.register("u", bad)


class TestInt8Pool:
    def test_raw_layout_and_footprint(self, cfg):
        fp = AdapterPool(4, cfg, rank=8)
        q8 = AdapterPool(4, cfg, rank=8, compress="int8")
        assert set(q8.pools()) == {"qa", "sa", "qb", "sb"}
        assert q8.pools()["qa"].dtype == jnp.int8
        # int8 payload + fp32 scales approach 4x smaller than the fp32
        # pool; at the reduced config's tiny D the scale vectors take a
        # proportionally larger bite, so just over 3x here.
        assert fp.nbytes() / q8.nbytes() > 3.0

    def test_int8_roundtrip_close_to_float(self, cfg):
        pool = AdapterPool(3, cfg, rank=4, compress="int8")
        ad = make_adapters(cfg, 4, seed=7)
        slot = pool.register("u", ad)
        p = pool.pools()
        deq = p["qa"][slot].astype(jnp.float32) * p["sa"][slot][..., None]
        err = jnp.max(jnp.abs(deq - ad["A"])) / jnp.max(jnp.abs(ad["A"]))
        assert float(err) < 0.02  # rowwise int8: <2% relative error


class TestGroupedSkipSum:
    def test_kernel_and_ref_paths_agree(self, cfg):
        l, d = cfg.n_layers, cfg.d_model
        pool = AdapterPool(4, cfg, rank=4)
        for t in range(3):
            pool.register(f"u{t}", make_adapters(cfg, 4, seed=10 + t))
        idx = pool.lookup([None, "u0", "u2", "u0"])
        acts = jax.random.normal(jax.random.key(20), (l, 4, 9, d), jnp.float32)
        out_k = grouped_skip_sum(acts, pool.pools(), idx, use_kernel=True)
        out_r = grouped_skip_sum(acts, pool.pools(), idx, use_kernel=False)
        np.testing.assert_allclose(
            np.asarray(out_k), np.asarray(out_r), atol=1e-4, rtol=1e-4
        )
        # Zero-slot row contributes exactly nothing.
        assert float(jnp.max(jnp.abs(out_k[0]))) < 1e-6

    def test_ref_path_pool_is_serve_time_constant(self, cfg):
        """The jnp oracle path must honour the same non-differentiable-pool
        invariant as the kernel path (float pool and int8 scales alike)."""
        l, d = cfg.n_layers, cfg.d_model
        acts = jax.random.normal(jax.random.key(40), (l, 2, 5, d), jnp.float32)
        idx = jnp.array([1, 0], jnp.int32)
        for compress in (None, "int8"):
            pool = AdapterPool(3, cfg, rank=4, compress=compress)
            pool.register("u", make_adapters(cfg, 4, seed=41))
            pools = pool.pools()
            diffable = {
                k: v for k, v in pools.items()
                if jnp.issubdtype(v.dtype, jnp.floating)
            }
            g = jax.grad(
                lambda p: jnp.sum(
                    grouped_skip_sum(acts, {**pools, **p}, idx, use_kernel=False) ** 2
                )
            )(diffable)
            for k, gv in g.items():
                assert float(jnp.max(jnp.abs(gv))) == 0.0, (compress, k)

    def test_int8_pool_feeds_kernel_raw(self, cfg):
        l, d = cfg.n_layers, cfg.d_model
        pool = AdapterPool(3, cfg, rank=4, compress="int8")
        pool.register("u", make_adapters(cfg, 4, seed=30))
        idx = pool.lookup(["u", None])
        acts = jax.random.normal(jax.random.key(31), (l, 2, 5, d), jnp.float32)
        out_k = grouped_skip_sum(acts, pool.pools(), idx, use_kernel=True)
        out_r = grouped_skip_sum(acts, pool.pools(), idx, use_kernel=False)
        np.testing.assert_allclose(
            np.asarray(out_k), np.asarray(out_r), atol=1e-4, rtol=1e-4
        )


class TestLRUSequences:
    """Eviction bookkeeping under interleaved register / evict / lookup
    sequences: freed slots are reused, the LRU order reflects *serving*
    traffic (lookup touches), and the data plane stays consistent with the
    control plane at every step."""

    def test_interleaved_register_evict_reuses_slots(self, cfg):
        pool = AdapterPool(4, cfg, rank=4)  # 3 usable slots
        s_a = pool.register("a", make_adapters(cfg, 4, seed=50))
        s_b = pool.register("b", make_adapters(cfg, 4, seed=51))
        pool.evict("a")
        # The freed slot is reused before any LRU eviction triggers.
        s_c = pool.register("c", make_adapters(cfg, 4, seed=52))
        assert s_c == s_a and pool.stats.evictions == 1
        s_d = pool.register("d", make_adapters(cfg, 4, seed=53))
        assert s_d not in (s_b, s_c)
        # Pool now full (b, c, d). Touch b via lookup -> c is LRU.
        pool.lookup(["b"])
        s_e = pool.register("e", make_adapters(cfg, 4, seed=54))
        assert s_e == s_c and not pool.has("c")
        assert pool.has("b") and pool.has("d") and pool.has("e")
        assert len(pool) == 3

    def test_evict_then_lookup_raises_and_counts_miss(self, cfg):
        pool = AdapterPool(3, cfg, rank=4)
        pool.register("u", make_adapters(cfg, 4, seed=55))
        pool.evict("u")
        with pytest.raises(KeyError):
            pool.lookup(["u"])
        assert pool.stats.misses == 1

    def test_data_plane_tracks_control_plane_through_churn(self, cfg):
        """After an eviction-heavy sequence, every resident tenant's slot
        still holds *its* adapters (no slot aliasing from the free list)."""
        pool = AdapterPool(3, cfg, rank=4)  # 2 usable slots
        stacks = {}
        for t in range(6):  # 3 waves of churn through 2 slots
            name = f"u{t}"
            ad = make_adapters(cfg, 4, seed=60 + t)
            stacks[name] = ad
            pool.register(name, ad)
            if t % 2 == 1:
                pool.lookup([f"u{t - 1}"])  # touch the older one
        for name in pool.tenants():
            slot = pool.lookup([name])[0]
            np.testing.assert_allclose(
                np.asarray(pool.pools()["A"][int(slot)]),
                np.asarray(stacks[name]["A"]),
                atol=1e-6, err_msg=name,
            )

    def test_pinned_slot_survives_eviction_pressure(self, cfg):
        """Satellite bar: a pinned tenant (in-flight training state) is
        never the LRU victim — its slot and its *data* survive arbitrary
        registration churn that evicts everything else around it."""
        pool = AdapterPool(4, cfg, rank=4)  # 3 usable slots
        ad_t = make_adapters(cfg, 4, seed=80)
        slot_t = pool.register("training", ad_t)
        pool.pin("training")
        for t in range(8):  # churn far past capacity
            pool.register(f"burst{t}", make_adapters(cfg, 4, seed=81 + t))
        assert pool.has("training")
        assert pool.lookup(["training"])[0] == slot_t
        np.testing.assert_allclose(
            np.asarray(pool.pools()["A"][slot_t]), np.asarray(ad_t["A"]),
            atol=1e-6,
        )
        assert pool.stats.evictions >= 6
        # Unpinned, it becomes evictable again: three fresh registrations
        # (pool holds 3) cycle every current resident out, training included.
        pool.unpin("training")
        for t in range(3):
            pool.register(f"more{t}", make_adapters(cfg, 4, seed=99 + t))
        assert not pool.has("training")

    def test_all_pinned_pool_rejects_new_registration(self, cfg):
        pool = AdapterPool(3, cfg, rank=4)  # 2 usable slots
        pool.register("a", make_adapters(cfg, 4, seed=90))
        pool.register("b", make_adapters(cfg, 4, seed=91))
        pool.pin("a")
        pool.pin("b")
        with pytest.raises(RuntimeError, match="pinned"):
            pool.register("c", make_adapters(cfg, 4, seed=92))
        # Re-registration of a pinned tenant is fine (keeps its slot).
        s = pool.register("a", make_adapters(cfg, 4, seed=93))
        assert s == pool.lookup(["a"])[0]

    def test_explicit_evict_of_pinned_raises(self, cfg):
        pool = AdapterPool(3, cfg, rank=4)
        pool.register("a", make_adapters(cfg, 4, seed=94))
        pool.pin("a")
        with pytest.raises(ValueError, match="pinned"):
            pool.evict("a")
        pool.unpin("a")
        pool.evict("a")
        assert not pool.has("a")

    def test_pin_unknown_tenant_raises(self, cfg):
        pool = AdapterPool(3, cfg, rank=4)
        with pytest.raises(KeyError):
            pool.pin("ghost")

    def test_version_tracks_slot_map_not_touches(self, cfg):
        pool = AdapterPool(3, cfg, rank=4)
        v0 = pool.version
        pool.register("a", make_adapters(cfg, 4, seed=95))
        assert pool.version == v0 + 1
        pool.lookup(["a"])          # touch: slots unchanged
        pool.touch(["a", None])
        pool.register("a", make_adapters(cfg, 4, seed=96))  # re-register
        assert pool.version == v0 + 1
        pool.evict("a")
        assert pool.version == v0 + 2

    def test_zero_slot_survives_churn(self, cfg):
        pool = AdapterPool(3, cfg, rank=4)
        for t in range(7):
            pool.register(f"u{t}", make_adapters(cfg, 4, seed=70 + t))
            if t % 3 == 0:
                pool.evict(f"u{t}")
        p = pool.pools()
        assert float(jnp.max(jnp.abs(p["A"][ZERO_SLOT]))) == 0.0
        assert float(jnp.max(jnp.abs(p["B"][ZERO_SLOT]))) == 0.0
