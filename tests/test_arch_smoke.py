"""Per-architecture smoke tests on reduced configs (CPU).

For every assigned architecture: instantiate a reduced config of the same
family (same pattern / GQA ratio / MoE top-k / frontend), run one forward +
one train step asserting shapes and no NaNs, and check serving consistency:
a decode step against a prefilled cache must reproduce the teacher-forced
logits at the same position.
"""

import jax
import jax.numpy as jnp
import pytest

# Full 10-arch forward/train/decode sweep (~4 min) -> nightly/full tier.
pytestmark = pytest.mark.slow

from repro.configs import get_config, list_archs, reduce_config
from repro.models.lm import (
    init_lm,
    init_serve_caches,
    lm_forward,
    readout,
    serve_decode,
    serve_prefill,
    train_loss_fn,
)

ARCHS = list_archs()


def _setup(arch, seed=0):
    cfg = reduce_config(get_config(arch))
    key = jax.random.key(seed)
    params = init_lm(key, cfg)
    return cfg, params


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, arch):
        cfg, params = _setup(arch)
        b, s = 2, 16
        key = jax.random.key(1)
        tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        prefix = (
            jax.random.normal(key, (b, cfg.frontend_seq, cfg.d_model))
            if cfg.frontend
            else None
        )
        out = lm_forward(params, cfg, tokens, mode="train", prefix_embeds=prefix)
        total = s + (cfg.frontend_seq if cfg.frontend else 0)
        assert out["h"].shape == (b, total, cfg.d_model)
        assert not bool(jnp.any(jnp.isnan(out["h"])))
        logits = readout(params, cfg, out["h"][:, -1:])
        assert logits.shape == (b, 1, cfg.vocab_size)
        assert not bool(jnp.any(jnp.isnan(logits)))

    def test_train_step_reduces_loss(self, arch):
        cfg, params = _setup(arch)
        b, s = 2, 16
        key = jax.random.key(2)
        tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        prefix = (
            jax.random.normal(key, (b, cfg.frontend_seq, cfg.d_model))
            if cfg.frontend
            else None
        )
        batch = {"tokens": tokens, "labels": tokens, "prefix_embeds": prefix}

        loss_fn = lambda p: train_loss_fn(p, cfg, batch)
        l0, grads = jax.value_and_grad(loss_fn)(params)
        assert jnp.isfinite(l0)
        # Plain SGD steps on all params must reduce loss on this batch.
        params2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype), params, grads)
        l1 = loss_fn(params2)
        assert jnp.isfinite(l1)
        assert float(l1) < float(l0), (arch, float(l0), float(l1))

    def test_decode_matches_teacher_forcing(self, arch):
        cfg, params = _setup(arch)
        b, s = 2, 12
        key = jax.random.key(3)
        tokens = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)

        # Teacher-forced logits at the last position.
        out = lm_forward(params, cfg, tokens, mode="train")
        ref = readout(params, cfg, out["h"][:, -1:])

        # Prefill on the first s tokens, then decode token s.
        caches = init_serve_caches(cfg, b, s + 8)
        _, caches = serve_prefill(params, cfg, tokens[:, :s], caches)
        logits, _ = serve_decode(
            params, cfg, tokens[:, s : s + 1], jnp.asarray(s, jnp.int32), caches
        )
        assert jnp.allclose(logits, ref, atol=3e-3, rtol=3e-3), (
            arch,
            float(jnp.max(jnp.abs(logits - ref))),
        )

    def test_param_count_positive(self, arch):
        cfg = get_config(arch)
        assert cfg.param_count() > 0
        assert cfg.active_param_count() <= cfg.param_count()


class TestConfigIntegrity:
    def test_ten_archs_assigned(self):
        assert len(ARCHS) == 10

    def test_full_param_counts_match_names(self):
        # Name-embedded sizes within tolerance (counts are analytic).
        expect = {
            "gemma3-27b": (27e9, 0.1),
            "gemma2-9b": (9.2e9, 0.1),
            "phi3.5-moe-42b-a6.6b": (42e9, 0.05),
            "qwen2-moe-a2.7b": (14.3e9, 0.1),  # total (A2.7B = active)
            "stablelm-1.6b": (1.6e9, 0.1),
            "xlstm-350m": (0.35e9, 0.35),
        }
        for arch, (target, tol) in expect.items():
            n = get_config(arch).param_count()
            assert abs(n - target) / target < tol, (arch, n)

    def test_moe_actives(self):
        phi = get_config("phi3.5-moe-42b-a6.6b")
        assert abs(phi.active_param_count() - 6.6e9) / 6.6e9 < 0.05
        qwen = get_config("qwen2-moe-a2.7b")
        assert abs(qwen.active_param_count() - 2.7e9) / 2.7e9 < 0.1

    def test_gqa_ratios(self):
        for arch in ARCHS:
            cfg = get_config(arch)
            assert cfg.n_heads % cfg.n_kv_heads == 0
            r = reduce_config(cfg)
            assert r.n_heads % r.n_kv_heads == 0
