"""``attn_decode``'s per-row position branch and its paged variant.

The continuous-batching scheduler drives decode with a (b,) position
vector (each row at its own depth); prefix reuse additionally swaps the
contiguous cache row for pool blocks behind a block table
(``attn_decode_paged``). All of these are layout moves, not math
changes, so the bar is bitwise equality with the classic scalar-position
decode given equal KV bytes — including at the edges: position 0 (the
whole rest of the cache is masked garbage), position T-1 (the last
slot), and rows at mixed depths versus each row decoded solo.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.attention import (
    AttnSpec,
    attn_decode,
    attn_decode_paged,
    init_attn,
)
from repro.models.config import ModelConfig


def mini_cfg(**kw):
    base = dict(
        name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=32, vocab_size=64, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


T = 8


@pytest.fixture(scope="module")
def setup():
    cfg = mini_cfg()
    spec = AttnSpec.from_config(cfg, local=False)
    params = init_attn(jax.random.key(0), cfg)
    b, hd = 3, cfg.resolved_head_dim
    x = jax.random.normal(jax.random.key(1), (b, 1, cfg.d_model))
    cache = {
        "k": jax.random.normal(jax.random.key(2), (b, T, cfg.n_kv_heads, hd)),
        "v": jax.random.normal(jax.random.key(3), (b, T, cfg.n_kv_heads, hd)),
    }
    return params, spec, x, cache


def assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestVectorPositionBranch:
    @pytest.mark.parametrize("p", [0, T // 2, T - 1])
    def test_all_rows_equal_matches_scalar_branch_bitwise(self, setup, p):
        """A constant position vector must reproduce the scalar branch
        exactly — output AND updated cache — at both cache edges."""
        params, spec, x, cache = setup
        b = x.shape[0]
        y_s, c_s = attn_decode(params, x, jnp.asarray(p, jnp.int32), spec,
                               cache)
        y_v, c_v = attn_decode(params, x, jnp.full((b,), p, jnp.int32), spec,
                               cache)
        np.testing.assert_array_equal(np.asarray(y_v), np.asarray(y_s))
        assert_trees_equal(c_v, c_s)

    def test_position_zero_ignores_cache_garbage(self, setup):
        """At pos 0 every other cache slot is garbage the mask must hide:
        huge-magnitude junk beyond the position changes nothing."""
        params, spec, x, cache = setup
        b = x.shape[0]
        pos = jnp.zeros((b,), jnp.int32)
        y_clean, _ = attn_decode(params, x, pos, spec, cache)
        junk = {n: c.at[:, 1:].set(1e3) for n, c in cache.items()}
        y_junk, c_junk = attn_decode(params, x, pos, spec, junk)
        np.testing.assert_array_equal(np.asarray(y_junk), np.asarray(y_clean))
        # only slot 0 was written; the junk is still there untouched
        np.testing.assert_array_equal(np.asarray(c_junk["k"][:, 1:]),
                                      np.full_like(cache["k"][:, 1:], 1e3))

    def test_last_slot_write_stays_in_bounds(self, setup):
        """pos == T-1 writes the final slot and attends the whole cache;
        earlier slots come through unmodified."""
        params, spec, x, cache = setup
        b = x.shape[0]
        _, c_v = attn_decode(params, x, jnp.full((b,), T - 1, jnp.int32),
                             spec, cache)
        for n in ("k", "v"):
            assert c_v[n].shape == cache[n].shape
            np.testing.assert_array_equal(np.asarray(c_v[n][:, : T - 1]),
                                          np.asarray(cache[n][:, : T - 1]))
            assert not np.array_equal(np.asarray(c_v[n][:, T - 1]),
                                      np.asarray(cache[n][:, T - 1]))

    def test_mixed_depths_match_each_row_solo(self, setup):
        """Rows at positions (0, T//2, T-1) in one batch: each row's
        output equals that row decoded alone through the scalar branch —
        batch-row independence, the property continuous batching needs."""
        params, spec, x, cache = setup
        pos = jnp.asarray([0, T // 2, T - 1], jnp.int32)
        y_v, c_v = attn_decode(params, x, pos, spec, cache)
        for r in range(3):
            row_cache = {n: c[r : r + 1] for n, c in cache.items()}
            y_r, c_r = attn_decode(params, x[r : r + 1],
                                   jnp.asarray(int(pos[r]), jnp.int32),
                                   spec, row_cache)
            np.testing.assert_array_equal(np.asarray(y_v[r : r + 1]),
                                          np.asarray(y_r))
            for n in ("k", "v"):
                np.testing.assert_array_equal(np.asarray(c_v[n][r : r + 1]),
                                              np.asarray(c_r[n]))


class TestPagedDecodeParity:
    def test_paged_matches_dense_vector_branch_bitwise(self, setup):
        """Scatter the dense cache rows into pool blocks; the block-table
        decode must land on the dense branch's exact bytes (output and
        written KV), mixed per-row depths included."""
        params, spec, x, cache = setup
        b, blk = x.shape[0], 4
        per_row = T // blk
        # row r's token span [j*blk, (j+1)*blk) lives in pool block
        # r*per_row + j; the table is just that layout, row-major.
        pool = {
            n: c.reshape(b * per_row, blk, *c.shape[2:])
            for n, c in cache.items()
        }
        table = jnp.arange(b * per_row, dtype=jnp.int32).reshape(b, per_row)
        pos = jnp.asarray([0, T // 2, T - 1], jnp.int32)
        y_d, c_d = attn_decode(params, x, pos, spec, cache)
        y_p, pool_p = attn_decode_paged(params, x, pos, spec, pool, table)
        np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_d))
        for n in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(pool_p[n].reshape(b, T, *cache[n].shape[2:])),
                np.asarray(c_d[n]),
            )
