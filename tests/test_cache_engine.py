"""TieredCacheEngine tests: placement, spill/readback equivalence, LRU,
prefetch, int8 compression (incl. the fused-kernel raw read path), the
disk-backed host tier, and end-to-end cached training through the engine."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core import methods as M
from repro.core import skip_cache as C
from repro.core.cache_engine import CacheStats, TieredCacheEngine, storage_layout
from repro.models.lm import init_lm
from repro.models.mlp import MLPConfig, init_mlp
from repro.optim import make_optimizer

LAYOUT = {"a": ((4,), jnp.float32), "lab": ((2,), jnp.int32)}


def fill(engine, n, batch=2, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, 4)).astype(np.float32)
    lab = rng.integers(0, 9, (n, 2)).astype(np.int32)
    for lo in range(0, n, batch):
        idx = jnp.arange(lo, min(lo + batch, n))
        engine.write(idx, {"a": jnp.asarray(a[lo : lo + batch]),
                           "lab": jnp.asarray(lab[lo : lo + batch])})
    return a, lab


class TestPlacement:
    def test_spill_and_readback_equivalence(self):
        """Rows pushed out of HBM by LRU spill must read back bit-exact."""
        eng = TieredCacheEngine(12, LAYOUT, capacity=4)
        a, lab = fill(eng, 12)
        assert eng.stats.spills > 0
        assert len(eng.resident_ids()) == 4
        out = eng.read(jnp.arange(12, dtype=jnp.int32).reshape(3, 4)[0])
        np.testing.assert_array_equal(np.asarray(out["a"]), a[:4])
        out = eng.read(jnp.array([0, 5, 11]))
        np.testing.assert_array_equal(np.asarray(out["a"]), a[[0, 5, 11]])
        np.testing.assert_array_equal(np.asarray(out["lab"]), lab[[0, 5, 11]])

    def test_lru_eviction_order(self):
        eng = TieredCacheEngine(6, LAYOUT, capacity=3)
        a, _ = fill(eng, 3)
        # Touch 0 so 1 becomes LRU, then force one eviction.
        eng.read(jnp.array([0]))
        eng.write(jnp.array([3]), {"a": jnp.zeros((1, 4)), "lab": jnp.zeros((1, 2), jnp.int32)})
        assert 1 not in eng.resident_ids()
        assert {0, 2, 3} == set(eng.resident_ids())
        # Evicted row is served from the host tier and promoted back.
        before = eng.stats.host_hits
        out = eng.read(jnp.array([1]))
        np.testing.assert_array_equal(np.asarray(out["a"]), a[1:2])
        assert eng.stats.host_hits == before + 1
        assert 1 in eng.resident_ids()

    def test_hbm_budget_derives_capacity(self):
        eng = TieredCacheEngine(10, LAYOUT, hbm_budget_bytes=3 * (4 * 4 + 2 * 4))
        assert eng.capacity == 3
        assert eng.hbm_nbytes() == 3 * eng.row_nbytes()

    def test_oversized_batch_assembles_without_promotion(self):
        eng = TieredCacheEngine(8, LAYOUT, capacity=2)
        a, _ = fill(eng, 8)
        out = eng.read(jnp.arange(8))
        np.testing.assert_array_equal(np.asarray(out["a"]), a)
        assert len(eng.resident_ids()) <= 2

    def test_read_unwritten_raises(self):
        eng = TieredCacheEngine(4, LAYOUT, capacity=2)
        fill(eng, 2)
        with pytest.raises(KeyError):
            eng.read(jnp.array([3]))

    def test_duplicate_ids_do_not_leak_rows(self):
        """Regression: duplicate sample ids in one batch must not strand
        HBM rows outside both the LRU map and the free list."""
        eng = TieredCacheEngine(8, LAYOUT, capacity=2)
        a, _ = fill(eng, 8)
        for _ in range(6):  # repeated duplicate-bearing reads used to leak
            out = eng.read(jnp.array([1, 1]))
            np.testing.assert_array_equal(np.asarray(out["a"]), a[[1, 1]])
            out = eng.read(jnp.array([2, 2]))
        assert len(eng.resident_ids()) + len(eng._free) == eng.capacity
        eng.write(jnp.array([3, 3]), {"a": jnp.zeros((2, 4)),
                                      "lab": jnp.zeros((2, 2), jnp.int32)})
        assert len(eng.resident_ids()) + len(eng._free) == eng.capacity

    def test_write_invalidates_stale_prefetch(self):
        """Regression: a write must supersede rows staged by prefetch, or a
        later read serves pre-write values."""
        eng = TieredCacheEngine(8, LAYOUT, capacity=2)
        fill(eng, 8)  # rows 0..5 spilled to host
        eng.prefetch(jnp.array([0]))
        eng.wait()
        new = {"a": jnp.full((1, 4), 42.0), "lab": jnp.zeros((1, 2), jnp.int32)}
        eng.write(jnp.array([0]), new)
        # Evict row 0 again so the next read cannot be served from HBM.
        eng.read(jnp.array([6, 7]))
        assert 0 not in eng.resident_ids()
        out = eng.read(jnp.array([0]))
        np.testing.assert_array_equal(np.asarray(out["a"]), np.full((1, 4), 42.0))

    def test_stats_hit_rate(self):
        st = CacheStats(hbm_hits=3, host_hits=1)
        assert st.reads() == 4 and st.hbm_hit_rate() == 0.75
        assert ("x/hbm_hits", 3.0) in st.as_rows("x")


class TestPrefetch:
    def test_prefetch_stages_host_rows(self):
        eng = TieredCacheEngine(8, LAYOUT, capacity=2)
        a, _ = fill(eng, 8)
        cold = [i for i in range(8) if i not in eng.resident_ids()][:2]
        eng.prefetch(jnp.asarray(cold))
        eng.wait()
        out = eng.read(jnp.asarray(cold))
        np.testing.assert_array_equal(np.asarray(out["a"]), a[cold])
        assert eng.stats.staged_hits == 2
        assert eng.stats.host_hits == 0

    def test_prefetch_of_resident_rows_is_noop(self):
        eng = TieredCacheEngine(4, LAYOUT, capacity=4)
        fill(eng, 4)
        eng.prefetch(jnp.arange(4))
        eng.wait()
        assert eng._staged == {}


class TestExport:
    def test_export_skipcache_roundtrip(self):
        eng = TieredCacheEngine(10, LAYOUT, capacity=3)
        a, _ = fill(eng, 10)
        full = eng.export_skipcache()
        assert int(full.hit_count()) == 10
        np.testing.assert_array_equal(np.asarray(full.slots["a"]), a)

    def test_flush_to_host_keeps_rows_readable(self):
        eng = TieredCacheEngine(4, LAYOUT, capacity=4)
        a, _ = fill(eng, 4)
        eng.flush_to_host()
        assert all(eng._host.has(i) for i in range(4))
        out = eng.read(jnp.arange(4))
        np.testing.assert_array_equal(np.asarray(out["a"]), a)


class TestDiskTier:
    def test_spill_through_disk_and_warm_restart(self, tmp_path):
        eng = TieredCacheEngine(8, LAYOUT, capacity=2, directory=str(tmp_path))
        a, lab = fill(eng, 8)
        eng.flush_to_host()
        assert any(f.name.endswith(".bin") for f in tmp_path.iterdir())
        # A fresh engine over the same directory serves the spilled rows.
        eng2 = TieredCacheEngine(8, LAYOUT, capacity=4, directory=str(tmp_path))
        eng2._present = set(range(8))  # manifest of written ids
        out = eng2.read(jnp.array([0, 3, 7]))
        np.testing.assert_array_equal(np.asarray(out["a"]), a[[0, 3, 7]])


class TestInt8Compression:
    def test_storage_layout_splits_float_slots(self):
        sl = storage_layout({"x": ((3, 8), jnp.float32), "lab": ((2,), jnp.int32)}, "int8")
        assert sl["x/q"] == ((3, 8), jnp.int8)
        assert sl["x/s"] == ((3,), jnp.float32)
        assert sl["lab"] == ((2,), jnp.int32)

    def test_read_dequantises_within_rowwise_bound(self):
        eng = TieredCacheEngine(6, {"x": ((16,), jnp.float32)}, capacity=2,
                                compress="int8")
        rng = np.random.default_rng(0)
        x = rng.standard_normal((6, 16)).astype(np.float32)
        for lo in range(0, 6, 2):
            eng.write(jnp.arange(lo, lo + 2), {"x": jnp.asarray(x[lo : lo + 2])})
        out = np.asarray(eng.read(jnp.arange(6))["x"])
        bound = np.abs(x).max(axis=-1, keepdims=True) / 127.0 + 1e-6
        assert (np.abs(out - x) <= bound * 1.01).all()

    def test_raw_read_feeds_fused_int8_kernel(self):
        """Engine raw (quantised) reads through skip_lora_fused_int8 must
        match dequant-then-skip_lora_fused — dequant stays inside the
        kernel, the engine never materialises bf16 activations."""
        from repro.kernels.skip_lora.ops import skip_lora_fused, skip_lora_fused_int8

        l, bsz, s, d, r = 2, 2, 64, 128, 4
        n = 4
        acts = jax.random.normal(jax.random.key(0), (n, l, s, d), jnp.float32)
        eng = TieredCacheEngine(n, {"acts": ((l, s, d), jnp.float32)},
                                capacity=2, compress="int8")
        for lo in range(0, n, 2):
            eng.write(jnp.arange(lo, lo + 2), {"acts": acts[lo : lo + 2]})
        idx = jnp.array([1, 3])
        raw = eng.read_raw(idx)
        q = jnp.swapaxes(raw["acts/q"], 0, 1)        # (L, B, S, D)
        scale = jnp.swapaxes(raw["acts/s"], 0, 1)    # (L, B, S)
        a = jax.random.normal(jax.random.key(1), (l, d, r)) / np.sqrt(d)
        b = jax.random.normal(jax.random.key(2), (l, r, d)) * 0.1
        fused = skip_lora_fused_int8(q, scale, a, b)
        deq = jnp.swapaxes(eng.read(idx)["acts"], 0, 1)
        ref = skip_lora_fused(deq, a, b)
        np.testing.assert_allclose(
            np.asarray(fused, np.float32), np.asarray(ref, np.float32),
            atol=5e-2, rtol=5e-2,
        )


class TestMLPEquivalence:
    """Satellite: cached updates through the engine == full-forward step."""

    CFG = MLPConfig(in_dim=16, hidden_dim=12, out_dim=3, lora_rank=2)

    def _populated(self):
        cfg = self.CFG
        backbone = init_mlp(jax.random.key(0), cfg)
        trainable, frozen = M.init_method(jax.random.key(1), cfg, backbone, "skip2_lora")
        n = 8
        x = jax.random.normal(jax.random.key(2), (n, cfg.in_dim))
        y = jax.random.randint(jax.random.key(3), (n,), 0, cfg.out_dim)
        cache = C.cache_for_mlp(n, cfg.dims)
        from repro.core.finetune import _populate_step

        pop = _populate_step(cfg)
        t_after, cache, _ = pop(trainable, frozen, cache, jnp.arange(n), x, y, 0.0)
        return cfg, trainable, frozen, cache, x, y, n

    def _cached_from_vals(self, cfg, trainable, vals, xb, yb, lr):
        xs = [xb] + [vals[f"x{k}"] for k in range(1, cfg.n_layers)]
        new_t, loss = M.cached_train_step(trainable, vals["y_base"], xs, yb, lr)
        return new_t, loss

    def test_fresh_cache_read_matches_full_forward_step(self):
        cfg, trainable, frozen, cache, x, y, n = self._populated()
        idx = jnp.arange(n)
        t_full, loss_full = M.train_step("skip_lora", cfg, trainable, frozen, x, y, 0.05)
        vals = C.cache_read(cache, idx)
        t_cached, loss_cached = self._cached_from_vals(cfg, trainable, vals, x, y, 0.05)
        assert abs(float(loss_full) - float(loss_cached)) < 1e-5
        for a, b in zip(jax.tree.leaves(t_full), jax.tree.leaves(t_cached)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    def test_tiered_engine_read_matches_full_forward_step(self):
        cfg, trainable, frozen, cache, x, y, n = self._populated()
        layout = {name: (arr.shape[1:], arr.dtype) for name, arr in cache.slots.items()}
        eng = TieredCacheEngine(n, layout, capacity=2)  # forces spills
        for lo in range(0, n, 2):
            idx = jnp.arange(lo, lo + 2)
            eng.write(idx, C.cache_read(cache, idx))
        t_full, loss_full = M.train_step("skip_lora", cfg, trainable, frozen, x, y, 0.05)
        # Churn the tiers first: batched reads force promotions + spills.
        for lo in range(0, n, 2):
            eng.read(jnp.arange(lo, lo + 2))
        # Engine values == fresh cache values, so a whole-set read must
        # reproduce the full-forward update exactly.
        vals = eng.read(jnp.arange(n))
        t_eng, loss_eng = self._cached_from_vals(cfg, trainable, vals, x, y, 0.05)
        assert abs(float(loss_full) - float(loss_eng)) < 1e-5
        for a, b in zip(jax.tree.leaves(t_full), jax.tree.leaves(t_eng)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


class TestEngineEpoch:
    def test_cached_epoch_via_engine_matches_scan_epoch(self):
        """The streaming engine epoch (per-batch reads + prefetch) must
        produce the same adapters as the fused scan epoch on the same
        visitation order."""
        from repro.core.finetune import (
            cached_epoch_via_engine,
            make_skip2_epoch_fns,
            _populate_step,
        )

        cfg = MLPConfig(in_dim=16, hidden_dim=12, out_dim=3, lora_rank=2)
        backbone = init_mlp(jax.random.key(0), cfg)
        trainable, frozen = M.init_method(jax.random.key(1), cfg, backbone, "skip2_lora")
        n, bs = 12, 4
        x = jax.random.normal(jax.random.key(2), (n, cfg.in_dim))
        y = jax.random.randint(jax.random.key(3), (n,), 0, cfg.out_dim)
        cache = C.cache_for_mlp(n, cfg.dims)
        pop = _populate_step(cfg)
        trainable, cache, _ = pop(trainable, frozen, cache, jnp.arange(n), x, y, 0.0)

        layout = {name: (arr.shape[1:], arr.dtype) for name, arr in cache.slots.items()}
        eng = TieredCacheEngine(n, layout, capacity=bs)  # spills guaranteed
        for lo in range(0, n, bs):
            idx = jnp.arange(lo, lo + bs)
            eng.write(idx, C.cache_read(cache, idx))

        idx_mat = jnp.arange(n).reshape(n // bs, bs)
        _, cached_epoch = make_skip2_epoch_fns(cfg, donate=False)
        t_scan, _ = cached_epoch(trainable, cache, x, y, idx_mat, 0.05)
        t_eng, _ = cached_epoch_via_engine(cfg, trainable, eng, x, y, idx_mat, 0.05)
        for a, b in zip(jax.tree.leaves(t_scan), jax.tree.leaves(t_eng)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        assert eng.stats.staged_hits + eng.stats.host_hits > 0


class TestLMEquivalence:
    def test_cached_step_through_engine_matches_device_cache(self):
        """LM-scale: populate -> engine placement with spills -> cached step
        from engine reads must equal the device-cache path bit-for-bit."""
        cfg = reduce_config(get_config("gemma-7b"))
        sl = SL.SkipLoRAConfig(rank=4, mode="full", cache_dtype="float32")
        params = init_lm(jax.random.key(0), cfg)
        adapters = SL.init_adapters(jax.random.key(1), cfg, sl)
        trainable, static = SL.split_trainable(adapters, sl)
        opt = make_optimizer("sgd", 0.0)
        opt_state = opt.init(trainable)
        b, s, n = 2, 16, 6
        tokens = jax.random.randint(jax.random.key(2), (n, s), 0, cfg.vocab_size)
        cache = SL.init_lm_cache(n, cfg, sl, s)
        populate = jax.jit(SL.make_populate_step(cfg, sl, opt))
        cached = jax.jit(SL.make_cached_step(cfg, sl, opt))
        from_vals = jax.jit(SL.make_cached_step_from_vals(cfg, sl, opt))
        for lo in range(0, n, b):
            idx = jnp.arange(lo, lo + b)
            batch = {"tokens": tokens[idx], "labels": tokens[idx]}
            trainable, opt_state, cache, _ = populate(
                params, trainable, static, opt_state, cache, batch, idx)

        engine = TieredCacheEngine(n, SL.lm_cache_layout(cfg, sl, s), capacity=b)
        for lo in range(0, n, b):
            idx = jnp.arange(lo, lo + b)
            engine.write(idx, C.cache_read(cache, idx))
        assert engine.stats.spills > 0
        for lo in range(0, n, b):
            idx = jnp.arange(lo, lo + b)
            _, _, loss_dev = cached(params, trainable, static, opt_state, cache, idx)
            _, _, loss_eng = from_vals(
                params, trainable, static, opt_state, engine.read(idx))
            assert float(loss_dev) == float(loss_eng)
