"""Host-offloaded cache store tests (incl. an end-to-end cached fine-tune
that round-trips activations through disk)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.cache_store import HostCacheStore
from repro.models.lm import init_lm
from repro.optim import make_optimizer


def spec_for(cfg, sl, seq):
    return SL.lm_cache_layout(cfg, sl, seq)


class TestHostCacheStore:
    def test_roundtrip(self, tmp_path):
        spec = {"a": ((2, 3), jnp.float32), "b": ((4,), jnp.int8)}
        store = HostCacheStore(str(tmp_path), spec)
        ids = np.array([3, 7])
        vals = {
            "a": np.arange(12, dtype=np.float32).reshape(2, 2, 3),
            "b": np.ones((2, 4), np.int8) * 5,
        }
        store.flush_batch(ids, vals)
        assert store.has(3) and store.has(7) and not store.has(0)
        out = store.read_batch(ids)
        np.testing.assert_array_equal(out["a"], vals["a"])
        np.testing.assert_array_equal(out["b"], vals["b"])

    def test_prefetch_path(self, tmp_path):
        spec = {"a": ((8,), jnp.float32)}
        store = HostCacheStore(str(tmp_path), spec)
        ids = np.arange(4)
        vals = {"a": np.random.randn(4, 8).astype(np.float32)}
        store.flush_batch(ids, vals)
        store.prefetch(ids[:2])
        store.wait()
        out = store.read_batch(ids[:2])  # must consume the staged buffer
        np.testing.assert_array_equal(out["a"], vals["a"][:2])
        # A mismatched read falls back to synchronous IO.
        store.prefetch(ids[:2])
        out2 = store.read_batch(ids[2:])
        np.testing.assert_array_equal(out2["a"], vals["a"][2:])

    def test_bfloat16_slots(self, tmp_path):
        spec = {"x": ((16,), jnp.bfloat16)}
        store = HostCacheStore(str(tmp_path), spec)
        v = jnp.linspace(-2, 2, 16).astype(jnp.bfloat16)[None]
        store.flush_batch(np.array([0]), {"x": v})
        out = store.read_batch(np.array([0]))
        np.testing.assert_array_equal(
            np.asarray(out["x"][0]).view(np.uint16),
            np.asarray(v[0]).view(np.uint16),
        )

    def test_atomic_write(self, tmp_path):
        spec = {"a": ((2,), jnp.float32)}
        store = HostCacheStore(str(tmp_path), spec)
        store.flush_batch(np.array([1]), {"a": np.ones((1, 2), np.float32)})
        # No stray tmp files after a successful flush.
        assert not any(f.endswith(".tmp") for f in (tmp_path).iterdir() for f in [f.name])


class TestEndToEndThroughDisk:
    def test_cached_step_from_host_store_matches_device_cache(self, tmp_path):
        """Populate -> flush to disk -> read back -> cached step must equal
        the device-cache path bit-for-bit (fp32 slots)."""
        cfg = reduce_config(get_config("gemma-7b"))
        sl = SL.SkipLoRAConfig(rank=4, mode="full", cache_dtype="float32")
        params = init_lm(jax.random.key(0), cfg)
        adapters = SL.init_adapters(jax.random.key(1), cfg, sl)
        trainable, static = SL.split_trainable(adapters, sl)
        opt = make_optimizer("sgd", 0.0)
        opt_state = opt.init(trainable)

        b, s, n = 2, 16, 4
        tokens = jax.random.randint(jax.random.key(2), (n, s), 0, cfg.vocab_size)
        idx = jnp.arange(b)
        batch = {"tokens": tokens[:b], "labels": tokens[:b]}
        cache = SL.init_lm_cache(n, cfg, sl, s)

        populate = jax.jit(SL.make_populate_step(cfg, sl, opt))
        cached = jax.jit(SL.make_cached_step(cfg, sl, opt))
        trainable, opt_state, cache, _ = populate(
            params, trainable, static, opt_state, cache, batch, idx
        )
        _, _, loss_device = cached(params, trainable, static, opt_state, cache, idx)

        # Flush the populated rows to the host store and rebuild a device
        # cache from disk.
        store = HostCacheStore(str(tmp_path), spec_for(cfg, sl, s))
        from repro.core.skip_cache import cache_read

        vals = cache_read(cache, idx)
        store.flush_batch(np.asarray(idx), vals)
        back = store.read_batch(np.asarray(idx))
        cache2 = SL.init_lm_cache(n, cfg, sl, s)
        from repro.core.skip_cache import cache_write

        cache2 = cache_write(cache2, idx, {k: jnp.asarray(v) for k, v in back.items()})
        _, _, loss_disk = cached(params, trainable, static, opt_state, cache2, idx)
        assert float(loss_device) == float(loss_disk)
