"""Tests for the Table-1 analytic cost model."""

import pytest

from repro.core import compute_model as cm


DIMS_FAN = (256, 96, 96, 3)
DIMS_HAR = (561, 96, 96, 6)
B = 20
R = 4


def total(method, dims=DIMS_FAN, hit=0.0):
    return cm.method_cost(method, B, dims, R, bn=True, cache_hit_rate=hit)


class TestLayerTypes:
    def test_ft_all_types(self):
        fcs, loras = cm.method_layer_types("ft_all", 3)
        assert fcs == [cm.FCType.YWB, cm.FCType.YWBX, cm.FCType.YWBX]
        assert all(l is cm.LoRAType.NONE for l in loras)

    def test_ft_last_types(self):
        fcs, _ = cm.method_layer_types("ft_last", 3)
        assert fcs == [cm.FCType.Y, cm.FCType.Y, cm.FCType.YWB]

    def test_lora_all_types(self):
        fcs, loras = cm.method_layer_types("lora_all", 3)
        assert fcs == [cm.FCType.Y, cm.FCType.YX, cm.FCType.YX]
        assert loras == [cm.LoRAType.YW, cm.LoRAType.YWX, cm.LoRAType.YWX]

    def test_skip_lora_types(self):
        fcs, loras = cm.method_layer_types("skip_lora", 3)
        assert fcs == [cm.FCType.Y] * 3
        assert loras == [cm.LoRAType.YW] * 3

    def test_unknown_method_raises(self):
        with pytest.raises(ValueError):
            cm.method_layer_types("nope", 3)


class TestCostOrdering:
    """The paper's qualitative cost claims, from the closed forms."""

    def test_backward_ordering(self):
        # Table 6: backward  FT-All > LoRA-All >> Skip-LoRA > LoRA-Last ~ FT-Last
        bwd = {m: total(m).backward for m in cm.method_layer_types.__defaults__ or ()}
        bwd = {m: total(m).backward for m in ("ft_all", "lora_all", "skip_lora", "lora_last", "ft_last")}
        assert bwd["ft_all"] > bwd["lora_all"] > bwd["skip_lora"] > bwd["lora_last"]

    def test_skip_lora_backward_close_to_lora_last(self):
        # Section 4.1: Skip-LoRA backward ~ LoRA-Last backward (both << LoRA-All).
        assert total("skip_lora").backward < 0.25 * total("lora_all").backward

    def test_skip_cache_forward_reduction(self):
        # Section 4.2: expected forward cost -> 1/E. With E=300 epochs the
        # hit rate is 299/300 and forward cost collapses.
        e = 300
        hit = cm.expected_hit_rate(e)
        fwd_cached = total("skip2_lora", hit=hit).forward
        fwd_full = total("skip_lora").forward
        assert fwd_cached < 0.15 * fwd_full

    def test_paper_headline_90pct_reduction(self):
        # Abstract: Skip2-LoRA cuts fine-tuning time ~90% vs LoRA-All (same
        # trainable-parameter count). Check the FLOP model reproduces this
        # for both dataset geometries at the paper's epoch counts.
        for dims, e in ((DIMS_FAN, 300), (DIMS_HAR, 600)):
            hit = cm.expected_hit_rate(e)
            skip2 = cm.method_cost("skip2_lora", B, dims, R, cache_hit_rate=hit).total
            lora_all = cm.method_cost("lora_all", B, dims, R).total
            reduction = 1.0 - skip2 / lora_all
            assert reduction > 0.80, (dims, reduction)

    def test_fc1_fc2_dominate_ft_all_lora(self):
        # Table 2: FC1+FC2 dominate FT-All-LoRA cost.
        dims = DIMS_FAN
        fcs, loras = cm.method_layer_types("ft_all_lora", 3)
        fc_cost_01 = (
            cm.fc_cost(fcs[0], B, dims[0], dims[1]).total
            + cm.fc_cost(fcs[1], B, dims[1], dims[2]).total
        )
        total_cost = cm.method_cost("ft_all_lora", B, dims, R).total
        assert fc_cost_01 > 0.7 * total_cost


class TestParamCounts:
    def test_skip_lora_matches_lora_all_param_count_shape(self):
        # Same number of adapters; counts differ only via output dim of
        # non-last adapters (paper: "same number of trainable parameters"
        # holds exactly when hidden width == out width of last layer is not
        # required; for the 256-96-96-3 net the counts are close).
        dims = DIMS_FAN
        skip = cm.trainable_param_count("skip_lora", dims, R)
        lall = cm.trainable_param_count("lora_all", dims, R)
        assert skip > 0 and lall > 0
        # adapters: lora_all = R*(256+96 + 96+96 + 96+3); skip = R*(256+3 + 96+3 + 96+3)
        assert abs(skip - lall) < lall  # same order of magnitude

    def test_ft_bias_smallest(self):
        dims = DIMS_FAN
        counts = {m: cm.trainable_param_count(m, dims, R) for m in
                  ("ft_all", "ft_last", "ft_bias", "lora_all", "skip_lora")}
        assert counts["ft_bias"] < counts["lora_all"]
        assert counts["ft_all"] == max(counts.values())

    def test_cache_size_matches_paper(self):
        # Section 4.3: Fan dataset, 470 samples, 256-96-96-3 net ->
        # C_skip stores y^1, y^2, y^3 per sample. The paper says 358KiB.
        n_samples = 470
        floats = n_samples * (96 + 96 + 3)
        kib = floats * 4 / 1024
        assert abs(kib - 358) < 1.0


class TestHitRate:
    def test_expected_hit_rate(self):
        assert cm.expected_hit_rate(1) == 0.0
        assert cm.expected_hit_rate(300) == pytest.approx(299 / 300)
        assert cm.expected_hit_rate(0) == 0.0
