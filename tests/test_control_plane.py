"""Adapter control plane (DESIGN.md §13): shadow split, regression gate,
versioned slots with rollback, and their checkpoint story.

Quick tier, all of it. The gate is strictly opt-in — a session without a
``ControlConfig`` must plan and write back bitwise as before — so these
tests cover the policy (ControlPlane), the mechanism (AdapterPool version
history + gated ``register_many``), the orchestration (SessionRuntime
reject/quarantine semantics on both the resident-scan and streaming adapt
paths), and the end-to-end poisoned-corpus acceptance bar.
"""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import batch_plan
from repro.core import lm_skiplora as SL
from repro.core.adapter_pool import AdapterPool
from repro.core.control_plane import ControlConfig, ControlPlane
from repro.core.runtime import SessionRuntime
from repro.models.lm import init_lm

COMPRESS = [None, "int8", "int4", "nf4"]


@pytest.fixture(scope="module")
def cfg():
    return reduce_config(get_config("stablelm-1.6b"))


@pytest.fixture(scope="module")
def params(cfg):
    return init_lm(jax.random.key(0), cfg)


def make_sl(**kw):
    kw.setdefault("rank", 4)
    kw.setdefault("mode", "full")
    kw.setdefault("cache_dtype", "float32")
    return SL.SkipLoRAConfig(**kw)


def make_runtime(cfg, params, *, n_t=2, n_per=8, seq=8, control=None, **kw):
    return SessionRuntime(
        cfg, make_sl(), params, max_tenants=n_t, samples_per_tenant=n_per,
        seq=seq, lr=5e-2, control=control, **kw
    )


def make_data(cfg, n_t, n_per, seq, seed=1):
    tokens = jax.random.randint(
        jax.random.key(seed), (n_t, n_per, seq), 0, cfg.vocab_size
    )
    labels = jax.random.randint(
        jax.random.key(seed + 1), (n_t, n_per, seq), 0, cfg.vocab_size
    )
    return tokens, labels


def make_adapters(cfg, seed, rank=4):
    ad = SL.init_adapters(jax.random.key(seed), cfg, make_sl(rank=rank))
    ad["B"] = jax.random.normal(jax.random.key(seed + 100), ad["B"].shape) * 0.05
    return ad


def slot_payload_np(pool, tenant):
    return {n: np.asarray(v) for n, v in pool.slot_payload(tenant).items()}


# An always-firing gate: any finite delta exceeds -inf, so the second
# write-back of any tenant is deterministically gated without needing a
# crafted regression.
ALWAYS = ControlConfig(holdout_every=4, threshold=float("-inf"))
NEVER = ControlConfig(holdout_every=4, threshold=float("inf"))


class TestShadowSplit:
    def test_holdout_rule_and_append_stability(self):
        train, held = batch_plan.shadow_split(16, every=4)
        np.testing.assert_array_equal(held, [3, 7, 11, 15])
        np.testing.assert_array_equal(
            np.sort(np.concatenate([train, held])), np.arange(16)
        )
        # Appending rows never reassigns an existing row between sides.
        t2, h2 = batch_plan.shadow_split(23, every=4)
        np.testing.assert_array_equal(h2[: held.size], held)
        np.testing.assert_array_equal(t2[: train.size], train)
        assert 0 in train  # row 0 always trains

    def test_none_is_all_train_and_validation(self):
        train, held = batch_plan.shadow_split(5, every=None)
        np.testing.assert_array_equal(train, np.arange(5))
        assert held.size == 0
        with pytest.raises(ValueError, match="every"):
            batch_plan.shadow_split(5, every=1)

    def test_fleet_index_matrix_trains_complement_only(self):
        idx = batch_plan.fleet_index_matrix(
            epoch=0, n_tenants=2, samples_per_tenant=8, batch_per_tenant=2,
            holdout_every=4,
        )
        train, held = batch_plan.shadow_split(8, every=4)
        for g in range(2):
            block = idx[:, g * 2:(g + 1) * 2].ravel() - g * 8
            assert sorted(block.tolist()) == sorted(train.tolist())
            assert not set(block.tolist()) & set(held.tolist())

    def test_holdout_none_is_bitwise_historical(self):
        a = batch_plan.fleet_index_matrix(
            epoch=3, n_tenants=2, samples_per_tenant=8, batch_per_tenant=4
        )
        b = batch_plan.fleet_index_matrix(
            epoch=3, n_tenants=2, samples_per_tenant=8, batch_per_tenant=4,
            holdout_every=None,
        )
        np.testing.assert_array_equal(a, b)

    def test_fleet_eval_index_layout(self):
        idx = batch_plan.fleet_eval_index(
            2, 8, holdout_every=4, partitions=[2, 0], partition_stride=16
        )
        np.testing.assert_array_equal(idx, [2 * 16 + 3, 2 * 16 + 7, 3, 7])
        with pytest.raises(ValueError, match="no held-out"):
            batch_plan.fleet_eval_index(1, 3, holdout_every=4)


class TestControlPolicy:
    def test_config_validation(self):
        with pytest.raises(ValueError, match="holdout_every"):
            ControlConfig(holdout_every=1)
        with pytest.raises(ValueError, match="mode"):
            ControlConfig(mode="warn")
        with pytest.raises(ValueError, match="history_depth"):
            ControlConfig(history_depth=0)

    def test_decide_semantics(self):
        cp = ControlPlane(ControlConfig(threshold=0.1, mode="quarantine"))
        assert cp.decide("t", None, 3.0) == "accept"   # no eval rows
        assert cp.decide("t", 3.0, None) == "accept"
        assert cp.decide("t", 3.0, 3.05) == "accept"   # within threshold
        assert cp.decide("t", 3.0, 3.2) == "quarantine"
        assert ControlPlane(ControlConfig()).decide("t", 3.0, 3.2) == "reject"

    def test_ledger_and_quarantine_lifecycle(self):
        cp = ControlPlane(ControlConfig(mode="quarantine"))
        cp.record(7, "quarantine", pre=1.0, post=2.0, step=4)
        assert cp.is_quarantined(7) and cp.quarantined == 1
        assert cp.last(7)["delta"] == 1.0
        cp.record(7, "accept", pre=2.0, post=1.5, step=8)
        assert not cp.is_quarantined(7) and cp.accepted == 1
        cp.record(7, "quarantine", pre=1.5, post=9.0, step=12)
        cp.record_rollback(7)
        assert not cp.is_quarantined(7) and cp.rollbacks == 1
        assert cp.last(7) is None
        with pytest.raises(ValueError, match="decision"):
            cp.record(7, "maybe")

    def test_auto_rollback_streak_policy(self):
        with pytest.raises(ValueError, match="auto_rollback_after"):
            ControlConfig(auto_rollback_after=0)
        cp = ControlPlane(ControlConfig(auto_rollback_after=2))
        cp.record("t", "reject", pre=1.0, post=2.0, step=1)
        assert not cp.should_auto_rollback("t")
        cp.record("t", "reject", pre=1.0, post=2.0, step=2)
        assert cp.should_auto_rollback("t")
        cp.record_rollback("t", auto=True)
        assert (cp.rollbacks, cp.auto_rollbacks) == (1, 1)
        assert not cp.should_auto_rollback("t")        # streak cleared
        # An accept resets the streak mid-way.
        cp.record("t", "reject", pre=1.0, post=2.0, step=3)
        cp.record("t", "accept", pre=1.0, post=0.5, step=4)
        cp.record("t", "reject", pre=0.5, post=2.0, step=5)
        assert not cp.should_auto_rollback("t")
        # Manual rollbacks don't count as auto.
        cp.record_rollback("t")
        assert (cp.rollbacks, cp.auto_rollbacks) == (2, 1)
        # Disabled (the default): streaks accumulate but never fire.
        cp0 = ControlPlane(ControlConfig())
        cp0.record("t", "reject", pre=1.0, post=2.0, step=1)
        cp0.record("t", "reject", pre=1.0, post=2.0, step=2)
        assert not cp0.should_auto_rollback("t")

    def test_streaks_survive_state_roundtrip(self):
        cp = ControlPlane(ControlConfig(mode="quarantine", auto_rollback_after=3))
        cp.record(3, "quarantine", pre=1.0, post=2.0, step=1)
        cp.record(3, "quarantine", pre=1.0, post=2.0, step=2)
        wire = json.loads(json.dumps(cp.state()))
        cp2 = ControlPlane(cp.config)
        cp2.load_state(wire)
        assert not cp2.should_auto_rollback(3)
        cp2.record(3, "quarantine", pre=1.0, post=2.0, step=3)
        assert cp2.should_auto_rollback(3)             # int key survived JSON

    def test_state_roundtrips_int_tenants_through_json(self):
        cp = ControlPlane(ControlConfig(mode="quarantine"))
        cp.record(3, "reject", pre=1.0, post=2.0, step=2)
        cp.record(4, "quarantine", pre=1.0, post=2.0, step=2)
        wire = json.loads(json.dumps(cp.state()))
        cp2 = ControlPlane(cp.config)
        cp2.load_state(wire)
        assert cp2.last(3)["decision"] == "reject"    # int key survived
        assert cp2.is_quarantined(4)
        assert (cp2.accepted, cp2.rejected, cp2.quarantined, cp2.rollbacks) \
            == (0, 1, 1, 0)


class TestPoolVersioning:
    @pytest.mark.parametrize("compress", COMPRESS)
    def test_rollback_restores_previous_version_bitwise(self, cfg, compress):
        pool = AdapterPool(3, cfg, rank=4, compress=compress, history=2)
        pool.register("u", make_adapters(cfg, 1), meta={"step": 4, "eval_loss": 2.0})
        v1 = slot_payload_np(pool, "u")
        pool.register("u", make_adapters(cfg, 2), meta={"step": 8, "eval_loss": 1.5})
        v2 = slot_payload_np(pool, "u")
        assert any(not np.array_equal(v1[n], v2[n]) for n in v1)
        assert pool.history_len("u") == 1
        assert pool.version_info("u") == {"step": 8, "eval_loss": 1.5, "history": 1}
        ver = pool.version
        meta = pool.rollback("u")
        assert meta == {"step": 4, "eval_loss": 2.0}
        assert pool.version == ver + 1      # serve idx memos must invalidate
        assert pool.stats.rollbacks == 1
        restored = slot_payload_np(pool, "u")
        for n in v1:    # storage layout archived -> bitwise even quantised
            np.testing.assert_array_equal(restored[n], v1[n], err_msg=n)
        assert pool.history_len("u") == 0
        with pytest.raises(KeyError, match="history"):
            pool.rollback("u")

    def test_history_depth_is_bounded(self, cfg):
        pool = AdapterPool(3, cfg, rank=4, history=2)
        for i in range(5):
            pool.register("u", make_adapters(cfg, 10 + i), meta={"step": i})
        assert pool.history_len("u") == 2
        assert pool.rollback("u") == {"step": 3, "eval_loss": None}
        assert pool.rollback("u") == {"step": 2, "eval_loss": None}
        with pytest.raises(KeyError, match="history"):
            pool.rollback("u")

    def test_eviction_drops_version_history(self, cfg):
        pool = AdapterPool(3, cfg, rank=4, history=2)  # 2 usable slots
        pool.register("a", make_adapters(cfg, 1))
        pool.register("a", make_adapters(cfg, 2))
        pool.register("b", make_adapters(cfg, 3))
        pool.register("c", make_adapters(cfg, 4))      # LRU-evicts a
        assert not pool.has("a")
        pool.register("a", make_adapters(cfg, 5))      # fresh again
        assert pool.history_len("a") == 0              # no stale archive
        with pytest.raises(KeyError):
            pool.rollback("a")

    def test_register_many_gate_suppresses_reregistration_only(self, cfg):
        pool = AdapterPool(4, cfg, rank=4, history=2)
        ad = {t: make_adapters(cfg, 20 + t) for t in range(3)}
        stack = jax.tree.map(lambda *xs: jnp.stack(xs), *(ad[t] for t in range(3)))
        pool.register_many([0, 1], jax.tree.map(lambda x: x[:2], stack))
        v0 = slot_payload_np(pool, 0)
        fresh = make_adapters(cfg, 30)
        stack2 = jax.tree.map(
            lambda *xs: jnp.stack(xs), ad[1], fresh, ad[0]
        )
        decisions = {1: "reject", 2: "reject", 0: "quarantine"}
        pool.register_many([1, 2, 0], stack2, gate=decisions.__getitem__)
        # Tenant 2 is FRESH: the gate has no served version to protect, so
        # its rows land even under a reject decision...
        assert pool.has(2)
        np.testing.assert_array_equal(
            slot_payload_np(pool, 2)["A"], np.asarray(stack2["A"][1])
        )
        # ...while the gated RE-registrations kept their old payloads.
        np.testing.assert_array_equal(slot_payload_np(pool, 0)["A"], v0["A"])
        assert pool.stats.gate_rejected == 1
        assert pool.history_len(1) == 0  # suppressed write: nothing archived

    @pytest.mark.parametrize("compress", COMPRESS)
    def test_state_roundtrip_carries_history(self, cfg, compress):
        pool = AdapterPool(3, cfg, rank=4, compress=compress, history=2)
        pool.register("u", make_adapters(cfg, 1), meta={"step": 2, "eval_loss": 3.0})
        pool.register("u", make_adapters(cfg, 2), meta={"step": 4, "eval_loss": 2.5})
        pool.register("v", make_adapters(cfg, 3), meta={"step": 2, "eval_loss": 9.0})
        twin = AdapterPool(3, cfg, rank=4, compress=compress, history=2)
        # The table rides a JSON manifest; round-trip it like a checkpoint.
        twin.load_state(
            pool.state_arrays(), json.loads(json.dumps(pool.slot_table()))
        )
        assert twin.version_info("u") == pool.version_info("u")
        assert twin.history_len("u") == 1 and twin.history_len("v") == 0
        a, b = pool.rollback("u"), twin.rollback("u")
        assert a == b
        for n, arr in slot_payload_np(pool, "u").items():
            np.testing.assert_array_equal(
                slot_payload_np(twin, "u")[n], arr, err_msg=n
            )


class TestGatedRuntime:
    def _adapted(self, cfg, params, control, **kw):
        rt = make_runtime(cfg, params, control=control, **kw)
        tokens, labels = make_data(cfg, 2, 8, 8)
        for t in range(2):
            rt.ingest(f"u{t}", tokens[t], labels[t])
        rt.adapt(epochs=1, batch_per_tenant=4, key=jax.random.key(3))
        return rt

    def test_reject_freezes_training_and_serving_state(self, cfg, params):
        rt = self._adapted(cfg, params, ALWAYS)
        assert {r["decision"] for _, r in rt.control_metrics()["tenants"]} \
            == {"accept"}  # first-ever write-back: nothing to protect
        step1 = rt.tenant("u0").step
        v1 = slot_payload_np(rt.pool.shards[0], "u0")
        rt.adapt(epochs=1, batch_per_tenant=4)
        rec = dict(rt.control_metrics()["tenants"])["u0"]
        assert rec["decision"] == "reject"
        assert rec["pre"] is not None and rec["post"] is not None
        assert rt.tenant("u0").step == step1          # training state frozen
        for n, arr in slot_payload_np(rt.pool.shards[0], "u0").items():
            np.testing.assert_array_equal(arr, v1[n])  # served slot kept
        assert rt.counters["control/reject"] == 2

    def test_quarantine_advances_state_but_serves_old(self, cfg, params):
        quar = ControlConfig(
            holdout_every=4, threshold=float("-inf"), mode="quarantine"
        )
        rt = self._adapted(cfg, params, quar)
        step1 = rt.tenant("u0").step
        v1 = slot_payload_np(rt.pool.shards[0], "u0")
        rt.adapt(epochs=1, batch_per_tenant=4)
        assert rt.control.is_quarantined("u0")
        assert rt.tenant("u0").step > step1           # training continues...
        for n, arr in slot_payload_np(rt.pool.shards[0], "u0").items():
            np.testing.assert_array_equal(arr, v1[n])  # ...serving does not
        assert rt.control_metrics()["quarantined_tenants"] == ["u0", "u1"]

    def test_streaming_adapt_path_evaluates_too(self, cfg, params):
        rt = self._adapted(cfg, params, NEVER, cache_capacity=8)
        out = rt.adapt(epochs=1, batch_per_tenant=4)
        assert out["path"] == "stream"
        rec = dict(rt.control_metrics()["tenants"])["u0"]
        assert rec["pre"] is not None and rec["post"] is not None
        assert rec["decision"] == "accept"
        assert rt.pool.history_len("u0") == 1         # accepted: archived

    def test_too_few_rows_passes_ungated(self, cfg, params):
        """A tenant below ``holdout_every`` rows has an empty eval set —
        it must adapt ungated (pre/post None), not crash the group."""
        rt = make_runtime(cfg, params, n_per=4, control=NEVER)
        tokens, labels = make_data(cfg, 1, 3, 8)
        rt.ingest("u0", tokens[0], labels[0])
        rt.adapt(epochs=1, batch_per_tenant=2, key=jax.random.key(3))
        rt.adapt(epochs=1, batch_per_tenant=2)
        rec = dict(rt.control_metrics()["tenants"])["u0"]
        assert rec["decision"] == "accept"
        assert rec["pre"] is None and rec["post"] is None

    def test_auto_rollback_fires_after_streak_and_resets_optimizer(
        self, cfg, params
    ):
        """threshold=-inf: the first write-back per tenant accepts, every
        later one rejects. With ``auto_rollback_after=2`` the second reject
        fires the automatic rollback: optimizer state zeroed, step reset,
        ledger counted — while the served slot (v1, never overwritten by
        the rejected versions) stays put."""
        control = ControlConfig(
            holdout_every=4, threshold=float("-inf"), auto_rollback_after=2
        )
        rt = self._adapted(cfg, params, control)       # adapt 1: accepts
        v1 = slot_payload_np(rt.pool.shards[0], "u0")
        rt.adapt(epochs=1, batch_per_tenant=4)         # reject, streak 1
        assert rt.control.auto_rollbacks == 0
        assert any(
            np.any(np.asarray(x)) for x in jax.tree.leaves(rt.tenant("u0").opt_mu)
        )
        rt.adapt(epochs=1, batch_per_tenant=4)         # reject, streak 2 -> fire
        assert rt.control.auto_rollbacks == 2          # both tenants
        assert rt.counters["control/auto_rollbacks"] == 2
        assert rt.counters["control/rollbacks"] == 2
        st = rt.tenant("u0")
        assert st.step == 0
        assert not any(
            np.any(np.asarray(x)) for x in jax.tree.leaves(st.opt_mu)
        )
        for n, arr in slot_payload_np(rt.pool.shards[0], "u0").items():
            np.testing.assert_array_equal(arr, v1[n])
        # The streak cleared with the rollback: one more reject is streak 1
        # again, no second firing.
        rt.adapt(epochs=1, batch_per_tenant=4)
        assert rt.control.auto_rollbacks == 2

    def test_auto_rollback_restores_archived_version(self, cfg, params):
        """With history beneath the served version, the automatic rollback
        restores it bitwise (the same mechanism the manual path uses)."""
        import dataclasses

        control = ControlConfig(
            holdout_every=4, threshold=float("inf"), auto_rollback_after=2
        )
        rt = self._adapted(cfg, params, control)       # v1 accepted
        v1 = slot_payload_np(rt.pool.shards[0], "u0")
        rt.adapt(epochs=1, batch_per_tenant=4)         # v2 accepted, v1 archived
        assert rt.pool.history_len("u0") == 1
        # The operator tightens the gate mid-session: every further
        # write-back now counts as a regression.
        rt.control.config = dataclasses.replace(
            rt.control.config, threshold=float("-inf")
        )
        rt.adapt(epochs=1, batch_per_tenant=4)         # reject, streak 1
        rt.adapt(epochs=1, batch_per_tenant=4)         # reject, streak 2 -> fire
        assert rt.control.auto_rollbacks == 2
        assert rt.pool.history_len("u0") == 0
        for n, arr in slot_payload_np(rt.pool.shards[0], "u0").items():
            np.testing.assert_array_equal(arr, v1[n])  # v2 rolled back to v1

    def test_control_off_keeps_historical_behaviour(self, cfg, params):
        rt = self._adapted(cfg, params, None)
        assert rt.control is None and rt.control_metrics() is None
        assert rt.pool.history_depth == 0
        with pytest.raises(KeyError):
            rt.pool.rollback("u0")

    def test_rollback_without_control_config_still_counts(self, cfg, params):
        rt = self._adapted(cfg, params, NEVER)
        rt.adapt(epochs=1, batch_per_tenant=4)        # v2 accepted, v1 archived
        before = dict(rt.control_metrics()["tenants"])["u0"]
        assert before is not None
        rt.rollback("u0")
        assert rt.counters["control/rollbacks"] == 1
        assert rt.control_metrics()["rollbacks"] == 1
        assert dict(rt.control_metrics()["tenants"]).get("u0") is None


class TestPoisonEndToEnd:
    """The ISSUE's acceptance bar, in-suite (the measured version lives in
    benchmarks/control_bench.py): a tenant whose recycled partition is
    refilled with constant-label garbage is gated on re-adapt; under an
    open gate the same poison lands and one rollback restores the previous
    version bitwise, eval record and served tokens included."""

    HOLD = 4

    def _poison(self, cfg, params, rows, seq):
        """All rows share one context; train rows carry random garbage
        labels while held-out rows keep the BASE model's own argmax (the
        distribution the tenant was serving well). Training on the garbage
        tears down exactly the calibration the held-out rows measure, so
        the regression is large and monotone — schemes with random held-out
        labels are confounded by the entropy-raising side effect of any
        training (a more uniform predictive distribution *lowers* expected
        loss on random targets)."""
        from repro.models.lm import lm_forward, readout

        rng = np.random.default_rng(23)
        row = rng.integers(0, cfg.vocab_size, (1, seq)).astype(np.int32)
        logits = readout(params, cfg, lm_forward(params, cfg, jnp.asarray(row))["h"])
        base_best = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
        garbage = rng.integers(0, cfg.vocab_size, (1, seq)).astype(np.int32)
        toks = np.repeat(row, rows, 0)
        labs = np.repeat(garbage, rows, 0)
        held = (np.arange(rows) + 1) % self.HOLD == 0
        labs[held] = base_best
        return toks, labs

    def _clean_session(self, cfg, params, control):
        rt = make_runtime(cfg, params, n_t=2, n_per=16, control=control)
        tokens, labels = make_data(cfg, 2, 16, 8)
        for t in range(2):
            rt.ingest(f"u{t}", tokens[t], labels[t])
        rt.adapt(epochs=2, batch_per_tenant=4, key=jax.random.key(3))
        return rt

    def _poison_victim(self, cfg, params, rt):
        rt.release("u0")                      # partition recycled, slot stays
        rt.ingest("u0", *self._poison(cfg, params, 16, 8))
        rt.adapt(["u0"], epochs=4, batch_per_tenant=4, key=jax.random.key(5))

    @pytest.mark.parametrize("mode", ["reject", "quarantine"])
    def test_gate_fires_and_served_slot_never_regresses(self, cfg, params, mode):
        ctl = ControlConfig(holdout_every=self.HOLD, threshold=0.0, mode=mode)
        rt = self._clean_session(cfg, params, ctl)
        clean_eval = rt.pool.version_info("u0")["eval_loss"]
        v_clean = slot_payload_np(rt.pool.shards[0], "u0")
        self._poison_victim(cfg, params, rt)
        rec = dict(rt.control_metrics()["tenants"])["u0"]
        assert rec["decision"] == mode and rec["delta"] > 0
        for n, arr in slot_payload_np(rt.pool.shards[0], "u0").items():
            np.testing.assert_array_equal(arr, v_clean[n], err_msg=n)
        # The SERVED version's recorded held-out loss never regressed.
        assert rt.pool.version_info("u0")["eval_loss"] == clean_eval
        assert rt.control.is_quarantined("u0") == (mode == "quarantine")

    def test_open_gate_poison_lands_and_rollback_restores(self, cfg, params):
        rt = self._clean_session(cfg, params, NEVER)
        prompts = jax.random.randint(jax.random.key(7), (1, 6), 0, cfg.vocab_size)
        v_clean = slot_payload_np(rt.pool.shards[0], "u0")
        clean_eval = rt.pool.version_info("u0")["eval_loss"]
        toks_clean = np.asarray(rt.serve(["u0"], prompts, max_new=6))
        self._poison_victim(cfg, params, rt)
        assert dict(rt.control_metrics()["tenants"])["u0"]["decision"] == "accept"
        toks_poisoned = np.asarray(rt.serve(["u0"], prompts, max_new=6))
        assert not np.array_equal(toks_clean, toks_poisoned)
        restored = rt.rollback("u0")
        assert restored["eval_loss"] == clean_eval
        for n, arr in slot_payload_np(rt.pool.shards[0], "u0").items():
            np.testing.assert_array_equal(arr, v_clean[n], err_msg=n)
        np.testing.assert_array_equal(
            np.asarray(rt.serve(["u0"], prompts, max_new=6)), toks_clean
        )


class TestControlCheckpoint:
    def _session_with_history(self, cfg, params, control):
        rt = make_runtime(cfg, params, control=control)
        tokens, labels = make_data(cfg, 2, 8, 8)
        for t in range(2):
            rt.ingest(f"u{t}", tokens[t], labels[t])
        rt.adapt(epochs=1, batch_per_tenant=4, key=jax.random.key(3))
        rt.adapt(epochs=1, batch_per_tenant=4)  # v2: v1 goes to history
        return rt

    def test_history_and_ledger_survive_restore(self, cfg, params, tmp_path):
        from repro.checkpoint.checkpoint import (
            restore_runtime_session,
            save_runtime_session,
        )

        rt = self._session_with_history(cfg, params, NEVER)
        assert rt.pool.history_len("u0") == 1
        path = save_runtime_session(str(tmp_path), 1, rt)
        rt_new = make_runtime(cfg, params, control=NEVER)
        restore_runtime_session(path, rt_new)
        assert rt_new.control_metrics() == rt.control_metrics()
        assert rt_new.pool.version_info("u0") == rt.pool.version_info("u0")
        assert rt_new.pool.history_len("u0") == 1
        # Rolling BOTH sessions back lands on the same bitwise payload and
        # the same served stream — the archive survived the manifest.
        a, b = rt.rollback("u0"), rt_new.rollback("u0")
        assert a == b
        prompts = jax.random.randint(jax.random.key(9), (1, 6), 0, cfg.vocab_size)
        np.testing.assert_array_equal(
            np.asarray(rt.serve(["u0"], prompts, max_new=4)),
            np.asarray(rt_new.serve(["u0"], prompts, max_new=4)),
        )

    def test_quarantine_set_survives_restore(self, cfg, params, tmp_path):
        from repro.checkpoint.checkpoint import (
            restore_runtime_session,
            save_runtime_session,
        )

        quar = ControlConfig(
            holdout_every=4, threshold=float("-inf"), mode="quarantine"
        )
        rt = self._session_with_history(cfg, params, quar)
        assert rt.control.is_quarantined("u0")
        path = save_runtime_session(str(tmp_path), 1, rt)
        rt_new = make_runtime(cfg, params, control=quar)
        restore_runtime_session(path, rt_new)
        assert rt_new.control.is_quarantined("u0")
        assert rt_new.control.quarantined == rt.control.quarantined

    def test_restore_into_uncontrolled_runtime_fails_loudly(
        self, cfg, params, tmp_path
    ):
        from repro.checkpoint.checkpoint import (
            restore_runtime_session,
            save_runtime_session,
        )

        rt = self._session_with_history(cfg, params, NEVER)
        path = save_runtime_session(str(tmp_path), 1, rt)
        with pytest.raises(ValueError, match="control"):
            restore_runtime_session(path, make_runtime(cfg, params))
