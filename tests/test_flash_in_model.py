"""Flash-attention path wired into the model: must match the einsum path."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import AttnSpec, attn_train, init_attn
from repro.models.config import ModelConfig


def mini_cfg(**kw):
    base = dict(
        name="t", family="dense", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


class TestFlashInModel:
    def test_attn_train_flash_matches_einsum(self):
        cfg = mini_cfg()
        spec = AttnSpec.from_config(cfg, local=False)
        params = init_attn(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (2, 256, cfg.d_model))
        ref = attn_train(params, x, spec)
        fl = attn_train(params, x, spec, use_flash=True)
        np.testing.assert_allclose(np.asarray(fl), np.asarray(ref), atol=2e-4, rtol=2e-4)

    def test_local_window_and_softcap(self):
        cfg = mini_cfg(sliding_window=128, attn_softcap=50.0)
        spec = AttnSpec.from_config(cfg, local=True)
        params = init_attn(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (1, 256, cfg.d_model))
        ref = attn_train(params, x, spec)
        fl = attn_train(params, x, spec, use_flash=True)
        np.testing.assert_allclose(np.asarray(fl), np.asarray(ref), atol=2e-4, rtol=2e-4)
